"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from
results/dryrun/*.json (idempotent: replaces the <!-- --> markers)."""
from __future__ import annotations

import json
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
RESULTS = ROOT / "results" / "dryrun"
EXP = ROOT / "EXPERIMENTS.md"

ARCH_ORDER = [
    "recurrentgemma-2b", "musicgen-large", "qwen3-32b", "qwen2.5-32b",
    "h2o-danube-1.8b", "yi-34b", "rwkv6-1.6b", "llava-next-34b",
    "dbrx-132b", "arctic-480b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _load():
    recs = {}
    for p in RESULTS.glob("*.json"):
        r = json.loads(p.read_text())
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def dryrun_table(recs) -> str:
    out = ["| arch | shape | mesh | status | GiB/dev | fits 16 GiB | "
           "compile (s) |", "|---|---|---|---|---|---|---|"]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            for m in ("single", "multi"):
                r = recs.get((a, s, m))
                if r is None:
                    continue
                if r["status"] == "skipped":
                    out.append(f"| {a} | {s} | {m} | skipped "
                               f"(sub-quadratic rule) | — | — | — |")
                    continue
                if r["status"] != "ok":
                    out.append(f"| {a} | {s} | {m} | **{r['status']}** "
                               f"| — | — | — |")
                    continue
                mem = r["memory"]
                out.append(
                    f"| {a} | {s} | {m} | ok | "
                    f"{mem['peak_gib_per_device']:.2f} | "
                    f"{'yes' if mem['fits_hbm_16gib'] else 'no'} | "
                    f"{r['timings']['compile_s']:.0f} |")
    n_ok = sum(1 for r in recs.values() if r["status"] == "ok")
    n_skip = sum(1 for r in recs.values() if r["status"] == "skipped")
    n_err = sum(1 for r in recs.values() if r["status"] == "error")
    head = (f"**{len(recs)} cells: {n_ok} compiled, {n_skip} skipped "
            f"(documented long_500k rule), {n_err} errors.** Every "
            "non-skipped (architecture × shape) lowers AND compiles on "
            "both production meshes.\n\n")
    return head + "\n".join(out)


def roofline_table(recs) -> str:
    out = ["| arch | shape | dominant | compute (ms) | memory (ms) | "
           "collective (ms) | frac | useful | MODEL_FLOPS |",
           "|---|---|---|---|---|---|---|---|---|"]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s, "single"))
            if r is None or r["status"] != "ok":
                continue
            rf = r["roofline"]
            out.append(
                f"| {a} | {s} | {rf['dominant'][:-2]} | "
                f"{rf['compute_s'] * 1e3:.1f} | {rf['memory_s'] * 1e3:.1f} | "
                f"{rf['collective_s'] * 1e3:.1f} | "
                f"{rf['roofline_fraction']:.3f} | "
                f"{rf['useful_ratio']:.2f} | {rf['model_flops']:.3g} |")
    return "\n".join(out)


def notes(recs) -> str:
    lines = ["Per-cell bottleneck notes (what would move the dominant term "
             "down):", ""]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s, "single"))
            if r is None or r["status"] != "ok":
                continue
            rf = r["roofline"]
            dom = rf["dominant"]
            if s == "train_4k":
                note = ("TP activation collectives dominate; pure-FSDP "
                        "layout removes them (§Perf cell 1)"
                        if dom == "collective_s" else
                        "attention score-chain HBM traffic; Pallas flash "
                        "kernel keeps it in VMEM (§Perf it 8)")
            elif s == "prefill_32k":
                note = ("32k score chain + cache writes; flash kernel + "
                        "larger q-chunks" if dom != "collective_s" else
                        "seq-parallel AGs + cache layout; fuse cache "
                        "write-out with attention")
            elif s == "decode_32k":
                note = ("weight+KV streaming floor (B/chip small); "
                        "grouped-GQA already applied, next: fused "
                        "decode-attention kernel + wider batch per chip")
            else:
                note = ("B=1 weight streaming floor -- inherent for "
                        "single-stream decode; batching is the lever")
            lines.append(f"* `{a} × {s}`: dominant={dom[:-2]} -> {note}.")
    return "\n".join(lines)


def _splice(text: str, tag: str, body: str) -> str:
    begin, end = f"<!-- BEGIN:{tag} -->", f"<!-- END:{tag} -->"
    i, j = text.index(begin), text.index(end)
    return text[: i + len(begin)] + "\n" + body.rstrip() + "\n" + text[j:]


def main():
    recs = _load()
    text = EXP.read_text()
    text = _splice(text, "DRYRUN", dryrun_table(recs))
    text = _splice(text, "ROOFLINE", roofline_table(recs))
    text = _splice(text, "NOTES", notes(recs))
    EXP.write_text(text)
    print("EXPERIMENTS.md updated "
          f"({len(recs)} cells rendered)")


if __name__ == "__main__":
    main()
