"""Paper Fig. 3: duality gap vs (simulated) operation time -- tree network
vs star network (CoCoA) when the center<->child links carry a large delay.

Setup mirrors §7: ridge regression on the wine-quality-like dataset, four
local workers; the tree adds two sub-centers (two workers each); delays of
t_delay = 1e5 * t_lp between the center and its direct children; sub-center
to worker links are delay-free.
"""
from __future__ import annotations

from typing import Dict

import jax
import numpy as np

from repro.api import Problem, Schedule, Session, Topology
from repro.data.synthetic import wine_like

T_LP = 1e-5          # measured-scale per-coordinate-step cost (paper §7)
R_DELAY = 1e5        # t_delay = R_DELAY * t_lp
LAM = 1e-2


def run(verbose: bool = True) -> Dict[str, Dict[str, np.ndarray]]:
    X, y = wine_like(m=1536)
    m = X.shape[0]
    problem = Problem.ridge(X, y, lam=LAM)
    t_delay = R_DELAY * T_LP
    H = 512  # local steps per round (same compute budget per leaf round)
    key = jax.random.PRNGKey(0)

    # star: 4 workers, each round pays the delayed center hop
    star_topo = Topology.star(4, m // 4, t_lp=T_LP, t_cp=3e-5,
                              t_delay=t_delay)
    res_star = Session.compile(
        problem, star_topo, Schedule(rounds=24, local_steps=H)).run(key=key)

    # tree: 2 sub-centers x 2 workers; only the sub-center<->root hop is
    # slow, and each root round amortizes it over `group_rounds` local
    # rounds of intra-group averaging.
    tree_topo = Topology.two_level(2, 2, m // 4, t_lp=T_LP, t_cp=3e-5,
                                   root_delay=t_delay, group_delay=0.0)
    res_tree = Session.compile(
        problem, tree_topo,
        Schedule(rounds=8, level_rounds=[3], local_steps=H)).run(key=key)

    out = {
        "star": {"time": res_star.times, "gap": res_star.gaps},
        "tree": {"time": res_tree.times, "gap": res_tree.gaps},
    }
    if verbose:
        print("fig3: duality gap vs simulated time "
              f"(t_delay = {R_DELAY:g} x t_lp)")
        print("  t_star            gap_star     |  t_tree            gap_tree")
        n = max(len(res_star.gaps), len(res_tree.gaps))
        for i in range(0, n, 2):
            s = ("  %-10.3g     %-12.4g" % (res_star.times[i],
                                            res_star.gaps[i])
                 if i < len(res_star.gaps) else " " * 29)
            t = ("  %-10.3g     %-12.4g" % (res_tree.times[i],
                                            res_tree.gaps[i])
                 if i < len(res_tree.gaps) else "")
            print(s + " |" + t)
        # headline: gap each reaches by the time the star finishes round 8
        t_budget = res_star.times[8] if len(res_star.times) > 8 else \
            res_star.times[-1]
        g_star = _gap_at(res_star.times, res_star.gaps, t_budget)
        g_tree = _gap_at(res_tree.times, res_tree.gaps, t_budget)
        print(f"  at t={t_budget:.1f}s: star gap={g_star:.3g}, "
              f"tree gap={g_tree:.3g} "
              f"({g_star / max(g_tree, 1e-30):.1f}x smaller with the tree)")
    return out


def _gap_at(times, gaps, t):
    i = int(np.searchsorted(times, t, side="right")) - 1
    return gaps[max(i, 0)]


def main() -> Dict:
    return run()


if __name__ == "__main__":
    main()
