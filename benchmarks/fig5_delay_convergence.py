"""Paper Fig. 5: wall-clock convergence of CoCoA (star, 3 workers) for
different local-iteration counts H under two delay regimes, on the paper's
synthetic problem (A in R^{100x600}, iid N(0,1)):

  (a) r = 10    (fast links): moderate H wins,
  (b) r = 1e5   (slow links): large H wins.

The 'time' axis is the paper's own model, eq. (9):
(t_lp*H + t_delay + t_cp) per outer round.

The H grid per regime runs through the vectorized sweep API: one
``sweep(..., schedules=[...])`` call per delay regime (each H is its own
Schedule -- a distinct plan -- while the lambda-free executor cache and
the problem are shared), returning a ``RunSet`` whose members are
bit-identical to the old one-run-per-H loop."""
from __future__ import annotations

from typing import Dict

from repro.api import Problem, Schedule, Topology, sweep
from repro.data.synthetic import gaussian_regression

T_LP = 4e-5
T_CP = 3e-5
LAM = 1e-2
HS = [10, 100, 1000, 10_000]
T_BUDGET = {10: 1.0, 1e5: 40.0}  # seconds of simulated time per regime


def run(verbose: bool = True) -> Dict:
    # paper: A (d x m) = 100 x 600 -> X (m x d) = 600 x 100
    X, y = gaussian_regression(m=600, d=100)
    m = X.shape[0]
    problem = Problem.ridge(X, y, lam=LAM)
    out: Dict = {}
    for r in (10, 1e5):
        t_delay = r * T_LP
        budget = T_BUDGET[r]
        topo = Topology.star(3, m // 3, t_lp=T_LP, t_cp=T_CP,
                             t_delay=t_delay)
        rounds_of = {}
        for H in HS:
            per_round = T_LP * H + t_delay + T_CP
            rounds_of[H] = min(max(int(budget / per_round), 1), 4000)
        rs = sweep(problem, topo,
                   schedules=[Schedule(rounds=rounds_of[H], local_steps=H)
                              for H in HS])
        out[r] = {
            H: {"time": res.times, "gap": res.gaps,
                "rounds": rounds_of[H]}
            for H, res in zip(HS, rs, strict=True)
        }
    if verbose:
        for r in (10, 1e5):
            print(f"fig5 (r={r:g}): final duality gap within "
                  f"{T_BUDGET[r]:g}s simulated time")
            finals = {}
            for H in HS:
                g = out[r][H]["gap"][-1]
                finals[H] = g
                print(f"  H={H:<6d} rounds={out[r][H]['rounds']:<5d} "
                      f"gap={g:.4g}")
            best = min(finals, key=finals.get)
            print(f"  best H = {best}")
        # paper's qualitative claim: the best H grows with the delay
        best10 = min(out[10], key=lambda H: out[10][H]["gap"][-1])
        best1e5 = min(out[1e5], key=lambda H: out[1e5][H]["gap"][-1])
        assert best1e5 >= best10, (best10, best1e5)
        print(f"  (best H grows with delay: {best10} -> {best1e5})")
    return out


def main() -> Dict:
    return run()


if __name__ == "__main__":
    main()
