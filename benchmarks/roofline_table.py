"""Assemble the §Roofline table from the dry-run result JSONs
(results/dryrun/*.json). Read-only: run `python -m repro.launch.dryrun`
first (this is enforced with a helpful message)."""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"

COLS = ("arch", "shape", "mesh", "dom", "comp_ms", "mem_ms", "coll_ms",
        "frac", "useful", "GiB/dev")


def load(mesh: str = "single") -> List[Dict]:
    rows = []
    for p in sorted(RESULTS.glob(f"*__{mesh}.json")):
        rec = json.loads(p.read_text())
        if rec["status"] != "ok":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": rec["mesh"], "status": rec["status"],
                         "reason": rec.get("reason", rec.get("error", ""))})
            continue
        r = rec["roofline"]
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
            "status": "ok",
            "dom": r["dominant"].replace("_s", ""),
            "comp_ms": r["compute_s"] * 1e3,
            "mem_ms": r["memory_s"] * 1e3,
            "coll_ms": r["collective_s"] * 1e3,
            "frac": r["roofline_fraction"],
            "useful": r["useful_ratio"],
            "GiB/dev": rec["memory"]["peak_gib_per_device"],
        })
    return rows


def render(rows: List[Dict]) -> str:
    out = [f"{'arch':<19}{'shape':<13}{'dom':<8}{'comp_ms':>9}{'mem_ms':>9}"
           f"{'coll_ms':>9}{'frac':>7}{'useful':>8}{'GiB/dev':>9}"]
    for r in rows:
        if r["status"] != "ok":
            out.append(f"{r['arch']:<19}{r['shape']:<13}"
                       f"-- {r['status']}: {r.get('reason','')[:60]}")
            continue
        out.append(
            f"{r['arch']:<19}{r['shape']:<13}{r['dom']:<8}"
            f"{r['comp_ms']:>9.2f}{r['mem_ms']:>9.2f}{r['coll_ms']:>9.2f}"
            f"{r['frac']:>7.3f}{r['useful']:>8.2f}{r['GiB/dev']:>9.2f}")
    return "\n".join(out)


def run(verbose: bool = True) -> List[Dict]:
    if not RESULTS.exists() or not list(RESULTS.glob("*.json")):
        print("roofline_table: no dry-run results found; run\n"
              "  PYTHONPATH=src python -m repro.launch.dryrun\nfirst.")
        return []
    rows = load("single")
    if verbose:
        print("roofline (single-pod 16x16, per §Roofline):")
        print(render(rows))
    return rows


def main() -> List[Dict]:
    return run()


if __name__ == "__main__":
    main()
