"""Benchmark harness: one module per paper artifact + the roofline table.

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run fig4 fig5  # subset
"""
from __future__ import annotations

import sys
import time


BENCHES = ("fig3", "fig4", "fig5", "roofline")


def main() -> None:
    want = sys.argv[1:] or list(BENCHES)
    for name in want:
        print(f"\n{'=' * 72}\n== benchmarks.{name}\n{'=' * 72}", flush=True)
        t0 = time.time()
        if name == "fig3":
            from benchmarks import fig3_tree_vs_star as m
        elif name == "fig4":
            from benchmarks import fig4_optimal_h as m
        elif name == "fig5":
            from benchmarks import fig5_delay_convergence as m
        elif name == "roofline":
            from benchmarks import roofline_table as m
        else:
            raise SystemExit(f"unknown benchmark {name!r}; "
                             f"choose from {BENCHES}")
        m.main()
        print(f"[{name}: {time.time() - t0:.1f}s]", flush=True)


if __name__ == "__main__":
    main()
