"""Micro-benchmark: the compiled tree-schedule engine vs the legacy Python
recursion on a depth-3, 8-leaf tree (the acceptance target is a >= 5x
host-path speedup; in practice the gap is much larger because the legacy
path pays one jit dispatch + full-vector alpha copies per leaf solve per
round, while the engine runs ONE compiled chunk program per root round).

Also splits cold compile time (plan lowering + trace + XLA compile on the
first run) from steady-state run time, plus a STRAGGLER scenario: on a
star network with a heavy per-round delay tail, the synchronous schedule
(barrier waits for the slowest leaf) vs the bounded-skip async schedule
(stragglers are dropped and re-join with stale deltas) compared on
simulated time-to-1e-3-duality-gap, a SWEEP scenario: a B=8 lambda
grid as one batched ``Session.sweep`` (one vmapped dispatch per chunk for
the whole grid; lambda is a runtime executor input) vs 8 sequential
``Session.run`` calls (acceptance target: >= 3x, members bit-identical),
plus the same grid on the batched MESH path (vmap inside shard_map) and
through the batched state-carry executor of a COMPRESSED plan (>= 2x vs
sequential members each, bit-identical), an ACCELERATION scenario: the
``Schedule(acceleration=)`` server-momentum flavor vs plain SDCA compared
on rounds-to-1e-3-duality-gap (acceptance target: >= 1.5x fewer rounds),
an ADAPTIVE-H scenario: the schedule as a runtime step-mask input
(one ``Schedule(h_cap=...)`` session executing many H values against ONE
cached executor, the delay-adaptive replanning path) vs a per-H recompile
(acceptance target: >= 2x), and a COMPRESSION scenario: int8 delta
compression on a bandwidth-bound star (>= 2x fewer simulated bytes/round
at equal final duality gap) plus the replicated-vs-sharded
(``mesh_sync="reduce_scatter"``) big-d server-memory comparison (>= 2x),
and an ELASTIC scenario: chunk-carry checkpointing overhead at snapshot
periods 1 and 5 (acceptance target: <= 10% wall overhead at every=5) plus
crash-at-50% recovery, resume-from-snapshot vs scratch restart compared
on simulated time-to-1e-3-gap from solve start, and a TREESYNC scenario:
the LM workload on the shared schedule engine -- the Session-driven
train program vs the legacy ``make_treesync_step`` loop (bit-identical;
>= 1x wall-clock parity gate) and eq.-(12) adaptive periods vs a fixed
every-step barrier on simulated time-to-loss.
Everything is recorded in ``BENCH_engine.json`` so the perf trajectory is
tracked across commits.

    PYTHONPATH=src python benchmarks/bench_engine.py
"""
from __future__ import annotations

import json
import time
from typing import Dict

import jax
import numpy as np

from repro.api import Problem, Schedule, Session, Topology
from repro.core.delay import StragglerModel
from repro.core.engine import host as host_mod
from repro.core.treedual import tree_dual_solve_reference
from repro.data.synthetic import gaussian_regression
from repro.runtime.straggler import StragglerPolicy

LAM = 0.1
BENCH_JSON = "BENCH_engine.json"
GAP_TARGET = 1e-3


def _time(fn, repeats: int = 3) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready((out.alpha, out.w))
        best = min(best, time.perf_counter() - t0)
    return best


def time_to_gap(history, target: float) -> float:
    for h in history:
        if h["gap"] <= target:
            return float(h["time"])
    return float("inf")


def straggler_scenario(verbose: bool = True) -> Dict[str, float]:
    """Sync vs bounded-skip async on a star with a heavy straggler tail.

    Both schedules see the SAME sampled per-round delay sequence (same
    model + seed); the synchronous one always waits for the slowest leaf,
    the async one drops detected stragglers (<= 3 consecutive skips) and
    folds their stale deltas back in later.  Reported: simulated seconds
    to reach a 1e-3 duality gap."""
    t_lp = 1e-5
    n_leaves = 8
    topo = Topology.star(n_leaves, 32, rounds=80, local_steps=64,
                         t_lp=t_lp, t_delay=0.02)
    X, y = gaussian_regression(m=topo.m_total, d=16)
    prob = Problem.ridge(X, y, lam=LAM)
    sess = Session.compile(prob, topo)
    key = jax.random.PRNGKey(0)
    model = StragglerModel(slow_prob=0.15, slow_factor=50.0, jitter=0.02)

    res_sync = sess.run(key=key, straggler=StragglerPolicy(
        model=model, max_consecutive=0, seed=0))      # never skips
    res_async = sess.run(key=key, straggler=StragglerPolicy(
        model=model, max_consecutive=3, seed=0))

    t_sync = time_to_gap(res_sync.history, GAP_TARGET)
    t_async = time_to_gap(res_async.history, GAP_TARGET)
    # both runs are seeded and deterministic; failing to reach the target
    # would write non-JSON Infinity values, so fail loudly instead
    assert np.isfinite(t_sync) and np.isfinite(t_async), (
        f"gap target {GAP_TARGET:g} not reached "
        f"(sync {res_sync.gaps[-1]:.2e}, async {res_async.gaps[-1]:.2e})")
    parts = np.array([h["participants"] for h in res_async.history
                      if "participants" in h])
    out = {
        "t_sync_to_gap_s": t_sync,
        "t_async_to_gap_s": t_async,
        "time_saved_ratio": t_sync / t_async,
        "gap_target": GAP_TARGET,
        "rounds_skipped_leaf_frac": float(1.0 - parts.mean() / n_leaves),
    }
    if verbose:
        print(f"bench_engine straggler scenario: {n_leaves}-leaf star, "
              "15% rounds 50x-slowed per leaf")
        print(f"  sync  time-to-{GAP_TARGET:g}-gap : {t_sync:9.3f} s")
        print(f"  async time-to-{GAP_TARGET:g}-gap : {t_async:9.3f} s  "
              f"(bounded-skip, {out['time_saved_ratio']:.1f}x faster)")
    assert t_async < t_sync, (t_async, t_sync)
    return out


def sweep_scenario(verbose: bool = True) -> Dict[str, float]:
    """B=8 lambda grid: one batched ``Session.sweep`` vs 8 sequential
    ``Session.run`` calls on the vmap backend.

    Both paths share the SAME lambda-free compiled chunk program (lambda
    is a runtime input); the sweep additionally fuses the whole grid into
    one vmapped dispatch per root round, so each grid point costs far
    less than a standalone run.  The scenario is a many-cheap-rounds
    CoCoA star (the fig.-3 regime), where per-round dispatch overhead --
    exactly what batching amortizes -- dominates a standalone run."""
    B = 8
    lams = np.logspace(-3.0, 0.0, B)
    topo = Topology.star(8, 16, rounds=160, local_steps=8)
    X, y = gaussian_regression(m=topo.m_total, d=8)
    sess = Session.compile(Problem.ridge(X, y, lam=LAM), topo)
    key = jax.random.PRNGKey(0)

    def sequential():
        return [sess.run(key=key, lam=float(l), record_history=False)
                for l in lams]

    def batched():
        return sess.sweep(lams=lams, record_history=False)

    # warm both paths (one compile each: the plain and batched executor
    # flavors), and check the fusion is lossless while we're at it
    rs, seq = batched(), sequential()
    np.testing.assert_array_equal(np.asarray(rs.alphas[3]),
                                  np.asarray(seq[3].alpha))

    # best-of-5: host dispatch timing has a heavy load-noise tail
    t_seq = t_batched = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        outs = sequential()
        jax.block_until_ready([o.alpha for o in outs])
        t_seq = min(t_seq, time.perf_counter() - t0)
        t0 = time.perf_counter()
        rs = batched()
        jax.block_until_ready(rs.alphas)
        t_batched = min(t_batched, time.perf_counter() - t0)

    speedup = t_seq / t_batched
    out = {
        "B": B,
        "t_sequential_s": t_seq,
        "t_batched_s": t_batched,
        "speedup": speedup,
        "per_point_ms": t_batched / B * 1e3,
    }
    if verbose:
        print(f"bench_engine sweep scenario: B={B} lambda grid, "
              "8-leaf star x 160 rounds, vmap backend")
        print(f"  8x sequential run : {t_seq * 1e3:9.2f} ms")
        print(f"  batched sweep     : {t_batched * 1e3:9.2f} ms  "
              f"({speedup:.1f}x faster, "
              f"{out['per_point_ms']:.2f} ms/grid point)")

    # the same grid on the two formerly-sequential sweep paths: the mesh
    # backend (the batch rides a vmap INSIDE shard_map) and a compressed
    # plan (the per-member EF residuals ride the batched state carry)
    n = len(jax.devices())
    topo_m = Topology.star(n, 128 // n, rounds=160, local_steps=8)
    Xm, ym = gaussian_regression(m=128, d=8)
    sess_m = Session.compile(Problem.ridge(Xm, ym, lam=LAM), topo_m,
                             backend="mesh")
    sess_c = Session.compile(Problem.ridge(X, y, lam=LAM), topo,
                             Schedule(compression="int8"))
    for tag, s in (("mesh_batched", sess_m), ("compressed_batched", sess_c)):
        def sequential_s():
            return [s.run(key=key, lam=float(l), record_history=False)
                    for l in lams]

        def batched_s():
            return s.sweep(lams=lams, record_history=False)

        rs_s, seq_s = batched_s(), sequential_s()       # warm + lossless
        np.testing.assert_array_equal(np.asarray(rs_s.alphas[3]),
                                      np.asarray(seq_s[3].alpha))
        t_sq = t_bt = float("inf")
        for _ in range(5):
            t0 = time.perf_counter()
            outs = sequential_s()
            jax.block_until_ready([o.alpha for o in outs])
            t_sq = min(t_sq, time.perf_counter() - t0)
            t0 = time.perf_counter()
            rs_s = batched_s()
            jax.block_until_ready(rs_s.alphas)
            t_bt = min(t_bt, time.perf_counter() - t0)
        out[tag] = {
            "t_sequential_s": t_sq,
            "t_batched_s": t_bt,
            "speedup": t_sq / t_bt,
        }
        if verbose:
            print(f"  {tag:18s}: sequential {t_sq * 1e3:9.2f} ms vs "
                  f"batched {t_bt * 1e3:9.2f} ms  "
                  f"({out[tag]['speedup']:.1f}x faster)")
    # the >= 3x / >= 2x gates are asserted in run() AFTER the json is
    # written, so a regression is recorded in the artifact instead of
    # discarding the run
    return out


def acceleration_scenario(verbose: bool = True) -> Dict[str, float]:
    """Server momentum (``Schedule(acceleration=)``, method "sdca_acc")
    vs plain SDCA on the paper's star topology, compared on ROUNDS to a
    1e-3 duality gap -- the unit the eq.-(12) planner trades in.  The
    coefficient is a runtime scalar operand of the same compiled program
    (acceleration=0 is bit-identical to plain), so the convergence win is
    free of any compile or dispatch cost.  Recorded gate: >= 1.5x fewer
    rounds at acceleration=0.6."""
    acc = 0.6
    topo = Topology.star(8, 32, rounds=60, local_steps=8)
    X, y = gaussian_regression(m=topo.m_total, d=24)
    prob = Problem(X, y, loss="squared", lam=LAM)
    key = jax.random.PRNGKey(0)

    def rounds_to_gap(history):
        for h in history:
            if h["gap"] <= GAP_TARGET:
                return int(h["round"])
        return None

    r_plain = Session.compile(prob, topo).run(key=key)
    r_acc = Session.compile(prob, topo, Schedule(acceleration=acc)).run(
        key=key)
    n_plain = rounds_to_gap(r_plain.history)
    n_acc = rounds_to_gap(r_acc.history)
    assert n_plain is not None and n_acc is not None, (
        f"gap target {GAP_TARGET:g} not reached (plain "
        f"{r_plain.history[-1]['gap']:.2e}, accelerated "
        f"{r_acc.history[-1]['gap']:.2e})")
    out = {
        "acceleration": acc,
        "rounds_plain_to_gap": n_plain,
        "rounds_accelerated_to_gap": n_acc,
        "rounds_saved_ratio": n_plain / n_acc,
        "gap_target": GAP_TARGET,
        "final_gap_plain": float(r_plain.history[-1]["gap"]),
        "final_gap_accelerated": float(r_acc.history[-1]["gap"]),
    }
    if verbose:
        print(f"bench_engine acceleration scenario: 8-leaf star, H=8, "
              f"server momentum {acc}")
        print(f"  plain sdca rounds-to-{GAP_TARGET:g}-gap    : {n_plain:4d}")
        print(f"  sdca_acc({acc}) rounds-to-{GAP_TARGET:g}-gap: {n_acc:4d}  "
              f"({out['rounds_saved_ratio']:.2f}x fewer rounds)")
    return out


def adaptive_h_scenario(verbose: bool = True) -> Dict[str, float]:
    """Retrace-free H replanning vs per-H recompiles.

    The schedule is a runtime step-mask input of the executors, so ONE
    session compiled at an H capacity (``Schedule(h_cap=...)``) executes
    every H value below it by swapping an input array -- exactly what a
    delay-adaptive session does between chunks.  The baseline is what the
    pre-refactor API had to do: a fresh plan (new leaf rounds => new
    fingerprint) and a fresh trace + XLA compile per H value."""
    hs = [8, 16, 32, 64]
    topo = Topology.star(8, 32, rounds=20, local_steps=64)
    X, y = gaussian_regression(m=topo.m_total, d=16)
    prob = Problem.ridge(X, y, lam=LAM)
    key = jax.random.PRNGKey(0)

    # runtime path: one cached executor, H swapped per run via step masks
    sess = Session.compile(prob, topo, Schedule(h_cap=max(hs)))
    sess.run(key=key, local_h=hs[0], record_history=False)  # warm compile
    stats0 = Session.cache_stats()
    t0 = time.perf_counter()
    outs = [sess.run(key=key, local_h=h, record_history=False) for h in hs]
    jax.block_until_ready([o.alpha for o in outs])
    t_runtime = time.perf_counter() - t0
    assert Session.cache_stats()["misses"] == stats0["misses"], \
        "the runtime-H path rebuilt an executor"

    # recompile path: a new program per H value (cold caches, as a fresh
    # process sweeping H would pay)
    host_mod._EXEC_CACHE.clear()
    t0 = time.perf_counter()
    outs2 = [
        Session.compile(prob, topo, Schedule(local_steps=h)).run(
            key=key, record_history=False)
        for h in hs
    ]
    jax.block_until_ready([o.alpha for o in outs2])
    t_recompile = time.perf_counter() - t0

    speedup = t_recompile / t_runtime
    out = {
        "hs": hs,
        "t_runtime_masks_s": t_runtime,
        "t_recompile_per_h_s": t_recompile,
        "speedup": speedup,
        "per_h_runtime_ms": t_runtime / len(hs) * 1e3,
    }
    if verbose:
        print(f"bench_engine adaptive-H scenario: {len(hs)} H values "
              f"{hs}, 8-leaf star x 20 rounds")
        print(f"  per-H recompiles  : {t_recompile * 1e3:9.2f} ms")
        print(f"  runtime step masks: {t_runtime * 1e3:9.2f} ms  "
              f"({speedup:.1f}x faster, "
              f"{out['per_h_runtime_ms']:.2f} ms/H value)")
    return out


def compression_scenario(verbose: bool = True) -> Dict[str, float]:
    """Compressed vs exact per-edge sync on a bandwidth-bound star, plus
    the big-d sharded-server (``mesh_sync="reduce_scatter"``) comparison.

    The star's uplink delay dominates its round time, so int8 delta
    compression (0.28x wire bytes, error feedback re-sending the
    truncation) should reach the same duality gap in ~3.5x fewer simulated
    wire-seconds; the recorded gate is >= 2x fewer bytes/round at equal
    final gap.  The big-d comparison is the per-device server-state
    footprint of the replicated ("psum") vs sharded ("reduce_scatter")
    mesh sync lowerings (``engine.mesh.mesh_state_floats``), timed for
    real when the process has enough devices for the mesh."""
    topo = Topology.star(8, 32, rounds=60, local_steps=32,
                         t_lp=1e-6, t_delay=0.01)
    X, y = gaussian_regression(m=topo.m_total, d=64)
    prob = Problem.ridge(X, y, lam=LAM)
    key = jax.random.PRNGKey(0)

    s_plain = Session.compile(prob, topo)
    s_comp = Session.compile(prob, topo, Schedule(compression="int8"))
    r_plain = s_plain.run(key=key)
    r_comp = s_comp.run(key=key)
    t_plain = time_to_gap(r_plain.history, GAP_TARGET)
    t_comp = time_to_gap(r_comp.history, GAP_TARGET)
    assert np.isfinite(t_plain) and np.isfinite(t_comp), (
        f"gap target {GAP_TARGET:g} not reached (exact "
        f"{r_plain.history[-1]['gap']:.2e}, int8 "
        f"{r_comp.history[-1]['gap']:.2e})")
    bytes_ratio = s_plain.bytes_per_round / s_comp.bytes_per_round

    # big-d: per-device server floats, replicated vs sharded sync
    from repro.core.engine import mesh as mesh_mod
    from repro.core.engine import plan as plan_mod
    big_d = 1_000_000
    topo2 = Topology.balanced([2, 4], m_leaf=8, local_steps=4)
    plan2 = plan_mod.compile_tree(Schedule().resolve(topo2).chunk_tree)
    f_psum = mesh_mod.mesh_state_floats(plan2, big_d, sync="psum")
    f_rs = mesh_mod.mesh_state_floats(plan2, big_d, sync="reduce_scatter")
    out = {
        "t_exact_to_gap_s": t_plain,
        "t_int8_to_gap_s": t_comp,
        "time_saved_ratio": t_plain / t_comp,
        "bytes_per_round_exact": s_plain.bytes_per_round,
        "bytes_per_round_int8": s_comp.bytes_per_round,
        "bytes_ratio": bytes_ratio,
        "gap_target": GAP_TARGET,
        "bigd_d": big_d,
        "bigd_server_floats_replicated": f_psum,
        "bigd_server_floats_sharded": f_rs,
        "bigd_memory_ratio": f_psum / f_rs,
    }

    # wall-clock of the two mesh lowerings, when the mesh fits
    if len(jax.devices()) >= topo2.n_leaves:
        Xm, ym = gaussian_regression(m=topo2.m_total, d=4096)
        pm = Problem.ridge(Xm, ym, lam=LAM)
        sm_ps = Session.compile(pm, topo2, Schedule(rounds=8),
                                backend="mesh")
        sm_rs = Session.compile(pm, topo2, Schedule(rounds=8),
                                backend="mesh", mesh_sync="reduce_scatter")
        run_ps = lambda: sm_ps.run(key=key, record_history=False)  # noqa: E731
        run_rs = lambda: sm_rs.run(key=key, record_history=False)  # noqa: E731
        o_ps, o_rs = run_ps(), run_rs()       # warm compiles
        np.testing.assert_allclose(np.asarray(o_ps.w), np.asarray(o_rs.w),
                                   atol=1e-5, rtol=1e-5)
        out["bigd_t_psum_s"] = _time(run_ps)
        out["bigd_t_reduce_scatter_s"] = _time(run_rs)

    if verbose:
        print("bench_engine compression scenario: 8-leaf star, "
              "10ms bandwidth-bound uplinks, int8 delta compression")
        print(f"  exact time-to-{GAP_TARGET:g}-gap : {t_plain:9.3f} s  "
              f"({s_plain.bytes_per_round:.0f} B/round)")
        print(f"  int8  time-to-{GAP_TARGET:g}-gap : {t_comp:9.3f} s  "
              f"({s_comp.bytes_per_round:.0f} B/round, "
              f"{bytes_ratio:.2f}x fewer bytes)")
        print(f"  big-d server floats (d={big_d:.0e}): replicated "
              f"{f_psum:.3g} vs sharded {f_rs:.3g} per device "
              f"({out['bigd_memory_ratio']:.1f}x)")
    return out


def elastic_scenario(verbose: bool = True) -> Dict[str, float]:
    """Checkpointed-carry overhead and crash recovery on a long star run.

    Overhead: the same 200-round solve with no checkpointing vs a
    chunk-carry snapshot every round and every 5 rounds, on a
    compute-representative star (H=256 local steps over 512-row blocks:
    the regime where the paper's round time is dominated by local work).
    The recorded gate is <= 10% wall overhead at every=5 -- the payload
    is just (alpha, w, key) and the carry snapshot is written one period
    deferred, so the per-save cost is a couple of async dispatches plus
    one small npz write.  The three variants are timed INTERLEAVED
    (best-of round-robin) so slow drift in box load hits all of them
    equally.  Recovery: the coordinator dies at 50% of a long small-star
    run; resuming from the newest snapshot vs restarting from scratch,
    compared on SIMULATED time from solve start to a 1e-3 duality gap
    (the scratch restart pays the pre-crash time again AND re-solves)."""
    import tempfile
    from repro.api import CheckpointPolicy

    rounds = 200
    topo = Topology.star(8, 512, rounds=rounds, local_steps=256,
                         t_lp=1e-5, t_delay=0.005)
    X, y = gaussian_regression(m=topo.m_total, d=128)
    prob = Problem.ridge(X, y, lam=LAM)
    sess = Session.compile(prob, topo)
    key = jax.random.PRNGKey(0)

    with tempfile.TemporaryDirectory() as td1, \
            tempfile.TemporaryDirectory() as td5:
        variants = {
            "plain": lambda: sess.run(key=key, record_history=False),
            "ck1": lambda: sess.run(key=key, record_history=False,
                                    checkpoint=CheckpointPolicy(
                                        directory=td1, every=1)),
            "ck5": lambda: sess.run(key=key, record_history=False,
                                    checkpoint=CheckpointPolicy(
                                        directory=td5, every=5)),
        }
        best = {k: float("inf") for k in variants}
        for fn in variants.values():
            fn()                                 # warm compiles
        for _ in range(5):
            for k, fn in variants.items():       # interleaved best-of
                t0 = time.perf_counter()
                out_r = fn()
                jax.block_until_ready((out_r.alpha, out_r.w))
                best[k] = min(best[k], time.perf_counter() - t0)
    t_plain, t_ck1, t_ck5 = best["plain"], best["ck1"], best["ck5"]

    # crash at 50% of a long convergence run: resume from the newest
    # snapshot vs scratch restart
    topo_s = Topology.star(8, 32, rounds=rounds, local_steps=16,
                           t_lp=1e-5, t_delay=0.005)
    Xs, ys = gaussian_regression(m=topo_s.m_total, d=16)
    sess_s = Session.compile(Problem.ridge(Xs, ys, lam=LAM), topo_s)
    crash_at = rounds // 2
    with tempfile.TemporaryDirectory() as td:
        pol = CheckpointPolicy(directory=td, every=5)
        leg = sess_s.run(crash_at, key=key, checkpoint=pol)
        t_crash = leg.history[-1]["time"]        # simulated clock at kill
        resumed = sess_s.resume(td, rounds=rounds - crash_at)
    t_resume_gap = time_to_gap(leg.history + resumed.history, GAP_TARGET)
    scratch = sess_s.run(key=key)
    t_scratch_gap = t_crash + time_to_gap(scratch.history, GAP_TARGET)
    assert np.isfinite(t_resume_gap) and np.isfinite(t_scratch_gap), (
        f"gap target {GAP_TARGET:g} not reached "
        f"(final gap {scratch.history[-1]['gap']:.2e})")

    out = {
        "rounds": rounds,
        "t_plain_s": t_plain,
        "t_ckpt_every1_s": t_ck1,
        "t_ckpt_every5_s": t_ck5,
        "overhead_every1": t_ck1 / t_plain - 1.0,
        "overhead_every5": t_ck5 / t_plain - 1.0,
        "crash_at_round": crash_at,
        "t_resume_to_gap_s": t_resume_gap,
        "t_scratch_to_gap_s": t_scratch_gap,
        "recovery_saved_ratio": t_scratch_gap / t_resume_gap,
        "gap_target": GAP_TARGET,
    }
    if verbose:
        print(f"bench_engine elastic scenario: 8-leaf star x {rounds} "
              "rounds, chunk-carry checkpoints")
        print(f"  no checkpoints   : {t_plain * 1e3:9.2f} ms")
        print(f"  every=1 snapshot : {t_ck1 * 1e3:9.2f} ms  "
              f"(+{out['overhead_every1'] * 100:.1f}%)")
        print(f"  every=5 snapshot : {t_ck5 * 1e3:9.2f} ms  "
              f"(+{out['overhead_every5'] * 100:.1f}%)")
        print(f"  crash at round {crash_at}: resume "
              f"{t_resume_gap:.3f} s vs scratch {t_scratch_gap:.3f} s "
              f"to {GAP_TARGET:g} gap "
              f"({out['recovery_saved_ratio']:.2f}x saved)")
    return out


def treesync_scenario(verbose: bool = True) -> Dict[str, float]:
    """The LM workload on the shared schedule engine, two comparisons.

    PARITY: the Session-driven LM train program (``Problem.lm`` +
    ``Session.compile(backend="mesh")``) vs the legacy
    ``make_treesync_step`` loop, steady-state wall-clock at the same
    fixed periods/seed.  The two paths jit the SAME math (the refactor
    only moved the periods from trace constants to a runtime operand),
    so the gate is parity: >= 1x within a 10% host-dispatch noise floor.

    ADAPTIVE: eq.-(12) replanned periods vs a fixed every-step barrier
    under the same simulated delay model, compared on simulated
    time-to-loss.  The fixed schedule pays the sync delay every
    optimizer step; the adaptive one feeds the replanned H into the
    runtime periods operand (zero retraces) and amortizes the barrier."""
    import dataclasses
    import warnings

    from repro.configs.base import ModelConfig
    from repro.core import treesync as tsy
    from repro.data.lm import lm_batch
    from repro.launch.mesh import make_host_mesh
    from repro.optim import make_sgd
    from repro.runtime.straggler import AdaptiveSchedule, StragglerPolicy

    cfg = dataclasses.replace(
        ModelConfig(name="tiny", family="dense", num_layers=2, d_model=32,
                    num_heads=4, num_kv_heads=2, head_dim=8, d_ff=64,
                    vocab_size=64, q_chunk_size=16, logits_chunk=16,
                    remat=False),
        activation_dtype="float32")
    mesh = make_host_mesh()
    opt = make_sgd(lr=0.05, momentum=0.0)
    prob = Problem.lm(cfg, opt, batch=8, seq=32, seed=0)
    steps = 24
    key = jax.random.PRNGKey(0)

    topo = Topology.from_mesh(mesh, sync_axes=("data",), periods=(4,))
    sess = Session.compile(prob, topo, backend="mesh", mesh=mesh)

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        ts = tsy.TreeSyncConfig(sync_axes=("data",), periods=(4,))
        n = tsy.replica_count(ts, mesh)
        step = jax.jit(tsy.make_treesync_step(cfg, opt, ts, mesh))

    def legacy():
        # a full run, like the session's: init the replica-stacked state
        # and generate each step's batch in-loop (both paths pay the
        # same host-side init + data stream)
        st = tsy.init_state(cfg, opt, key, mesh, ts)
        for i in range(steps):
            st, _ = step(st, tsy.split_batch(lm_batch(cfg, 8, 32, i,
                                                      seed=0), n))
        return st

    def session():
        return sess.run(steps=steps, key=key, record_history=False)

    # warm both jits, and confirm the refactor is lossless while at it
    st_leg, out_sess = legacy(), session()
    for a, b in zip(jax.tree.leaves(st_leg.params),
                    jax.tree.leaves(out_sess.state.params), strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    t_legacy = t_session = float("inf")
    for _ in range(5):                           # interleaved best-of
        t0 = time.perf_counter()
        jax.block_until_ready(legacy().params)
        t_legacy = min(t_legacy, time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(session().state.params)
        t_session = min(t_session, time.perf_counter() - t0)
    parity = t_legacy / t_session

    # adaptive periods vs a fixed every-step barrier, simulated clocks:
    # the fixed schedule pays the 20ms link every optimizer step, the
    # adaptive one replans H from the measured delays and amortizes it
    loss_target = 4.2           # crossed ~step 30 of the seeded stream
    lm_steps = 160
    topo_d = Topology.from_mesh(mesh, sync_axes=("data",), periods=(1,),
                                level_delays=[0.02], t_lp=1e-4)
    sess_d = Session.compile(prob, topo_d, backend="mesh", mesh=mesh)
    model = StragglerModel(slow_prob=0.15, slow_factor=20.0, jitter=0.002)
    r_fixed = sess_d.run(steps=lm_steps, key=key, straggler=StragglerPolicy(
        model=model, max_consecutive=0, seed=0))
    r_adapt = sess_d.run(steps=lm_steps, key=key, straggler=StragglerPolicy(
        model=model, max_consecutive=0, seed=0,
        adaptive=AdaptiveSchedule(C=1.0, delta=0.05, t_total=4.0,
                                  h_max=16)))
    hit_f = [h["time"] for h in r_fixed.history if h["loss"] <= loss_target]
    hit_a = [h["time"] for h in r_adapt.history if h["loss"] <= loss_target]
    assert hit_f and hit_a, (
        f"loss target {loss_target} not reached "
        f"(fixed {r_fixed.final_loss:.3f}, adaptive {r_adapt.final_loss:.3f})")
    t_fixed, t_adapt = hit_f[0], hit_a[0]

    out = {
        "steps": steps,
        "t_legacy_s": t_legacy,
        "t_session_s": t_session,
        "parity": parity,
        "loss_target": loss_target,
        "t_fixed_to_loss_s": t_fixed,
        "t_adaptive_to_loss_s": t_adapt,
        "time_saved_ratio": t_fixed / t_adapt,
        "adaptive_final_h": r_adapt.history[-1].get("h", 1),
    }
    if verbose:
        print(f"bench_engine treesync scenario: tiny LM x {steps} steps, "
              f"{sess.n_replicas} replica(s), periods=(4,)")
        print(f"  legacy step loop : {t_legacy * 1e3:9.2f} ms")
        print(f"  Session program  : {t_session * 1e3:9.2f} ms  "
              f"({parity:.2f}x, bit-identical)")
        print(f"  fixed periods=(1,) time-to-{loss_target:.3f}-loss : "
              f"{t_fixed:9.3f} s (simulated)")
        print(f"  eq.-(12) adaptive time-to-{loss_target:.3f}-loss : "
              f"{t_adapt:9.3f} s  ({out['time_saved_ratio']:.1f}x faster, "
              f"final H={out['adaptive_final_h']})")
    return out


def analysis_scenario(t_compile_s: float,
                      verbose: bool = True) -> Dict[str, float]:
    """Verifier overhead: ``verify_plan`` is wired into EVERY
    ``Session.compile`` (strict or not), so its wall-time must stay a
    rounding error next to the compile it rides on (plan lowering +
    executor trace + XLA, the headline scenario's ``t_compile_s``).
    Timed on the same depth-3 tree, full verify = structural checks +
    fingerprint audit + schedule view.  The recorded gate is <= 5% of
    compile time."""
    from repro.analysis import verify_plan
    from repro.core.engine import plan as plan_mod

    topo = Topology.balanced([2, 2, 2], m_leaf=32, local_steps=128,
                             level_rounds=[10, 2, 2])

    def lower_cold():
        plan_mod._compile_tree_cached.cache_clear()
        return plan_mod.compile_tree(topo.tree)

    plan = lower_cold()                          # warm imports / allocator
    t_lower = min(_time_host(lower_cold) for _ in range(3))
    t_verify = min(_time_host(lambda: verify_plan(plan)) for _ in range(3))
    out = {
        "t_lower_ms": t_lower * 1e3,
        "t_verify_ms": t_verify * 1e3,
        "t_compile_ms": t_compile_s * 1e3,
        "overhead_frac": t_verify / t_compile_s,
    }
    if verbose:
        print("bench_engine analysis scenario: depth-3, 8-leaf tree")
        print(f"  plan lowering    : {t_lower * 1e3:9.2f} ms  (cold)")
        print(f"  verify_plan      : {t_verify * 1e3:9.2f} ms  "
              f"({out['overhead_frac'] * 100:.2f}% of the "
              f"{t_compile_s * 1e3:.0f} ms Session.compile)")
    return out


def _time_host(fn, repeats: int = 3) -> float:
    """Best-of wall time for host-side (no device output) callables."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(verbose: bool = True) -> Dict[str, float]:
    # depth-3, 8-leaf balanced tree: 10 root x 2 x 2 rounds, H=128
    topo = Topology.balanced([2, 2, 2], m_leaf=32, local_steps=128,
                             level_rounds=[10, 2, 2])
    m = topo.m_total
    X, y = gaussian_regression(m=m, d=32)
    problem = Problem.ridge(X, y, lam=LAM)
    key = jax.random.PRNGKey(0)

    legacy = lambda: tree_dual_solve_reference(   # noqa: E731
        topo.tree, X, y, loss=problem.loss, lam=LAM, key=key,
        record_history=False)

    # cold path: executor cache emptied -> compile + trace + first run
    host_mod._EXEC_CACHE.clear()
    t0 = time.perf_counter()
    sess = Session.compile(problem, topo)
    t_compile_py = time.perf_counter() - t0          # plan lowering + bind
    t0 = time.perf_counter()
    out = sess.run(key=key, record_history=False)
    jax.block_until_ready((out.alpha, out.w))
    t_first_run = time.perf_counter() - t0           # includes XLA compile

    engine = lambda: sess.run(key=key, record_history=False)  # noqa: E731

    # warm both paths (compile + trace caches), then time steady-state
    legacy()
    t_legacy = _time(legacy)
    t_engine = _time(engine)
    t_compile = t_compile_py + (t_first_run - t_engine)
    speedup = t_legacy / t_engine

    results = {
        "t_legacy_s": t_legacy,
        "t_engine_s": t_engine,
        "t_compile_s": t_compile,
        "t_first_run_s": t_first_run,
        "speedup": speedup,
    }
    results["straggler"] = straggler_scenario(verbose=verbose)
    results["sweep"] = sweep_scenario(verbose=verbose)
    results["acceleration"] = acceleration_scenario(verbose=verbose)
    results["adaptive_h"] = adaptive_h_scenario(verbose=verbose)
    results["compression"] = compression_scenario(verbose=verbose)
    results["elastic"] = elastic_scenario(verbose=verbose)
    results["treesync"] = treesync_scenario(verbose=verbose)
    results["analysis"] = analysis_scenario(t_compile, verbose=verbose)
    if verbose:
        print("bench_engine: depth-3, 8-leaf tree "
              f"(m={m}, 40 ticks x H=128), host path")
        print(f"  legacy recursion : {t_legacy * 1e3:9.2f} ms")
        print(f"  compiled engine  : {t_engine * 1e3:9.2f} ms  (steady-state)")
        print(f"  compile overhead : {t_compile * 1e3:9.2f} ms  "
              "(plan + trace + XLA, first solve only)")
        print(f"  speedup          : {speedup:9.1f}x")
    with open(BENCH_JSON, "w") as f:
        json.dump(results, f, indent=2)
        f.write("\n")
    if verbose:
        print(f"  wrote {BENCH_JSON}")
    # gates run AFTER the json is written so a regression is still
    # recorded in the artifact instead of discarding the run
    assert speedup >= 5.0, f"engine speedup {speedup:.1f}x < 5x target"
    assert results["sweep"]["speedup"] >= 3.0, (
        f"sweep speedup {results['sweep']['speedup']:.1f}x < 3x target")
    for tag in ("mesh_batched", "compressed_batched"):
        assert results["sweep"][tag]["speedup"] >= 2.0, (
            f"{tag} sweep speedup "
            f"{results['sweep'][tag]['speedup']:.1f}x < 2x target")
    assert results["acceleration"]["rounds_saved_ratio"] >= 1.5, (
        f"accelerated method saves only "
        f"{results['acceleration']['rounds_saved_ratio']:.2f}x rounds "
        "to the gap target (>= 1.5x target)")
    assert results["adaptive_h"]["speedup"] >= 2.0, (
        f"adaptive-H speedup {results['adaptive_h']['speedup']:.1f}x "
        "< 2x target")
    assert results["compression"]["bytes_ratio"] >= 2.0, (
        f"compressed sync ships only "
        f"{results['compression']['bytes_ratio']:.2f}x fewer bytes/round "
        "(>= 2x target at equal final gap)")
    assert results["compression"]["bigd_memory_ratio"] >= 2.0, (
        f"sharded server state saves only "
        f"{results['compression']['bigd_memory_ratio']:.2f}x memory "
        "(>= 2x target)")
    assert results["elastic"]["overhead_every5"] <= 0.10, (
        f"every=5 checkpointing costs "
        f"{results['elastic']['overhead_every5'] * 100:.1f}% wall overhead "
        "(<= 10% target)")
    # the two LM paths jit identical programs, so this is a parity gate
    # (>= 1x) with a 10% floor for host dispatch noise
    assert results["treesync"]["parity"] >= 0.9, (
        f"Session-driven LM program runs {results['treesync']['parity']:.2f}x "
        "the legacy treesync loop (>= 1x parity target)")
    assert results["treesync"]["time_saved_ratio"] >= 1.0, (
        f"adaptive periods reach the loss target only "
        f"{results['treesync']['time_saved_ratio']:.2f}x faster than the "
        "fixed barrier (>= 1x target)")
    assert results["analysis"]["overhead_frac"] <= 0.05, (
        f"verify_plan costs {results['analysis']['overhead_frac'] * 100:.1f}% "
        "of plan compile time (<= 5% target: it runs on every "
        "Session.compile)")
    return results


def main() -> Dict[str, float]:
    return run()


if __name__ == "__main__":
    main()
