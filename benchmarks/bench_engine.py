"""Micro-benchmark: the compiled tree-schedule engine vs the legacy Python
recursion on a depth-3, 8-leaf tree (the acceptance target is a >= 5x
host-path speedup; in practice the gap is much larger because the legacy
path pays one jit dispatch + full-vector alpha copies per leaf solve per
round, while the engine is ONE lax.scan program).

    PYTHONPATH=src python benchmarks/bench_engine.py
"""
from __future__ import annotations

import time
from typing import Dict

import jax

from repro.core.dual import LOSSES
from repro.core.engine.plan import balanced_tree
from repro.core.treedual import tree_dual_solve, tree_dual_solve_reference
from repro.data.synthetic import gaussian_regression

LAM = 0.1


def _time(fn, repeats: int = 3) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready((out.alpha, out.w))
        best = min(best, time.perf_counter() - t0)
    return best


def run(verbose: bool = True) -> Dict[str, float]:
    # depth-3, 8-leaf balanced tree: 10 root x 2 x 2 rounds, H=128
    tree = balanced_tree([2, 2, 2], [10, 2, 2], local_steps=128, m_leaf=32)
    m = tree.total_data()
    X, y = gaussian_regression(m=m, d=32)
    loss = LOSSES["squared"]
    key = jax.random.PRNGKey(0)
    kw = dict(loss=loss, lam=LAM, key=key, record_history=False)

    legacy = lambda: tree_dual_solve_reference(tree, X, y, **kw)  # noqa: E731
    engine = lambda: tree_dual_solve(tree, X, y, **kw)            # noqa: E731

    # warm both paths (compile + trace caches), then time steady-state
    legacy(); engine()
    t_legacy = _time(legacy)
    t_engine = _time(engine)
    speedup = t_legacy / t_engine

    if verbose:
        print("bench_engine: depth-3, 8-leaf tree "
              f"(m={m}, 40 ticks x H=128), host path")
        print(f"  legacy recursion : {t_legacy * 1e3:9.2f} ms")
        print(f"  compiled engine  : {t_engine * 1e3:9.2f} ms")
        print(f"  speedup          : {speedup:9.1f}x")
    assert speedup >= 5.0, f"engine speedup {speedup:.1f}x < 5x target"
    return {"t_legacy": t_legacy, "t_engine": t_engine, "speedup": speedup}


def main() -> Dict[str, float]:
    return run()


if __name__ == "__main__":
    main()
