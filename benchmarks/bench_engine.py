"""Micro-benchmark: the compiled tree-schedule engine vs the legacy Python
recursion on a depth-3, 8-leaf tree (the acceptance target is a >= 5x
host-path speedup; in practice the gap is much larger because the legacy
path pays one jit dispatch + full-vector alpha copies per leaf solve per
round, while the engine runs ONE compiled chunk program per root round).

Also splits cold compile time (plan lowering + trace + XLA compile on the
first run) from steady-state run time, and records the numbers in
``BENCH_engine.json`` so the perf trajectory is tracked across commits.

    PYTHONPATH=src python benchmarks/bench_engine.py
"""
from __future__ import annotations

import json
import time
from typing import Dict

import jax

from repro.api import Problem, Session, Topology
from repro.core.engine import host as host_mod
from repro.core.treedual import tree_dual_solve_reference
from repro.data.synthetic import gaussian_regression

LAM = 0.1
BENCH_JSON = "BENCH_engine.json"


def _time(fn, repeats: int = 3) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready((out.alpha, out.w))
        best = min(best, time.perf_counter() - t0)
    return best


def run(verbose: bool = True) -> Dict[str, float]:
    # depth-3, 8-leaf balanced tree: 10 root x 2 x 2 rounds, H=128
    topo = Topology.balanced([2, 2, 2], m_leaf=32, local_steps=128,
                             level_rounds=[10, 2, 2])
    m = topo.m_total
    X, y = gaussian_regression(m=m, d=32)
    problem = Problem.ridge(X, y, lam=LAM)
    key = jax.random.PRNGKey(0)

    legacy = lambda: tree_dual_solve_reference(   # noqa: E731
        topo.tree, X, y, loss=problem.loss, lam=LAM, key=key,
        record_history=False)

    # cold path: executor cache emptied -> compile + trace + first run
    host_mod._EXEC_CACHE.clear()
    t0 = time.perf_counter()
    sess = Session.compile(problem, topo)
    t_compile_py = time.perf_counter() - t0          # plan lowering + bind
    t0 = time.perf_counter()
    out = sess.run(key=key, record_history=False)
    jax.block_until_ready((out.alpha, out.w))
    t_first_run = time.perf_counter() - t0           # includes XLA compile

    engine = lambda: sess.run(key=key, record_history=False)  # noqa: E731

    # warm both paths (compile + trace caches), then time steady-state
    legacy()
    t_legacy = _time(legacy)
    t_engine = _time(engine)
    t_compile = t_compile_py + (t_first_run - t_engine)
    speedup = t_legacy / t_engine

    results = {
        "t_legacy_s": t_legacy,
        "t_engine_s": t_engine,
        "t_compile_s": t_compile,
        "t_first_run_s": t_first_run,
        "speedup": speedup,
    }
    if verbose:
        print("bench_engine: depth-3, 8-leaf tree "
              f"(m={m}, 40 ticks x H=128), host path")
        print(f"  legacy recursion : {t_legacy * 1e3:9.2f} ms")
        print(f"  compiled engine  : {t_engine * 1e3:9.2f} ms  (steady-state)")
        print(f"  compile overhead : {t_compile * 1e3:9.2f} ms  "
              "(plan + trace + XLA, first solve only)")
        print(f"  speedup          : {speedup:9.1f}x")
    with open(BENCH_JSON, "w") as f:
        json.dump(results, f, indent=2)
        f.write("\n")
    if verbose:
        print(f"  wrote {BENCH_JSON}")
    assert speedup >= 5.0, f"engine speedup {speedup:.1f}x < 5x target"
    return results


def main() -> Dict[str, float]:
    return run()


if __name__ == "__main__":
    main()
