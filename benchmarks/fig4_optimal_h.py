"""Paper Fig. 4: (a) the eq.-(12) bound as a function of H for several
delay ratios r (t_delay = r * t_lp); (b) the optimal H vs r; (c) the same
H* surfacing through the sessionized API (``Schedule(rounds="auto")``).

Constants exactly as in §7: (C, K, delta, t_total, t_lp, t_cp) =
(0.5, 3, 1/300, 1, 4e-5, 3e-5)."""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.api import Schedule, Topology
from repro.core.delay import log_bound, optimal_h, optimal_h_vs_delay

PARAMS = dict(C=0.5, K=3, delta=1 / 300, t_total=1.0, t_lp=4e-5, t_cp=3e-5)


def run(verbose: bool = True) -> Dict:
    # (a) bound vs H for a few delay ratios
    hs = np.unique(np.round(np.logspace(0, np.log10(2000), 60))).astype(int)
    rs_a = [0, 10, 1e3, 1e5]
    curves = {}
    for r in rs_a:
        vals = [log_bound(int(h), t_delay=r * PARAMS["t_lp"], **PARAMS)
                for h in hs]
        curves[r] = np.array(vals)

    # (b) optimal H for r in [0, 1e10]
    rs_b = np.logspace(0, 10, 21)
    rs_b = np.concatenate([[0.0], rs_b])
    h_opt = optimal_h_vs_delay(rs_b, h_max=10**7, **PARAMS)

    # (c) the API path: Schedule(rounds="auto") resolving the same H* from
    # a star Topology carrying the delay (m_leaf chosen so delta matches)
    h_api = {}
    for r in (0.0, 1e3, 1e7):
        topo = Topology.star(PARAMS["K"], 300, t_lp=PARAMS["t_lp"],
                             t_cp=PARAMS["t_cp"],
                             t_delay=r * PARAMS["t_lp"])
        # t_cp is inherited from the topology (Topology.internal_t_cp)
        resolved = Schedule.auto(
            t_total=PARAMS["t_total"], C=PARAMS["C"],
            h_max=10**7).resolve(topo)
        h_api[r] = resolved.chunk_tree.leaves()[0].rounds
        h_ref, _ = optimal_h(t_delay=r * PARAMS["t_lp"], h_max=10**7,
                             **PARAMS)
        assert h_api[r] == h_ref, (r, h_api[r], h_ref)

    if verbose:
        print("fig4(a): log10(bound) vs H   (t_delay = r * t_lp)")
        hdr = "  H      " + "".join(f"r={r:<12g}" for r in rs_a)
        print(hdr)
        for i in range(0, len(hs), 10):
            row = f"  {hs[i]:<6d} " + "".join(
                f"{curves[r][i] / np.log(10):<13.1f}" for r in rs_a)
            print(row)
        print("fig4(b): optimal H vs r")
        for r, h in zip(rs_b, h_opt):
            print(f"  r={r:<12.3g} H*={int(h)}")
        # the paper's qualitative claim: H* is nondecreasing in the delay
        assert all(b >= a for a, b in zip(h_opt, h_opt[1:])), h_opt
        print("  (H* nondecreasing in delay: confirmed)")
        print("fig4(c): Schedule(rounds='auto') H* by delay ratio:",
              {f"r={r:g}": h for r, h in h_api.items()})
    return {"hs": hs, "curves": curves, "rs": rs_b, "h_opt": h_opt,
            "h_api": h_api}


def main() -> Dict:
    return run()


if __name__ == "__main__":
    main()
