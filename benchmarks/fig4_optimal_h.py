"""Paper Fig. 4: (a) the eq.-(12) bound as a function of H for several
delay ratios r (t_delay = r * t_lp); (b) the optimal H vs r; (c) the same
H* surfacing through the sessionized API (``Schedule(rounds="auto")``);
(d) an EMPIRICAL convergence-vs-H comparison run as ONE batched H-axis
sweep -- H is a runtime step-mask input of the executors, so the whole
grid shares a single compiled program (``Schedule(h_cap=...)`` +
``Session.sweep(local_hs=...)``), where this benchmark previously had to
rebuild a program per H value.

Constants exactly as in §7: (C, K, delta, t_total, t_lp, t_cp) =
(0.5, 3, 1/300, 1, 4e-5, 3e-5)."""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.api import Problem, Schedule, Session, Topology
from repro.core.delay import log_bound, optimal_h, optimal_h_vs_delay

PARAMS = dict(C=0.5, K=3, delta=1 / 300, t_total=1.0, t_lp=4e-5, t_cp=3e-5)


def run(verbose: bool = True) -> Dict:
    # (a) bound vs H for a few delay ratios
    hs = np.unique(np.round(np.logspace(0, np.log10(2000), 60))).astype(int)
    rs_a = [0, 10, 1e3, 1e5]
    curves = {}
    for r in rs_a:
        vals = [log_bound(int(h), t_delay=r * PARAMS["t_lp"], **PARAMS)
                for h in hs]
        curves[r] = np.array(vals)

    # (b) optimal H for r in [0, 1e10]
    rs_b = np.logspace(0, 10, 21)
    rs_b = np.concatenate([[0.0], rs_b])
    h_opt = optimal_h_vs_delay(rs_b, h_max=10**7, **PARAMS)

    # (c) the API path: Schedule(rounds="auto") resolving the same H* from
    # a star Topology carrying the delay (m_leaf chosen so delta matches)
    h_api = {}
    for r in (0.0, 1e3, 1e7):
        topo = Topology.star(PARAMS["K"], 300, t_lp=PARAMS["t_lp"],
                             t_cp=PARAMS["t_cp"],
                             t_delay=r * PARAMS["t_lp"])
        # t_cp is inherited from the topology (Topology.internal_t_cp)
        resolved = Schedule.auto(
            t_total=PARAMS["t_total"], C=PARAMS["C"],
            h_max=10**7).resolve(topo)
        h_api[r] = resolved.chunk_tree.leaves()[0].rounds
        h_ref, _ = optimal_h(t_delay=r * PARAMS["t_lp"], h_max=10**7,
                             **PARAMS)
        assert h_api[r] == h_ref, (r, h_api[r], h_ref)

    # (d) empirical time-to-gap vs H: ONE batched H-axis sweep (a single
    # vmapped dispatch per round for the whole grid -- the step-mask
    # operand batches alongside lambda and seeds) instead of one program
    # per H value.  Simulated wall-clock per round is eq. (9)'s
    # t_lp*H + t_delay + t_cp, so the empirical sweet spot mirrors (a).
    hs_d = [4, 16, 64, 256]
    h_cap = max(hs_d)
    t_delay = 1e3 * PARAMS["t_lp"]
    topo_d = Topology.star(PARAMS["K"], 100, rounds=40, local_steps=h_cap,
                           t_lp=PARAMS["t_lp"], t_cp=PARAMS["t_cp"],
                           t_delay=t_delay)
    from repro.data.synthetic import gaussian_regression
    X, y = gaussian_regression(m=topo_d.m_total, d=12)
    sess = Session.compile(Problem.ridge(X, y, lam=0.05), topo_d,
                           Schedule(h_cap=h_cap))
    rs = sess.sweep(local_hs=hs_d)              # one batched dispatch/round
    gap_target = 0.05 * float(rs.gaps[:, 0].max())
    t_to_gap = {}
    for i, h in enumerate(hs_d):
        round_time = PARAMS["t_lp"] * h + t_delay + PARAMS["t_cp"]
        rounds_needed = np.argmax(rs.gaps[i] <= gap_target) \
            if (rs.gaps[i] <= gap_target).any() else np.inf
        t_to_gap[h] = float(rounds_needed * round_time)

    if verbose:
        print("fig4(a): log10(bound) vs H   (t_delay = r * t_lp)")
        hdr = "  H      " + "".join(f"r={r:<12g}" for r in rs_a)
        print(hdr)
        for i in range(0, len(hs), 10):
            row = f"  {hs[i]:<6d} " + "".join(
                f"{curves[r][i] / np.log(10):<13.1f}" for r in rs_a)
            print(row)
        print("fig4(b): optimal H vs r")
        for r, h in zip(rs_b, h_opt, strict=True):
            print(f"  r={r:<12.3g} H*={int(h)}")
        # the paper's qualitative claim: H* is nondecreasing in the delay
        assert all(b >= a
                   for a, b in zip(h_opt, h_opt[1:], strict=False)), h_opt
        print("  (H* nondecreasing in delay: confirmed)")
        print("fig4(c): Schedule(rounds='auto') H* by delay ratio:",
              {f"r={r:g}": h for r, h in h_api.items()})
        print("fig4(d): empirical simulated time-to-5%-gap by H "
              "(one batched H-axis sweep, r=1e3):")
        for h, t in t_to_gap.items():
            print(f"  H={h:<5d} t={t:.4f} s")
        # under a heavy delay the smallest H must not be the sweet spot
        finite = {h: t for h, t in t_to_gap.items() if np.isfinite(t)}
        assert finite and min(finite, key=finite.get) > min(hs_d), t_to_gap
    return {"hs": hs, "curves": curves, "rs": rs_b, "h_opt": h_opt,
            "h_api": h_api, "t_to_gap": t_to_gap}


def main() -> Dict:
    return run()


if __name__ == "__main__":
    main()
