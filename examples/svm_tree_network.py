"""SVM (smoothed hinge) trained with TreeDualMethod over three topologies,
showing the paper's headline effect: when the root links are slow, deeper
trees that localize communication converge faster in wall-clock terms.

    PYTHONPATH=src python examples/svm_tree_network.py
"""
import jax
import numpy as np

from repro.api import Problem, Schedule, Session, Topology
from repro.data.synthetic import gaussian_classification

LAM = 0.02
T_LP = 1e-5
SLOW = 1e5 * T_LP   # root-link delay (paper Fig. 3 regime)


def main():
    X, y = gaussian_classification(m=1024, d=64)
    problem = Problem.svm(X, y, lam=LAM, smoothing=1.0)
    key = jax.random.PRNGKey(1)

    topologies = {
        "star-8 (CoCoA)": (
            Topology.star(8, 128, t_lp=T_LP, t_delay=SLOW),
            Schedule(rounds=12, local_steps=384)),
        "tree 2x4": (
            Topology.two_level(2, 4, 128, t_lp=T_LP, root_delay=SLOW,
                               group_delay=1e-4),
            Schedule(rounds=6, level_rounds=[2], local_steps=384)),
        "tree 4x2": (
            Topology.two_level(4, 2, 128, t_lp=T_LP, root_delay=SLOW,
                               group_delay=1e-4),
            Schedule(rounds=6, level_rounds=[2], local_steps=384)),
    }

    print(f"{'topology':<16}{'sim-time(s)':>12}{'final gap':>14}"
          f"{'gap @ t=13s':>14}")
    for name, (topo, sched) in topologies.items():
        res = Session.compile(problem, topo, sched).run(key=key)
        # gap at a common wall-clock budget
        t_common = 13.0
        i = max(int(np.searchsorted(res.times, t_common, "right")) - 1, 0)
        print(f"{name:<16}{res.times[-1]:>12.2f}{res.gaps[-1]:>14.3e}"
              f"{res.gaps[i]:>14.3e}")

    print("\n(deeper trees pay the slow root hop fewer times per unit of "
          "local progress)")


if __name__ == "__main__":
    main()
