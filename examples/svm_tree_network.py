"""SVM (smoothed hinge) trained with TreeDualMethod over three topologies,
showing the paper's headline effect: when the root links are slow, deeper
trees that localize communication converge faster in wall-clock terms.

    PYTHONPATH=src python examples/svm_tree_network.py
"""
import jax

from repro.core.dual import LOSSES, duality_gap
from repro.core.tree import star, two_level
from repro.core.treedual import tree_dual_solve
from repro.data.synthetic import gaussian_classification

LAM = 0.02
T_LP = 1e-5
SLOW = 1e5 * T_LP   # root-link delay (paper Fig. 3 regime)


def main():
    X, y = gaussian_classification(m=1024, d=64)
    loss = LOSSES["smooth_hinge_1"]
    key = jax.random.PRNGKey(1)

    topologies = {
        "star-8 (CoCoA)": star(
            8, 128, outer_rounds=12, local_steps=384,
            t_lp=T_LP, t_delay=SLOW),
        "tree 2x4": two_level(
            2, 4, 128, root_rounds=6, group_rounds=2, local_steps=384,
            t_lp=T_LP, root_delay=SLOW, group_delay=1e-4),
        "tree 4x2": two_level(
            4, 2, 128, root_rounds=6, group_rounds=2, local_steps=384,
            t_lp=T_LP, root_delay=SLOW, group_delay=1e-4),
    }

    print(f"{'topology':<16}{'sim-time(s)':>12}{'final gap':>14}"
          f"{'gap @ t=13s':>14}")
    for name, tree in topologies.items():
        res = tree_dual_solve(tree, X, y, loss=loss, lam=LAM, key=key)
        # gap at a common wall-clock budget
        import numpy as np
        t_common = 13.0
        i = max(int(np.searchsorted(res.times, t_common, "right")) - 1, 0)
        print(f"{name:<16}{res.times[-1]:>12.2f}{res.gaps[-1]:>14.3e}"
              f"{res.gaps[i]:>14.3e}")

    print("\n(deeper trees pay the slow root hop fewer times per unit of "
          "local progress)")


if __name__ == "__main__":
    main()
