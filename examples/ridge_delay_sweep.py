"""Delay-aware tuning demo (paper §6): for a range of link delays, let
``Schedule(rounds="auto")`` pick the eq.-(12)-optimal local iteration count
H from the topology's delay model, and verify it against actual simulated
runs of CoCoA (star sessions) on ridge regression.

    PYTHONPATH=src python examples/ridge_delay_sweep.py
"""
from repro.api import Problem, Schedule, Session, Topology
from repro.core.delay import optimal_h
from repro.core.dual import duality_gap
from repro.data.synthetic import gaussian_regression

T_LP, T_CP, LAM, K = 4e-5, 3e-5, 1e-2, 3
BUDGET = 2.0  # seconds of simulated wall-clock


def main():
    X, y = gaussian_regression(m=600, d=100)
    m = X.shape[0]
    problem = Problem.ridge(X, y, lam=LAM)

    print(f"{'r':>10} {'H* (auto)':>12} {'best H (sim)':>14} "
          f"{'gap @ H*':>12}")
    for r in (1.0, 100.0, 1e4):
        t_delay = r * T_LP
        topo = Topology.star(K, m // K, t_lp=T_LP, t_cp=T_CP,
                             t_delay=t_delay)

        # the session's auto schedule runs eq. (12) at compile time
        auto = Session.compile(
            problem, topo,
            Schedule.auto(t_total=BUDGET, C=0.5, delta=1 / (m // K),
                          t_cp=T_CP, h_max=10**6))
        h_star = auto.resolved.chunk_tree.leaves()[0].rounds
        h_ref, _ = optimal_h(C=0.5, K=K, delta=1 / (m // K), t_total=BUDGET,
                             t_lp=T_LP, t_delay=t_delay, t_cp=T_CP,
                             h_max=10**6)
        assert h_star == h_ref, (h_star, h_ref)

        # simulate a small grid around H* -- one vectorized sweep over the
        # schedule axis -- and report the empirical best
        hs = sorted({max(h_star // 8, 1), max(h_star // 2, 1), h_star,
                     h_star * 2, h_star * 8})
        scheds = [
            Schedule(rounds=min(max(int(
                BUDGET / (T_LP * H + t_delay + T_CP)), 1), 2000),
                local_steps=H)
            for H in hs
        ]
        rs = auto.sweep(schedules=scheds, record_history=False)
        gaps = {
            H: float(duality_gap(res.alpha, X, y, problem.loss, LAM))
            for H, res in zip(hs, rs, strict=True)
        }
        best = min(gaps, key=gaps.get)
        print(f"{r:>10.0f} {h_star:>12d} {best:>14d} {gaps[h_star]:>12.3e}")
        # the eq.-(12) pick is within ~4x of the empirical best
        assert best / 8 <= h_star <= best * 8, (r, h_star, best)

    print("\n(the analytic H* tracks the empirically-best H across delay "
          "regimes)")


if __name__ == "__main__":
    main()
