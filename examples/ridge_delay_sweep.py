"""Delay-aware tuning demo (paper §6): for a range of link delays, compute
the eq.-(12)-optimal local iteration count H and verify it against actual
simulated runs of CoCoA on ridge regression.

    PYTHONPATH=src python examples/ridge_delay_sweep.py
"""
import jax
import numpy as np

from repro.core.delay import optimal_h
from repro.core.dual import LOSSES
from repro.core.treedual import cocoa_star_solve
from repro.data.synthetic import gaussian_regression

T_LP, T_CP, LAM, K = 4e-5, 3e-5, 1e-2, 3
BUDGET = 2.0  # seconds of simulated wall-clock


def main():
    X, y = gaussian_regression(m=600, d=100)
    m = X.shape[0]
    loss = LOSSES["squared"]

    print(f"{'r':>10} {'H* (eq.12)':>12} {'best H (sim)':>14} "
          f"{'gap @ H*':>12}")
    for r in (1.0, 100.0, 1e4):
        t_delay = r * T_LP
        h_star, _ = optimal_h(C=0.5, K=K, delta=1 / (m // K),
                              t_total=BUDGET, t_lp=T_LP, t_delay=t_delay,
                              t_cp=T_CP, h_max=10**6)

        # simulate a small grid around H* and report the empirical best
        gaps = {}
        for H in sorted({max(h_star // 8, 1), max(h_star // 2, 1), h_star,
                         h_star * 2, h_star * 8}):
            rounds = max(int(BUDGET / (T_LP * H + t_delay + T_CP)), 1)
            rounds = min(rounds, 2000)
            res = cocoa_star_solve(
                X, y, K, loss=loss, lam=LAM, outer_rounds=rounds,
                local_steps=H, key=jax.random.PRNGKey(0))
            gaps[H] = float(res.gaps[-1])
        best = min(gaps, key=gaps.get)
        print(f"{r:>10.0f} {h_star:>12d} {best:>14d} {gaps[h_star]:>12.3e}")
        # the eq.-(12) pick is within ~4x of the empirical best
        assert best / 8 <= h_star <= best * 8, (r, h_star, best)

    print("\n(the analytic H* tracks the empirically-best H across delay "
          "regimes)")


if __name__ == "__main__":
    main()
