"""Quickstart: the paper's algorithm through the sessionized API.

Solves ridge regression with distributed dual coordinate ascent on a
2-level tree network (root -> 2 sub-centers -> 4 workers), streaming the
duality gap per round as the solve runs, warm-restarts the session for a
few extra rounds, and compares against the closed-form optimum.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.api import Problem, Schedule, Session, Topology
from repro.core.dual import dual_value, ridge_dual_optimum
from repro.data.synthetic import gaussian_regression


def main():
    X, y = gaussian_regression(m=512, d=64)
    problem = Problem(X, y, loss="squared", lam=0.05)

    # the network: 2 sub-centers, 2 leaf workers each, 128 points/worker
    topology = Topology.two_level(
        n_groups=2, workers_per_group=2, m_per_worker=128,
        root_delay=0.5e-1, group_delay=1e-4, t_lp=1e-5)
    schedule = Schedule(rounds=10, level_rounds=[2], local_steps=256)

    session = Session.compile(problem, topology, schedule, backend="vmap")

    print("round  sim-time(s)   duality-gap")
    res = session.run(key=jax.random.PRNGKey(0), on_round=lambda h: print(
        f"{h['round']:>5}  {h['time']:>11.4f}   {h['gap']:.3e}"))

    # warm restart: 5 more rounds, continuing the state and RNG chain
    res = session.run(rounds=5, warm_start=res)
    print(f"after warm restart (+5 rounds): gap {res.history[-1]['gap']:.3e}")

    # certificate: compare with the exact dual optimum
    a_star = ridge_dual_optimum(X, y, problem.lam)
    d_star = float(dual_value(a_star, X, y, problem.loss, problem.lam))
    d_ours = float(dual_value(res.alpha, X, y, problem.loss, problem.lam))
    print(f"\nD(alpha*) = {d_star:.6f}")
    print(f"D(ours)   = {d_ours:.6f}  (suboptimality {d_star - d_ours:.2e})")
    w_err = float(jnp.linalg.norm(
        res.w - (X.T @ a_star) / (problem.lam * X.shape[0])))
    print(f"||w - w*|| = {w_err:.2e}")
    assert d_star - d_ours < 1e-3, "did not reach the optimum"

    # the topology is a serializable spec
    rt = Topology.from_json(topology.to_json())
    assert rt == topology
    print("topology JSON round-trip: ok")


if __name__ == "__main__":
    main()
