"""Quickstart: the paper's algorithm in 40 lines.

Solves ridge regression with distributed dual coordinate ascent on a
2-level tree network (root -> 2 sub-centers -> 4 workers), prints the
duality gap per round, and compares against the closed-form optimum.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core.dual import LOSSES, dual_value, ridge_dual_optimum
from repro.core.tree import two_level
from repro.core.treedual import tree_dual_solve
from repro.data.synthetic import gaussian_regression


def main():
    X, y = gaussian_regression(m=512, d=64)
    lam = 0.05
    loss = LOSSES["squared"]

    # the network: 2 sub-centers, 2 leaf workers each, 128 points/worker
    tree = two_level(
        n_groups=2, workers_per_group=2, m_per_worker=128,
        root_rounds=10, group_rounds=2, local_steps=256,
        t_lp=1e-5, root_delay=0.5e-1, group_delay=1e-4,
    )
    res = tree_dual_solve(tree, X, y, loss=loss, lam=lam,
                          key=jax.random.PRNGKey(0))

    print("round  sim-time(s)   duality-gap")
    for h in res.history:
        print(f"{h['round']:>5}  {h['time']:>11.4f}   {h['gap']:.3e}")

    # certificate: compare with the exact dual optimum
    a_star = ridge_dual_optimum(X, y, lam)
    d_star = float(dual_value(a_star, X, y, loss, lam))
    d_ours = float(dual_value(res.alpha, X, y, loss, lam))
    print(f"\nD(alpha*) = {d_star:.6f}")
    print(f"D(ours)   = {d_ours:.6f}  (suboptimality {d_star - d_ours:.2e})")
    w_err = float(jnp.linalg.norm(res.w - (X.T @ a_star) / (lam * X.shape[0])))
    print(f"||w - w*|| = {w_err:.2e}")


if __name__ == "__main__":
    main()
