"""End-to-end driver (deliverable b): train a ~100M-param decoder-only LM
for a few hundred steps with the paper's TreeSync schedule + checkpointing.

The config is a scaled-down qwen3-family model (~100M params); on this CPU
container it runs in minutes. Pass --steps/--mode to experiment; compare
--mode sync (fully synchronous DP = the paper's star) against the default
TreeSync (H=4 local steps per sync).

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse

from repro.configs.base import ModelConfig
from repro.launch.train import train

CFG_100M = ModelConfig(
    name="repro-100m",
    family="dense",
    num_layers=10,
    d_model=640,
    num_heads=10,
    num_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab_size=32_768,
    qk_norm=True,
    q_chunk_size=128,
    logits_chunk=128,
    remat=False,
    param_dtype="float32",
)  # ~104M params (printed at startup)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--mode", default="treesync",
                    choices=["treesync", "sync"])
    ap.add_argument("--periods", type=int, nargs="+", default=[4])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm_ckpt")
    args = ap.parse_args()

    print(f"training {CFG_100M.name} "
          f"({CFG_100M.param_count() / 1e6:.0f}M params), "
          f"mode={args.mode}, steps={args.steps}")
    out = train(
        CFG_100M, steps=args.steps, batch=args.batch, seq=args.seq,
        mode=args.mode, periods=args.periods, lr=1e-3,
        ckpt_dir=args.ckpt_dir, ckpt_every=100, log_every=20,
    )
    h = out["history"]
    print(f"loss: {h[0]['loss']:.3f} -> {h[-1]['loss']:.3f} "
          f"({out['wall_s']:.0f}s wall)")
    assert h[-1]["loss"] < h[0]["loss"], "training failed to reduce loss"


if __name__ == "__main__":
    main()
