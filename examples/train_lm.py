"""End-to-end driver (deliverable b): train a ~100M-param decoder-only LM
for a few hundred steps with the paper's TreeSync schedule + checkpointing.

Since the schedule-engine unification, ``--sync`` and the default
TreeSync schedule are the SAME Session-driven program (``Problem.lm`` +
``Session.compile(backend="mesh")``): sync is just all periods 1 --
compare it against the default H=4 local steps per sync.  ``--smoke``
swaps in a tiny config for CI (seconds, any machine).

    PYTHONPATH=src python examples/train_lm.py --steps 200
    PYTHONPATH=src python examples/train_lm.py --steps 20 --smoke
"""
import argparse

from repro.configs.base import ModelConfig
from repro.launch.train import train

CFG_100M = ModelConfig(
    name="repro-100m",
    family="dense",
    num_layers=10,
    d_model=640,
    num_heads=10,
    num_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab_size=32_768,
    qk_norm=True,
    q_chunk_size=128,
    logits_chunk=128,
    remat=False,
    param_dtype="float32",
)  # ~104M params (printed at startup)

CFG_SMOKE = ModelConfig(
    name="repro-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=256,
    vocab_size=512,
    q_chunk_size=32,
    logits_chunk=32,
    remat=False,
    param_dtype="float32",
)  # CI-sized: a few seconds on one CPU


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config + short sequences (CI smoke)")
    ap.add_argument("--sync", action="store_true",
                    help="all periods 1 (the fully synchronous star)")
    ap.add_argument("--periods", type=int, nargs="+", default=[4])
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = CFG_SMOKE if args.smoke else CFG_100M
    batch, seq = (4, 32) if args.smoke else (args.batch, args.seq)
    ckpt = args.ckpt_dir
    if ckpt is None and not args.smoke:
        ckpt = "/tmp/repro_train_lm_ckpt"

    print(f"training {cfg.name} ({cfg.param_count() / 1e6:.1f}M params), "
          f"sync={args.sync}, steps={args.steps}")
    out = train(
        cfg, steps=args.steps, batch=batch, seq=seq,
        sync=args.sync, periods=args.periods, lr=1e-3,
        ckpt_dir=ckpt, ckpt_every=100, log_every=20,
    )
    h = out["history"]
    print(f"loss: {h[0]['loss']:.3f} -> {h[-1]['loss']:.3f} "
          f"({out['wall_s']:.0f}s wall)")
    assert h[-1]["loss"] < h[0]["loss"], "training failed to reduce loss"


if __name__ == "__main__":
    main()
