"""Multi-head attention: GQA, qk-norm, QKV bias, sliding window, RoPE.

Training/prefill uses a *query-chunked* implementation (lax.scan over query
blocks) so the (S x S) score matrix is never materialized -- mandatory for
the 32k prefill shapes. Decode attends a (possibly ring-buffered) KV cache.

``attention_impl="flash"`` routes to the Pallas flash kernel
(repro.kernels.flash_attention) on TPU; the XLA paths below are the oracle.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init, rms_norm, rope, split_keys

Array = jax.Array
NEG_INF = -2.0**30


def init_attn_params(key, cfg: ModelConfig, dtype):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = split_keys(key, 6)
    p = {
        "wq": dense_init(ks[0], (d, h * hd), dtype),
        "wk": dense_init(ks[1], (d, kv * hd), dtype),
        "wv": dense_init(ks[2], (d, kv * hd), dtype),
        "wo": dense_init(ks[3], (h * hd, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kv * hd,), dtype)
        p["bv"] = jnp.zeros((kv * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def _project_qkv(p, cfg: ModelConfig, x: Array, positions: Array):
    """x: (B, S, D) -> q (B,S,H,hd), k/v (B,S,KV,hd) with rope + qk-norm."""
    B, S, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, h, hd)
    k = k.reshape(B, S, kv, hd)
    v = v.reshape(B, S, kv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _repeat_kv(x: Array, n_rep: int) -> Array:
    if n_rep == 1:
        return x
    return jnp.repeat(x, n_rep, axis=2)


def _masked_softmax(scores: Array, mask: Array) -> Array:
    scores = jnp.where(mask, scores.astype(jnp.float32), NEG_INF)
    # guard fully-masked rows (outside window) against NaN
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - jax.lax.stop_gradient(m))
    e = jnp.where(mask, e, 0.0)
    return e / (jnp.sum(e, axis=-1, keepdims=True) + 1e-30)


def attention_train(
    p, cfg: ModelConfig, x: Array, positions: Array,
    window: Optional[int] = None,
) -> Array:
    """Causal (optionally windowed) self-attention over full sequences,
    chunked over queries. x: (B, S, D) -> (B, S, D).

    GQA is computed with *grouped* einsums (query heads reshaped to
    (kv_heads, group)): K/V are never materialized at q-head width, which
    cuts their HBM stream h/kv-fold."""
    B, S, D = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    rep = h // kv
    win = window if window is not None else cfg.window
    q, k, v = _project_qkv(p, cfg, x, positions)
    scale = hd**-0.5

    qc = min(cfg.q_chunk_size, S)
    n_chunks = S // qc
    assert S % qc == 0, f"seq {S} must divide q_chunk {qc}"

    kpos = positions  # (B, S)

    def chunk_fn(carry, inputs):
        q_blk, qpos = inputs  # (B, qc, H, hd), (B, qc)
        qg = q_blk.reshape(B, qc, kv, rep, hd)
        # scores: (B, KV, rep, qc, S)
        s = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k,
                       preferred_element_type=jnp.float32) * scale
        causal = qpos[:, None, None, :, None] >= kpos[:, None, None, None, :]
        if win is not None:
            causal &= (qpos[:, None, None, :, None]
                       - kpos[:, None, None, None, :]) < win
        probs = _masked_softmax(s, causal)
        o = jnp.einsum("bgrqk,bkgd->bqgrd", probs.astype(v.dtype), v)
        return carry, o.reshape(B, qc, h, hd)

    q_chunks = q.reshape(B, n_chunks, qc, h, hd).swapaxes(0, 1)
    p_chunks = positions.reshape(B, n_chunks, qc).swapaxes(0, 1)
    # unroll in analysis mode: XLA cost_analysis counts a while body once
    _, outs = jax.lax.scan(chunk_fn, None, (q_chunks, p_chunks),
                           unroll=not cfg.scan_layers)
    out = outs.swapaxes(0, 1).reshape(B, S, h * hd)
    return out @ p["wo"]


def attention_flash(p, cfg: ModelConfig, x: Array, positions: Array,
                    window: Optional[int] = None) -> Array:
    """Pallas flash-attention path (TPU target; interpret-mode on CPU)."""
    from repro.kernels.flash_attention.ops import flash_attention

    B, S, D = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q, k, v = _project_qkv(p, cfg, x, positions)
    win = window if window is not None else cfg.window
    out = flash_attention(q, k, v, causal=True, window=win)
    return out.reshape(B, S, h * hd) @ p["wo"]


def attend(p, cfg: ModelConfig, x: Array, positions: Array,
           window: Optional[int] = None) -> Array:
    if cfg.attention_impl == "flash":
        return attention_flash(p, cfg, x, positions, window)
    return attention_train(p, cfg, x, positions, window)


# ---------------------------------------------------------------------------
# decode: one new token against a KV cache
# ---------------------------------------------------------------------------
def init_layer_cache(cfg: ModelConfig, batch: int, max_len: int,
                     window: Optional[int] = None, dtype=jnp.bfloat16):
    """KV cache for ONE attention layer. Windowed layers use a ring buffer of
    size `window`; `pos` tracks absolute positions of each slot (-1 = empty)."""
    win = window if window is not None else cfg.window
    n = min(max_len, win) if win is not None else max_len
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, n, kv, hd), dtype),
        "v": jnp.zeros((batch, n, kv, hd), dtype),
        "slot_pos": jnp.full((n,), -1, jnp.int32),
    }


def decode_attention(
    p, cfg: ModelConfig, x: Array, pos: Array, cache: dict,
    window: Optional[int] = None,
) -> Tuple[Array, dict]:
    """x: (B, 1, D); pos: scalar int32 (same position for the whole batch,
    standard batched decode). Returns (out (B,1,D), new cache)."""
    B = x.shape[0]
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(p, cfg, x, positions)

    n = cache["k"].shape[1]
    slot = pos % n  # ring for windowed layers; identity while pos < n
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                     (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                     (0, slot, 0, 0))
    slot_pos = jax.lax.dynamic_update_slice(
        cache["slot_pos"], pos[None].astype(jnp.int32), (slot,))

    # grouped-GQA scores: K/V streamed at kv-head width (never repeated)
    qg = q.reshape(B, 1, kv, h // kv, hd)
    s = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k,
                   preferred_element_type=jnp.float32) * hd**-0.5
    win = window if window is not None else cfg.window
    valid = (slot_pos >= 0) & (slot_pos <= pos)
    if win is not None:
        valid &= (pos - slot_pos) < win
    probs = _masked_softmax(s, valid[None, None, None, None, :])
    o = jnp.einsum("bgrqk,bkgd->bqgrd", probs.astype(v.dtype), v)
    out = o.reshape(B, 1, h * hd) @ p["wo"]
    return out, {"k": k, "v": v, "slot_pos": slot_pos}
