"""Composable decoder-only model covering every assigned architecture:
dense GQA transformers (qk-norm / QKV-bias / sliding-window variants),
MoE (top-k + optional dense residual), RG-LRU hybrids (Griffin), and RWKV-6.

Layers are grouped into repeating *pattern blocks* (cfg.block_pattern) and
stacked, so the forward pass is a single lax.scan per group -- this keeps the
HLO compact enough to dry-run 64-layer 32B+ configs on a 512-device mesh.

Three execution modes per sub-layer: train (no cache), prefill (build cache),
decode (consume cache; O(1) state for recurrent families).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import mlp as mlp_mod
from repro.models import rglru as rglru_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models.common import (cast_floats, dense_init, dtype_of, rms_norm,
                                 split_keys)
from repro.models.loss import chunked_xent
from repro.models.shardctx import constrain

Array = jax.Array
PyTree = Any


def _pattern(cfg: ModelConfig) -> Tuple[str, ...]:
    if cfg.is_rwkv:
        return ("rwkv",)
    return cfg.block_pattern or ("attn",)


def block_layout(cfg: ModelConfig) -> Tuple[Tuple[str, ...], int, Tuple[str, ...]]:
    """(pattern, n_full_blocks, tail_kinds)."""
    p = _pattern(cfg)
    n_full = cfg.num_layers // len(p)
    tail = tuple(p[: cfg.num_layers % len(p)])
    return p, n_full, tail


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _init_sublayer(key, cfg: ModelConfig, kind: str, dtype) -> Dict:
    k1, k2 = jax.random.split(key)
    p: Dict[str, Any] = {"ln1": jnp.zeros((cfg.d_model,), dtype)}
    if kind == "attn":
        p["mix"] = attn_mod.init_attn_params(k1, cfg, dtype)
    elif kind == "rec":
        p["mix"] = rglru_mod.init_rglru_params(k1, cfg, dtype)
    elif kind == "rwkv":
        p["mix"] = rwkv_mod.init_rwkv_params(k1, cfg, dtype)
    else:
        raise ValueError(kind)
    if kind != "rwkv":
        p["ln2"] = jnp.zeros((cfg.d_model,), dtype)
        p["ffn"] = mlp_mod.init_ffn_params(k2, cfg, dtype)
    else:
        p["ln2"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def init_params(cfg: ModelConfig, key: Array) -> PyTree:
    dtype = dtype_of(cfg.param_dtype)
    pattern, n_full, tail = block_layout(cfg)
    k_emb, k_blocks, k_tail, k_un = jax.random.split(key, 4)

    def init_block(bk):
        ks = split_keys(bk, len(pattern))
        return {f"sub{i}": _init_sublayer(ks[i], cfg, kind, dtype)
                for i, kind in enumerate(pattern)}

    params: Dict[str, Any] = {
        "embed": dense_init(k_emb, (cfg.vocab_size, cfg.d_model), dtype,
                            scale=0.02),
        "final_ln": jnp.zeros((cfg.d_model,), dtype),
    }
    if n_full:
        params["blocks"] = jax.vmap(init_block)(
            jax.random.split(k_blocks, n_full))
    if tail:
        ks = split_keys(k_tail, len(tail))
        params["tail"] = [
            _init_sublayer(ks[i], cfg, kind, dtype)
            for i, kind in enumerate(tail)
        ]
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(
            k_un, (cfg.d_model, cfg.vocab_size), dtype)
    return params


def _unembed(cfg: ModelConfig, params) -> Array:
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["unembed"]


# ---------------------------------------------------------------------------
# sub-layer application (train mode)
# ---------------------------------------------------------------------------
def _sublayer_train(p, cfg: ModelConfig, kind: str, x: Array,
                    positions: Array) -> Tuple[Array, Array]:
    """Returns (x, moe_aux_loss)."""
    p = cast_floats(p, x.dtype)
    aux = jnp.float32(0.0)
    h = rms_norm(x, p["ln1"])
    if kind == "attn":
        x = x + attn_mod.attend(p["mix"], cfg, h, positions)
    elif kind == "rec":
        x = x + rglru_mod.rglru_block(p["mix"], cfg, h)
    elif kind == "rwkv":
        x = x + rwkv_mod.time_mix(p["mix"], cfg, h)
        h2 = rms_norm(x, p["ln2"])
        x = x + rwkv_mod.channel_mix(p["mix"], cfg, h2)
        return x, aux
    h2 = rms_norm(x, p["ln2"])
    out, aux = mlp_mod.ffn(p["ffn"], cfg, h2)
    x = x + out
    return x, aux


def _block_train(blk, cfg: ModelConfig, pattern, x: Array,
                 positions: Array) -> Tuple[Array, Array]:
    aux = jnp.float32(0.0)
    for i, kind in enumerate(pattern):
        x, a = _sublayer_train(blk[f"sub{i}"], cfg, kind, x, positions)
        aux = aux + a
    return x, aux


def _embed_inputs(cfg: ModelConfig, params, batch) -> Array:
    dtype = dtype_of(cfg.activation_dtype)
    if cfg.input_mode == "embeddings" and "embeds" in batch:
        return batch["embeds"].astype(dtype)
    x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(dtype)
    if cfg.tie_embeddings:
        x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(dtype)
    return x


def forward_hidden(cfg: ModelConfig, params, batch: Dict[str, Array]
                   ) -> Tuple[Array, Array]:
    """Full-sequence forward to final hidden states. Returns (h, moe_aux)."""
    pattern, n_full, tail = block_layout(cfg)
    x = _embed_inputs(cfg, params, batch)
    x = constrain(x, "act_batch", "act_seq", "act_embed")
    B, S, _ = x.shape
    positions = batch.get(
        "positions", jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    )

    def inner(blk, x):
        x, a = _block_train(blk, cfg=cfg, pattern=pattern, x=x,
                            positions=positions)
        return constrain(x, "act_batch", "act_seq", "act_embed"), a

    if cfg.remat:
        block_fn = jax.checkpoint(
            inner, policy=jax.checkpoint_policies.nothing_saveable)
    else:
        block_fn = inner

    aux = jnp.float32(0.0)
    if n_full:
        if cfg.scan_layers:
            def scan_body(carry, blk):
                x, aux = carry
                x, a = block_fn(blk, x)
                return (x, aux + a), None

            (x, aux), _ = jax.lax.scan(scan_body, (x, aux),
                                       params["blocks"])
        else:  # unrolled: analysis-grade HLO (see ModelConfig.scan_layers)
            for i in range(n_full):
                blk = jax.tree.map(lambda t, i=i: t[i], params["blocks"])
                x, a = block_fn(blk, x)
                aux = aux + a
    for i, kind in enumerate(tail):
        x, a = _sublayer_train(params["tail"][i], cfg, kind, x, positions)
        aux = aux + a
        x = constrain(x, "act_batch", "act_seq", "act_embed")
    return rms_norm(x, params["final_ln"]), aux


def forward_train(cfg: ModelConfig, params, batch: Dict[str, Array]
                  ) -> Tuple[Array, Dict[str, Array]]:
    """Causal-LM loss. batch: tokens/embeds, labels, optional mask."""
    h, aux = forward_hidden(cfg, params, batch)
    labels = batch["labels"]
    mask = batch.get("mask", jnp.ones_like(labels, jnp.float32))
    loss_sum, n = chunked_xent(h, _unembed(cfg, params), labels, mask,
                               cfg.logits_chunk,
                               unroll=not cfg.scan_layers)
    loss = loss_sum / jnp.maximum(n, 1.0)
    total = loss + 0.01 * aux
    return total, {"loss": loss, "moe_aux": aux, "tokens": n}


# ---------------------------------------------------------------------------
# cache init
# ---------------------------------------------------------------------------
def _init_sublayer_cache(cfg: ModelConfig, kind: str, batch: int,
                         max_len: int, dtype):
    if kind == "attn":
        return attn_mod.init_layer_cache(cfg, batch, max_len, dtype=dtype)
    if kind == "rec":
        return rglru_mod.init_rglru_cache(cfg, batch, dtype=dtype)
    if kind == "rwkv":
        return rwkv_mod.init_rwkv_cache(cfg, batch, dtype=dtype)
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> PyTree:
    pattern, n_full, tail = block_layout(cfg)

    def one_block(_):
        return {
            f"sub{i}": _init_sublayer_cache(cfg, kind, batch, max_len, dtype)
            for i, kind in enumerate(pattern)
        }

    cache: Dict[str, Any] = {"pos": jnp.int32(0)}
    if n_full:
        cache["blocks"] = jax.vmap(one_block)(jnp.arange(n_full))
    if tail:
        cache["tail"] = [
            _init_sublayer_cache(cfg, kind, batch, max_len, dtype)
            for kind in tail
        ]
    return cache


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------
def _sublayer_decode(p, cfg: ModelConfig, kind: str, x: Array, pos: Array,
                     cache) -> Tuple[Array, PyTree]:
    p = cast_floats(p, x.dtype)
    h = rms_norm(x, p["ln1"])
    if kind == "attn":
        o, cache = attn_mod.decode_attention(p["mix"], cfg, h, pos, cache)
        x = x + o
    elif kind == "rec":
        o, cache = rglru_mod.rglru_decode(p["mix"], cfg, h, cache)
        x = x + o
    elif kind == "rwkv":
        o, cache = rwkv_mod.time_mix_decode(p["mix"], cfg, h, cache)
        x = x + o
        h2 = rms_norm(x, p["ln2"])
        o, cache = rwkv_mod.channel_mix_decode(p["mix"], cfg, h2, cache)
        return x + o, cache
    h2 = rms_norm(x, p["ln2"])
    x = x + mlp_mod.ffn(p["ffn"], cfg, h2)[0]
    return x, cache


def decode_step(cfg: ModelConfig, params, cache: PyTree, tokens: Array
                ) -> Tuple[Array, PyTree]:
    """One token per sequence. tokens: (B, 1) -> logits (B, V)."""
    pattern, n_full, tail = block_layout(cfg)
    pos = cache["pos"]
    x = _embed_inputs(cfg, params, {"tokens": tokens})
    x = constrain(x, "act_batch", None, "act_embed")

    new_cache: Dict[str, Any] = {"pos": pos + 1}
    if n_full:
        def body(x, inp):
            blk, blk_cache = inp
            ncache = {}
            for i, kind in enumerate(pattern):
                x, c = _sublayer_decode(blk[f"sub{i}"], cfg, kind, x, pos,
                                        blk_cache[f"sub{i}"])
                ncache[f"sub{i}"] = c
            return x, ncache

        if cfg.scan_layers:
            x, new_cache["blocks"] = jax.lax.scan(
                body, x, (params["blocks"], cache["blocks"]))
        else:
            nblocks = cache["blocks"]
            for i in range(n_full):
                blk = jax.tree.map(lambda t, i=i: t[i], params["blocks"])
                bc = jax.tree.map(lambda t, i=i: t[i], cache["blocks"])
                x, nc = body(x, (blk, bc))
                nblocks = jax.tree.map(
                    lambda full, new, i=i: full.at[i].set(new), nblocks, nc)
            new_cache["blocks"] = nblocks
    if tail:
        new_cache["tail"] = []
        for i, kind in enumerate(tail):
            x, c = _sublayer_decode(params["tail"][i], cfg, kind, x, pos,
                                    cache["tail"][i])
            new_cache["tail"].append(c)
    h = rms_norm(x, params["final_ln"])
    logits = (h[:, 0].astype(jnp.float32)
              @ _unembed(cfg, params).astype(jnp.float32))
    return logits, new_cache


# ---------------------------------------------------------------------------
# prefill: full-sequence forward that also builds the decode cache
# ---------------------------------------------------------------------------
def _attn_prefill_cache(p, cfg: ModelConfig, h: Array, positions: Array,
                        max_len: int, dtype) -> PyTree:
    """Recompute k/v for the whole prompt and lay them out ring-consistently."""
    B, S, _ = h.shape
    _, k, v = attn_mod._project_qkv(p["mix"], cfg, h, positions)
    cache = attn_mod.init_layer_cache(cfg, B, max_len, dtype=dtype)
    n = cache["k"].shape[1]
    take = min(n, S)
    src = slice(S - take, S)  # last `take` positions
    pos_tail = positions[0, src]
    slots = pos_tail % n
    cache["k"] = cache["k"].at[:, slots].set(k[:, src].astype(dtype))
    cache["v"] = cache["v"].at[:, slots].set(v[:, src].astype(dtype))
    cache["slot_pos"] = cache["slot_pos"].at[slots].set(pos_tail)
    return cache


def _sublayer_prefill(p, cfg: ModelConfig, kind: str, x: Array,
                      positions: Array, max_len: int, dtype
                      ) -> Tuple[Array, PyTree]:
    p = cast_floats(p, x.dtype)
    h = rms_norm(x, p["ln1"])
    if kind == "attn":
        cache = _attn_prefill_cache(p, cfg, h, positions, max_len, dtype)
        x = x + attn_mod.attend(p["mix"], cfg, h, positions)
    elif kind == "rec":
        u = h @ p["mix"]["w_in"]
        gate = jax.nn.gelu(h @ p["mix"]["w_gate"])
        cw = cfg.conv_width
        padded = jnp.pad(u, ((0, 0), (cw - 1, 0), (0, 0)))
        conv = sum(padded[:, i: i + u.shape[1]] * p["mix"]["conv"][i]
                   for i in range(cw))
        a, b = rglru_mod._gates(p["mix"], conv)
        hseq = rglru_mod._scan_linear(a, b)
        cache = {"h": hseq[:, -1], "conv": padded[:, -(cw - 1):]
                 if cw > 1 else jnp.zeros((x.shape[0], 0, cfg.lru_width), dtype)}
        x = x + ((hseq.astype(x.dtype) * gate) @ p["mix"]["w_out"])
    elif kind == "rwkv":
        x, cache = _rwkv_prefill(p, cfg, x)
        return x, cache
    else:
        raise ValueError(kind)
    h2 = rms_norm(x, p["ln2"])
    x = x + mlp_mod.ffn(p["ffn"], cfg, h2)[0]
    return x, cache


def _rwkv_prefill(p, cfg: ModelConfig, x: Array) -> Tuple[Array, PyTree]:
    """Run the rwkv sublayer over the prompt, returning terminal state."""
    h = rms_norm(x, p["ln1"])
    B, S, D = h.shape
    N = cfg.rwkv_head_dim
    H = D // N
    pm = p["mix"]
    h_prev = jnp.pad(h, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    xr, xk, xv, xw, xg = rwkv_mod._ddlerp(pm, h, h_prev)
    r = rwkv_mod._heads((xr @ pm["wr"]).astype(jnp.float32), H, N)
    k = rwkv_mod._heads((xk @ pm["wk"]).astype(jnp.float32), H, N)
    v = rwkv_mod._heads((xv @ pm["wv"]).astype(jnp.float32), H, N)
    g = jax.nn.silu(xg @ pm["wg"])
    log_w = rwkv_mod._heads(rwkv_mod._log_decay(pm, xw), H, N)
    y, state = _wkv_chunked_with_state(r, k, v, log_w, pm["u"])
    y = y.transpose(0, 2, 1, 3).reshape(B, S, D)
    y = rms_norm(y.astype(x.dtype), pm["ln_x"])
    x = x + (y * g) @ pm["wo"]
    tm_prev = h[:, -1]
    h2 = rms_norm(x, p["ln2"])
    x = x + rwkv_mod.channel_mix(pm, cfg, h2)
    cache = {"wkv": state, "tm_prev": tm_prev, "cm_prev": h2[:, -1]}
    return x, cache


def _wkv_chunked_with_state(r, k, v, log_w, u):
    """Same as rwkv6._wkv_chunked but also returns the terminal state."""
    B, H, S, N = r.shape
    n = min(rwkv_mod.CHUNK, S)
    nc = S // n
    rc, kc, vc, wc = (
        t.reshape(B, H, nc, n, N).transpose(2, 0, 1, 3, 4)
        for t in (r, k, v, log_w)
    )

    def chunk(state, inp):
        rr, kk, vv, lwst = inp
        lw = jnp.cumsum(lwst, axis=2)
        lw_prev = lw - lwst
        q_t = rr * jnp.exp(lw_prev)
        k_t = kk * jnp.exp(-lw)
        inter = jnp.einsum("bhin,bhnm->bhim", q_t, state)
        scores = jnp.einsum("bhin,bhjn->bhij", q_t, k_t)
        mask = jnp.tril(jnp.ones((n, n), bool), k=-1)
        scores = jnp.where(mask, scores, 0.0)
        diag = jnp.einsum("bhin,bhin->bhi", rr, u[None, :, None, :] * kk)
        y = (jnp.einsum("bhij,bhjm->bhim", scores, vv)
             + diag[..., None] * vv + inter)
        lw_n = lw[:, :, -1:, :]
        k_rem = kk * jnp.exp(lw_n - lw)
        new_state = (jnp.exp(lw_n[:, :, 0, :, None]) * state
                     + jnp.einsum("bhjn,bhjm->bhnm", k_rem, vv))
        return new_state, y

    state0 = jnp.zeros((B, H, N, N), jnp.float32)
    state, ys = jax.lax.scan(chunk, state0, (rc, kc, vc, wc))
    return ys.transpose(1, 2, 0, 3, 4).reshape(B, H, S, N), state


def prefill(cfg: ModelConfig, params, batch: Dict[str, Array],
            max_len: Optional[int] = None, cache_dtype=jnp.bfloat16
            ) -> Tuple[Array, PyTree]:
    """Process a prompt; return (last-position logits (B, V), decode cache)."""
    pattern, n_full, tail = block_layout(cfg)
    x = _embed_inputs(cfg, params, batch)
    x = constrain(x, "act_batch", "act_seq", "act_embed")
    B, S, _ = x.shape
    max_len = max_len or S
    positions = batch.get(
        "positions", jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    )

    cache: Dict[str, Any] = {"pos": jnp.int32(S)}
    if n_full:
        def body(x, blk):
            ncache = {}
            for i, kind in enumerate(pattern):
                x, c = _sublayer_prefill(blk[f"sub{i}"], cfg, kind, x,
                                         positions, max_len, cache_dtype)
                ncache[f"sub{i}"] = c
            return constrain(x, "act_batch", "act_seq", "act_embed"), ncache

        if cfg.scan_layers:
            x, cache["blocks"] = jax.lax.scan(body, x, params["blocks"])
        else:
            caches = []
            for i in range(n_full):
                blk = jax.tree.map(lambda t, i=i: t[i], params["blocks"])
                x, nc = body(x, blk)
                caches.append(nc)
            cache["blocks"] = jax.tree.map(
                lambda *ts: jnp.stack(ts), *caches)
    if tail:
        cache["tail"] = []
        for i, kind in enumerate(tail):
            x, c = _sublayer_prefill(params["tail"][i], cfg, kind, x,
                                     positions, max_len, cache_dtype)
            cache["tail"].append(c)
    h = rms_norm(x, params["final_ln"])
    logits = (h[:, -1].astype(jnp.float32)
              @ _unembed(cfg, params).astype(jnp.float32))
    return logits, cache
