"""Sequence-chunked cross-entropy: the (B, S, V) logits tensor is never
materialized (vocab up to 256k x 1M tokens would be ~1 TB); logits are
computed and reduced chunk-by-chunk under lax.scan."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def chunked_xent(
    h: Array,           # (B, S, D) final hidden states
    unemb: Array,       # (D, V)
    labels: Array,      # (B, S) int32
    mask: Array,        # (B, S) {0,1}
    chunk: int = 512,
    unroll: bool = False,  # analysis mode: while bodies count once
) -> tuple[Array, Array]:
    """Returns (sum_loss, sum_mask)."""
    B, S, D = h.shape
    c = min(chunk, S)
    assert S % c == 0, f"S={S} not divisible by loss chunk {c}"
    nc = S // c
    hs = h.reshape(B, nc, c, D).swapaxes(0, 1)
    ls = labels.reshape(B, nc, c).swapaxes(0, 1)
    ms = mask.reshape(B, nc, c).swapaxes(0, 1)

    # remat: without this, grad-of-scan saves every chunk's (B, c, V)
    # logits for the softmax backward -- 20 GiB/device at 256k vocab
    # (measured, see EXPERIMENTS.md §Perf); recomputing them per chunk in
    # the backward keeps only (lse, ll) per chunk.
    @jax.checkpoint
    def chunk_loss(hc, lc, mc):
        logits = hc.astype(jnp.float32) @ unemb.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - ll) * mc)

    def body(carry, inp):
        hc, lc, mc = inp
        return (carry[0] + chunk_loss(hc, lc, mc),
                carry[1] + jnp.sum(mc)), None

    (loss_sum, n), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.float32(0.0)), (hs, ls, ms),
        unroll=unroll,
    )
    return loss_sum, n
