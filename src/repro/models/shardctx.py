"""Activation-sharding constraints for the model code.

The model modules are mesh-agnostic; the launcher enters
``activation_sharding(mesh, rules)`` *inside* the traced step function, and
``constrain(x, *logical_axes)`` pins activation shardings at block
boundaries. Without these pins GSPMD is free to (and on this workload
does) replicate the batch dim and shard d_model instead, exploding per-chip
activation memory ~data_parallelism-fold (measured: qwen3 train_4k went
from 366 GiB/device to HBM scale after pinning -- see EXPERIMENTS.md §Perf).

Logical activation axes (resolved through launch.sharding.AxisRules with
the same divisibility guards as weights):
  act_batch -- global-batch dim    -> ("pod", "data")
  act_seq   -- sequence dim        -> None (sequence parallelism = hillclimb)
  act_embed -- d_model dim         -> None
  act_heads -- attention heads dim -> ("model",)
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CTX: contextvars.ContextVar = contextvars.ContextVar(
    "activation_sharding", default=None)


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, rules):
    token = _CTX.set((mesh, rules))
    try:
        yield
    finally:
        _CTX.reset(token)


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Pin x's sharding by logical axis names (None = unconstrained dim).
    No-op outside an activation_sharding context (pure-CPU tests)."""
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    from repro.launch.sharding import _fit  # local import: avoid cycle
    assert len(logical) == x.ndim, (logical, x.shape)
    used: set = set()
    spec = []
    for dim, name in zip(x.shape, logical, strict=True):
        if name is None:
            spec.append(None)
            continue
        axes = rules.get(name)
        if axes is None:
            spec.append(None)
            continue
        axes = tuple(a for a in axes if a in mesh.axis_names)
        got = _fit(dim, axes, mesh, used, None)
        spec.append(got)
        if got:
            used.update(got if isinstance(got, tuple) else (got,))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))
