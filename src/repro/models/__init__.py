from repro.models.transformer import (  # noqa: F401
    forward_train,
    init_cache,
    init_params,
    prefill,
    decode_step,
)
