"""RWKV-6 "Finch" (arXiv:2404.05892): attention-free token/channel mixing
with data-dependent decay.

Time mixing (per head, head_dim = N):
    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
with w_t = exp(-exp(w0 + lora_w(x~_t)))  (data-dependent decay), and
data-dependent token-shift interpolation (ddlerp) on the r/k/v/w/g inputs.

Training/prefill runs a *chunked* parallel form (O(S * n * N) intra-chunk +
O(S/n * N^2) state carries; sub-quadratic in S). Decode carries
(S, shift) state -- O(1) per token, enabling the 500k long-context cell.

Numerics: per-step log-decay is clamped to [-4, -1e-4] and chunks are kept
short (16) so every exp() stays inside the f32 range (see test_rwkv6).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init, rms_norm, split_keys

Array = jax.Array

CHUNK = 16
LORA_RANK = 64
MIX_LORA_RANK = 32
LOG_W_MIN, LOG_W_MAX = -4.0, -1e-4
_MIX_NAMES = ("r", "k", "v", "w", "g")


def init_rwkv_params(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    n_heads = d // cfg.rwkv_head_dim
    ks = split_keys(key, 12)
    return {
        # time mix
        "mu": 0.5 * jnp.ones((5, d), jnp.float32),
        "mix_lora_a": dense_init(ks[0], (d, 5 * MIX_LORA_RANK), jnp.float32),
        "mix_lora_b": dense_init(ks[1], (5, MIX_LORA_RANK, d), jnp.float32,
                                 scale=0.01),
        "wr": dense_init(ks[2], (d, d), dtype),
        "wk": dense_init(ks[3], (d, d), dtype),
        "wv": dense_init(ks[4], (d, d), dtype),
        "wg": dense_init(ks[5], (d, d), dtype),
        "wo": dense_init(ks[6], (d, d), dtype),
        "w0": jnp.linspace(-1.5, 1.5, d).astype(jnp.float32),
        "w_lora_a": dense_init(ks[7], (d, LORA_RANK), jnp.float32),
        "w_lora_b": dense_init(ks[8], (LORA_RANK, d), jnp.float32, scale=0.01),
        "u": 0.1 * jnp.ones((n_heads, cfg.rwkv_head_dim), jnp.float32),
        "ln_x": jnp.zeros((d,), jnp.float32),
        # channel mix
        "cm_mu_k": 0.5 * jnp.ones((d,), jnp.float32),
        "cm_mu_r": 0.5 * jnp.ones((d,), jnp.float32),
        "cm_wk": dense_init(ks[9], (d, cfg.d_ff), dtype),
        "cm_wv": dense_init(ks[10], (cfg.d_ff, d), dtype),
        "cm_wr": dense_init(ks[11], (d, d), dtype),
    }


def _ddlerp(p, x: Array, x_prev: Array):
    """Data-dependent token-shift: one mixed input per r/k/v/w/g stream."""
    dx = x_prev - x
    base = x + dx * p["mu"][:, None, None, :]  # (5, B, S, D) via broadcast
    lora = jnp.tanh((x + dx * 0.5) @ p["mix_lora_a"])  # (B, S, 5*R)
    B, S, _ = x.shape
    lora = lora.reshape(B, S, 5, MIX_LORA_RANK).transpose(2, 0, 1, 3)
    adj = jnp.einsum("nbsr,nrd->nbsd", lora, p["mix_lora_b"])
    return base + adj * dx  # (5, B, S, D)


def _log_decay(p, xw: Array) -> Array:
    """log w_t in [LOG_W_MIN, LOG_W_MAX]; xw: (B, S, D)."""
    lora = jnp.tanh(xw.astype(jnp.float32) @ p["w_lora_a"]) @ p["w_lora_b"]
    return jnp.clip(-jnp.exp(p["w0"] + lora), LOG_W_MIN, LOG_W_MAX)


def _wkv_chunked(r, k, v, log_w, u):
    """r/k/v/log_w: (B, H, S, N); u: (H, N). Returns (B, H, S, N)."""
    B, H, S, N = r.shape
    n = min(CHUNK, S)
    assert S % n == 0
    nc = S // n
    rc, kc, vc, wc = (
        t.reshape(B, H, nc, n, N).transpose(2, 0, 1, 3, 4)
        for t in (r, k, v, log_w)
    )

    def chunk(state, inp):
        rr, kk, vv, lwst = inp  # (B, H, n, N)
        lw = jnp.cumsum(lwst, axis=2)  # within-chunk cumulative log decay
        lw_prev = lw - lwst  # lw_{t-1} (zero at t=0)
        q_t = rr * jnp.exp(lw_prev)
        k_t = kk * jnp.exp(-lw)
        inter = jnp.einsum("bhin,bhnm->bhim", q_t, state)
        scores = jnp.einsum("bhin,bhjn->bhij", q_t, k_t)
        mask = jnp.tril(jnp.ones((n, n), bool), k=-1)
        scores = jnp.where(mask, scores, 0.0)
        diag = jnp.einsum("bhin,bhin->bhi", rr, u[None, :, None, :] * kk)
        y = (
            jnp.einsum("bhij,bhjm->bhim", scores, vv)
            + diag[..., None] * vv
            + inter
        )
        lw_n = lw[:, :, -1:, :]  # (B, H, 1, N)
        k_rem = kk * jnp.exp(lw_n - lw)
        new_state = (
            jnp.exp(lw_n[:, :, 0, :, None]) * state
            + jnp.einsum("bhjn,bhjm->bhnm", k_rem, vv)
        )
        return new_state, y

    state0 = jnp.zeros((B, H, N, N), jnp.float32)
    _, ys = jax.lax.scan(chunk, state0, (rc, kc, vc, wc))
    return ys.transpose(1, 2, 0, 3, 4).reshape(B, H, S, N)


def _heads(x: Array, H: int, N: int) -> Array:
    B, S, _ = x.shape
    return x.reshape(B, S, H, N).transpose(0, 2, 1, 3)


def time_mix(p, cfg: ModelConfig, x: Array) -> Array:
    """x: (B, S, D) -> (B, S, D), parallel (chunked) over time."""
    B, S, D = x.shape
    N = cfg.rwkv_head_dim
    H = D // N
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    xr, xk, xv, xw, xg = _ddlerp(p, x, x_prev)
    r = _heads((xr @ p["wr"]).astype(jnp.float32), H, N)
    k = _heads((xk @ p["wk"]).astype(jnp.float32), H, N)
    v = _heads((xv @ p["wv"]).astype(jnp.float32), H, N)
    g = jax.nn.silu(xg @ p["wg"])
    log_w = _heads(_log_decay(p, xw), H, N)
    y = _wkv_chunked(r, k, v, log_w, p["u"])  # (B, H, S, N)
    y = y.transpose(0, 2, 1, 3).reshape(B, S, D)
    y = rms_norm(y.astype(x.dtype), p["ln_x"])
    return (y * g) @ p["wo"]


def channel_mix(p, cfg: ModelConfig, x: Array) -> Array:
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    xk = x + (x_prev - x) * p["cm_mu_k"]
    xr = x + (x_prev - x) * p["cm_mu_r"]
    kk = jnp.square(jax.nn.relu(xk @ p["cm_wk"]))
    return jax.nn.sigmoid(xr @ p["cm_wr"]) * (kk @ p["cm_wv"])


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------
def init_rwkv_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    d = cfg.d_model
    N = cfg.rwkv_head_dim
    H = d // N
    return {
        "wkv": jnp.zeros((batch, H, N, N), jnp.float32),
        "tm_prev": jnp.zeros((batch, d), dtype),
        "cm_prev": jnp.zeros((batch, d), dtype),
    }


def time_mix_decode(p, cfg: ModelConfig, x: Array, cache: dict
                    ) -> Tuple[Array, dict]:
    """x: (B, 1, D); O(1) state update."""
    B, _, D = x.shape
    N = cfg.rwkv_head_dim
    H = D // N
    x_prev = cache["tm_prev"][:, None].astype(x.dtype)
    xr, xk, xv, xw, xg = _ddlerp(p, x, x_prev)
    r = _heads((xr @ p["wr"]).astype(jnp.float32), H, N)[:, :, 0]
    k = _heads((xk @ p["wk"]).astype(jnp.float32), H, N)[:, :, 0]
    v = _heads((xv @ p["wv"]).astype(jnp.float32), H, N)[:, :, 0]
    g = jax.nn.silu(xg @ p["wg"])
    w = jnp.exp(_heads(_log_decay(p, xw), H, N)[:, :, 0])  # (B, H, N)
    S = cache["wkv"]  # (B, H, N, N)
    kv = jnp.einsum("bhn,bhm->bhnm", k, v)
    y = jnp.einsum("bhn,bhnm->bhm", r, S + p["u"][None, :, :, None] * kv)
    S_new = w[..., None] * S + kv
    y = y.reshape(B, 1, D)
    y = rms_norm(y.astype(x.dtype), p["ln_x"])
    out = (y * g) @ p["wo"]
    return out, {**cache, "wkv": S_new, "tm_prev": x[:, 0]}


def channel_mix_decode(p, cfg: ModelConfig, x: Array, cache: dict
                       ) -> Tuple[Array, dict]:
    x_prev = cache["cm_prev"][:, None].astype(x.dtype)
    xk = x + (x_prev - x) * p["cm_mu_k"]
    xr = x + (x_prev - x) * p["cm_mu_r"]
    kk = jnp.square(jax.nn.relu(xk @ p["cm_wk"]))
    out = jax.nn.sigmoid(xr @ p["cm_wr"]) * (kk @ p["cm_wv"])
    return out, {**cache, "cm_prev": x[:, 0]}
