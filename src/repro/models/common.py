"""Shared model primitives: norms, rotary embeddings, init helpers."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


def rms_norm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dt)


def rope(x: Array, positions: Array, theta: float = 10_000.0) -> Array:
    """Rotary embedding. x: (..., S, H, hd); positions: (..., S) int."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    ang = ang[..., None, :]  # broadcast over heads: (..., S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    xr1 = x1 * cos - x2 * sin
    xr2 = x2 * cos + x1 * sin
    return jnp.concatenate([xr1, xr2], axis=-1).astype(x.dtype)


def dense_init(key: Array, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0] if len(shape) >= 2 else shape[-1]
    s = scale if scale is not None else fan_in**-0.5
    return (jax.random.normal(key, shape) * s).astype(dtype)


def split_keys(key: Array, n: int):
    return list(jax.random.split(key, n))


def cast_floats(tree, dtype):
    """Cast float leaves to `dtype` (mixed precision: f32 master weights are
    cast to the activation dtype at use; sensitive paths re-cast to f32
    internally)."""
    def c(t):
        if hasattr(t, "dtype") and jnp.issubdtype(t.dtype, jnp.floating):
            return t.astype(dtype)
        return t
    return jax.tree.map(c, tree)
