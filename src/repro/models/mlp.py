"""Feed-forward layers: SwiGLU / GELU MLPs and capacity-based top-k MoE.

The MoE uses scatter-based token dispatch (GShard-style, static capacity) so
the (tokens x experts) one-hot never feeds a matmul: tokens are scattered
into an (E, C, D) buffer, experts run as one batched einsum (expert-parallel
over the "model" mesh axis), and results gather back with combine weights.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init, split_keys

Array = jax.Array


# ---------------------------------------------------------------------------
# dense MLPs
# ---------------------------------------------------------------------------
def init_mlp_params(key, cfg: ModelConfig, dtype, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = split_keys(key, 3)
    if cfg.mlp_type == "swiglu":
        return {
            "w_gate": dense_init(ks[0], (d, f), dtype),
            "w_up": dense_init(ks[1], (d, f), dtype),
            "w_down": dense_init(ks[2], (f, d), dtype),
        }
    return {
        "w_up": dense_init(ks[0], (d, f), dtype),
        "w_down": dense_init(ks[1], (f, d), dtype),
    }


def mlp(p, cfg: ModelConfig, x: Array) -> Array:
    if cfg.mlp_type == "swiglu":
        return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    return jax.nn.gelu(x @ p["w_up"]) @ p["w_down"]


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------
def init_moe_params(key, cfg: ModelConfig, dtype):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = split_keys(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e), jnp.float32),
        "w_gate": dense_init(ks[1], (e, d, f), dtype),
        "w_up": dense_init(ks[2], (e, d, f), dtype),
        "w_down": dense_init(ks[3], (e, f, d), dtype),
    }
    if cfg.moe_dense_ff:
        p["dense"] = init_mlp_params(ks[4], cfg, dtype, d_ff=cfg.moe_dense_ff)
    return p


def moe(p, cfg: ModelConfig, x: Array) -> Tuple[Array, Array]:
    """x: (B, S, D) -> ((B, S, D), aux_loss). Top-k routing, static
    capacity, scatter dispatch; optional parallel dense residual branch
    (arctic). The load-balance aux loss shares this router pass (computing
    it separately doubled router+top_k work -- see EXPERIMENTS.md §Perf)."""
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    T = B * S
    xf = x.reshape(T, D)

    logits = (xf.astype(jnp.float32) @ p["router"])  # (T, E) in f32
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_idx = jax.lax.top_k(probs, K)  # (T, K)
    # Switch-style load-balance loss from the same routing decision
    frac = jnp.mean(jax.nn.one_hot(gate_idx, E, dtype=jnp.float32),
                    axis=(0, 1))
    aux = E * jnp.sum(frac * jnp.mean(probs, axis=0))
    gate_w = gate_w / (jnp.sum(gate_w, axis=-1, keepdims=True) + 1e-9)

    # flatten (token, k) assignments
    eids = gate_idx.reshape(T * K)
    C = max(int(cfg.capacity_factor * T * K / E), 1)

    onehot = jax.nn.one_hot(eids, E, dtype=jnp.int32)  # (T*K, E)
    pos_in_e = (jnp.cumsum(onehot, axis=0) * onehot).sum(-1) - 1  # (T*K,)
    keep = (pos_in_e < C) & (pos_in_e >= 0)
    slot = jnp.clip(pos_in_e, 0, C - 1)

    tok_rep = jnp.repeat(xf, K, axis=0)  # (T*K, D)
    buf = jnp.zeros((E, C, D), x.dtype)
    buf = buf.at[eids, slot].add(
        jnp.where(keep[:, None], tok_rep, 0.0).astype(x.dtype),
        mode="drop",
    )

    # batched expert FFN: (E, C, D) x (E, D, F)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) * jnp.einsum(
        "ecd,edf->ecf", buf, p["w_up"]
    )
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"])  # (E, C, D)

    # gather back and combine
    out_tok = out_buf[eids, slot] * keep[:, None].astype(x.dtype)  # (T*K, D)
    out = (out_tok.reshape(T, K, D) * gate_w[..., None].astype(x.dtype)).sum(1)

    if cfg.moe_dense_ff:
        out = out + mlp(p["dense"], cfg, xf)

    return out.reshape(B, S, D), aux


def ffn(p, cfg: ModelConfig, x: Array) -> Tuple[Array, Array]:
    """Returns (out, moe_aux_loss) -- aux is 0 for dense FFNs."""
    if cfg.is_moe:
        return moe(p, cfg, x)
    return mlp(p, cfg, x), jnp.float32(0.0)


def init_ffn_params(key, cfg: ModelConfig, dtype):
    return init_moe_params(key, cfg, dtype) if cfg.is_moe else init_mlp_params(
        key, cfg, dtype)
