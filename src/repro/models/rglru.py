"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Block:  x -> [in-proj -> causal conv1d(w=4) -> RG-LRU] * gelu(gate-proj)
          -> out-proj

RG-LRU:  r_t = sigmoid(x_t W_a);  i_t = sigmoid(x_t W_x)
         a_t = exp(-c * softplus(Lambda) * r_t)          (c = 8)
         h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill uses jax.lax.associative_scan over time (parallel,
sub-quadratic); decode carries (h, conv tail) state — O(1) per token, which
is what makes the 500k-token long-context cell feasible for this family.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init, split_keys

Array = jax.Array
_C = 8.0


def init_rglru_params(key, cfg: ModelConfig, dtype):
    d, w = cfg.d_model, cfg.lru_width
    ks = split_keys(key, 6)
    return {
        "w_in": dense_init(ks[0], (d, w), dtype),
        "w_gate": dense_init(ks[1], (d, w), dtype),
        "conv": dense_init(ks[2], (cfg.conv_width, w), dtype, scale=0.1),
        "w_a": dense_init(ks[3], (w, w), dtype),
        "w_x": dense_init(ks[4], (w, w), dtype),
        # Lambda parametrized so softplus(lam) spreads decays in (0.9, 0.999)
        "lam": jnp.linspace(-2.0, 2.0, w).astype(jnp.float32),
        "w_out": dense_init(ks[5], (w, d), dtype),
    }


def _gates(p, u: Array):
    """u: (..., W) conv output -> (a_t, b_t) of the recurrence."""
    r = jax.nn.sigmoid(u.astype(jnp.float32) @ p["w_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(u.astype(jnp.float32) @ p["w_x"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (
        i * u.astype(jnp.float32)
    )
    return a, b


def _scan_linear(a: Array, b: Array) -> Array:
    """h_t = a_t h_{t-1} + b_t along axis=1 (time), h_0 = 0."""

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_block(p, cfg: ModelConfig, x: Array) -> Array:
    """x: (B, S, D) -> (B, S, D), parallel over time."""
    u = x @ p["w_in"]  # (B, S, W)
    gate = jax.nn.gelu(x @ p["w_gate"])
    # causal conv1d, width cw
    cw = cfg.conv_width
    pad = jnp.pad(u, ((0, 0), (cw - 1, 0), (0, 0)))
    conv = sum(pad[:, i : i + u.shape[1]] * p["conv"][i] for i in range(cw))
    a, b = _gates(p, conv)
    h = _scan_linear(a, b).astype(x.dtype)
    return (h * gate) @ p["w_out"]


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    return {
        "h": jnp.zeros((batch, cfg.lru_width), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.lru_width), dtype),
    }


def rglru_decode(p, cfg: ModelConfig, x: Array, cache: dict
                 ) -> Tuple[Array, dict]:
    """x: (B, 1, D) -> (B, 1, D); O(1) state update."""
    u = (x @ p["w_in"])[:, 0]  # (B, W)
    gate = jax.nn.gelu(x @ p["w_gate"])[:, 0]
    hist = jnp.concatenate([cache["conv"], u[:, None]], axis=1)  # (B, cw, W)
    conv = jnp.einsum("bcw,cw->bw", hist, p["conv"])
    a, b = _gates(p, conv)
    h = a * cache["h"] + b
    out = ((h.astype(x.dtype) * gate) @ p["w_out"])[:, None]
    return out, {"h": h, "conv": hist[:, 1:]}
