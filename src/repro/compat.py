"""Version shims for jax API drift (the repo pins jax 0.4.37 but the code
is written against the modern surface).

* ``shard_map``: ``jax.shard_map`` only exists in newer jax; 0.4.37 ships it
  as ``jax.experimental.shard_map.shard_map`` with the replication check
  spelled ``check_rep`` instead of ``check_vma``.
* ``make_abstract_mesh`` lives in ``repro.launch.mesh`` (the AbstractMesh
  constructor signature changed across versions).
* ``on_tpu``: backend probe shared by every kernel call site that flips
  Pallas interpret mode.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` where available, else the 0.4.x experimental one.

    ``check_vma`` maps onto the old ``check_rep`` flag; both default to off
    because the tree programs psum over axis subsets (per-level averaging),
    which the replication checker cannot express."""
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=check_vma)
        except TypeError:
            # very new versions may rename/drop the flag; only swallow the
            # mismatch when the caller wasn't relying on the check
            if check_vma:
                raise
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)


def on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except RuntimeError:
        return False
