"""RG-LRU recurrence as a Pallas kernel (Griffin's hot loop, TPU-adapted).

The recurrence h_t = a_t h_{t-1} + b_t is sequential in t but elementwise
in the channel dim. GPU implementations lean on warp-level scans; the TPU
adaptation instead:

  * grid = (B, W/block_w): each program owns a (S, block_w) channel strip
    resident in VMEM (lane-dim block_w a multiple of 128 for full VREG
    occupancy),
  * walks t in *chunks of T_CHUNK rows*, keeping the running h in VREGs;
    within a chunk the first-order recurrence is evaluated by log2(T_CHUNK)
    rounds of the classic parallel-prefix combine
    (a, b) ∘ (a', b') = (a·a', a'·b + b') realized with jnp.roll/where on
    the (T_CHUNK, block_w) tile — VPU work, no HBM traffic,
  * one VMEM read of (a, b) and one write of h per element total: the
    kernel is HBM-bandwidth-bound at ~3 streams, the roofline floor for
    this op (the jnp associative_scan oracle materializes O(log S) full
    intermediates instead).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

T_CHUNK = 256


def _chunk_prefix(a: jax.Array, b: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """In-chunk inclusive prefix of the recurrence along axis 0 via
    log-depth combines. a, b: (T, w) -> (A, Bc) with
    h_t = A_t * h_{-1} + Bc_t."""
    T = a.shape[0]
    k = 1
    while k < T:
        a_sh = jnp.roll(a, k, axis=0)
        b_sh = jnp.roll(b, k, axis=0)
        row = jax.lax.broadcasted_iota(jnp.int32, a.shape, 0)
        valid = row >= k
        a_new = jnp.where(valid, a * a_sh, a)
        b_new = jnp.where(valid, a * b_sh + b, b)
        a, b = a_new, b_new
        k *= 2
    return a, b


def _rglru_kernel(a_ref, b_ref, h0_ref, h_ref, hlast_ref):
    S, w = a_ref.shape
    h = h0_ref[...]  # (w,) running state in VREGs

    n_chunks = S // T_CHUNK if S >= T_CHUNK else 1
    chunk = min(T_CHUNK, S)

    def body(c, h):
        a_c = jax.lax.dynamic_slice_in_dim(a_ref[...], c * chunk, chunk, 0)
        b_c = jax.lax.dynamic_slice_in_dim(b_ref[...], c * chunk, chunk, 0)
        A, Bc = _chunk_prefix(a_c.astype(jnp.float32),
                              b_c.astype(jnp.float32))
        h_chunk = A * h[None, :] + Bc  # (chunk, w)
        pl.store(h_ref, (pl.ds(c * chunk, chunk), slice(None)),
                 h_chunk.astype(h_ref.dtype))
        return h_chunk[-1]

    h = jax.lax.fori_loop(0, n_chunks, body, h)
    hlast_ref[...] = h.astype(hlast_ref.dtype)


def rglru_scan_kernel(a: jax.Array, b: jax.Array, h0: jax.Array,
                      block_w: int = 128, interpret: bool = True
                      ) -> Tuple[jax.Array, jax.Array]:
    """a, b: (B, S, W) f32; h0: (B, W). Returns (h (B,S,W), h_last (B,W))."""
    B, S, W = a.shape
    block_w = min(block_w, W)
    assert W % block_w == 0, (W, block_w)
    grid = (B, W // block_w)

    h, hlast = pl.pallas_call(
        _rglru_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, S, block_w), lambda i, j: (i, 0, j)),
            pl.BlockSpec((None, S, block_w), lambda i, j: (i, 0, j)),
            pl.BlockSpec((None, block_w), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((None, S, block_w), lambda i, j: (i, 0, j)),
            pl.BlockSpec((None, block_w), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, W), a.dtype),
            jax.ShapeDtypeStruct((B, W), a.dtype),
        ],
        interpret=interpret,
    )(a, b, h0)
    return h, hlast
