"""Pure-jnp oracle for the RG-LRU diagonal linear recurrence:
h_t = a_t * h_{t-1} + b_t (elementwise), h_0 given."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def rglru_scan_ref(a: jax.Array, b: jax.Array,
                   h0: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """a, b: (B, S, W); h0: (B, W). Returns (h (B, S, W), h_last (B, W))."""
    def step(h, ab):
        a_t, b_t = ab
        h = a_t * h + b_t
        return h, h

    aT = a.swapaxes(0, 1)  # (S, B, W)
    bT = b.swapaxes(0, 1)
    h_last, hs = jax.lax.scan(step, h0, (aT, bT))
    return hs.swapaxes(0, 1), h_last
