from repro.kernels.rglru.ops import rglru_scan

__all__ = ["rglru_scan"]
