"""jit'd wrapper for the RG-LRU scan kernel (interpret mode off-TPU)."""
from __future__ import annotations

import functools
from typing import Tuple

import jax

from repro.kernels.rglru.kernel import rglru_scan_kernel


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except RuntimeError:
        return False


@functools.partial(jax.jit, static_argnames=("block_w",))
def rglru_scan(a, b, h0, *, block_w: int = 128
               ) -> Tuple[jax.Array, jax.Array]:
    """Diagonal linear recurrence h_t = a_t h_{t-1} + b_t over (B, S, W).
    Returns (all states, final state)."""
    return rglru_scan_kernel(a, b, h0, block_w=block_w,
                             interpret=not _on_tpu())
