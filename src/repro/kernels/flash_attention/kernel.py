"""Blocked online-softmax attention (FlashAttention), TPU-adapted.

TPU mapping (vs. the CUDA original):
  * grid = (B, H, Sq/block_q): each program owns one MXU-aligned query
    block; K/V for that (batch, kv-head) live in VMEM for the program's
    lifetime (HBM->VMEM once, not once per query block pass as on SMEM-
    limited GPUs).
  * the k-loop is a lax.fori_loop over MXU-aligned (block_k x d) slices
    with *data-dependent trip bounds*: causal masking prunes blocks above
    the diagonal, sliding windows prune blocks below `window` -- the
    pruning is on loop bounds (skipped compute), not just masks.
  * online softmax state (m, l, acc) stays in VREGs (f32), one rescale per
    k block; GQA is an index_map trick (q-head h reads kv-head h*KV//H),
    never a materialized repeat.

VMEM budget per program: (2*Sk*d + 3*block_q*d) * bytes -- 32k context at
d=128/bf16 is ~16 MiB, inside v5e's ~128 MiB VMEM.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -2.0**30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, scale: float,
                 causal: bool, window: Optional[int], seq_offset: int):
    block_q, d = q_ref.shape
    Sk = k_ref.shape[0]
    qi = pl.program_id(2)
    q_start = qi * block_q + seq_offset  # absolute position of first query

    q = q_ref[...].astype(jnp.float32) * scale

    # trip bounds: causal prunes blocks past this q block's last row;
    # a window prunes blocks older than (first row - window).
    nk = Sk // block_k
    if causal:
        hi = jnp.minimum((q_start + block_q + block_k - 1) // block_k, nk)
    else:
        hi = nk
    if window is not None:
        lo = jnp.maximum((q_start - window + 1) // block_k, 0)
    else:
        lo = 0

    qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)

    def body(j, carry):
        m_i, l_i, acc = carry
        k_blk = jax.lax.dynamic_slice_in_dim(
            k_ref[...], j * block_k, block_k, axis=0).astype(jnp.float32)
        v_blk = jax.lax.dynamic_slice_in_dim(
            v_ref[...], j * block_k, block_k, axis=0).astype(jnp.float32)
        s = q @ k_blk.T  # (block_q, block_k) on the MXU
        kpos = j * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_k), 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_new = jnp.maximum(m_i, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_i - m_new)
        l_new = l_i * alpha + jnp.sum(p, axis=1)
        acc = acc * alpha[:, None] + p @ v_blk
        return m_new, l_new, acc

    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, d), jnp.float32)
    m_i, l_i, acc = jax.lax.fori_loop(lo, hi, body, (m0, l0, acc0))

    o_ref[...] = (acc / (l_i[:, None] + 1e-30)).astype(o_ref.dtype)


def flash_attention_kernel(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True, window: Optional[int] = None,
    scale: Optional[float] = None, block_q: int = 128, block_k: int = 128,
    seq_offset: int = 0, interpret: bool = True,
) -> jax.Array:
    """q: (B, Sq, H, d); k/v: (B, Sk, KV, d). Returns (B, Sq, H, d)."""
    B, Sq, H, D = q.shape
    _, Sk, KV, _ = k.shape
    assert H % KV == 0
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0
    s = scale if scale is not None else D**-0.5

    grid = (B, H, Sq // block_q)
    kernel = functools.partial(
        _attn_kernel, block_k=block_k, scale=s, causal=causal,
        window=window, seq_offset=seq_offset)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, None, D),
                         lambda b, h, i: (b, i, h, 0)),
            pl.BlockSpec((None, Sk, None, D),
                         lambda b, h, i, KV=KV, H=H: (b, 0, h * KV // H, 0)),
            pl.BlockSpec((None, Sk, None, D),
                         lambda b, h, i, KV=KV, H=H: (b, 0, h * KV // H, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, None, D),
                               lambda b, h, i: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v)
