"""Pure-jnp oracle for blocked flash attention: causal / sliding-window /
GQA, f32 softmax accumulation."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.0**30


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: Optional[int] = None,
                  scale: Optional[float] = None) -> jax.Array:
    """q: (B, Sq, H, d); k/v: (B, Sk, KV, d) with H % KV == 0.
    Returns (B, Sq, H, d). Query i attends keys j with j <= i (causal)
    and i - j < window (if windowed); q position offset assumes aligned
    suffixes (Sq == Sk for training)."""
    B, Sq, H, D = q.shape
    _, Sk, KV, _ = k.shape
    assert H % KV == 0
    rep = H // KV
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    s = scale if scale is not None else D**-0.5

    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * s
    qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    e = jnp.where(mask[None, None], e, 0.0)
    p = e / (jnp.sum(e, axis=-1, keepdims=True) + 1e-30)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
