"""Public jit'd wrapper for the flash-attention kernel.

On this CPU container the kernel executes in interpret mode (the Pallas
body runs as traced jnp on CPU); on TPU set interpret=False (the default
flips automatically when a TPU backend is present).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.flash_attention.kernel import flash_attention_kernel


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except RuntimeError:
        return False


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale",
                                             "block_q", "block_k",
                                             "seq_offset"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    scale: Optional[float] = None, block_q: int = 128,
                    block_k: int = 128, seq_offset: int = 0):
    """Blocked online-softmax attention; see kernel.py for the TPU layout.

    q: (B, Sq, H, d); k/v: (B, Sk, KV, d) with H % KV == 0.
    """
    return flash_attention_kernel(
        q, k, v, causal=causal, window=window, scale=scale,
        block_q=block_q, block_k=block_k, seq_offset=seq_offset,
        interpret=not _on_tpu())
