"""Pallas TPU kernels for the perf-critical compute layers.

  sdca             -- Procedure P (LocalSDCA) as a single VMEM-resident
                      kernel: H sequential closed-form coordinate steps with
                      zero HBM round-trips between steps (the paper's
                      compute hot spot, TPU-adapted).
  flash_attention  -- blocked online-softmax causal/GQA/windowed attention
                      (the LM stack's dominant non-matmul HBM term).
  rglru            -- the RG-LRU diagonal recurrence (Griffin) as a
                      chunked parallel-prefix kernel: one HBM read of
                      (a, b) + one write of h total (the associative_scan
                      oracle materializes O(log S) full intermediates).

Each kernel ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
public wrapper) and ref.py (pure-jnp oracle); tests sweep shapes/dtypes in
interpret mode against the oracle (this container has no TPU).
"""
