from repro.kernels.sdca.ops import sdca_block_solve

__all__ = ["sdca_block_solve"]
