"""Pure-jnp oracle for the blocked SDCA kernel: K workers, each running H
sequential closed-form coordinate maximizations over its own data block
(Procedure P / Algorithm 1's inner parallel loop)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.dual import Loss


def sdca_block_ref(
    X: jax.Array,       # (K, m_b, d) per-worker data blocks
    y: jax.Array,       # (K, m_b)
    alpha: jax.Array,   # (K, m_b) current dual blocks
    w: jax.Array,       # (d,) shared primal iterate, or (K, d) per-worker
    idx: jax.Array,     # (K, H) int32 coordinate choices
    *,
    loss: Loss,
    lm: float,          # lambda * m_total
    step_mask: jax.Array = None,  # optional (K, H) 0/1 per-step gating
) -> Tuple[jax.Array, jax.Array]:
    """Returns (delta_alpha (K, m_b), delta_w (K, d))."""
    K, m_b, d = X.shape
    H = idx.shape[1]
    xsq_over_lm = jnp.sum(X * X, axis=2) / lm  # (K, m_b)

    def worker(Xk, yk, ak, wk, idxk, mk, xsqk):
        def body(h, carry):
            a_c, w_c = carry
            i = idxk[h]
            x_i = Xk[i]
            wx = jnp.sum(w_c * x_i)  # same accumulation as the kernel's VPU dot
            dlt = loss.coord_delta(wx, a_c[i], yk[i], xsqk[i])
            if mk is not None:
                dlt = dlt * mk[h]
            return a_c.at[i].add(dlt), w_c + (dlt / lm) * x_i

        a_end, w_end = jax.lax.fori_loop(0, H, body, (ak, wk))
        return a_end - ak, w_end - wk

    da, dw = jax.vmap(
        worker,
        in_axes=(0, 0, 0,
                 0 if w.ndim == 2 else None,
                 0, 0 if step_mask is not None else None, 0),
    )(X, y, alpha, w, idx, step_mask, xsq_over_lm)
    return da, dw
