"""Pure-jnp oracle for the blocked SDCA kernel: K workers, each running H
sequential closed-form coordinate maximizations over its own data block
(Procedure P / Algorithm 1's inner parallel loop)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.dual import Loss


def sdca_block_ref(
    X: jax.Array,       # (K, m_b, d) per-worker data blocks
    y: jax.Array,       # (K, m_b)
    alpha: jax.Array,   # (K, m_b) current dual blocks
    w: jax.Array,       # (d,) shared primal iterate (w = A alpha)
    idx: jax.Array,     # (K, H) int32 coordinate choices
    *,
    loss: Loss,
    lm: float,          # lambda * m_total
) -> Tuple[jax.Array, jax.Array]:
    """Returns (delta_alpha (K, m_b), delta_w (K, d))."""
    K, m_b, d = X.shape
    H = idx.shape[1]
    xsq_over_lm = jnp.sum(X * X, axis=2) / lm  # (K, m_b)

    def worker(Xk, yk, ak, idxk, xsqk):
        def body(h, carry):
            a_c, w_c = carry
            i = idxk[h]
            x_i = Xk[i]
            wx = jnp.dot(w_c, x_i)
            dlt = loss.coord_delta(wx, a_c[i], yk[i], xsqk[i])
            return a_c.at[i].add(dlt), w_c + (dlt / lm) * x_i

        a_end, w_end = jax.lax.fori_loop(0, H, body, (ak, w))
        return a_end - ak, w_end - w

    da, dw = jax.vmap(worker)(X, y, alpha, idx, xsq_over_lm)
    return da, dw
