"""Blocked LocalSDCA as one Pallas kernel (the paper's compute hot spot).

Procedure P is a *sequential* scalar-update loop: pick coordinate i, dot
w.x_i, closed-form delta, rank-1 update of w. On an accelerator a naive
port round-trips HBM every step (one (d,) read + write per coordinate) and
is latency-bound. TPU adaptation:

  * grid = (K,): one program per worker block (Algorithm 1's "for all
    workers in parallel" IS the kernel grid).
  * the whole block X (m_b x d), labels/alpha/||x||^2 vectors and the
    private w copy are VMEM-resident for the kernel's lifetime; the H
    coordinate steps run inside one lax.fori_loop with VREG arithmetic and
    ZERO HBM traffic between steps.
  * the sequential-dependence math of the paper is preserved exactly
    (same iterates bit-for-bit vs. ref.py in f32): what changes is only
    WHERE the iterates live (VMEM/VREG vs HBM).
  * coordinate choices are passed in as an (K, H) int32 array (computed
    with the standard jax PRNG outside) so kernel and oracle see identical
    randomness.

VMEM per program: (m_b*d + 3*m_b + 2*d + H) * 4B; m_b=2048, d=512, H=4096
=> ~4.3 MiB, comfortably inside v5e VMEM.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.dual import Loss


def _sdca_kernel(X_ref, y_ref, a_ref, w_ref, xsq_ref, idx_ref,
                 da_ref, dw_ref, *, lm: float, loss: Loss, H: int):
    X = X_ref[...]          # (m_b, d) resident
    y = y_ref[...]
    a0 = a_ref[...]
    w0 = w_ref[...]         # (d,) shared input iterate
    xsq = xsq_ref[...]      # ||x_i||^2 / (lam m)
    idx = idx_ref[...]      # (H,)

    def body(h, carry):
        a_c, w_c = carry
        i = idx[h]
        x_i = jax.lax.dynamic_slice_in_dim(X, i, 1, axis=0)[0]  # (d,)
        a_i = jax.lax.dynamic_slice_in_dim(a_c, i, 1, axis=0)[0]
        y_i = jax.lax.dynamic_slice_in_dim(y, i, 1, axis=0)[0]
        x2_i = jax.lax.dynamic_slice_in_dim(xsq, i, 1, axis=0)[0]
        wx = jnp.sum(w_c * x_i)                                # VPU dot
        dlt = loss.coord_delta(wx, a_i, y_i, x2_i)
        a_c = jax.lax.dynamic_update_slice_in_dim(
            a_c, (a_i + dlt)[None], i, axis=0)
        w_c = w_c + (dlt / lm) * x_i                           # rank-1, VREG
        return a_c, w_c

    a_end, w_end = jax.lax.fori_loop(0, H, body, (a0, w0))
    da_ref[...] = a_end - a0
    dw_ref[...] = w_end - w0


def sdca_block_kernel(
    X: jax.Array,      # (K, m_b, d)
    y: jax.Array,      # (K, m_b)
    alpha: jax.Array,  # (K, m_b)
    w: jax.Array,      # (d,)
    idx: jax.Array,    # (K, H)
    *,
    loss: Loss,
    lm: float,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (delta_alpha (K, m_b), delta_w (K, d))."""
    K, m_b, d = X.shape
    H = idx.shape[1]
    xsq = jnp.sum(X * X, axis=2) / lm

    kernel = functools.partial(_sdca_kernel, lm=lm, loss=loss, H=H)
    da, dw = pl.pallas_call(
        kernel,
        grid=(K,),
        in_specs=[
            pl.BlockSpec((None, m_b, d), lambda k: (k, 0, 0)),
            pl.BlockSpec((None, m_b), lambda k: (k, 0)),
            pl.BlockSpec((None, m_b), lambda k: (k, 0)),
            pl.BlockSpec((d,), lambda k: (0,)),       # shared w
            pl.BlockSpec((None, m_b), lambda k: (k, 0)),
            pl.BlockSpec((None, H), lambda k: (k, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, m_b), lambda k: (k, 0)),
            pl.BlockSpec((None, d), lambda k: (k, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((K, m_b), X.dtype),
            jax.ShapeDtypeStruct((K, d), X.dtype),
        ],
        interpret=interpret,
    )(X, y, alpha, w, xsq, idx)
    return da, dw
