"""Blocked LocalSDCA as one Pallas kernel (the paper's compute hot spot).

Procedure P is a *sequential* scalar-update loop: pick coordinate i, dot
w.x_i, closed-form delta, rank-1 update of w. On an accelerator a naive
port round-trips HBM every step (one (d,) read + write per coordinate) and
is latency-bound. TPU adaptation:

  * grid = (K,): one program per worker block (Algorithm 1's "for all
    workers in parallel" IS the kernel grid).
  * the whole block X (m_b x d), labels/alpha/||x||^2 vectors and the
    private w copy are VMEM-resident for the kernel's lifetime; the H
    coordinate steps run inside one lax.fori_loop with VREG arithmetic and
    ZERO HBM traffic between steps.
  * the sequential-dependence math of the paper is preserved exactly
    (same iterates bit-for-bit vs. ref.py in f32): what changes is only
    WHERE the iterates live (VMEM/VREG vs HBM).
  * coordinate choices are passed in as an (K, H) int32 array (computed
    with the standard jax PRNG outside) so kernel and oracle see identical
    randomness.

VMEM per program: (m_b*d + 3*m_b + 2*d + H) * 4B; m_b=2048, d=512, H=4096
=> ~4.3 MiB, comfortably inside v5e VMEM.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.dual import Loss


def _sdca_steps(X, y, a0, w0, xsq, idx, mask, *, lm, loss: Loss,
                H: int):
    """The H sequential coordinate maximizations (VMEM/VREG resident)."""
    def body(h, carry):
        a_c, w_c = carry
        i = idx[h]
        x_i = jax.lax.dynamic_slice_in_dim(X, i, 1, axis=0)[0]  # (d,)
        a_i = jax.lax.dynamic_slice_in_dim(a_c, i, 1, axis=0)[0]
        y_i = jax.lax.dynamic_slice_in_dim(y, i, 1, axis=0)[0]
        x2_i = jax.lax.dynamic_slice_in_dim(xsq, i, 1, axis=0)[0]
        wx = jnp.sum(w_c * x_i)                                # VPU dot
        dlt = loss.coord_delta(wx, a_i, y_i, x2_i)
        if mask is not None:  # engine schedules: idle ticks / padded steps
            dlt = dlt * jax.lax.dynamic_slice_in_dim(mask, h, 1, axis=0)[0]
        a_c = jax.lax.dynamic_update_slice_in_dim(
            a_c, (a_i + dlt)[None], i, axis=0)
        w_c = w_c + (dlt / lm) * x_i                           # rank-1, VREG
        return a_c, w_c

    return jax.lax.fori_loop(0, H, body, (a0, w0))


def _sdca_kernel(X_ref, y_ref, a_ref, w_ref, xsq_ref, idx_ref, lm_ref,
                 da_ref, dw_ref, *, loss: Loss, H: int):
    a_end, w_end = _sdca_steps(
        X_ref[...], y_ref[...], a_ref[...], w_ref[...], xsq_ref[...],
        idx_ref[...], None, lm=lm_ref[0], loss=loss, H=H)
    da_ref[...] = a_end - a_ref[...]
    dw_ref[...] = w_end - w_ref[...]


def _sdca_kernel_masked(X_ref, y_ref, a_ref, w_ref, xsq_ref, idx_ref,
                        lm_ref, mask_ref, da_ref, dw_ref, *, loss: Loss,
                        H: int):
    a_end, w_end = _sdca_steps(
        X_ref[...], y_ref[...], a_ref[...], w_ref[...], xsq_ref[...],
        idx_ref[...], mask_ref[...], lm=lm_ref[0], loss=loss, H=H)
    da_ref[...] = a_end - a_ref[...]
    dw_ref[...] = w_end - w_ref[...]


def sdca_block_kernel(
    X: jax.Array,      # (K, m_b, d)
    y: jax.Array,      # (K, m_b)
    alpha: jax.Array,  # (K, m_b)
    w: jax.Array,      # (d,) shared, or (K, d) per-block (engine schedules)
    idx: jax.Array,    # (K, H)
    *,
    loss: Loss,
    lm,
    step_mask: jax.Array = None,  # optional (K, H) 0/1 per-step gating
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (delta_alpha (K, m_b), delta_w (K, d)).

    ``w`` may be the classic shared (d,) iterate (every program reads the
    same block) or a per-worker (K, d) batch -- the unified engine gives
    each leaf its own w replica between syncs.  ``step_mask`` zeroes the
    coordinate delta of masked steps, which is how the engine runs leaves
    with heterogeneous H (padded to H_max) and idle ticks inside one grid.
    ``lm`` (lambda * m_total) may be a Python float or a TRACED scalar --
    it enters the kernel as a (1,) operand, so one compiled kernel serves
    a whole regularization grid.
    """
    K, m_b, d = X.shape
    H = idx.shape[1]
    xsq = jnp.sum(X * X, axis=2) / lm
    lm_arr = jnp.broadcast_to(jnp.asarray(lm, X.dtype), (1,))

    if w.ndim == 2:
        w_spec = pl.BlockSpec((None, d), lambda k: (k, 0))
    else:
        w_spec = pl.BlockSpec((d,), lambda k: (0,))           # shared w
    in_specs = [
        pl.BlockSpec((None, m_b, d), lambda k: (k, 0, 0)),
        pl.BlockSpec((None, m_b), lambda k: (k, 0)),
        pl.BlockSpec((None, m_b), lambda k: (k, 0)),
        w_spec,
        pl.BlockSpec((None, m_b), lambda k: (k, 0)),
        pl.BlockSpec((None, H), lambda k: (k, 0)),
        pl.BlockSpec((1,), lambda k: (0,)),                   # lm scalar
    ]
    operands = [X, y, alpha, w, xsq, idx, lm_arr]
    if step_mask is not None:
        kernel = functools.partial(_sdca_kernel_masked, loss=loss, H=H)
        in_specs.append(pl.BlockSpec((None, H), lambda k: (k, 0)))
        operands.append(step_mask)
    else:
        kernel = functools.partial(_sdca_kernel, loss=loss, H=H)

    da, dw = pl.pallas_call(
        kernel,
        grid=(K,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((None, m_b), lambda k: (k, 0)),
            pl.BlockSpec((None, d), lambda k: (k, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((K, m_b), X.dtype),
            jax.ShapeDtypeStruct((K, d), X.dtype),
        ],
        interpret=interpret,
    )(*operands)
    return da, dw
