"""Public jit'd wrapper for the blocked-SDCA kernel: one outer CoCoA round
(all K workers' LocalSDCA in a single kernel launch + the 1/K averaging)."""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.compat import on_tpu as _on_tpu
from repro.core.dual import Loss
from repro.kernels.sdca.kernel import sdca_block_kernel


@functools.partial(jax.jit, static_argnames=("loss", "num_steps", "m_total",
                                             "lam"))
def sdca_block_solve(
    X: jax.Array,        # (K, m_b, d) worker data blocks
    y: jax.Array,        # (K, m_b)
    alpha: jax.Array,    # (K, m_b)
    w: jax.Array,        # (d,)
    key: jax.Array,
    *,
    loss: Loss,
    lam: float,
    m_total: int,
    num_steps: int,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One synchronous outer round: every worker runs H = num_steps local
    coordinate steps from the shared w; returns the 1/K-averaged updates
    (new_alpha (K, m_b), new_w (d,), delta_w_per_worker (K, d))."""
    K, m_b, _ = X.shape
    lm = lam * m_total
    idx = jax.random.randint(key, (K, num_steps), 0, m_b)
    da, dw = sdca_block_kernel(X, y, alpha, w, idx, loss=loss, lm=lm,
                               interpret=not _on_tpu())
    new_alpha = alpha + da / K
    new_w = w + jnp.sum(dw, axis=0) / K
    return new_alpha, new_w, dw
