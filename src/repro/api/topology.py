"""The :class:`Topology` object: a serializable tree-network spec.

Wraps the engine's :class:`~repro.core.tree.TreeNode` with

  * builders for the paper's network families (star, balanced multi-level,
    two-level, imbalanced/heterogeneous groups),
  * a stable dict/JSON wire format (``to_dict``/``from_dict``/``to_json``/
    ``from_json`` round-trip any tree), and
  * the sync-level view (:meth:`sync_levels`) that feeds the eq.-(12)
    delay planner when a :class:`~repro.api.schedule.Schedule` uses
    ``rounds="auto"``.

Round counts stored on the tree are *defaults*; a Schedule may override
them without touching the Topology.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence

from repro.core import tree as tree_mod
from repro.core.delay import FixedLevel
from repro.core.tree import TreeNode


def _node_to_dict(node: TreeNode) -> dict:
    d = {
        "name": node.name,
        "rounds": node.rounds,
        "up_delay": node.up_delay,
        "t_cp": node.t_cp,
        "t_lp": node.t_lp,
        "data_size": node.data_size,
    }
    if node.up_compress:
        d["up_compress"] = node.up_compress
    if node.children:
        d["children"] = [_node_to_dict(c) for c in node.children]
    return d


def _node_from_dict(d: dict) -> TreeNode:
    return TreeNode(
        name=d["name"],
        children=tuple(_node_from_dict(c) for c in d.get("children", ())),
        rounds=int(d.get("rounds", 1)),
        up_delay=float(d.get("up_delay", 0.0)),
        t_cp=float(d.get("t_cp", 0.0)),
        t_lp=float(d.get("t_lp", 0.0)),
        data_size=int(d.get("data_size", 0)),
        up_compress=str(d.get("up_compress", "")),
    )


@dataclasses.dataclass(frozen=True)
class Topology:
    """A tree network.  The root is always an internal node."""
    tree: TreeNode

    def __post_init__(self):
        if self.tree.is_leaf:
            raise ValueError("a Topology's root must be an internal node")
        names = [l.name for l in self.tree.leaves()]
        if len(set(names)) != len(names):
            raise ValueError(f"leaf names must be unique, got {names}")

    # ---- structure queries ---------------------------------------------
    @property
    def n_leaves(self) -> int:
        return len(self.tree.leaves())

    @property
    def m_total(self) -> int:
        return self.tree.total_data()

    @property
    def depth(self) -> int:
        """Number of internal depths (star = 1, two-level = 2, ...)."""
        return self.tree.depth()

    def leaf_sizes(self) -> List[int]:
        return [l.data_size for l in self.tree.leaves()]

    def sync_levels(self) -> List[FixedLevel]:
        """The per-depth sync structure, innermost first (the order
        ``repro.core.delay.plan_hierarchical_h`` consumes).

        Requires structural level-homogeneity: one fan-out per internal
        depth and all leaves at the same depth with equal ``data_size`` and
        ``t_lp``.  The level delay is the slowest child up-link at that
        depth (the synchronous barrier waits for it)."""
        by_depth: Dict[int, set] = {}
        delays: Dict[int, float] = {}
        leaf_info = set()
        leaf_depths = set()

        def visit(node: TreeNode, depth: int):
            if node.is_leaf:
                leaf_depths.add(depth)
                leaf_info.add((node.data_size, node.t_lp))
                return
            by_depth.setdefault(depth, set()).add(len(node.children))
            for c in node.children:
                delays[depth] = max(delays.get(depth, 0.0), c.up_delay)
                visit(c, depth + 1)
        visit(self.tree, 0)

        D = max(by_depth) + 1
        if leaf_depths != {D}:
            raise ValueError(
                "sync_levels needs all leaves at one depth; got leaves at "
                f"depths {sorted(leaf_depths)} with internal depths 0..{D-1}")
        if len(leaf_info) != 1:
            raise ValueError(
                f"sync_levels needs congruent leaves, got {sorted(leaf_info)}")
        bad = {d: ks for d, ks in by_depth.items() if len(ks) != 1}
        if bad:
            raise ValueError(f"sync_levels needs one fan-out per depth: {bad}")
        return [
            FixedLevel(name=f"depth{d}", group_size=next(iter(by_depth[d])),
                       delay_s=delays[d])
            for d in range(D - 1, -1, -1)
        ]

    def leaf_sync_delays(self) -> List[float]:
        """Per-leaf nominal sync-path delay (seconds), leaf order: the sum
        of ``up_delay`` along the leaf's path to the root -- what one root
        round's barrier pays to hear from that leaf.  The base delays that
        ``Session.run(straggler=...)`` hands the
        :class:`~repro.core.delay.StragglerModel` sampler."""
        out: List[float] = []

        def visit(node: TreeNode, acc: float):
            acc += node.up_delay
            if node.is_leaf:
                out.append(acc)
                return
            for c in node.children:
                visit(c, acc)
        visit(self.tree, -self.tree.up_delay)  # the root has no up-link
        return out

    def leaf_t_lp(self) -> float:
        """The (homogeneous) per-coordinate-step cost at the leaves."""
        vals = {l.t_lp for l in self.tree.leaves()}
        if len(vals) != 1:
            raise ValueError(f"heterogeneous leaf t_lp: {sorted(vals)}")
        return vals.pop()

    def internal_t_cp(self) -> float:
        """The per-aggregation compute cost carried by the internal nodes
        (the slowest one: the barrier waits for it)."""
        def visit(node: TreeNode) -> float:
            if node.is_leaf:
                return 0.0
            return max([node.t_cp] + [visit(c) for c in node.children])
        return visit(self.tree)

    def with_compression(
        self, spec, *, names: Optional[Sequence[str]] = None,
        min_up_delay: Optional[float] = None,
    ) -> "Topology":
        """A copy with ``up_compress=spec`` stamped on matching up-links.

        With no filter every non-root edge gets the spec; ``names``
        restricts it to those nodes' up-links, ``min_up_delay`` to edges at
        least that slow -- the topological way to say "compress the
        cross-pod hops, leave the fast intra-pod links exact".  Filters
        compose (both must match).  Pass ``spec=""`` to clear overrides.
        """
        if spec:
            from repro.core import compression as comp_mod
            comp_mod.parse_spec(spec)  # fail fast on typos
        sel = set(names) if names is not None else None

        def visit(node: TreeNode, is_root: bool) -> TreeNode:
            kids = tuple(visit(c, False) for c in node.children)
            node = dataclasses.replace(node, children=kids)
            if is_root:
                return node
            if sel is not None and node.name not in sel:
                return node
            if min_up_delay is not None and node.up_delay < min_up_delay:
                return node
            return dataclasses.replace(node, up_compress=str(spec))
        return Topology(tree=visit(self.tree, True))

    # ---- membership editing (elastic sessions) -------------------------
    def leaf_names(self) -> List[str]:
        return [l.name for l in self.tree.leaves()]

    def leaf_span(self, name: str) -> "tuple[int, int]":
        """``(offset, size)`` of leaf ``name``'s block in the flat dual
        vector (leaves in tree order) -- where membership events splice
        alpha and the stacked (X, y) rows."""
        off = 0
        for l in self.tree.leaves():
            if l.name == name:
                return off, l.data_size
            off += l.data_size
        raise KeyError(f"no leaf named {name!r}")

    def without_leaf(self, name: str) -> "Topology":
        """A copy with leaf ``name`` permanently removed (the *leave* half
        of a membership event).  Internal nodes left childless are pruned
        with it; removing the last leaf is an error."""
        found = [False]

        def visit(node: TreeNode) -> Optional[TreeNode]:
            if node.is_leaf:
                if node.name == name:
                    found[0] = True
                    return None
                return node
            kids = tuple(k for k in (visit(c) for c in node.children)
                         if k is not None)
            if not kids:
                return None
            return dataclasses.replace(node, children=kids)

        new_root = visit(self.tree)
        if not found[0]:
            raise KeyError(f"no leaf named {name!r}")
        if new_root is None or new_root.is_leaf:
            raise ValueError(
                f"removing {name!r} leaves no usable tree (the root must "
                "keep at least one leaf under an internal node)")
        return Topology(tree=new_root)

    def with_leaf(
        self, name: str, *, parent: Optional[str] = None,
        data_size: int, local_steps: Optional[int] = None,
        up_delay: float = 0.0, t_lp: Optional[float] = None,
    ) -> "Topology":
        """A copy with a new leaf appended under internal node ``parent``
        (default: the root) -- the *join* half of a membership event.
        ``local_steps`` / ``t_lp`` default to the values shared by the
        existing leaves (their max / first, respectively)."""
        if name in self.leaf_names():
            raise ValueError(f"leaf name {name!r} already exists")
        leaves = self.tree.leaves()
        if local_steps is None:
            local_steps = max(l.rounds for l in leaves)
        if t_lp is None:
            t_lp = leaves[0].t_lp
        target = parent if parent is not None else self.tree.name
        hit = [0]

        def visit(node: TreeNode) -> TreeNode:
            if node.is_leaf:
                return node
            kids = tuple(visit(c) for c in node.children)
            if node.name == target:
                hit[0] += 1
                kids = kids + (TreeNode(
                    name=name, rounds=int(local_steps),
                    data_size=int(data_size), up_delay=float(up_delay),
                    t_lp=float(t_lp)),)
            return dataclasses.replace(node, children=kids)

        new_root = visit(self.tree)
        if hit[0] != 1:
            raise KeyError(
                f"parent {target!r} matched {hit[0]} internal nodes; "
                "need exactly one")
        return Topology(tree=new_root)

    # ---- serialization -------------------------------------------------
    def to_dict(self) -> dict:
        return _node_to_dict(self.tree)

    @classmethod
    def from_dict(cls, d: dict) -> "Topology":
        return cls(tree=_node_from_dict(d))

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_json(cls, s: str) -> "Topology":
        return cls.from_dict(json.loads(s))

    # ---- builders ------------------------------------------------------
    @classmethod
    def from_tree(cls, tree: TreeNode) -> "Topology":
        return cls(tree=tree)

    @classmethod
    def star(
        cls, n_workers: int, m_per_worker: int, *,
        rounds: int = 10, local_steps: int = 64,
        t_lp: float = 0.0, t_cp: float = 0.0, t_delay: float = 0.0,
    ) -> "Topology":
        """The CoCoA star network (paper Fig. 1 / Algorithm 1)."""
        return cls(tree=tree_mod.star(
            n_workers, m_per_worker, outer_rounds=rounds,
            local_steps=local_steps, t_lp=t_lp, t_cp=t_cp, t_delay=t_delay))

    @classmethod
    def two_level(
        cls, n_groups: int, workers_per_group: int, m_per_worker: int, *,
        root_rounds: int = 10, group_rounds: int = 2, local_steps: int = 64,
        t_lp: float = 0.0, t_cp: float = 0.0,
        root_delay: float = 0.0, group_delay: float = 0.0,
    ) -> "Topology":
        """Paper Fig. 2: root -> sub-centers -> workers."""
        return cls(tree=tree_mod.two_level(
            n_groups, workers_per_group, m_per_worker,
            root_rounds=root_rounds, group_rounds=group_rounds,
            local_steps=local_steps, t_lp=t_lp, t_cp=t_cp,
            root_delay=root_delay, group_delay=group_delay))

    @classmethod
    def balanced(
        cls, branching: Sequence[int], *, m_leaf: int,
        local_steps: int = 64, level_rounds: Optional[Sequence[int]] = None,
        level_delays: Optional[Sequence[float]] = None,
        t_lp: float = 0.0, t_cp: float = 0.0,
    ) -> "Topology":
        """A level-homogeneous tree, top-down: ``branching[i]`` children per
        node at internal depth ``i``.  ``level_rounds[i]`` are the depth-i
        round defaults (all 1 if omitted); ``level_delays[i]`` is the
        up-link delay of the children *under* depth ``i`` (0 if omitted)."""
        L = len(branching)
        rounds = list(level_rounds) if level_rounds is not None else [1] * L
        delays = list(level_delays) if level_delays is not None else [0.0] * L
        assert len(rounds) == L and len(delays) == L, (branching, rounds,
                                                       delays)

        def build(d: int, path: tuple, up: float) -> TreeNode:
            tag = "-".join(str(p) for p in path)
            if d == L:
                return TreeNode(name=f"L{tag}", rounds=local_steps,
                                data_size=m_leaf, t_lp=t_lp, up_delay=up)
            kids = tuple(build(d + 1, path + (k,), delays[d])
                         for k in range(branching[d]))
            name = "root" if d == 0 else f"N{tag}"
            return TreeNode(name=name, children=kids, rounds=rounds[d],
                            t_cp=t_cp, up_delay=up)
        return cls(tree=build(0, (), 0.0))

    @classmethod
    def from_mesh(
        cls, mesh, *, sync_axes: Sequence[str] = ("data", "pod"),
        periods: Optional[Sequence[int]] = None,
        level_delays: Optional[Sequence[float]] = None,
        t_lp: float = 0.0, t_cp: float = 0.0, m_leaf: int = 1,
    ) -> "Topology":
        """The LM-training tree of a device mesh: one leaf per replica,
        one internal level per sync axis.

        ``sync_axes`` are bottom-up (fastest link first), as in
        ``TreeSyncConfig``; axes missing from the mesh or of size 1 are
        dropped.  ``periods[i]`` (bottom-up, default all 1) is the number
        of level-i rounds per level-(i+1) sync -- the leaves' local-H and
        the internal rounds of the tree, exactly what
        ``Schedule(rounds="auto")`` re-plans from ``level_delays[i]``,
        the delay of the link *crossing* axis ``i``.  The root's rounds
        stay 1: the run length is the Schedule's business.

        ``m_leaf`` is a nominal per-leaf data size (LM training has no
        (m, d) design matrix; it only feeds the delay model's bandwidth
        terms)."""
        from repro.launch.mesh import axis_size

        axes = tuple(a for a in sync_axes
                     if a in mesh.axis_names and axis_size(mesh, a) > 1)
        sizes = [axis_size(mesh, a) for a in axes]       # bottom-up
        L = len(axes)
        if L == 0:
            # single replica: a one-leaf star so the plan/delay machinery
            # still has a (trivial) tree; keep the first link delay so
            # eq.-(12) replanning stays meaningful on one device
            return cls.balanced(
                [1], m_leaf=m_leaf,
                local_steps=(list(periods) or [1])[0] if periods else 1,
                level_delays=[level_delays[0]] if level_delays else None,
                t_lp=t_lp, t_cp=t_cp)
        ps = list(periods) if periods is not None else [1] * L
        if len(ps) != L:
            raise ValueError(
                f"{len(ps)} periods for {L} present sync axes {axes}")
        ds = list(level_delays) if level_delays is not None else [0.0] * L
        if len(ds) != L:
            raise ValueError(
                f"{len(ds)} level_delays for {L} present sync axes {axes}")
        branching = list(reversed(sizes))                # top-down
        # top-down rounds: root runs 1 (chunked by the Session), depth d
        # runs periods[L-d]; leaves run periods[0] local steps
        rounds = [1] + [ps[L - d] for d in range(1, L)]
        return cls.balanced(branching, m_leaf=m_leaf, local_steps=ps[0],
                            level_rounds=rounds,
                            level_delays=list(reversed(ds)),
                            t_lp=t_lp, t_cp=t_cp)

    @classmethod
    def groups(
        cls, group_sizes: Sequence[Sequence[int]], *,
        root_rounds: int = 10, group_rounds: int = 2, local_steps: int = 64,
        t_lp: float = 0.0, t_cp: float = 0.0,
        root_delay: float = 0.0, group_delay: float = 0.0,
    ) -> "Topology":
        """An imbalanced/heterogeneous two-level tree: one sub-center per
        entry of ``group_sizes``, whose leaves own the listed (possibly
        unequal) data-block sizes; singleton groups may be passed as bare
        ints, attaching that leaf directly to the root (mixed depth)."""
        children = []
        for g, sizes in enumerate(group_sizes):
            if isinstance(sizes, int):
                children.append(TreeNode(
                    name=f"W{g}", rounds=local_steps, data_size=sizes,
                    t_lp=t_lp, up_delay=root_delay))
                continue
            ws = tuple(
                TreeNode(name=f"W{g}-{j}", rounds=local_steps, data_size=sz,
                         t_lp=t_lp, up_delay=group_delay)
                for j, sz in enumerate(sizes))
            children.append(TreeNode(
                name=f"S{g}", children=ws, rounds=group_rounds,
                up_delay=root_delay, t_cp=t_cp))
        return cls(tree=TreeNode(name="root", children=tuple(children),
                                 rounds=root_rounds, t_cp=t_cp))
