"""The :class:`Schedule` object: *how many* rounds at every tree level.

The paper's central knob is the local/global iteration trade-off (eq.
(9)-(12)): more local steps H amortize a slow link but dilute each
aggregation.  A Schedule either pins the knob explicitly (``rounds``,
``level_rounds``, ``local_steps``) or delegates it to the paper's eq.-(12)
planner with ``rounds="auto"``: at compile time
``repro.core.delay.plan_hierarchical_h`` is run over the topology's
link-delay structure (:meth:`Topology.sync_levels`) and picks the
per-level H, with the root round count set by the :class:`DelayModel`'s
simulated-time budget.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Union

from repro.core.delay import plan_hierarchical_h
from repro.core.tree import TreeNode

from repro.api.topology import Topology


@dataclasses.dataclass(frozen=True)
class DelayModel:
    """Parameters of the paper's SS6 delay-aware bound (eq. (11)-(12)).

    ``t_total`` is the simulated wall-clock budget the auto-planner
    optimizes for; ``delta`` defaults to 1/m_leaf (one coordinate's share of
    a leaf block); ``t_cp`` defaults to the topology's own per-aggregation
    cost (``Topology.internal_t_cp``); ``h_max`` caps the per-level H
    search.

    ``C="auto"`` calibrates the improvement constant from a short pilot
    run instead of taking it as given: ``Session.compile`` runs
    ``pilot_rounds`` root rounds under the topology's default schedule on
    the host backend, fits C from the observed per-round gap contractions
    (:func:`repro.core.delay.fit_C`), and plans with the fitted value
    (inspectable as ``session.fitted_C``)."""
    t_total: float
    C: Union[float, str] = 0.5
    delta: Optional[float] = None
    t_cp: Optional[float] = None
    h_max: int = 10**6
    pilot_rounds: int = 8

    def __post_init__(self):
        if isinstance(self.C, str) and self.C != "auto":
            raise ValueError(
                f"C must be a float or the string 'auto', got {self.C!r}")
        # pilot_rounds only matters when a pilot will actually run
        if self.C == "auto" and self.pilot_rounds < 2:
            raise ValueError(
                f"pilot_rounds must be >= 2 (fit_C needs at least two "
                f"observations), got {self.pilot_rounds}")


@dataclasses.dataclass(frozen=True)
class ResolvedSchedule:
    """A Schedule bound to one Topology: concrete per-depth round counts.

    ``chunk_tree`` is the full tree with the root pinned to ONE round --
    the unit :class:`~repro.api.session.Session` compiles and then iterates
    ``rounds`` times (warm restarts and streaming fall out of the same
    program)."""
    chunk_tree: TreeNode
    rounds: int                      # default root-round count for run()
    weighting: str
    per_round_time: float            # simulated seconds per root round
    level_plan: Optional[List[dict]]  # eq.-(12) output when rounds="auto"

    @property
    def full_tree(self) -> TreeNode:
        """The equivalent monolithic tree (root runs all ``rounds``)."""
        return dataclasses.replace(self.chunk_tree, rounds=self.rounds)


def _apply_rounds(
    node: TreeNode, depth: int, *,
    local_steps: Optional[int],
    rounds_of_depth,  # callable depth -> Optional[int]
) -> TreeNode:
    if node.is_leaf:
        if local_steps is None:
            return node
        return dataclasses.replace(node, rounds=local_steps)
    kids = tuple(
        _apply_rounds(c, depth + 1, local_steps=local_steps,
                      rounds_of_depth=rounds_of_depth)
        for c in node.children)
    r = rounds_of_depth(depth)
    return dataclasses.replace(node, children=kids,
                               rounds=node.rounds if r is None else r)


@dataclasses.dataclass(frozen=True)
class Schedule:
    """Round counts per tree level.

    * ``rounds``: root rounds -- an int, ``None`` (use the topology's
      default), or ``"auto"`` (eq.-(12) planning; requires ``delay``).
    * ``level_rounds``: per-internal-depth rounds below the root, top-down
      (depth 1, 2, ...); ``None`` keeps the topology's defaults.
    * ``local_steps``: H at the leaves; ``None`` keeps the defaults.
    * ``weighting``: ``"uniform"`` (paper 1/K) or ``"size"``
      (|block|-proportional, CoCoA-style).
    * ``delay``: the :class:`DelayModel` driving ``rounds="auto"``.
    """
    rounds: Union[int, str, None] = None
    local_steps: Optional[int] = None
    level_rounds: Optional[Sequence[int]] = None
    weighting: str = "uniform"
    delay: Optional[DelayModel] = None

    @classmethod
    def auto(cls, t_total: float, *, C: Union[float, str] = 0.5,
             delta: Optional[float] = None, t_cp: Optional[float] = None,
             h_max: int = 10**6, weighting: str = "uniform",
             pilot_rounds: int = 8) -> "Schedule":
        """Shorthand for ``Schedule(rounds="auto", delay=DelayModel(...))``
        (``C="auto"`` calibrates C from a pilot run at compile time)."""
        return cls(rounds="auto", weighting=weighting,
                   delay=DelayModel(t_total=t_total, C=C, delta=delta,
                                    t_cp=t_cp, h_max=h_max,
                                    pilot_rounds=pilot_rounds))

    # -----------------------------------------------------------------
    def resolve(self, topology: Topology) -> ResolvedSchedule:
        """Bind to ``topology``: produce concrete per-depth round counts."""
        if self.rounds == "auto":
            return self._resolve_auto(topology)
        if isinstance(self.rounds, str):
            raise ValueError(
                f"rounds must be an int, None, or 'auto'; got {self.rounds!r}")

        level = dict(enumerate(self.level_rounds or (), start=1))
        tree = _apply_rounds(
            topology.tree, 0, local_steps=self.local_steps,
            rounds_of_depth=lambda d: None if d == 0 else level.get(d))
        rounds = topology.tree.rounds if self.rounds is None else \
            int(self.rounds)
        if rounds < 0:
            raise ValueError(f"rounds must be >= 0, got {rounds}")
        chunk = dataclasses.replace(tree, rounds=1)
        return ResolvedSchedule(
            chunk_tree=chunk, rounds=rounds, weighting=self.weighting,
            per_round_time=chunk.solve_time(), level_plan=None)

    def _resolve_auto(self, topology: Topology) -> ResolvedSchedule:
        if self.delay is None:
            raise ValueError(
                "Schedule(rounds='auto') needs delay=DelayModel(t_total=...)")
        if isinstance(self.delay.C, str):
            raise ValueError(
                "DelayModel(C='auto') needs a pilot run to calibrate C, "
                "which requires the problem data: resolve this schedule "
                "through Session.compile(problem, topology, schedule) "
                "instead of Schedule.resolve(topology)")
        if self.local_steps is not None or self.level_rounds is not None:
            raise ValueError(
                "rounds='auto' plans local_steps/level_rounds itself; "
                "don't pass them explicitly")
        dm = self.delay
        levels = topology.sync_levels()      # innermost first, length D
        t_lp = topology.leaf_t_lp()
        if not t_lp > 0:
            raise ValueError(
                "rounds='auto' needs leaf t_lp > 0 (the delay trade-off is "
                "meaningless with free local iterations)")
        m_leaf = topology.tree.leaves()[0].data_size
        delta = dm.delta if dm.delta is not None else 1.0 / m_leaf
        t_cp = dm.t_cp if dm.t_cp is not None else topology.internal_t_cp()
        lp = plan_hierarchical_h(
            levels, C=dm.C, delta=delta, t_total=dm.t_total, t_lp=t_lp,
            t_cp=t_cp, h_max=dm.h_max)

        D = len(levels)
        # lp[0] plans the leaves' H; lp[i] (i >= 1) plans how many rounds of
        # the level below one sync at internal depth D-1-i amortizes; the
        # root's own count comes from the time budget.
        local_steps = int(lp[0]["H"])
        rounds_of = {D - i: int(lp[i]["H"]) for i in range(1, D)}
        tree = _apply_rounds(
            topology.tree, 0, local_steps=local_steps,
            rounds_of_depth=lambda d: None if d == 0 else rounds_of.get(d))
        root_rounds = max(1, int(dm.t_total / lp[-1]["round_time"]))
        chunk = dataclasses.replace(tree, rounds=1)
        return ResolvedSchedule(
            chunk_tree=chunk, rounds=root_rounds, weighting=self.weighting,
            per_round_time=chunk.solve_time(), level_plan=lp)
