"""The :class:`Schedule` object: *how many* rounds at every tree level.

The paper's central knob is the local/global iteration trade-off (eq.
(9)-(12)): more local steps H amortize a slow link but dilute each
aggregation.  A Schedule either pins the knob explicitly (``rounds``,
``level_rounds``, ``local_steps``) or delegates it to the paper's eq.-(12)
planner with ``rounds="auto"``: at compile time
``repro.core.delay.plan_hierarchical_h`` is run over the topology's
link-delay structure (:meth:`Topology.sync_levels`) and picks the
per-level H, with the root round count set by the :class:`DelayModel`'s
simulated-time budget.

Heterogeneous and RUNTIME local H:

* ``local_steps`` also accepts a per-leaf spec -- a ``{leaf_name: H}``
  dict or a left-to-right sequence -- so leaves with more data run more
  local iterations (the imbalanced-data regime of arXiv:2308.14783);
* ``h_cap=`` compiles the plan with a larger per-leaf H *capacity* and
  turns the actual H into a runtime input of the executors (a step mask,
  see ``repro.core.engine.plan.steps_for_h``): ``Session.run(local_h=...)``
  and ``Session.sweep(local_hs=...)`` then execute any H schedule up to
  the cap -- and delay-adaptive sessions replan H between chunks -- with
  ZERO retraces;
* ``DelayModel(straggler=StragglerModel(...))`` makes ``rounds="auto"``
  run the straggler-aware planner variant, optimizing H jointly with the
  ``BoundedSkip`` threshold over the topology's per-leaf delays
  (``repro.core.delay.optimal_h_bounded_skip``); the planned threshold is
  inspectable as ``resolved.skip`` / buildable via
  ``Session.straggler_policy()``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core import compression as comp_mod
from repro.core.delay import (StragglerModel, checkpoint_period,
                              choose_compression, plan_hierarchical_h)
from repro.core.tree import TreeNode

from repro.api.topology import Topology


@dataclasses.dataclass(frozen=True)
class DelayModel:
    """Parameters of the paper's SS6 delay-aware bound (eq. (11)-(12)).

    ``t_total`` is the simulated wall-clock budget the auto-planner
    optimizes for; ``delta`` defaults to 1/m_leaf (one coordinate's share of
    a leaf block); ``t_cp`` defaults to the topology's own per-aggregation
    cost (``Topology.internal_t_cp``); ``h_max`` caps the per-level H
    search.

    ``C="auto"`` calibrates the improvement constant from a short pilot
    run instead of taking it as given: ``Session.compile`` runs
    ``pilot_rounds`` root rounds under the topology's default schedule on
    the host backend, fits C from the observed per-round gap contractions
    (:func:`repro.core.delay.fit_C`), and plans with the fitted value
    (inspectable as ``session.fitted_C``).

    ``straggler`` (a :class:`~repro.core.delay.StragglerModel`) switches
    the planner to the straggler-aware variant: the innermost level's H is
    optimized JOINTLY with the bounded-skip threshold (``0..skip_max``)
    over the topology's per-leaf sync delays
    (:func:`repro.core.delay.optimal_h_bounded_skip`) -- dropping
    stragglers shrinks the effective barrier delay but dilutes eq. (11)'s
    per-round improvement by the participation fraction.

    ``mtbf`` (mean time between failures, simulated seconds) together
    with ``ckpt_write`` (the cost of one checkpoint write) makes the
    round-time model fault-aware: the resolved schedule carries the
    Young/Daly-optimal checkpoint period
    (:func:`repro.core.delay.checkpoint_period`) as
    ``resolved.ckpt_every`` -- what ``CheckpointPolicy(every="auto")``
    executes -- and ``rounds="auto"``'s time budget charges the amortized
    write cost (``t_round + ckpt_write / period`` per root round)."""
    t_total: float
    C: Union[float, str] = 0.5
    delta: Optional[float] = None
    t_cp: Optional[float] = None
    h_max: int = 10**6
    pilot_rounds: int = 8
    straggler: Optional[StragglerModel] = None
    skip_max: int = 3
    ckpt_write: float = 0.0
    mtbf: Optional[float] = None

    def __post_init__(self):
        if isinstance(self.C, str) and self.C != "auto":
            raise ValueError(
                f"C must be a float or the string 'auto', got {self.C!r}")
        # pilot_rounds only matters when a pilot will actually run
        if self.C == "auto" and self.pilot_rounds < 2:
            raise ValueError(
                f"pilot_rounds must be >= 2 (fit_C needs at least two "
                f"observations), got {self.pilot_rounds}")
        if self.skip_max < 0:
            raise ValueError(
                f"skip_max must be >= 0, got {self.skip_max}")
        if self.ckpt_write < 0:
            raise ValueError(
                f"ckpt_write must be >= 0, got {self.ckpt_write}")
        if self.mtbf is not None and not self.mtbf > 0:
            raise ValueError(f"mtbf must be > 0, got {self.mtbf}")


@dataclasses.dataclass(frozen=True)
class ResolvedSchedule:
    """A Schedule bound to one Topology: concrete per-depth round counts.

    ``chunk_tree`` is the full tree with the root pinned to ONE round --
    the unit :class:`~repro.api.session.Session` compiles and then iterates
    ``rounds`` times (warm restarts and streaming fall out of the same
    program).

    ``runtime_h`` (set iff the schedule declared an ``h_cap``) is the
    per-leaf local-H the session should EXECUTE at runtime via step masks;
    the ``chunk_tree`` leaves then carry the (larger) compiled H capacity.
    ``skip`` / ``straggler_model`` carry the straggler-aware planner's
    jointly-optimized bounded-skip threshold (``rounds="auto"`` with
    ``DelayModel(straggler=...)``).

    ``compression`` is the resolved TOP-DOWN per-depth edge-compression
    spec tuple (entry ``d`` compresses the up-links into depth-``d``
    nodes -- the form ``engine.plan.compile_tree`` consumes) or ``None``;
    the simulated clocks (``per_round_time``/``round_time_for``) charge
    the COMPRESSED link delays (each edge's ``up_delay`` scaled by its
    spec's wire ratio).

    ``ckpt_every`` (set iff the schedule's :class:`DelayModel` declared an
    ``mtbf``) is the Young/Daly-optimal checkpoint period in root rounds
    (:func:`repro.core.delay.checkpoint_period`) -- what
    ``CheckpointPolicy(every="auto")`` resolves to."""
    chunk_tree: TreeNode
    rounds: int                      # default root-round count for run()
    weighting: str
    per_round_time: float            # simulated seconds per root round
    level_plan: Optional[List[dict]]  # eq.-(12) output when rounds="auto"
    runtime_h: Optional[tuple] = None  # per-leaf runtime H under h_cap
    skip: Optional[int] = None         # planned BoundedSkip threshold
    straggler_model: Optional[StragglerModel] = None
    compression: Optional[tuple] = None  # top-down per-depth specs
    ckpt_every: Optional[int] = None   # Young/Daly period (root rounds)

    @property
    def full_tree(self) -> TreeNode:
        """The equivalent monolithic tree (root runs all ``rounds``)."""
        return dataclasses.replace(self.chunk_tree, rounds=self.rounds)

    def round_time_for(self, local_h=None) -> float:
        """Simulated seconds of one root round under runtime local-H
        ``local_h`` (scalar or per-leaf; ``None`` -> the schedule's own
        per-round time).  Runtime H is clamped to the compiled per-leaf
        capacity, exactly as the executors' step masks clamp it."""
        if local_h is None:
            return self.per_round_time
        t = runtime_tree(self.chunk_tree, local_h)
        return compressed_time_tree(t, self.compression).solve_time()


def leaf_h_spec(h, n_leaves: int) -> np.ndarray:
    """Normalize a runtime local-H spec -- a scalar, a per-leaf ``(n,)``
    vector, or a per-slot ``(S, n)`` array -- to per-leaf ``(n,)`` counts
    (per-slot specs reduce to their per-leaf MAX, the slot that binds the
    round's compute).  The single normalizer behind the session's
    simulated clock, history ``"h"`` entries, and replan comparisons; the
    executors' mask builder (``engine.plan.steps_for_h``) resolves the
    same specs at full per-slot granularity."""
    arr = np.asarray(h, np.int64)
    if arr.ndim == 2:
        arr = arr.max(axis=0)
    return np.broadcast_to(arr, (n_leaves,))


def runtime_tree(chunk_tree: TreeNode, h) -> TreeNode:
    """The chunk tree with its leaves clamped to the RUNTIME local-H
    schedule ``h`` (scalar, per-leaf, or per-slot; ``None`` = the
    compiled tree itself) -- the tree whose compute time the simulated
    clocks charge when step masks gate trailing iterations off.  Runtime
    H never exceeds a leaf's compiled capacity."""
    if h is None:
        return chunk_tree
    leaves = chunk_tree.leaves()
    hs = leaf_h_spec(h, len(leaves))
    hs = [min(int(v), int(l.rounds)) for v, l in zip(hs, leaves, strict=True)]
    return _apply_rounds(chunk_tree, 0, [0],
                         leaf_steps_of=lambda i, name: hs[i],
                         rounds_of_depth=lambda d: None)


def compressed_time_tree(tree: TreeNode,
                         level_spec: Optional[Sequence]) -> TreeNode:
    """A copy of ``tree`` with every up-link delay scaled by its edge's
    compression wire ratio -- what the simulated clocks should charge when
    deltas ship compressed.  ``level_spec`` is the top-down per-depth
    default (entry ``d`` = up-links into depth-``d`` nodes, the
    ``compile_tree`` convention); a node's own ``up_compress`` overrides
    it, exactly as plan compilation does.  Treats the whole ``up_delay``
    as bandwidth-bound (the :class:`~repro.core.delay.FixedLevel` default
    view -- ``TreeNode.up_delay`` does not split latency out)."""
    def visit(node: TreeNode, depth: int) -> TreeNode:
        kids = tuple(visit(c, depth + 1) for c in node.children)
        if kids != node.children:
            node = dataclasses.replace(node, children=kids)
        if depth == 0:
            return node
        spec = node.up_compress or (
            level_spec[depth - 1]
            if level_spec is not None and depth - 1 < len(level_spec)
            else None)
        if not spec:
            return node
        kind, frac = comp_mod.parse_spec(spec)
        ratio = comp_mod.wire_ratio(kind, frac)
        if ratio == 1.0:
            return node
        return dataclasses.replace(node, up_delay=node.up_delay * ratio)
    return visit(tree, 0)


def _leaf_steps_resolver(tree: TreeNode, local_steps):
    """Normalize a ``local_steps`` spec -- ``None``, an int, a ``{leaf
    name: H}`` dict, or a left-to-right per-leaf sequence -- into a
    ``(leaf_index, leaf_name) -> Optional[int]`` lookup."""
    if local_steps is None or isinstance(local_steps, int):
        return lambda i, name: local_steps
    leaves = tree.leaves()
    if isinstance(local_steps, dict):
        unknown = set(local_steps) - {l.name for l in leaves}
        if unknown:
            raise ValueError(
                f"local_steps names unknown leaves {sorted(unknown)}; "
                f"topology leaves are {[l.name for l in leaves]}")
        return lambda i, name: local_steps.get(name)
    seq = [int(v) for v in local_steps]
    if len(seq) != len(leaves):
        raise ValueError(
            f"per-leaf local_steps must list all {len(leaves)} leaves "
            f"left-to-right, got {len(seq)} entries")
    return lambda i, name: seq[i]


def _apply_rounds(
    node: TreeNode, depth: int, counter, *,
    leaf_steps_of,    # callable (leaf index, leaf name) -> Optional[int]
    rounds_of_depth,  # callable depth -> Optional[int]
) -> TreeNode:
    if node.is_leaf:
        i = counter[0]
        counter[0] += 1
        r = leaf_steps_of(i, node.name)
        if r is None:
            return node
        return dataclasses.replace(node, rounds=int(r))
    kids = tuple(
        _apply_rounds(c, depth + 1, counter, leaf_steps_of=leaf_steps_of,
                      rounds_of_depth=rounds_of_depth)
        for c in node.children)
    r = rounds_of_depth(depth)
    return dataclasses.replace(node, children=kids,
                               rounds=node.rounds if r is None else r)


@dataclasses.dataclass(frozen=True)
class Schedule:
    """Round counts per tree level.

    * ``rounds``: root rounds -- an int, ``None`` (use the topology's
      default), or ``"auto"`` (eq.-(12) planning; requires ``delay``).
    * ``level_rounds``: per-internal-depth rounds below the root, top-down
      (depth 1, 2, ...); ``None`` keeps the topology's defaults.
    * ``local_steps``: H at the leaves -- an int, a ``{leaf_name: H}``
      dict, or a left-to-right per-leaf sequence (heterogeneous H for
      imbalanced leaf datasets); ``None`` keeps the defaults.
    * ``h_cap``: compile the plan with this per-leaf H *capacity* and make
      the executed H a RUNTIME input: the session runs ``local_steps``
      (or the topology's defaults) via step masks, and ``run(local_h=)``/
      ``sweep(local_hs=)``/delay-adaptive replanning swap in any other H
      up to the cap with zero retraces.
    * ``weighting``: ``"uniform"`` (paper 1/K) or ``"size"``
      (|block|-proportional, CoCoA-style).
    * ``delay``: the :class:`DelayModel` driving ``rounds="auto"``.
    * ``compression``: delta compression of the up-link syncs -- ``None``
      (only the topology's per-edge ``up_compress`` overrides apply), one
      spec string (``"none"``/``"int8"``/``"topk_<frac>"``) for every
      depth, a top-down per-depth sequence, or ``"auto"`` (requires
      ``rounds="auto"``: :func:`repro.core.delay.choose_compression`
      picks per level by the eq.-(12) bound -- slow bandwidth-bound hops
      compress, fast ones stay exact).  The resolved specs ride on
      ``ResolvedSchedule.compression`` into plan compilation, and the
      simulated clocks charge the compressed link delays.
    * ``acceleration``: Nesterov-style momentum coefficient on the server
      combine (Ma et al., arXiv 1711.05305) in ``[0, 1]``.  ``None``
      (default) runs the plain ``"sdca"`` method; any float -- including
      ``0.0``, which is bit-identical to plain -- selects
      ``get_method("sdca_acc")``, with the coefficient a RUNTIME scalar
      operand (zero retraces across different values).  ``rounds="auto"``
      plans under the accelerated per-round factor, so momentum buys
      fewer root rounds for the same bound.
    """
    rounds: Union[int, str, None] = None
    local_steps: Union[int, Sequence[int], Dict[str, int], None] = None
    level_rounds: Optional[Sequence[int]] = None
    weighting: str = "uniform"
    delay: Optional[DelayModel] = None
    h_cap: Optional[int] = None
    compression: Union[str, Sequence, None] = None
    acceleration: Optional[float] = None

    def __post_init__(self):
        if self.acceleration is not None \
                and not 0.0 <= float(self.acceleration) <= 1.0:
            raise ValueError(
                f"acceleration must be in [0, 1] (0 = plain SDCA, 1 = full "
                f"Nesterov rate); got {self.acceleration}")

    @classmethod
    def auto(cls, t_total: float, *, C: Union[float, str] = 0.5,
             delta: Optional[float] = None, t_cp: Optional[float] = None,
             h_max: int = 10**6, weighting: str = "uniform",
             pilot_rounds: int = 8,
             straggler: Optional[StragglerModel] = None,
             skip_max: int = 3, h_cap: Optional[int] = None,
             compression: Union[str, Sequence, None] = None,
             acceleration: Optional[float] = None) -> "Schedule":
        """Shorthand for ``Schedule(rounds="auto", delay=DelayModel(...))``
        (``C="auto"`` calibrates C from a pilot run at compile time;
        ``straggler=`` switches to the straggler-aware joint (H, skip)
        planner; ``h_cap=`` keeps the planned H a runtime input so
        adaptive sessions can replan it without retracing;
        ``compression="auto"`` lets the same eq.-(12) machinery choose
        per-level delta compression; ``acceleration=`` runs and plans the
        accelerated server-momentum flavor)."""
        return cls(rounds="auto", weighting=weighting, h_cap=h_cap,
                   compression=compression, acceleration=acceleration,
                   delay=DelayModel(t_total=t_total, C=C, delta=delta,
                                    t_cp=t_cp, h_max=h_max,
                                    pilot_rounds=pilot_rounds,
                                    straggler=straggler, skip_max=skip_max))

    def _normalized_compression(self, D: int) -> Optional[tuple]:
        """The top-down per-depth spec tuple for a depth-``D`` topology
        (validated), or ``None``.  ``"auto"`` is resolved elsewhere."""
        c = self.compression
        if c is None:
            return None
        if isinstance(c, str):
            comp_mod.parse_spec(c)  # fail fast on typos
            return (c,) * D
        out = tuple(None if v in (None, "") else str(v) for v in c)
        if len(out) != D:
            raise ValueError(
                f"per-depth compression must list all {D} internal depths "
                f"top-down, got {len(out)} entries")
        for v in out:
            if v is not None:
                comp_mod.parse_spec(v)
        return out

    # -----------------------------------------------------------------
    def resolve(self, topology: Topology) -> ResolvedSchedule:
        """Bind to ``topology``: produce concrete per-depth round counts."""
        if self.rounds == "auto":
            return self._resolve_auto(topology)
        if isinstance(self.rounds, str):
            raise ValueError(
                f"rounds must be an int, None, or 'auto'; got {self.rounds!r}")
        if self.compression == "auto":
            raise ValueError(
                "compression='auto' needs rounds='auto' (the eq.-(12) "
                "DelayModel chooses the per-level specs)")
        comp = self._normalized_compression(topology.depth)

        level = dict(enumerate(self.level_rounds or (), start=1))
        tree = _apply_rounds(
            topology.tree, 0, [0],
            leaf_steps_of=_leaf_steps_resolver(topology.tree,
                                               self.local_steps),
            rounds_of_depth=lambda d: None if d == 0 else level.get(d))
        rounds = topology.tree.rounds if self.rounds is None else \
            int(self.rounds)
        if rounds < 0:
            raise ValueError(f"rounds must be >= 0, got {rounds}")
        tree, runtime_h = self._apply_h_cap(tree)
        chunk = dataclasses.replace(tree, rounds=1)
        resolved = ResolvedSchedule(
            chunk_tree=chunk, rounds=rounds, weighting=self.weighting,
            per_round_time=compressed_time_tree(chunk, comp).solve_time(),
            level_plan=None, runtime_h=runtime_h, compression=comp)
        if runtime_h is not None:
            # the simulated clock charges the RUNTIME H, not the capacity
            resolved = dataclasses.replace(
                resolved, per_round_time=resolved.round_time_for(runtime_h))
        return self._with_ckpt_plan(resolved)

    def _with_ckpt_plan(self, resolved: ResolvedSchedule) -> ResolvedSchedule:
        """Attach the Young/Daly checkpoint period when the DelayModel is
        fault-aware (``mtbf`` declared)."""
        dm = self.delay
        if dm is None or dm.mtbf is None:
            return resolved
        every = checkpoint_period(
            resolved.per_round_time, dm.ckpt_write, dm.mtbf,
            max_period=max(resolved.rounds, 1))
        return dataclasses.replace(resolved, ckpt_every=every)

    def _apply_h_cap(self, tree: TreeNode):
        """Pad the leaves to the ``h_cap`` capacity; the displaced per-leaf
        counts become the session's runtime H (executed via step masks)."""
        if self.h_cap is None:
            return tree, None
        cap = int(self.h_cap)
        runtime_h = tuple(l.rounds for l in tree.leaves())
        if cap < max(runtime_h):
            raise ValueError(
                f"h_cap={cap} is below the schedule's own local steps "
                f"(max {max(runtime_h)}); the capacity must cover every "
                "H the session should be able to execute")
        padded = _apply_rounds(
            tree, 0, [0], leaf_steps_of=lambda i, name: cap,
            rounds_of_depth=lambda d: None)
        return padded, runtime_h

    def _resolve_auto(self, topology: Topology) -> ResolvedSchedule:
        if self.delay is None:
            raise ValueError(
                "Schedule(rounds='auto') needs delay=DelayModel(t_total=...)")
        if isinstance(self.delay.C, str):
            raise ValueError(
                "DelayModel(C='auto') needs a pilot run to calibrate C, "
                "which requires the problem data: resolve this schedule "
                "through Session.compile(problem, topology, schedule) "
                "instead of Schedule.resolve(topology)")
        if self.local_steps is not None or self.level_rounds is not None:
            raise ValueError(
                "rounds='auto' plans local_steps/level_rounds itself; "
                "don't pass them explicitly")
        dm = self.delay
        levels = topology.sync_levels()      # innermost first, length D
        t_lp = topology.leaf_t_lp()
        if not t_lp > 0:
            raise ValueError(
                "rounds='auto' needs leaf t_lp > 0 (the delay trade-off is "
                "meaningless with free local iterations)")
        m_leaf = topology.tree.leaves()[0].data_size
        delta = dm.delta if dm.delta is not None else 1.0 / m_leaf
        t_cp = dm.t_cp if dm.t_cp is not None else topology.internal_t_cp()
        D = len(levels)
        if self.compression == "auto":
            # eq.-(12) per-level spec choice: cheaper compressed rounds vs.
            # the diluted improvement constant, innermost-first
            comp_rows = choose_compression(
                levels, C=dm.C, delta=delta, t_total=dm.t_total, t_lp=t_lp,
                t_cp=t_cp, h_max=dm.h_max,
                acceleration=self.acceleration or 0.0)
            comp_levels = [r["spec"] for r in comp_rows]
            comp = tuple(reversed(comp_levels))  # innermost-first -> top-down
        else:
            comp = self._normalized_compression(D)
            comp_levels = list(reversed(comp)) if comp is not None else None
        lp = plan_hierarchical_h(
            levels, C=dm.C, delta=delta, t_total=dm.t_total, t_lp=t_lp,
            t_cp=t_cp, h_max=dm.h_max,
            # the compiled capacity bounds the innermost search space, so
            # the planned round times / root budget stay consistent with
            # what the executors can actually run
            h_max0=self.h_cap,
            straggler=dm.straggler, skip_max=dm.skip_max,
            base_delays=(topology.leaf_sync_delays()
                         if dm.straggler is not None else None),
            compression=comp_levels,
            acceleration=self.acceleration or 0.0)
        # lp[0] plans the leaves' H; lp[i] (i >= 1) plans how many rounds of
        # the level below one sync at internal depth D-1-i amortizes; the
        # root's own count comes from the time budget.
        local_steps = int(lp[0]["H"])
        rounds_of = {D - i: int(lp[i]["H"]) for i in range(1, D)}
        tree = _apply_rounds(
            topology.tree, 0, [0],
            leaf_steps_of=lambda i, name: local_steps,
            rounds_of_depth=lambda d: None if d == 0 else rounds_of.get(d))
        # fault-aware budget: every root round additionally pays the
        # AMORTIZED checkpoint-write cost at the Young/Daly period
        budget_round_time = lp[-1]["round_time"]
        if dm.mtbf is not None:
            period = checkpoint_period(budget_round_time, dm.ckpt_write,
                                       dm.mtbf)
            budget_round_time += dm.ckpt_write / period
        root_rounds = max(1, int(dm.t_total / budget_round_time))
        tree, runtime_h = self._apply_h_cap(tree)
        chunk = dataclasses.replace(tree, rounds=1)
        resolved = ResolvedSchedule(
            chunk_tree=chunk, rounds=root_rounds, weighting=self.weighting,
            per_round_time=compressed_time_tree(chunk, comp).solve_time(),
            level_plan=lp, runtime_h=runtime_h, skip=lp[0].get("skip"),
            straggler_model=dm.straggler, compression=comp)
        if runtime_h is not None:
            resolved = dataclasses.replace(
                resolved, per_round_time=resolved.round_time_for(runtime_h))
        return self._with_ckpt_plan(resolved)
