"""Session-driven LM training: the second workload on the schedule engine.

``Problem.lm(cfg, optimizer, batch=, seq=)`` + ``Session.compile(...,
backend="mesh")`` dispatch here: the same Schedule -> ResolvedSchedule ->
``compile_tree`` plan IR that drives SDCA is lowered through
``engine.plan.schedule_view`` into the method-agnostic schedule layer
(per-level periods, group sizes, per-edge codecs), and the
``"lm_treesync"`` Method (``engine.method`` / ``engine.lm``) supplies the
local step and the per-level combine.  One replica-stacked jitted step
takes the periods as a RUNTIME (L,) operand, so

  * ``run(local_h=...)`` and straggler-adaptive eq.-(12) replanning
    change an input array, never the compiled program (zero retraces);
  * ``run(straggler=StragglerPolicy(...))`` drops straggling replicas
    from the barrier via a runtime participation mask (absentees keep
    stale state and rejoin, as in the SDCA path);
  * ``run(checkpoint=...)`` / ``resume`` snapshot the exact
    ``TreeSyncState`` carry at outer-round boundaries and restart
    bit-identically (the data stream is a pure function of
    ``(seed, step)``);
  * ``sweep`` runs an (lr x seed x local_h) grid as ONE vmapped dispatch
    per step through ONE cached executor (lr is a runtime operand of the
    optimizers since PR 8).

At fixed periods the program is bit-identical to the legacy
``core.treesync.make_treesync_step`` path (tested in
``tests/test_lm_session.py``).
"""
from __future__ import annotations

import dataclasses
import time
from math import prod
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import plan_check, trace_guard as guard_mod
from repro.api.schedule import Schedule
from repro.api.topology import Topology
from repro.core.engine import lm as lm_mod
from repro.core.engine import plan as plan_mod
from repro.core.engine.method import get_method
from repro.data.lm import lm_batch

PyTree = Any
TreeSyncState = lm_mod.TreeSyncState


@dataclasses.dataclass
class LMResult:
    """One LM run: the final replica-stacked state plus the per-step
    history (``{"step", "loss", "sec"}``; straggler runs add ``"time"``
    (simulated async clock), ``"time_sync"``, ``"participants"`` and,
    when the policy is adaptive, the executed ``"h"``)."""
    state: TreeSyncState
    history: List[dict]
    wall_s: float

    @property
    def final_loss(self) -> Optional[float]:
        return self.history[-1]["loss"] if self.history else None

    def consensus(self) -> PyTree:
        """The fully-averaged model (what you checkpoint / serve)."""
        return lm_mod.consensus_params(self.state)


@dataclasses.dataclass
class LMRunSet:
    """A fused LM sweep: per-member configs, the stacked (B, R, ...)
    final states and the batched (B, T) loss history."""
    points: List[Any]
    states: TreeSyncState            # leaves (B, R, ...)
    losses: np.ndarray               # (B, T) float32
    lrs: List[Optional[float]]

    def __len__(self) -> int:
        return len(self.points)

    @property
    def final_losses(self) -> np.ndarray:
        return self.losses[:, -1]

    def best(self) -> int:
        """Index of the member with the lowest final loss."""
        return int(np.nanargmin(self.final_losses))

    def member_state(self, i: int) -> TreeSyncState:
        return jax.tree.map(lambda t: t[i], self.states)


class LMSession:
    """Compiled LM training program: (LMProblem, Topology, Schedule) on
    the mesh backend.  Mirrors :class:`repro.api.session.Session`'s
    surface (``run`` / ``resume`` / ``sweep`` / ``cache_stats``)."""

    def __init__(self, problem, topology, resolved, plan, sview, mesh,
                 sync_axes: Tuple[str, ...]):
        self.problem = problem
        self.topology = topology
        self.resolved = resolved
        self.plan = plan
        self.sview = sview
        self.backend = "mesh"
        self._mesh = mesh
        self._sync_axes = sync_axes
        self._axes = lm_mod.present_axes(mesh, sync_axes)
        self._level_sizes = lm_mod.level_sizes_for(mesh, sync_axes)
        self._method = get_method(problem.method)
        self._guard = None          # TraceGuard when compiled strict
        self._built = set()         # executor variants already compiled
        # the LM combine compresses the outermost edge only (legacy
        # TreeSync semantics); schedule_view is bottom-up, so [-1] is the
        # up-link into the root
        comp = sview.compression
        if any(c != "none" for c in comp[:-1]):
            raise ValueError(
                f"LM training compresses the outermost (root) edge only; "
                f"schedule plans per-level codecs {comp} (bottom-up)")
        self._compression = comp[-1] if comp else "none"

    # ------------------------------------------------------------------
    @classmethod
    def compile(cls, problem, topology: Optional[Topology] = None,
                schedule: Optional[Schedule] = None, *,
                backend: str = "mesh", mesh=None,
                sync_axes: Sequence[str] = ("data", "pod"),
                strict=False,
                ) -> "LMSession":
        """Lower ``topology`` under ``schedule`` into the LM train
        program.  ``topology`` defaults to ``Topology.from_mesh(mesh)``
        (one leaf per replica, one level per present sync axis); an
        explicit topology must have the mesh's fan-outs.  ``mesh``
        defaults to a host mesh over the available devices."""
        if backend != "mesh":
            raise ValueError(
                "LM training is replica-stacked data-parallel: the replica "
                "dim is sharded over the sync axes and every combine is a "
                "mesh all-reduce; compile with backend='mesh' "
                f"(got {backend!r})")
        if mesh is None:
            from repro.launch.mesh import make_host_mesh
            mesh = make_host_mesh()
        axes = lm_mod.present_axes(mesh, tuple(sync_axes))
        sizes = tuple(lm_mod.axis_size(mesh, a) for a in axes)  # bottom-up
        if topology is None:
            topology = Topology.from_mesh(mesh, sync_axes=tuple(sync_axes))
        schedule = schedule or Schedule()
        resolved = schedule.resolve(topology)
        plan = plan_mod.compile_tree(resolved.chunk_tree,
                                     weighting=resolved.weighting,
                                     compression=resolved.compression)
        sview = plan_mod.schedule_view(plan)
        R = max(prod(sizes), 1)
        if prod(sview.group_sizes) != R or (
                len(axes) > 0 and sview.group_sizes != sizes):
            raise ValueError(
                f"topology fan-outs {sview.group_sizes} (bottom-up) do not "
                f"match the mesh's sync-axis sizes {sizes} over {axes}: one "
                "leaf per replica, one level per mesh axis "
                "(Topology.from_mesh builds a matching tree)")
        # the structural verifier runs on every compile (TreePlan checks
        # subsume the schedule-view checks the LM program consumes)
        plan_check.verify_plan(plan)
        sess = cls(problem, topology, resolved, plan, sview, mesh,
                   tuple(sync_axes))
        sess._guard = guard_mod.as_trace_guard(strict)
        return sess

    # ------------------------------------------------------------------
    @property
    def n_replicas(self) -> int:
        return max(prod(self._level_sizes), 1)

    @property
    def periods(self) -> Tuple[int, ...]:
        """Planned per-level periods, bottom-up (leaf H first) -- what
        ``Schedule(rounds='auto')`` chose, or the topology's own."""
        return self.sview.periods

    @property
    def steps_per_round(self) -> int:
        """Local steps per outer (root) round: prod(periods)."""
        return prod(self.sview.periods)

    @property
    def level_plan(self):
        """The eq.-(12) planner output when the schedule was ``"auto"``."""
        return self.resolved.level_plan

    @property
    def default_rounds(self) -> int:
        return self.resolved.rounds

    def cache_stats(self) -> dict:
        """LM executor-cache counters (hits/misses/size)."""
        return self._method.cache_stats()

    # ------------------------------------------------------------------
    def init_state(self, key=None, *, seed: Optional[int] = None
                   ) -> TreeSyncState:
        if key is None:
            key = jax.random.PRNGKey(
                self.problem.seed if seed is None else int(seed))
        return lm_mod.init_lm_state(
            self.problem.cfg, self.problem.optimizer, key, self.n_replicas,
            compression=self._compression)

    def _executor(self, *, masked: bool = False, with_lr: bool = False,
                  batched: bool = False):
        return self._method.executor(
            cfg=self.problem.cfg, optimizer=self.problem.optimizer,
            level_sizes=self._level_sizes, compression=self._compression,
            average_opt_state=self.problem.average_opt_state,
            masked=masked, with_lr=with_lr, batched=batched)

    def _run_periods(self, local_h) -> List[int]:
        ps = list(self.sview.periods)
        if local_h is not None:
            if int(local_h) < 1:
                raise ValueError(f"local_h must be >= 1, got {local_h}")
            ps[0] = int(local_h)
        return ps

    def _batch_at(self, step: int):
        p = self.problem
        return lm_mod.split_batch(
            lm_batch(p.cfg, p.batch, p.seq, step, seed=p.seed),
            self.n_replicas)

    # ------------------------------------------------------------------
    def run(
        self,
        rounds: Optional[int] = None,
        *,
        steps: Optional[int] = None,
        key=None,
        warm_start: Optional[TreeSyncState] = None,
        local_h=None,
        lr: Optional[float] = None,
        straggler=None,
        checkpoint=None,
        record_history: bool = True,
        on_step=None,
        _history_prefix: Sequence[dict] = (),
        _final_save: bool = True,
    ) -> LMResult:
        """Run ``rounds`` outer rounds (default: the schedule's), each
        ``prod(periods)`` local steps; ``steps=`` overrides with an exact
        local-step count (the final round truncates).

        ``local_h`` overrides the leaf period for this run; under an
        adaptive ``straggler`` policy the replanned eq.-(12) H feeds the
        NEXT round's periods operand -- both are runtime inputs, so
        neither ever retraces.  ``warm_start`` continues from a previous
        result's state (the deterministic data stream continues from
        ``state.step``).  ``checkpoint`` snapshots the exact state every
        ``policy.every`` outer rounds; see :meth:`resume`.  ``lr``
        overrides the optimizer's step size (a runtime operand)."""
        p = self.problem
        R = self.n_replicas
        L = len(self._level_sizes)
        periods = self._run_periods(local_h)
        spr = prod(periods)

        if warm_start is not None:
            state = warm_start.state if isinstance(warm_start, LMResult) \
                else warm_start
            # the step executor donates its state carry; copy so the
            # caller's warm-start buffers stay valid after this run
            state = jax.tree.map(jnp.copy, state)
        else:
            state = self.init_state(key)
        start = int(state.step)
        if steps is not None:
            total = int(steps)
        else:
            T = self.resolved.rounds if rounds is None else int(rounds)
            if T < 0:
                raise ValueError(f"rounds must be >= 0, got {T}")
            total = T * spr

        ckpt_mgr, ck_every, ckpt_policy = None, 0, None
        if checkpoint is not None:
            if straggler is not None:
                raise ValueError(
                    "checkpoint= does not compose with straggler=: the "
                    "policy's sampled-delay RNG and skip counters are host "
                    "state the snapshot cannot capture, so a resumed run "
                    "would diverge; checkpoint synchronous runs only")
            from repro.runtime import fault as fault_mod
            ckpt_policy, ckpt_mgr, ck_every = fault_mod.bind_policy(
                checkpoint, self.resolved)

        masked = straggler is not None
        if masked:
            n_leaves = self.plan.n_leaves
            if n_leaves != R:
                raise ValueError(
                    f"straggler= needs one topology leaf per replica "
                    f"(got {n_leaves} leaves for {R} replicas)")
            t_lp = self.topology.leaf_t_lp()
            straggler.bind(self.topology.leaf_sync_delays(),
                           t_compute=spr * t_lp, t_lp=t_lp)
        adaptive = masked and getattr(straggler, "adaptive", None) is not None

        # strict mode: fetching a variant this session has ALREADY built
        # must hit the cache (zero budget -- a cleared cache or a drifted
        # key raises); the first fetch of a variant is budgeted one build.
        # From then on every step dispatch must hit.  (No host-sync guard
        # on the LM path: the deterministic data stream is host-generated
        # per step by design.)
        guard = self._guard

        def _retrace_ctx(budget=0):
            import contextlib
            if guard is None or not guard.error_on_retrace:
                return contextlib.nullcontext()
            return guard.retrace_region(budget)

        variant = (masked, lr is not None)
        with _retrace_ctx(0 if variant in self._built else 1):
            exec_fn = self._executor(masked=masked, with_lr=lr is not None)
        self._built.add(variant)
        periods_arr = jnp.asarray(periods[:L], jnp.int32)
        part = jnp.ones((R,), jnp.float32) if masked else None
        lr_arr = None if lr is None else jnp.asarray(lr, jnp.float32)

        history: List[dict] = []
        clock = {"async": 0.0, "sync": 0.0}
        t_start = time.time()
        i, done = start, 0
        while done < total:
            n_this = min(spr, total - done)
            final = done + n_this >= total
            extra = None
            if masked:
                st = straggler.step(final=final)
                part = jnp.asarray(st.mask, jnp.float32)
                clock["async"] += st.dt_async
                clock["sync"] += st.dt_sync
                extra = {"time": clock["async"],
                         "time_sync": clock["sync"],
                         "participants": int(st.mask.sum())}
                if adaptive:
                    extra["h"] = periods[0]
            for _ in range(n_this):
                t0 = time.time()
                with _retrace_ctx():
                    state, metrics = exec_fn(state, self._batch_at(i),
                                             periods_arr, part, lr_arr)
                i += 1
                done += 1
                if record_history:
                    entry = {"step": i, "loss": float(metrics["loss"]),
                             "sec": time.time() - t0}
                    if extra:
                        entry.update(extra)
                    history.append(entry)
                    if on_step is not None:
                        on_step(entry)
            if guard is not None and guard.sanitize:
                guard.check_carry(state, f"state@step{i}")
            # eq.-(12) replanning feeds the NEXT round through the runtime
            # periods operand: a new input array, never a recompile
            if adaptive and straggler.last_h_suggest is not None:
                h_new = max(int(straggler.last_h_suggest), 1)
                if h_new != periods[0]:
                    periods[0] = h_new
                    spr = prod(periods)
                    periods_arr = jnp.asarray(periods[:L], jnp.int32)
                    straggler.retime(spr * self.topology.leaf_t_lp())
            if ckpt_mgr is not None:
                r_no = (i - start + spr - 1) // spr
                if r_no % ck_every == 0 or (final and _final_save):
                    meta = {
                        "version": 1,
                        "step": i,
                        "steps_total": start + total,
                        "periods": list(periods),
                        "plan": self.plan.fingerprint,
                        "seed": int(p.seed),
                        "lr": None if lr is None else float(lr),
                        "history": list(_history_prefix) + history,
                    }
                    ckpt_mgr.save(i, state, metadata=meta)
        if ckpt_mgr is not None:
            ckpt_mgr.wait()
        return LMResult(state=state,
                        history=list(_history_prefix) + history,
                        wall_s=time.time() - t_start)

    # ------------------------------------------------------------------
    def resume(self, checkpoint, *, steps: Optional[int] = None,
               record_history: bool = True, on_step=None) -> LMResult:
        """Restart a checkpointed run from its newest snapshot,
        bit-identically to the uninterrupted run: the restored
        ``TreeSyncState`` is the complete carry, and the data stream is a
        pure function of ``(seed, step)``, so restore + continue = never
        crashed.  Runs the remaining steps (``steps_total - step``, or
        ``steps=`` to override) and keeps checkpointing into the same
        directory; the returned history is the full concatenated
        series."""
        from repro.runtime import fault as fault_mod
        policy, mgr, _ = fault_mod.bind_policy(checkpoint, self.resolved)
        last = mgr.latest_step()
        if last is None:
            raise FileNotFoundError(
                f"no complete checkpoints under {policy.directory!r}")
        meta = mgr.metadata(last)
        if meta.get("plan") != self.plan.fingerprint:
            raise ValueError(
                "checkpoint was written under a different plan "
                "(topology/schedule/compression changed between save and "
                "resume); compile a matching session")
        if int(meta.get("seed", self.problem.seed)) != int(self.problem.seed):
            raise ValueError(
                f"checkpoint data stream has seed {meta['seed']}; this "
                f"problem uses seed {self.problem.seed}")
        step, state = mgr.restore(self.init_state(jax.random.PRNGKey(0)),
                                  last)
        remaining = int(meta["steps_total"]) - step if steps is None \
            else int(steps)
        if remaining < 0:
            raise ValueError(f"steps must be >= 0, got {remaining}")
        lr = meta.get("lr")
        periods = meta.get("periods")
        local_h = None
        if periods is not None and tuple(periods) != self.sview.periods:
            local_h = int(periods[0])
        return self.run(steps=remaining, warm_start=state, local_h=local_h,
                        lr=lr, checkpoint=policy,
                        record_history=record_history, on_step=on_step,
                        _history_prefix=[dict(e)
                                         for e in meta.get("history", [])])

    # ------------------------------------------------------------------
    def sweep(self, spec=None, *, lrs=None, seeds=None, local_hs=None,
              rounds: Optional[int] = None, steps: Optional[int] = None,
              ) -> LMRunSet:
        """Run an (lr x seed x local_h) grid as ONE vmapped dispatch per
        step through ONE cached executor: per-member state and periods
        are batched operands, the data batch is shared (seeds vary the
        INIT key; the stream belongs to the problem), and lr rides the
        optimizers' runtime-lr operand.  ``spec`` is a
        :class:`repro.api.sweep.Sweep` (axes ``lrs``/``seeds``/
        ``local_hs``; ``lams``/``schedules`` are SDCA axes and rejected
        here), or pass the axes directly."""
        from repro.api.sweep import Sweep
        if spec is None:
            spec = Sweep(lrs=lrs, seeds=seeds, local_hs=local_hs)
        if spec.lams is not None or spec.schedules is not None:
            raise ValueError(
                "LM sweeps batch lrs=, seeds=, and local_hs= (runtime "
                "operands of one executor); lams= has no LM meaning and a "
                "schedules= axis changes the compiled program -- run one "
                "sweep per schedule")
        if spec.continuation or spec.resume is not None:
            raise ValueError(
                "continuation/resume are SDCA sweep features; LM sweeps "
                "run straight grids")
        points = spec.expand(0.0)
        B = len(points)
        L = len(self._level_sizes)
        spr = prod(self.sview.periods)
        if steps is not None:
            total = int(steps)
        else:
            T = self.resolved.rounds if rounds is None else int(rounds)
            total = T * spr

        states = [self.init_state(seed=pt.seed if isinstance(
            pt.seed, (int, np.integer)) else None,
            key=None if pt.seed is None or isinstance(
                pt.seed, (int, np.integer)) else pt.seed)
            for pt in points]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *states)
        periods_b = np.tile(np.asarray(self.sview.periods[:L], np.int32),
                            (B, 1))
        for b, pt in enumerate(points):
            if pt.local_h is not None:
                periods_b[b, 0] = int(pt.local_h)
        periods_b = jnp.asarray(periods_b)
        with_lr = spec.lrs is not None
        lr_b = jnp.asarray([pt.lr for pt in points], jnp.float32) \
            if with_lr else None

        exec_fn = self._executor(with_lr=with_lr, batched=True)
        losses = []
        for i in range(total):
            stacked, metrics = exec_fn(stacked, self._batch_at(i),
                                       periods_b, None, lr_b)
            losses.append(np.asarray(metrics["loss"], np.float32))
        return LMRunSet(points=points, states=stacked,
                        losses=np.stack(losses, axis=1) if losses
                        else np.zeros((B, 0), np.float32),
                        lrs=[pt.lr for pt in points])
