"""Vectorized sweeps: a declarative config grid over (lambda, seed,
schedule, local-H) axes, executed as BATCHED device programs.

The paper's experiments (Figs. 3-5) and its eq. (11)-(12) analysis are
grids -- sweeps over regularization, H, and delay regimes -- and the same
lambda/H trade-off study is the central workload of CoCoA-style methods.
A :class:`Sweep` names the axes once; :meth:`repro.api.Session.sweep` (or
the one-shot :func:`sweep`) runs every config and returns a
:class:`RunSet`:

    rs = Session.compile(prob, topo).sweep(
        lams=np.logspace(-3, 0, 8), seeds=[0, 1, 2])
    rs.gaps            # (B, T) batched history
    best = rs.best()   # the member with the smallest final duality gap

Execution model (why this is not a host loop):

  * lambda AND the local-iteration schedule are RUNTIME inputs of the
    engine executors (see ``engine.host.get_host_executor``: lambda as
    the ``lm`` scalar, H as the step-mask operand gating trailing
    coordinate steps), so every lambda and every H up to the compiled
    capacity share one compiled chunk program;
  * on the host backends (``vmap``/``pallas``) the whole (lambda x
    local-H x seed) batch within one schedule runs through the
    ``batched=True`` executor -- ONE ``jax.vmap``-ed dispatch per
    root-round chunk for all B configs, with per-config warm-start
    states, key plans, and step masks;
  * a ``local_hs`` axis (the paper's eq.-(12) H sweep -- fig. 4) needs a
    plan whose H capacity covers the grid: compile the session with
    ``Schedule(h_cap=max(hs))`` and every H value becomes a mask over
    the same drawn coordinate stream;
  * a ``schedules`` axis changes the plan, so each schedule compiles its
    own program (the lambda-free executor cache still deduplicates), and
    its (lambda x local-H x seed) sub-batch fuses as above;
  * the mesh backend fuses the same way: the per-shard program is
    ``jax.vmap``-ped over the config axis INSIDE the ``shard_map``
    (collectives batch elementwise, so every member's psum /
    reduce-scatter sync is bitwise the standalone one) -- ONE sharded
    dispatch per chunk for the whole group, under either ``mesh_sync``;
  * compressed plans (and ``Schedule(acceleration=)`` groups) fuse
    through the BATCHED state-carry executors: per-member error-feedback
    residuals and server-momentum anchors ride the vmapped chunk carry;
  * ``continuation=True`` batches every lambda stage over the non-lambda
    (local-H x seed) axes: stage k+1 warm-starts from stage k's stacked
    duals with the primal rebuilt per member (``w = X^T alpha /
    (lam m)``), so a path over B chains costs ``len(lams)`` fused
    dispatch sequences instead of ``B * len(lams)`` sequential runs;
  * only checkpointed fleets of stateful or continuation groups fall
    back to member-at-a-time runs (their per-member snapshot payloads
    carry state a stacked group file cannot), still through the same
    cached executors -- with history pulled to the host AFTER the member
    loop, never inside it.

Every member is bit-identical to the corresponding standalone
``Session.run`` (asserted in ``tests/test_sweep.py``).  That guarantee
extends to histories because each member's objective is evaluated by the
SAME memoized jitted objective the single-run path uses -- B small
dispatches per recorded round (a vmapped objective would be one dispatch
but could reassociate reductions).  The one-dispatch-per-round fusion
claim is therefore about the solve path; with ``record_history=True``
use ``history_every=k`` to amortize the recording cost on long runs.

``continuation=True`` turns the lambda axis into a warm-started
regularization path: members are solved in descending-lambda order, each
warm-started from the previous member's ``(alpha, w)`` (with its own RNG
chain, so each member still reproduces as a standalone warm-started run).
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import os
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.schedule import Schedule
from repro.core.engine import host as host_mod
from repro.core.engine import plan as plan_mod
from repro.core.instrument import (
    SolveResult, history_row, record_round, stack_histories)

Array = jax.Array

_MAXIMIZE = {"dual"}          # every other metric is minimized


@dataclasses.dataclass(frozen=True)
class SweepPoint:
    """One resolved config of a :class:`Sweep` (its position on each
    axis); ``schedule`` is an index into ``Sweep.schedules`` (``None`` =
    the session's own schedule), ``seed`` an int / PRNG key (``None`` =
    the default key, as in ``Session.run``), ``local_h`` the runtime
    local-iteration count (scalar or per-leaf; ``None`` = the session
    schedule's own H)."""
    index: int
    lam: float
    seed: Optional[object] = None
    schedule: Optional[int] = None
    local_h: Optional[object] = None
    lr: Optional[float] = None

    def key(self):
        if self.seed is None:
            return jax.random.PRNGKey(0)
        if isinstance(self.seed, (int, np.integer)):
            return jax.random.PRNGKey(int(self.seed))
        return self.seed

    def to_dict(self) -> dict:
        seed = self.seed
        if isinstance(seed, np.integer):
            seed = int(seed)              # np.int64 is not JSON-serializable
        elif seed is not None and not isinstance(seed, int):
            seed = np.asarray(plan_mod._raw_key(seed)).tolist()
        h = self.local_h
        if h is not None:
            h = int(h) if np.ndim(h) == 0 else \
                [int(v) for v in np.asarray(h).reshape(-1)]
        out = {"lam": float(self.lam),
               "seed": seed,
               "schedule": self.schedule,
               "local_h": h}
        if self.lr is not None:        # LM-only axis; SDCA dicts unchanged
            out["lr"] = float(self.lr)
        return out


@dataclasses.dataclass(frozen=True)
class Sweep:
    """A declarative config grid.

    * ``lams`` -- regularization values (default: the problem's lambda);
    * ``seeds`` -- RNG seeds (ints) or explicit PRNG keys (default: the
      session default key);
    * ``schedules`` -- :class:`~repro.api.schedule.Schedule` objects
      (default: the session's schedule);
    * ``local_hs`` -- runtime local-iteration counts (scalars or per-leaf
      sequences; default: the session schedule's own H).  H is a runtime
      step-mask input of the executors, so the whole axis shares ONE
      compiled program -- compile the session with a covering
      ``Schedule(h_cap=...)``;
    * ``mode`` -- ``"grid"`` takes the cartesian product of the provided
      axes (schedules outermost, then lams, then local_hs, then seeds);
      ``"zip"`` pairs them elementwise (all provided axes must share one
      length);
    * ``continuation=True`` -- warm-started regularization path over the
      lambda axis (descending lambda), per (schedule, local_h, seed)
      chain.
    * ``resume=`` -- a fleet checkpoint directory a previous
      ``run_sweep(..., checkpoint=...)`` of the SAME spec wrote
      (validated against its ``fleet.json``): completed members restore
      instantly, interrupted members continue from their newest
      snapshot, untouched members run from scratch -- every member
      bit-identical to its uninterrupted run, on any process / mesh.
    """
    lams: Optional[Sequence[float]] = None
    seeds: Optional[Sequence] = None
    schedules: Optional[Sequence[Schedule]] = None
    local_hs: Optional[Sequence] = None
    lrs: Optional[Sequence[float]] = None
    mode: str = "grid"
    continuation: bool = False
    resume: Optional[Union[str, os.PathLike]] = None

    def __post_init__(self):
        if self.mode not in ("grid", "zip"):
            raise ValueError(f"mode must be 'grid' or 'zip', got "
                             f"{self.mode!r}")
        if all(ax is None for ax in (self.lams, self.seeds,
                                     self.schedules, self.local_hs,
                                     self.lrs)):
            raise ValueError("a Sweep needs at least one axis: lams=, "
                             "seeds=, schedules=, local_hs=, or lrs=")
        for name, ax in (("lams", self.lams), ("seeds", self.seeds),
                         ("schedules", self.schedules),
                         ("local_hs", self.local_hs), ("lrs", self.lrs)):
            if ax is not None and len(ax) == 0:
                raise ValueError(f"{name} must be non-empty when given")
        if self.mode == "zip":
            sizes = {len(ax) for ax in (self.schedules, self.lams,
                                        self.lrs, self.local_hs, self.seeds)
                     if ax is not None}
            if len(sizes) > 1:
                raise ValueError(
                    f"mode='zip' needs equal-length axes, got lengths "
                    f"{sorted(sizes)}")
        if self.continuation:
            if self.lams is None:
                raise ValueError("continuation=True needs a lams= axis "
                                 "to chain over")
            if self.mode != "grid":
                raise ValueError("continuation=True needs mode='grid' so "
                                 "every (schedule, seed) chain covers the "
                                 "full lambda path")

    @property
    def shape(self) -> Tuple[int, ...]:
        """Lengths of the PROVIDED axes, (schedules, lams, local_hs,
        seeds) order for ``"grid"``; the common (post-init-validated)
        length for ``"zip"``."""
        sizes = [len(ax) for ax in (self.schedules, self.lams, self.lrs,
                                    self.local_hs, self.seeds)
                 if ax is not None]
        if self.mode == "zip":
            return (sizes[0],)
        return tuple(sizes)

    def expand(self, default_lam: float) -> List[SweepPoint]:
        """Resolve the axes into the flat config list (B points)."""
        if self.mode == "zip":
            B = self.shape[0]
            return [
                SweepPoint(
                    index=i,
                    lam=float(self.lams[i]) if self.lams is not None
                    else float(default_lam),
                    seed=self.seeds[i] if self.seeds is not None else None,
                    schedule=i if self.schedules is not None else None,
                    local_h=(self.local_hs[i]
                             if self.local_hs is not None else None),
                    lr=(float(self.lrs[i])
                        if self.lrs is not None else None))
                for i in range(B)
            ]
        scheds = (range(len(self.schedules))
                  if self.schedules is not None else [None])
        lams = ([float(v) for v in self.lams]
                if self.lams is not None else [float(default_lam)])
        lrs = ([float(v) for v in self.lrs]
               if self.lrs is not None else [None])
        hs = list(self.local_hs) if self.local_hs is not None else [None]
        seeds = list(self.seeds) if self.seeds is not None else [None]
        return [
            SweepPoint(index=i, lam=lam, seed=seed, schedule=si,
                       local_h=h, lr=lr)
            for i, (si, lam, lr, h, seed) in enumerate(
                itertools.product(scheds, lams, lrs, hs, seeds))
        ]


@dataclasses.dataclass
class RunSet:
    """The result of a sweep: stacked ``(B, ...)`` iterates, one batched
    history (``(B, T)`` arrays, NaN-padded where schedules recorded fewer
    rounds), and per-config :class:`SolveResult` views (``rs[i]``)."""
    points: List[SweepPoint]
    alphas: Array                             # (B, m)
    ws: Array                                 # (B, d)
    history: Optional[Dict[str, np.ndarray]]  # {field: (B, T)} or None
    next_keys: List
    shape: Tuple[int, ...]

    def __len__(self) -> int:
        return len(self.points)

    def __iter__(self):
        return (self[i] for i in range(len(self)))

    def __getitem__(self, i: int) -> SolveResult:
        """Member ``i`` as a standalone :class:`SolveResult` view."""
        hist = [] if self.history is None else history_row(self.history, i)
        return SolveResult(alpha=self.alphas[i], w=self.ws[i],
                           history=hist, next_key=self.next_keys[i],
                           lam=self.points[i].lam)

    # ---- batched history accessors ----------------------------------
    def _field(self, name: str) -> np.ndarray:
        if self.history is None:
            raise ValueError("this sweep ran with record_history=False")
        return self.history[name]

    @property
    def times(self) -> np.ndarray:
        return self._field("time")

    @property
    def duals(self) -> np.ndarray:
        return self._field("dual")

    @property
    def primals(self) -> np.ndarray:
        return self._field("primal")

    @property
    def gaps(self) -> np.ndarray:
        return self._field("gap")

    def final(self, metric: str = "gap") -> np.ndarray:
        """Each member's last recorded value of ``metric`` (B,)."""
        series = self._field(metric)
        out = np.full((len(self),), np.nan)
        for b in range(len(self)):
            finite = np.nonzero(np.isfinite(series[b]))[0]
            if len(finite):
                out[b] = series[b, finite[-1]]
        return out

    def best_index(self, metric: str = "gap") -> int:
        """Index of the best member by final ``metric`` (gap/primal/time
        minimized, dual maximized)."""
        vals = self.final(metric)
        if not np.isfinite(vals).any():
            raise ValueError(f"no member recorded a finite {metric!r}")
        if metric in _MAXIMIZE:
            return int(np.nanargmax(vals))
        return int(np.nanargmin(vals))

    def best(self, metric: str = "gap") -> SolveResult:
        return self[self.best_index(metric)]

    def to_dict(self) -> dict:
        """JSON-serializable form: configs, final metrics, the batched
        history (NaN -> None), and the stacked iterates."""
        def _clean(arr):
            return [[None if not np.isfinite(v) else float(v) for v in row]
                    for row in np.asarray(arr)]
        out = {
            "shape": list(self.shape),
            "configs": [p.to_dict() for p in self.points],
            "alphas": np.asarray(self.alphas).tolist(),
            "ws": np.asarray(self.ws).tolist(),
        }
        if self.history is not None:
            out["history"] = {f: _clean(a) for f, a in self.history.items()}
            out["final_gap"] = [None if not np.isfinite(v) else float(v)
                                for v in self.final("gap")]
        return out


# ---------------------------------------------------------------------------
# execution
# ---------------------------------------------------------------------------
def _session_for(session, spec: Sweep, schedule_index):
    """The (sub)session a schedule-group runs through: the caller's own
    session for ``None``, else a fresh compile of that Schedule -- the
    lambda-free executor cache deduplicates the programs underneath."""
    if schedule_index is None:
        return session
    from repro.api.session import Session
    return Session.compile(
        session.problem, session.topology, spec.schedules[schedule_index],
        backend=session.backend, mesh=session._mesh,
        mesh_axes=session._mesh_axes,
        mesh_use_kernel=session._mesh_use_kernel,
        mesh_sync=session._mesh_sync)


def _steps_for_point(gsess, pt: SweepPoint) -> np.ndarray:
    """The (S, n, h_max) runtime step mask member ``pt`` executes: its own
    ``local_h`` when the point sits on an H axis, else the session
    schedule's runtime H, else the full compiled capacity."""
    plan = gsess.plan
    h = pt.local_h if pt.local_h is not None else gsess.resolved.runtime_h
    return plan_mod.full_steps(plan) if h is None else \
        plan_mod.steps_for_h(plan, h)


def _fleet_every(policy, resolved) -> int:
    """Resolve a fleet policy's ``every`` against a group's schedule."""
    every = policy.every
    if every == "auto":
        every = getattr(resolved, "ckpt_every", None)
        if every is None:
            raise ValueError(
                "CheckpointPolicy(every='auto') needs a schedule compiled "
                "with DelayModel(mtbf=..., ckpt_write=...)")
    return int(every)


def _run_group_batched(gsess, pts: List[SweepPoint], rounds, record_history,
                       history_every, fleet=None, warm=None):
    """The fused path: all of a schedule-group's (lambda x local-H x seed)
    configs through ONE batched chunk program per root round -- lambda
    enters as the per-config ``lm`` scalar, the H axis as the per-config
    step-mask operand.  On the host backends that is a ``jax.vmap`` over
    the flat executor; on the mesh backend the per-shard program vmaps
    over the config axis INSIDE the ``shard_map`` (collectives batch
    elementwise, bitwise the standalone sync).  Compressed and
    accelerated groups dispatch through the batched STATE-CARRY
    executors, whose vmapped carry threads per-member error-feedback
    residuals and momentum anchors across chunks.

    ``warm`` is an optional stacked warm start ``(alphas (B, m),
    ws (B, d))`` -- the continuation path's stage hand-off.

    ``fleet`` is ``(policy, group_dir, resuming)`` when the sweep
    checkpoints: the group snapshots its stacked ``(B, m)/(B, d)``
    iterates at chunk boundaries (ONE file per group, not per member --
    all members advance in lockstep in this path), and a resume restores
    the stack, re-derives the per-member key plans from the (validated
    identical) spec, and continues the loop mid-run bit-identically.
    Only stateless groups take this path with a fleet (a stacked
    ``(a, w)`` file cannot carry residual/anchor state)."""
    from repro.api.session import _objective
    prob, plan, resolved = gsess.problem, gsess.plan, gsess.resolved
    X, y, loss = prob.X, prob.y, prob.loss
    m = prob.m
    mesh = gsess.backend == "mesh"
    accelerated = gsess.acceleration is not None
    use_state = plan.has_compression or accelerated
    T = resolved.rounds if rounds is None else int(rounds)
    every = int(history_every)
    if every < 1:
        raise ValueError(f"history_every must be >= 1, got {every}")
    chunk = resolved.chunk_tree
    K_root = len(chunk.children)
    # per-member simulated round time: an H-axis member's clock charges
    # its own runtime H, exactly as the standalone run does
    dts = [resolved.round_time_for(
        pt.local_h if pt.local_h is not None else resolved.runtime_h)
        for pt in pts]
    B = len(pts)

    raw_keys = [plan_mod._raw_key(pt.key()) for pt in pts]
    keys_np = np.stack([
        plan_mod.chunked_key_plan(chunk, plan, k, T) for k in raw_keys])
    steps_np = np.stack([_steps_for_point(gsess, pt) for pt in pts])
    lms = jnp.stack([host_mod.regularizer_scale(pt.lam, m, X.dtype)
                     for pt in pts])
    acc_args = (jnp.asarray(float(gsess.acceleration), X.dtype),) \
        if accelerated else ()

    exec_b = fnb = None
    if mesh:
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.engine import mesh as mesh_mod
        sh_b = NamedSharding(
            gsess._mesh, P(None, tuple(reversed(gsess._mesh_axes))))
        mkw = dict(axes=gsess._mesh_axes, loss=loss,
                   use_kernel=gsess._mesh_use_kernel,
                   sync=gsess._mesh_sync, batched=True)
        if use_state:
            exec_b = mesh_mod.get_mesh_executor(
                plan, gsess._mesh, carry_state=True,
                accelerated=accelerated, **mkw)
        else:
            fnb = mesh_mod.get_mesh_executor(plan, gsess._mesh, **mkw)
        # mesh operand layouts put the leaf dim ahead of the tick dim
        # (exactly the per-round transposes the standalone run applies)
        keys_all = jnp.asarray(keys_np.transpose(0, 1, 3, 2, 4))
        part = jax.device_put(
            jnp.asarray(plan_mod.full_participation(plan), X.dtype).T,
            gsess._spec_sharding)
        steps = jax.device_put(
            jnp.asarray(steps_np.transpose(0, 2, 1, 3), X.dtype), sh_b)
    else:
        if use_state:
            exec_b = host_mod.get_host_executor(
                plan, loss=loss, record_history=False,
                backend=gsess.backend, carry_state=True, batched=True,
                accelerated=accelerated)
        else:
            fnb = host_mod.get_host_executor(
                plan, loss=loss, record_history=False,
                backend=gsess.backend, batched=True)
        keys_all = jnp.asarray(keys_np)               # (B, T, S, n, 2)
        part = jnp.asarray(plan_mod.full_participation(plan))
        steps = jnp.asarray(steps_np)                 # (B, S, n, h_max)

    if warm is not None:
        a = jnp.asarray(warm[0], X.dtype)
        w = jnp.asarray(warm[1], X.dtype)
    else:
        a = jnp.zeros((B, m), X.dtype)
        w = jnp.zeros((B, prob.d), X.dtype)

    mgr, ck_every, t0 = None, 0, 0
    hist_prefix: List[List[dict]] = [[] for _ in pts]
    if fleet is not None:
        from repro.runtime.checkpoint import CheckpointManager
        policy, gdir, resuming = fleet
        mgr = CheckpointManager(directory=str(gdir), keep=policy.keep,
                                async_save=policy.async_save)
        ck_every = _fleet_every(policy, resolved)
        if resuming and mgr.latest_step() is not None:
            meta = mgr.metadata()
            if meta.get("plan") != plan.fingerprint:
                raise ValueError(
                    "fleet group checkpoint was written under a different "
                    "plan; resume with the identical spec and session")
            if int(meta["rounds_total"]) != T:
                raise ValueError(
                    f"fleet group was launched for {meta['rounds_total']} "
                    f"rounds, this resume asks for {T}")
            template = {"a": np.zeros((B, m), X.dtype),
                        "w": np.zeros((B, prob.d), X.dtype)}
            t0, payload = mgr.restore(template)
            a = jnp.asarray(payload["a"])
            w = jnp.asarray(payload["w"])
            hist_prefix = [list(h) for h in meta.get(
                "histories", [[] for _ in pts])]

    # deferred history: queue the (tiny) objective dispatches inside the
    # chunk loop and pull everything to the host ONCE at the end, so
    # recording never forces a per-round device sync.  Values come from
    # the SAME memoized jitted objective the single-run path records
    # with, per config -- batched members' histories are bit-identical to
    # their standalone runs'.
    recorded: List[tuple] = []        # (t, [(dual, primal)] * B)

    def rec(t, a_batch):
        recorded.append((t, [
            _objective(a_batch[b], X, y, loss, float(pt.lam))
            for b, pt in enumerate(pts)]))

    def hists_now() -> List[List[dict]]:
        out = [list(h) for h in hist_prefix]
        if recorded:
            # ONE explicit device_get for every queued objective scalar
            vals = jax.device_get([v for _, v in recorded])
            for (t_r, _), vrow in zip(recorded, vals, strict=True):
                for b, (dv, pv) in enumerate(vrow):
                    record_round(out[b], t_r, t_r * dts[b], float(dv),
                                 float(pv))
        return out

    state = None
    if use_state:
        state = exec_b.init(X, a, w)
    elif mesh:
        a = a.reshape(B, plan.n_leaves, plan.m_b)

    def a_flat():
        if use_state:
            return exec_b.finalize(state)[0]
        return a.reshape(B, m) if mesh else a

    if record_history and t0 == 0:
        rec(0, a_flat())
    for t in range(t0 + 1, T + 1):
        if mesh:
            kys = jax.device_put(keys_all[:, t - 1], sh_b)
            if use_state:
                state = exec_b.step(gsess._Xs, gsess._ys, state, kys,
                                    part, steps, lms, *acc_args)
            else:
                a, wrows = fnb(gsess._Xs, gsess._ys, a, w, kys, part,
                               steps, lms)
                w = wrows[:, 0]
        elif use_state:
            state = exec_b.step(X, y, keys_all[:, t - 1], state, part,
                                steps, lms, *acc_args)
        else:
            a, w = fnb(X, y, keys_all[:, t - 1], a, w, part, steps, lms)
        if record_history and (t % every == 0 or t == T):
            rec(t, a_flat())
        if mgr is not None and (t % ck_every == 0 or t == T):
            mgr.save(t, {"a": a.reshape(B, m) if mesh else a, "w": w},
                     {"round": t, "rounds_total": T,
                      "plan": plan.fingerprint,
                      "histories": hists_now()})
    next_keys = [plan_mod.advance_root_key(k, T, K_root) for k in raw_keys]
    if mgr is not None:
        mgr.wait()

    if use_state:
        a, w = exec_b.finalize(state)
    elif mesh:
        a = a.reshape(B, m)
    histories = hists_now()
    results = [
        SolveResult(alpha=a[b], w=w[b], history=histories[b],
                    next_key=next_keys[b], lam=pts[b].lam)
        for b in range(B)
    ]
    return results


def _member_result(gsess, pt: SweepPoint, rounds, record_history,
                   history_every, warm, fleet):
    """One sequential member, optionally through its own per-member
    checkpoint directory (``member_<index>`` under the fleet root): on
    resume, a completed member restores instantly (its final round is
    always snapshotted), an interrupted one continues mid-run, and an
    untouched one runs from scratch -- each bit-identical to its
    uninterrupted run."""
    if fleet is None:
        return gsess.run(rounds, key=pt.key(), lam=pt.lam,
                         local_h=pt.local_h, warm_start=warm,
                         record_history=record_history,
                         history_every=history_every,
                         _defer_history=True)
    policy, root, resuming = fleet
    mp = dataclasses.replace(
        policy, directory=str(Path(root) / f"member_{pt.index:04d}"))
    if resuming:
        try:
            return gsess.resume(mp, record_history=record_history,
                                history_every=history_every, lam=pt.lam,
                                local_h=pt.local_h)
        except FileNotFoundError:
            pass                      # never started: fall through
    return gsess.run(rounds, key=pt.key(), lam=pt.lam, local_h=pt.local_h,
                     warm_start=warm, record_history=record_history,
                     history_every=history_every, checkpoint=mp,
                     _defer_history=True)


def _run_group_continuation(gsess, pts: List[SweepPoint], rounds,
                            record_history, history_every):
    """The fused regularization path: one BATCHED stage per lambda value
    (descending), vectorized over the non-lambda (local-H x seed) chain
    axes.  Stage k+1 warm-starts every chain from stage k's stacked dual
    iterates; the primal is REBUILT per member under the new lambda (the
    invariant is ``w = X^T alpha / (lam m)``, so the previous stage's w
    is inconsistent once lambda changes) by the SAME unbatched
    ``w_of_alpha`` the standalone warm-started run applies -- each
    member stays bit-identical to its sequential chain."""
    from repro.core.dual import w_of_alpha
    X = gsess.problem.X
    stages: Dict[float, List[SweepPoint]] = {}
    for pt in pts:
        stages.setdefault(float(pt.lam), []).append(pt)

    def chain_key(p: SweepPoint):
        return (repr(p.local_h), repr(p.seed))

    results: Dict[int, SolveResult] = {}
    prev: Optional[List[SolveResult]] = None
    for lam in sorted(stages, reverse=True):
        # grid expansion gives every lambda stage the same chain set;
        # sorting by chain key aligns stage b with its warm-start source
        spts = sorted(stages[lam], key=chain_key)
        warm = None
        if prev is not None:
            warm = (jnp.stack([r.alpha for r in prev]),
                    jnp.stack([w_of_alpha(r.alpha, X, lam) for r in prev]))
        stage_res = _run_group_batched(gsess, spts, rounds, record_history,
                                       history_every, warm=warm)
        for pt, res in zip(spts, stage_res, strict=True):
            results[pt.index] = res
        prev = stage_res
    return [results[pt.index] for pt in pts]


def _run_group_sequential(gsess, pts: List[SweepPoint], rounds,
                          record_history, history_every, continuation,
                          fleet=None):
    """Member-at-a-time fallback -- ONLY for checkpointed fleets whose
    members need per-member snapshot state (continuation chains,
    compressed/accelerated carries); every member still reuses the
    group's one cached lambda-free executor.  History recording stays
    deferred inside each member's run and is materialized HERE, after
    the member loop -- one explicit transfer per member at the end, no
    device sync inside the loop."""
    from repro.api.session import materialize_history
    results = {}
    if continuation:
        # per-seed chains over the lambda path, strongest regularization
        # first; each member is warm-started from the previous one's dual
        # iterate with its OWN key, so it reproduces standalone.  The
        # primal must be REBUILT under the new lambda (the invariant is
        # w = X^T alpha / (lam m), so the previous w is inconsistent
        # once lambda changes).
        from repro.core.dual import w_of_alpha
        X = gsess.problem.X
        chains: Dict[object, List[SweepPoint]] = {}
        for pt in pts:
            chains.setdefault((repr(pt.seed), repr(pt.local_h)),
                              []).append(pt)
        for chain in chains.values():
            prev = None
            for pt in sorted(chain, key=lambda p: -p.lam):
                warm = None if prev is None \
                    else (prev.alpha, w_of_alpha(prev.alpha, X, pt.lam))
                res = _member_result(gsess, pt, rounds, record_history,
                                     history_every, warm, fleet)
                results[pt.index] = res
                prev = res
    else:
        for pt in pts:
            results[pt.index] = _member_result(
                gsess, pt, rounds, record_history, history_every, None,
                fleet)
    for res in results.values():
        materialize_history(res.history)
    return [results[pt.index] for pt in pts]


def _fleet_policy(checkpoint, spec: Sweep):
    """Normalize ``run_sweep``'s ``checkpoint=`` / ``Sweep.resume`` pair
    into one :class:`~repro.runtime.fault.CheckpointPolicy` rooted at the
    fleet directory (or ``None`` when the sweep doesn't checkpoint)."""
    from repro.runtime.fault import CheckpointPolicy
    if isinstance(checkpoint, (str, os.PathLike)):
        checkpoint = CheckpointPolicy(directory=str(checkpoint))
    if spec.resume is None:
        return checkpoint
    if checkpoint is not None and \
            str(checkpoint.directory) != str(spec.resume):
        raise ValueError(
            f"Sweep(resume={str(spec.resume)!r}) and checkpoint directory "
            f"{str(checkpoint.directory)!r} disagree; point both at the "
            "interrupted fleet")
    if checkpoint is None:
        checkpoint = CheckpointPolicy(directory=str(spec.resume))
    return checkpoint


def run_sweep(session, spec: Sweep, *, rounds=None, record_history=True,
              history_every=1, checkpoint=None) -> RunSet:
    """Execute ``spec`` through ``session`` (the engine behind
    ``Session.sweep``); see the module docstring for the batching
    rules.

    ``checkpoint`` (a directory or
    :class:`~repro.runtime.fault.CheckpointPolicy`) makes the fleet
    resumable: the root holds a ``fleet.json`` spec record, fused groups
    snapshot their stacked iterates under ``group_<i>/``, sequential
    members checkpoint individually under ``member_<i>/``.  A later
    ``Sweep(resume=<dir>)`` of the IDENTICAL spec (validated) continues
    the interrupted fleet -- on any process or mesh -- with every member
    bit-identical to its uninterrupted run."""
    if spec.lrs is not None:
        raise ValueError(
            "lrs= is an LM-training axis (the optimizer step size); SDCA "
            "has no learning rate -- sweep lams= instead, or compile an "
            "LM session (Problem.lm) and sweep through it")
    points = spec.expand(float(session.problem.lam))
    policy = _fleet_policy(checkpoint, spec)
    resuming = spec.resume is not None
    fleet_root = None
    if policy is not None:
        fleet_root = Path(str(policy.directory))
        fleet_root.mkdir(parents=True, exist_ok=True)
        cfg = {"points": [p.to_dict() for p in points],
               "rounds": None if rounds is None else int(rounds)}
        cfg_path = fleet_root / "fleet.json"
        if resuming and cfg_path.exists():
            old = json.loads(cfg_path.read_text())
            if old != cfg:
                raise ValueError(
                    "fleet.json mismatch: this Sweep's (points, rounds) "
                    "differ from the interrupted fleet's; resume with the "
                    "identical spec")
        else:
            cfg_path.write_text(json.dumps(cfg))

    groups: Dict[Optional[int], List[SweepPoint]] = {}
    for pt in points:
        groups.setdefault(pt.schedule, []).append(pt)

    results: List[Optional[SolveResult]] = [None] * len(points)
    for sidx in sorted(groups, key=lambda s: (s is not None, s)):
        pts = groups[sidx]
        gsess = _session_for(session, spec, sidx)
        # every backend fuses, including mesh (vmap inside shard_map) and
        # compressed/accelerated plans (batched state-carry executors).
        # Only a checkpointed fleet whose members need per-member snapshot
        # state -- a continuation chain, or residual/anchor carry a
        # stacked (a, w) group file cannot hold -- runs sequentially.
        use_state = (gsess.plan.has_compression
                     or gsess.acceleration is not None)
        fuse = policy is None or not (spec.continuation or use_state)
        gfleet = None
        if policy is not None:
            gname = f"group_{sidx}" if sidx is not None else "group_base"
            gdir = fleet_root / gname if fuse else fleet_root
            gfleet = (policy, gdir, resuming)
        if fuse and spec.continuation:
            group_res = _run_group_continuation(
                gsess, pts, rounds, record_history, history_every)
        elif fuse:
            group_res = _run_group_batched(
                gsess, pts, rounds, record_history, history_every,
                fleet=gfleet)
        else:
            group_res = _run_group_sequential(
                gsess, pts, rounds, record_history, history_every,
                spec.continuation, fleet=gfleet)
        for pt, res in zip(pts, group_res, strict=True):
            results[pt.index] = res

    history = None
    if record_history:
        history = stack_histories([r.history for r in results])
    return RunSet(
        points=points,
        alphas=jnp.stack([jnp.asarray(r.alpha) for r in results]),
        ws=jnp.stack([jnp.asarray(r.w) for r in results]),
        history=history,
        next_keys=[r.next_key for r in results],
        shape=spec.shape,
    )


def sweep(
    problem,
    topology,
    spec: Optional[Sweep] = None,
    schedule: Optional[Schedule] = None,
    *,
    backend: str = "vmap",
    lams: Optional[Sequence[float]] = None,
    seeds: Optional[Sequence] = None,
    schedules: Optional[Sequence[Schedule]] = None,
    local_hs: Optional[Sequence] = None,
    mode: str = "grid",
    continuation: bool = False,
    rounds: Optional[int] = None,
    record_history: bool = True,
    history_every: int = 1,
    checkpoint=None,
    mesh=None,
    mesh_axes=None,
    mesh_use_kernel: bool = True,
) -> RunSet:
    """One-shot convenience: ``Session.compile(...).sweep(...)``.

    ``schedule`` is the baseline schedule configs default to; a
    ``schedules`` axis (or ``spec.schedules``) overrides it per config."""
    from repro.api.session import Session
    sess = Session.compile(problem, topology, schedule, backend=backend,
                           mesh=mesh, mesh_axes=mesh_axes,
                           mesh_use_kernel=mesh_use_kernel)
    # Session.sweep raises if a spec AND inline axes are both given --
    # forward everything so the one-shot path validates identically
    return sess.sweep(spec, lams=lams, seeds=seeds, schedules=schedules,
                      local_hs=local_hs, mode=mode,
                      continuation=continuation,
                      rounds=rounds, record_history=record_history,
                      history_every=history_every, checkpoint=checkpoint)
