"""Declarative, sessionized solver API (the user-facing surface).

Four objects, one flow::

    from repro.api import Problem, Topology, Schedule, Session

    prob  = Problem(X, y, loss="squared", lam=0.05)
    topo  = Topology.two_level(2, 2, 128, root_delay=1.0, t_lp=1e-5)
    sched = Schedule.auto(t_total=8.0)          # eq.-(12) delay-aware H
    sess  = Session.compile(prob, topo, sched, backend="vmap")
    res   = sess.run()                          # SolveResult
    more  = sess.run(rounds=5, warm_start=res)  # exact continuation

``Problem`` is the data + loss (by registry name), ``Topology`` the
serializable tree network, ``Schedule`` the per-level round counts (or
``rounds="auto"`` to delegate to the paper's eq.-(12) planner), and
``Session`` the compiled binding with ``backend=`` one of
``"vmap" | "pallas" | "mesh"``.  :func:`solve` is the one-shot shorthand.

Grids are first-class: ``Session.sweep`` / :func:`sweep` run a
:class:`Sweep` over (lambda, seed, schedule, local-H) axes as BATCHED
device programs (lambda AND the local-iteration schedule are runtime
executor inputs -- the latter a step mask, see ``Schedule(h_cap=...)``
-- so a whole regularization or H grid shares one compiled chunk
program and vmaps into a single dispatch per round) and return a
:class:`RunSet` of stacked results::

    rs = sweep(prob, topo, lams=np.logspace(-3, 0, 8), seeds=[0, 1])
    rs.best().w

LM training is the second workload on the same engine:
``Problem.lm(cfg, optimizer, batch=8, seq=128)`` compiled with
``backend="mesh"`` returns an :class:`LMSession` driven by the SAME
Schedule/planner/straggler/checkpoint machinery (the plan IR is
method-agnostic; see ``repro.core.engine.method``), and ``Sweep(lrs=,
seeds=, local_hs=)`` grids fuse into one vmapped dispatch.

The legacy entry points (``tree_dual_solve``, ``cocoa_star_solve``,
``mesh_tree_dual_solve``, ``engine.solve``, ``make_treesync_step``) are
thin shims over this surface; see ``docs/api.md`` for the migration
table.
"""
from repro.api.lm import LMResult, LMRunSet, LMSession      # noqa: F401
from repro.api.problem import LMProblem, Problem            # noqa: F401
from repro.api.schedule import DelayModel, Schedule         # noqa: F401
from repro.api.session import Session, solve                # noqa: F401
from repro.api.sweep import RunSet, Sweep, sweep            # noqa: F401
from repro.api.topology import Topology                     # noqa: F401
from repro.core.instrument import SolveResult               # noqa: F401
from repro.runtime.fault import (                           # noqa: F401
    CheckpointPolicy, ElasticSession, FaultModel, MembershipLog,
    run_with_faults)

__all__ = ["Problem", "LMProblem", "Topology", "Schedule", "DelayModel",
           "Session", "LMSession", "LMResult", "LMRunSet",
           "SolveResult", "Sweep", "RunSet", "solve", "sweep",
           "CheckpointPolicy", "ElasticSession", "FaultModel",
           "MembershipLog", "run_with_faults"]
