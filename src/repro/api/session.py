"""The :class:`Session` object: Problem x Topology x Schedule -> executor.

``Session.compile`` lowers the topology once (the chunk plan: the full tree
with the root pinned to one round), fetches the memoized executor for the
chosen backend, and validates everything up front.  ``Session.run`` then
iterates that one compiled program:

  * any number of root rounds without re-tracing,
  * warm restarts (``warm_start=`` a previous result or an ``(alpha, w)``
    pair) that bit-reproduce one longer run when continued with the
    returned ``next_key``, with the history's round/time axes continuing
    where the previous run stopped,
  * streamed history (``on_round=`` fires after every root round, not just
    at the end),
  * straggler-adaptive async execution (``straggler=`` a
    :class:`~repro.runtime.straggler.StragglerPolicy`): per chunk, sampled
    per-leaf link delays decide which leaves the barrier drops; dropped
    leaves keep solving on stale snapshots and re-join later (participation
    masks, see ``repro.core.engine.plan``), and the history records the
    simulated async wall-clock next to the synchronous-equivalent one.

All three backends sit behind ``backend=``: ``"vmap"`` (host XLA),
``"pallas"`` (blocked-SDCA leaf kernel), ``"mesh"`` (``shard_map`` device
program; level-homogeneous topologies).  Chunking is exact, not
approximate: every root round ends with a root sync that refreshes every
snapshot, so (state, RNG-chain) is a complete carry and the chunked
iterates are bit-identical to the monolithic program's.
"""
from __future__ import annotations

import functools
from math import prod
from typing import Callable, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import dual as dual_mod
from repro.core import tree as tree_mod
from repro.core.engine import host as host_mod
from repro.core.engine import mesh as mesh_mod
from repro.core.engine import plan as plan_mod
from repro.core.instrument import SolveResult, record_round
from repro.api.problem import Problem
from repro.api.schedule import ResolvedSchedule, Schedule
from repro.api.topology import Topology

Array = jax.Array

BACKENDS = ("vmap", "pallas", "mesh")


# lam is a TRACED scalar: lambda sweeps hit one compiled objective instead
# of retracing per value (only the loss object stays static)
@functools.partial(jax.jit, static_argnames=("loss",))
def _objective(alpha: Array, X: Array, y: Array, loss, lam):
    w = dual_mod.w_of_alpha(alpha, X, lam)
    return (dual_mod.dual_value(alpha, X, y, loss, lam),
            dual_mod.primal_value(w, X, y, loss, lam))


class Session:
    """A compiled (problem, topology, schedule, backend) binding.

    Construct with :meth:`compile`; executors are memoized at the engine
    layer (plan fingerprint x loss x lambda x flags), so compiling the same
    configuration twice reuses one jit program -- see :meth:`cache_stats`.
    """

    def __init__(self, problem: Problem, topology: Topology,
                 resolved: ResolvedSchedule, backend: str, plan, fn,
                 mesh=None, mesh_axes=None, mesh_use_kernel: bool = True):
        self.problem = problem
        self.topology = topology
        self.resolved = resolved
        self.backend = backend
        self.plan = plan
        self._fn = fn
        self._mesh = mesh
        self._mesh_axes = mesh_axes
        self._mesh_use_kernel = mesh_use_kernel
        if backend == "mesh":
            from jax.sharding import NamedSharding, PartitionSpec as P
            spec = P(tuple(reversed(mesh_axes)))
            sh = NamedSharding(mesh, spec)
            n, m_b = plan.n_leaves, plan.m_b
            self._spec_sharding = sh
            self._Xs = jax.device_put(
                problem.X.reshape(n, m_b, problem.d), sh)
            self._ys = jax.device_put(problem.y.reshape(n, m_b), sh)

    # ------------------------------------------------------------------
    @classmethod
    def compile(
        cls,
        problem: Problem,
        topology: Topology,
        schedule: Optional[Schedule] = None,
        *,
        backend: str = "vmap",
        mesh=None,
        mesh_axes: Optional[Sequence[str]] = None,
        mesh_use_kernel: bool = True,
    ) -> "Session":
        """Lower ``topology`` under ``schedule`` and bind the ``backend``
        executor.  ``mesh``/``mesh_axes`` (axes innermost-first, as in
        ``engine.mesh``) and ``mesh_use_kernel`` (Pallas vs pure-jnp leaf
        solver) apply to ``backend="mesh"`` only; when the mesh is omitted,
        one matching the plan's per-depth fan-outs is built from the
        available devices."""
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; use {BACKENDS}")
        schedule = schedule or Schedule()
        resolved = schedule.resolve(topology)
        if problem.m != topology.m_total:
            raise ValueError(
                f"problem has m={problem.m} examples but the topology "
                f"assigns {topology.m_total}")
        plan = plan_mod.compile_tree(resolved.chunk_tree,
                                     weighting=resolved.weighting)

        if backend in ("vmap", "pallas"):
            fn = host_mod.get_host_executor(
                plan, loss=problem.loss, lam=problem.lam,
                record_history=False, backend=backend)
            return cls(problem, topology, resolved, backend, plan, fn)

        # ---- mesh backend -------------------------------------------
        if plan.levels is None:
            raise ValueError(
                "backend='mesh' needs a level-homogeneous topology "
                "(uniform per-depth fan-out/rounds, congruent leaves)")
        if resolved.weighting != "uniform":
            raise ValueError("backend='mesh' supports weighting='uniform'")
        D = plan.depth
        if mesh is None:
            sizes = [plan.levels[d].group_size for d in range(D)]  # top-down
            names = tuple(f"lvl{d}" for d in range(D))
            need = prod(sizes)
            have = len(jax.devices())
            if have < need:
                raise RuntimeError(
                    f"backend='mesh' needs {need} devices for fan-outs "
                    f"{sizes}, have {have} (set "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=N on "
                    "CPU, or pass mesh=)")
            mesh = jax.make_mesh(tuple(sizes), names,
                                 devices=jax.devices()[:need])
            mesh_axes = tuple(reversed(names))       # innermost first
        elif mesh_axes is None:
            raise ValueError("pass mesh_axes (innermost level first) "
                             "together with an explicit mesh")
        fn = mesh_mod.get_mesh_executor(
            plan, mesh, axes=tuple(mesh_axes), loss=problem.loss,
            lam=problem.lam, use_kernel=mesh_use_kernel)
        return cls(problem, topology, resolved, backend, plan, fn,
                   mesh=mesh, mesh_axes=tuple(mesh_axes),
                   mesh_use_kernel=mesh_use_kernel)

    # ------------------------------------------------------------------
    @property
    def level_plan(self):
        """The eq.-(12) planner output when the schedule was ``"auto"``."""
        return self.resolved.level_plan

    @property
    def default_rounds(self) -> int:
        return self.resolved.rounds

    @staticmethod
    def cache_stats() -> dict:
        """Engine-layer executor-cache counters (hits/misses/size)."""
        return host_mod.executor_cache_stats()

    # ------------------------------------------------------------------
    def run(
        self,
        rounds: Optional[int] = None,
        *,
        key: Optional[Array] = None,
        warm_start: Union[SolveResult, Tuple[Array, Array], None] = None,
        record_history: bool = True,
        on_round: Optional[Callable[[dict], None]] = None,
        straggler=None,
    ) -> SolveResult:
        """Run ``rounds`` root rounds (default: the schedule's).

        ``warm_start`` continues from a previous state; passing the previous
        :class:`SolveResult` also continues its RNG chain (``next_key``)
        unless ``key`` overrides it, making split runs bit-identical to one
        long run -- and continues the history's round/time axes, so split
        histories concatenate into one monotone series.  ``on_round(entry)``
        streams each history entry as it is produced (requires
        ``record_history=True``).

        ``straggler`` (a :class:`~repro.runtime.straggler.StragglerPolicy`)
        switches the run to straggler-adaptive async execution: each chunk,
        the policy samples per-leaf sync delays from the topology's nominal
        link delays, drops straggling leaves from the barrier (bounded
        consecutive skips; dropped leaves keep solving on stale snapshots
        and re-join with renormalized weights), and the history's ``time``
        axis accrues the simulated *async* wall-clock, with the
        synchronous-equivalent time in ``time_sync`` and the participant
        count in ``participants``.  The final chunk always runs a full
        barrier so the returned iterates satisfy ``w = A alpha``.  An
        always-participate policy is bit-identical to the synchronous
        run."""
        T = self.resolved.rounds if rounds is None else int(rounds)
        if T < 0:
            raise ValueError(f"rounds must be >= 0, got {T}")
        X, y = self.problem.X, self.problem.y
        loss, lam = self.problem.loss, self.problem.lam
        m = self.problem.m

        alpha, w, k = self._start_state(warm_start, key)
        K_root = len(self.resolved.chunk_tree.children)
        chunk_tree, plan = self.resolved.chunk_tree, self.plan
        dt = self.resolved.per_round_time

        # warm restarts continue the history axes instead of resetting the
        # clock to zero and duplicating the warm state as a round-0 entry
        t0_round, t0_time = 0, 0.0
        record_initial = True
        if isinstance(warm_start, SolveResult) and warm_start.history:
            t0_round = int(warm_start.history[-1]["round"])
            t0_time = float(warm_start.history[-1]["time"])
            record_initial = False

        mesh = self.backend == "mesh"
        state_exec = None
        if straggler is not None:
            t_compute = tree_mod.strip_delays(chunk_tree).solve_time()
            t_lp = max([l.t_lp for l in chunk_tree.leaves()])
            straggler.bind(self.topology.leaf_sync_delays(), t_compute,
                           t_lp=t_lp)
            # the flat (alpha, w) pair is not a complete carry once leaves
            # can skip syncs (absent leaves keep divergent replicas and
            # stale snapshots), so async runs thread the executors' full
            # blocked state across chunks instead
            if mesh:
                state_exec = mesh_mod.get_mesh_executor(
                    plan, self._mesh, axes=self._mesh_axes,
                    loss=self.problem.loss, lam=self.problem.lam,
                    use_kernel=self._mesh_use_kernel, carry_state=True)
            else:
                state_exec = host_mod.get_host_executor(
                    plan, loss=self.problem.loss, lam=self.problem.lam,
                    record_history=False, backend=self.backend,
                    carry_state=True)
        if mesh:
            a_carry = jnp.asarray(alpha, X.dtype).reshape(
                plan.n_leaves, plan.m_b)
        else:
            a_carry = jnp.asarray(alpha, X.dtype)
        w = jnp.asarray(w, X.dtype)

        history: list = []
        clock = {"async": t0_time, "sync": t0_time}

        def record(t: int, a_flat: Array, extra: Optional[dict] = None):
            if not record_history:
                return
            dv, pv = _objective(a_flat, X, y, loss, float(lam))
            time = clock["async"] if straggler is not None else \
                t0_time + t * dt
            record_round(history, t0_round + t, time, float(dv), float(pv))
            if extra:
                history[-1].update(extra)
            if on_round is not None:
                on_round(history[-1])

        # the all-ones mask is loop-invariant: convert (and, on mesh,
        # device_put) it once instead of per round
        if mesh:
            part_ones = jax.device_put(
                jnp.asarray(plan_mod.full_participation(plan), X.dtype).T,
                self._spec_sharding)
        else:
            part_ones = jnp.asarray(plan_mod.full_participation(plan))
        state = None
        if state_exec is not None:
            state = state_exec.init(X, a_carry, w)

        # all rounds' keys in one walk of the equivalent monolithic tree
        # (the legacy chain), so the chunk loop does no host RNG work
        keys_all = plan_mod.chunked_key_plan(chunk_tree, plan, k, T)
        if record_initial:
            record(0, a_carry.reshape(m) if mesh else a_carry)
        for t in range(1, T + 1):
            keys = keys_all[t - 1]
            extra = None
            prt = part_ones
            if straggler is not None:
                step = straggler.step(final=(t == T))
                part = plan_mod.chunk_participation(plan, step.mask)
                prt = jax.device_put(
                    jnp.asarray(part, X.dtype).T, self._spec_sharding) \
                    if mesh else jnp.asarray(part)
                clock["async"] += step.dt_async
                clock["sync"] += step.dt_sync
                extra = {"time_sync": clock["sync"],
                         "participants": int(step.mask.sum())}
            if mesh:
                kys = jax.device_put(
                    jnp.asarray(keys.transpose(1, 0, 2)),
                    self._spec_sharding)
                if state_exec is None:
                    a_carry, wrows = self._fn(self._Xs, self._ys, a_carry,
                                              w, kys, prt)
                    w = wrows[0]
                    record(t, a_carry.reshape(m), extra)
                else:
                    state = state_exec.step(self._Xs, self._ys, *state,
                                            kys, prt)
                    if record_history:
                        record(t, state[0].reshape(m), extra)
            elif state_exec is None:
                a_carry, w = self._fn(X, y, jnp.asarray(keys), a_carry, w,
                                      prt)
                record(t, a_carry, extra)
            else:
                state = state_exec.step(X, y, jnp.asarray(keys), state,
                                        prt)
                if record_history:
                    record(t, state_exec.finalize(state)[0], extra)
        k = plan_mod.advance_root_key(k, T, K_root)

        if state_exec is not None:
            alpha_out, w = state_exec.finalize(state)
            if mesh:
                alpha_out = alpha_out.reshape(m)
        else:
            alpha_out = a_carry.reshape(m) if mesh else a_carry
        return SolveResult(alpha=alpha_out, w=w, history=history, next_key=k)

    # ------------------------------------------------------------------
    def _start_state(self, warm_start, key):
        X = self.problem.X
        k = None if key is None else plan_mod._raw_key(key)
        if warm_start is None:
            alpha = jnp.zeros((self.problem.m,), X.dtype)
            w = jnp.zeros((self.problem.d,), X.dtype)
        elif isinstance(warm_start, SolveResult):
            alpha, w = warm_start.alpha, warm_start.w
            if k is None and warm_start.next_key is not None:
                k = plan_mod._raw_key(warm_start.next_key)
        else:
            alpha, w = warm_start
        if k is None:
            k = plan_mod._raw_key(jax.random.PRNGKey(0))
        alpha = jnp.asarray(alpha)
        w = jnp.asarray(w)
        if alpha.shape != (self.problem.m,):
            raise ValueError(
                f"warm-start alpha must be ({self.problem.m},), got "
                f"{alpha.shape}")
        if w.shape != (self.problem.d,):
            raise ValueError(
                f"warm-start w must be ({self.problem.d},), got {w.shape}")
        return alpha, w, k


def solve(
    problem: Problem,
    topology: Topology,
    schedule: Optional[Schedule] = None,
    *,
    backend: str = "vmap",
    key: Optional[Array] = None,
    rounds: Optional[int] = None,
    record_history: bool = True,
    mesh=None,
    mesh_axes: Optional[Sequence[str]] = None,
    mesh_use_kernel: bool = True,
    on_round: Optional[Callable[[dict], None]] = None,
) -> SolveResult:
    """One-shot convenience: ``Session.compile(...).run(...)``."""
    sess = Session.compile(problem, topology, schedule, backend=backend,
                           mesh=mesh, mesh_axes=mesh_axes,
                           mesh_use_kernel=mesh_use_kernel)
    return sess.run(rounds, key=key, record_history=record_history,
                    on_round=on_round)
