"""The :class:`Session` object: Problem x Topology x Schedule -> executor.

``Session.compile`` lowers the topology once (the chunk plan: the full tree
with the root pinned to one round), fetches the memoized executor for the
chosen backend, and validates everything up front.  ``Session.run`` then
iterates that one compiled program:

  * any number of root rounds without re-tracing,
  * warm restarts (``warm_start=`` a previous result or an ``(alpha, w)``
    pair) that bit-reproduce one longer run when continued with the
    returned ``next_key``, with the history's round/time axes continuing
    where the previous run stopped,
  * streamed history (``on_round=`` fires after every root round, not just
    at the end),
  * straggler-adaptive async execution (``straggler=`` a
    :class:`~repro.runtime.straggler.StragglerPolicy`): per chunk, sampled
    per-leaf link delays decide which leaves the barrier drops; dropped
    leaves keep solving on stale snapshots and re-join later (participation
    masks, see ``repro.core.engine.plan``), and the history records the
    simulated async wall-clock next to the synchronous-equivalent one.

All three backends sit behind ``backend=``: ``"vmap"`` (host XLA),
``"pallas"`` (blocked-SDCA leaf kernel), ``"mesh"`` (``shard_map`` device
program; level-homogeneous topologies).  Chunking is exact, not
approximate: every root round ends with a root sync that refreshes every
snapshot, so (state, RNG-chain) is a complete carry and the chunked
iterates are bit-identical to the monolithic program's.
"""
from __future__ import annotations

import contextlib
import functools
from math import prod
from typing import Callable, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import plan_check, trace_guard as guard_mod
from repro.core import dual as dual_mod
from repro.core import tree as tree_mod
from repro.core.engine import host as host_mod
from repro.core.engine import mesh as mesh_mod
from repro.core.engine import plan as plan_mod
from repro.core.engine.method import get_method
from repro.core.instrument import SolveResult, record_round
from repro.api.problem import Problem
from repro.api.schedule import (
    ResolvedSchedule, Schedule, leaf_h_spec, runtime_tree)
from repro.api.topology import Topology

Array = jax.Array

BACKENDS = ("vmap", "pallas", "mesh")


# lam is a TRACED scalar: lambda sweeps hit one compiled objective instead
# of retracing per value (only the loss object stays static); jit here is
# deliberate -- history recording is outside the engine's dispatch path
@functools.partial(jax.jit, static_argnames=("loss",))  # analysis: allow(jit-outside-engine)
def _objective(alpha: Array, X: Array, y: Array, loss, lam):
    w = dual_mod.w_of_alpha(alpha, X, lam)
    return (dual_mod.dual_value(alpha, X, y, loss, lam),
            dual_mod.primal_value(w, X, y, loss, lam))


def materialize_history(history) -> None:
    """Pull a deferred history's objective values to the host in ONE
    explicit ``jax.device_get`` (legal even under the strict host-sync
    guard, which blocks only IMPLICIT transfers).  ``Session.run`` records
    device scalars and calls this at stream/checkpoint/exit points; the
    sweep layer's sequential path defers further and materializes every
    member's history together, outside the member loop."""
    pending = [e for e in history if not isinstance(e["dual"], float)]
    if not pending:
        return
    vals = jax.device_get([(e["dual"], e["primal"]) for e in pending])
    for e, (dv, pv) in zip(pending, vals, strict=True):
        # recompute the gap as a host float64 subtraction so the entry is
        # bit-identical to eagerly-recorded histories
        e["dual"], e["primal"] = float(dv), float(pv)
        e["gap"] = e["primal"] - e["dual"]


class Session:
    """A compiled (problem, topology, schedule, backend) binding.

    Construct with :meth:`compile`; executors are memoized at the engine
    layer (plan fingerprint x loss x flags -- lambda is a RUNTIME input of
    the compiled program, not a cache key), so compiling the same
    configuration twice, or with a different lambda, reuses one jit
    program -- see :meth:`cache_stats`.
    """

    def __init__(self, problem: Problem, topology: Topology,
                 resolved: ResolvedSchedule, backend: str, plan, fn,
                 mesh=None, mesh_axes=None, mesh_use_kernel: bool = True,
                 mesh_sync: str = "psum",
                 acceleration: Optional[float] = None):
        self.problem = problem
        self.topology = topology
        self.resolved = resolved
        self.backend = backend
        self.plan = plan
        self._fn = fn
        self.fitted_C = None        # set when DelayModel(C="auto") calibrated
        self._guard = None          # TraceGuard when compiled strict
        self._mesh = mesh
        self._mesh_axes = mesh_axes
        self._mesh_use_kernel = mesh_use_kernel
        self._mesh_sync = mesh_sync
        # None = plain "sdca"; a float (0.0 included) = the "sdca_acc"
        # method with this server-momentum coefficient as the default
        # runtime operand
        self.acceleration = acceleration
        if backend == "mesh":
            from jax.sharding import NamedSharding, PartitionSpec as P
            spec = P(tuple(reversed(mesh_axes)))
            sh = NamedSharding(mesh, spec)
            n, m_b = plan.n_leaves, plan.m_b
            self._spec_sharding = sh
            self._Xs = jax.device_put(
                problem.X.reshape(n, m_b, problem.d), sh)
            self._ys = jax.device_put(problem.y.reshape(n, m_b), sh)

    # ------------------------------------------------------------------
    @classmethod
    def compile(
        cls,
        problem: Problem,
        topology: Topology,
        schedule: Optional[Schedule] = None,
        *,
        backend: str = "vmap",
        mesh=None,
        mesh_axes: Optional[Sequence[str]] = None,
        mesh_use_kernel: bool = True,
        mesh_sync: str = "psum",
        strict=False,
    ) -> "Session":
        """Lower ``topology`` under ``schedule`` and bind the ``backend``
        executor.  ``mesh``/``mesh_axes`` (axes innermost-first, as in
        ``engine.mesh``) and ``mesh_use_kernel`` (Pallas vs pure-jnp leaf
        solver) apply to ``backend="mesh"`` only; when the mesh is omitted,
        one matching the plan's per-depth fan-outs is built from the
        available devices.

        ``mesh_sync`` selects the mesh sync lowering: ``"psum"``
        (replicated server state, bit-identical to the host backends) or
        ``"reduce_scatter"`` (server state sharded across each sync
        group's devices -- per-device server memory drops from ``O(L*d)``
        to ``O(L*d/K)``, the big-``d`` path; full participation only, so
        it composes with compression but not with ``straggler=``).

        A non-SDCA problem (``Problem.lm(...)``) dispatches by its
        ``method`` marker to that method's session type (the plan IR is
        method-agnostic; the Method supplies local step + combine).

        ``strict`` (bool, or a :class:`repro.analysis.TraceGuard`) turns
        the run loop's performance contract into errors: an unexpected
        executor-cache miss raises ``UnexpectedRetraceError`` with a
        structured diff of the offending cache key, implicit host
        transfers inside the dispatch region raise ``HostSyncError``
        (from the second chunk on -- the first chunk's builds legally
        upload constants), and ``TraceGuard(sanitize=True)`` checks the
        chunk carry for NaN/Inf every round.  The plan-IR verifier
        (``repro.analysis.verify_plan``) runs on EVERY compile, strict
        or not."""
        if getattr(problem, "method", "sdca") not in ("sdca", None):
            from repro.api.lm import LMSession
            return LMSession.compile(problem, topology, schedule,
                                     backend=backend, mesh=mesh,
                                     strict=strict)
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; use {BACKENDS}")
        schedule = schedule or Schedule()
        if problem.m != topology.m_total:
            raise ValueError(
                f"problem has m={problem.m} examples but the topology "
                f"assigns {topology.m_total}")
        fitted_C = None
        if (schedule.rounds == "auto" and schedule.delay is not None
                and getattr(schedule.delay, "C", None) == "auto"):
            # only rounds="auto" consumes the DelayModel; an explicit-rounds
            # schedule would ignore the fitted C, so don't pay the pilot
            schedule, fitted_C = _calibrate_C(problem, topology, schedule)
        resolved = schedule.resolve(topology)
        # Schedule(acceleration=) selects the accelerated method flavor --
        # a structural executor variant; the coefficient itself stays a
        # runtime operand of the compiled programs
        acceleration = schedule.acceleration
        method = get_method("sdca_acc" if acceleration is not None
                            else "sdca")
        plan = plan_mod.compile_tree(resolved.chunk_tree,
                                     weighting=resolved.weighting,
                                     compression=resolved.compression)
        # every compiled plan passes the structural verifier (geometry,
        # schedule coherence, aggregation convexity, compression specs,
        # RNG schedule-independence, fingerprint soundness) BEFORE an
        # executor is built against it
        plan_check.verify_plan(plan)
        guard = guard_mod.as_trace_guard(strict)

        if backend in ("vmap", "pallas"):
            fn = method.executor(
                plan=plan, backend=backend, loss=problem.loss,
                record_history=False)
            sess = cls(problem, topology, resolved, backend, plan, fn,
                       acceleration=acceleration)
            sess.fitted_C = fitted_C
            sess._guard = guard
            return sess

        # ---- mesh backend -------------------------------------------
        if plan.levels is None:
            raise ValueError(
                "backend='mesh' needs a level-homogeneous topology "
                "(uniform per-depth fan-out/rounds, congruent leaves)")
        if resolved.weighting != "uniform":
            raise ValueError("backend='mesh' supports weighting='uniform'")
        if mesh_sync not in mesh_mod.SYNC_MODES:
            raise ValueError(f"unknown mesh_sync {mesh_sync!r}; use "
                             f"{mesh_mod.SYNC_MODES}")
        D = plan.depth
        if mesh is None:
            sizes = [plan.levels[d].group_size for d in range(D)]  # top-down
            names = tuple(f"lvl{d}" for d in range(D))
            need = prod(sizes)
            have = len(jax.devices())
            if have < need:
                raise RuntimeError(
                    f"backend='mesh' needs {need} devices for fan-outs "
                    f"{sizes}, have {have} (set "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=N on "
                    "CPU, or pass mesh=)")
            mesh = jax.make_mesh(tuple(sizes), names,
                                 devices=jax.devices()[:need])
            mesh_axes = tuple(reversed(names))       # innermost first
        elif mesh_axes is None:
            raise ValueError("pass mesh_axes (innermost level first) "
                             "together with an explicit mesh")
        fn = method.executor(
            plan=plan, backend="mesh", mesh=mesh, axes=tuple(mesh_axes),
            loss=problem.loss, use_kernel=mesh_use_kernel, sync=mesh_sync)
        sess = cls(problem, topology, resolved, backend, plan, fn,
                   mesh=mesh, mesh_axes=tuple(mesh_axes),
                   mesh_use_kernel=mesh_use_kernel, mesh_sync=mesh_sync,
                   acceleration=acceleration)
        sess.fitted_C = fitted_C
        sess._guard = guard
        return sess

    # ------------------------------------------------------------------
    @property
    def level_plan(self):
        """The eq.-(12) planner output when the schedule was ``"auto"``."""
        return self.resolved.level_plan

    @property
    def default_rounds(self) -> int:
        return self.resolved.rounds

    @property
    def bytes_per_round(self) -> float:
        """Simulated uplink bytes one root round ships under this plan's
        per-edge compression (``engine.plan.plan_bytes_per_round``) -- the
        quantity the delay model's bandwidth terms charge; compare against
        an uncompressed session of the same topology for the wire saving."""
        return plan_mod.plan_bytes_per_round(
            self.plan, self.problem.d,
            dtype_bytes=self.problem.X.dtype.itemsize)

    @staticmethod
    def cache_stats() -> dict:
        """Engine-layer executor-cache counters (hits/misses/size)."""
        return host_mod.executor_cache_stats()

    # ------------------------------------------------------------------
    def run(
        self,
        rounds: Optional[int] = None,
        *,
        key: Optional[Array] = None,
        warm_start: Union[SolveResult, Tuple[Array, Array], None] = None,
        record_history: bool = True,
        history_every: int = 1,
        on_round: Optional[Callable[[dict], None]] = None,
        straggler=None,
        lam: Optional[float] = None,
        local_h=None,
        acceleration: Optional[float] = None,
        checkpoint=None,
        _ef_state=None,
        _history_prefix=(),
        _defer_history: bool = False,
        _final_save: bool = True,
    ) -> SolveResult:
        """Run ``rounds`` root rounds (default: the schedule's).

        ``warm_start`` continues from a previous state; passing the previous
        :class:`SolveResult` also continues its RNG chain (``next_key``)
        unless ``key`` overrides it, making split runs bit-identical to one
        long run -- and continues the history's round/time axes, so split
        histories concatenate into one monotone series.  ``on_round(entry)``
        streams each history entry as it is produced (requires
        ``record_history=True``).

        ``history_every=k`` records only every k-th root round (plus the
        initial state and ALWAYS the final round), so very long runs don't
        pay the per-round objective evaluation; the iterates are unaffected.

        ``lam`` overrides the problem's regularization for THIS run:
        lambda is a runtime input of the cached executors (not a compile
        key), so running a whole regularization grid through one session
        never retraces -- :meth:`sweep` batches exactly this.  Warm
        starting from a :class:`SolveResult` produced under a DIFFERENT
        lambda rebuilds the primal from the dual (``w = X^T alpha /
        (lam m)``, the eq.-(13) invariant) automatically; a plain
        ``(alpha, w)`` pair is taken as-is, so rebuild ``w`` yourself
        when crossing lambdas.

        ``local_h`` overrides the LOCAL iteration count for this run -- a
        scalar or a per-leaf sequence.  The schedule is a runtime input of
        the cached executors (a step mask gating trailing coordinate
        steps; draws always cover the compiled per-leaf H capacity, so the
        RNG stream is schedule-independent): running many H values through
        one session never retraces.  Values are clamped to the compiled
        capacity -- compile with ``Schedule(h_cap=...)`` for headroom.
        Default: the schedule's own runtime H (``resolved.runtime_h``)
        when an ``h_cap`` was declared, else the full compiled H
        (bit-identical to the static program).

        ``straggler`` (a :class:`~repro.runtime.straggler.StragglerPolicy`)
        switches the run to straggler-adaptive async execution: each chunk,
        the policy samples per-leaf sync delays from the topology's nominal
        link delays, drops straggling leaves from the barrier (bounded
        consecutive skips; dropped leaves keep solving on stale snapshots
        and re-join with renormalized weights), and the history's ``time``
        axis accrues the simulated *async* wall-clock, with the
        synchronous-equivalent time in ``time_sync`` and the participant
        count in ``participants``.  The final chunk always runs a full
        barrier so the returned iterates satisfy ``w = A alpha``.  An
        always-participate policy is bit-identical to the synchronous
        run.  When the policy carries an ``adaptive=AdaptiveSchedule``,
        its replanned H is fed back into the NEXT chunk's step-mask
        operand (clamped to the compiled capacity): the session replans
        with ZERO retraces, and each chunk's executed H is recorded in the
        history (``"h"``).

        ``checkpoint`` (a directory path or a
        :class:`~repro.runtime.fault.CheckpointPolicy`) snapshots the
        exact chunk carry every ``policy.every`` root rounds (plus always
        the final round): flat (alpha, w), the advanced root RNG key and
        any error-feedback residuals, with enough metadata (plan
        fingerprint, round/time cursors, lambda, local_h, recorded
        history) that :meth:`resume` restarts bit-identically on ANY
        backend -- including a mesh with a different device count.
        Checkpointing composes with compression but not with
        ``straggler=`` (a mid-run blocked state under skipped syncs holds
        divergent per-leaf replicas the flat payload cannot represent).
        ``acceleration`` overrides the server-momentum coefficient for
        THIS run (sessions compiled with ``Schedule(acceleration=...)``
        only): the coefficient is a runtime scalar operand of the
        ``sdca_acc`` executors, so sweeping it never retraces, and ``0``
        is bit-identical to the plain method.  Accelerated runs thread
        the executors' full blocked state (the per-depth momentum
        anchors) across chunks; they compose with compression but not
        with ``straggler=`` or ``checkpoint=``.

        ``_ef_state`` / ``_history_prefix`` / ``_final_save`` are
        :meth:`resume`'s private restore hooks; ``_defer_history`` leaves
        the recorded entries' objective values as device scalars for the
        caller to materialize in one batch (:func:`materialize_history`
        -- the sweep layer's sequential path)."""
        T = self.resolved.rounds if rounds is None else int(rounds)
        if T < 0:
            raise ValueError(f"rounds must be >= 0, got {T}")
        every = int(history_every)
        if every < 1:
            raise ValueError(f"history_every must be >= 1, got {every}")
        X, y = self.problem.X, self.problem.y
        loss = self.problem.loss
        lam = self.problem.lam if lam is None else float(lam)
        m = self.problem.m
        lm_in = host_mod.regularizer_scale(lam, m, X.dtype)

        accelerated = self.acceleration is not None
        if acceleration is not None and not accelerated:
            raise ValueError(
                "this session runs the plain 'sdca' method; compile with "
                "Schedule(acceleration=...) to bind the accelerated "
                "executors (the coefficient itself is then a runtime "
                "operand)")
        acc_run = self.acceleration if acceleration is None \
            else float(acceleration)
        if accelerated and not 0.0 <= float(acc_run) <= 1.0:
            raise ValueError(
                f"acceleration must be in [0, 1], got {acc_run}")
        if accelerated and straggler is not None:
            raise ValueError(
                "acceleration does not compose with straggler=: a skipped "
                "sync leaves the momentum anchors extrapolating against "
                "stale combination states, which breaks the paired "
                "primal-dual consistency; run accelerated sessions "
                "synchronously")
        if accelerated and checkpoint is not None:
            raise ValueError(
                "acceleration does not compose with checkpoint=: the "
                "per-depth momentum anchors are part of the chunk carry "
                "but not of the flat (alpha, w, residuals) snapshot "
                "payload, so a resumed run would diverge")
        # the momentum coefficient is a RUNTIME operand of the sdca_acc
        # executors: converted once here, never part of a cache key
        acc_args = (jnp.asarray(float(acc_run), X.dtype),) \
            if accelerated else ()

        alpha, w, k = self._start_state(warm_start, key, lam)
        K_root = len(self.resolved.chunk_tree.children)
        chunk_tree, plan = self.resolved.chunk_tree, self.plan
        h_run = local_h if local_h is not None else self.resolved.runtime_h
        dt = self.resolved.round_time_for(h_run)

        # warm restarts continue the history axes instead of resetting the
        # clock to zero and duplicating the warm state as a round-0 entry
        t0_round, t0_time = 0, 0.0
        record_initial = True
        if isinstance(warm_start, SolveResult) and warm_start.history:
            t0_round = int(warm_start.history[-1]["round"])
            t0_time = float(warm_start.history[-1]["time"])
            record_initial = False

        ckpt_mgr, ck_every, k_cur = None, 0, k
        ckpt_pending, k_lag = None, 0
        if checkpoint is not None:
            if straggler is not None:
                raise ValueError(
                    "checkpoint= does not compose with straggler=: a "
                    "mid-run blocked state under skipped syncs holds "
                    "divergent per-leaf replicas and stale snapshots the "
                    "flat chunk-carry payload cannot represent; checkpoint "
                    "synchronous (or compressed) runs only")
            from repro.runtime import fault as fault_mod
            _, ckpt_mgr, ck_every = fault_mod.bind_policy(
                checkpoint, self.resolved)
            h_meta = None if local_h is None else \
                np.asarray(local_h).tolist()

        mesh = self.backend == "mesh"
        if (straggler is not None and mesh
                and self._mesh_sync == "reduce_scatter"):
            raise ValueError(
                "mesh_sync='reduce_scatter' assumes full participation "
                "(the sharded-server sync has no per-leaf gating); use "
                "mesh_sync='psum' for straggler-adaptive runs")
        state_exec = None
        if straggler is not None:
            t_compute = tree_mod.strip_delays(
                runtime_tree(chunk_tree, h_run)).solve_time()
            t_lp = max([l.t_lp for l in chunk_tree.leaves()])
            straggler.bind(self.topology.leaf_sync_delays(), t_compute,
                           t_lp=t_lp)
        guard = self._guard
        # the flat (alpha, w) pair is not a complete carry once leaves can
        # skip syncs (absent leaves keep divergent replicas and stale
        # snapshots), once edges compress (error-feedback residuals must
        # persist across root rounds), or once the server combine carries
        # momentum (the per-depth anchors outlive root-round boundaries),
        # so such runs thread the executors' full blocked state across
        # chunks instead.  Under strict mode the fetch is budgeted ONE
        # miss (the first state-carry run builds; later runs must hit).
        if straggler is not None or plan.has_compression or accelerated:
            with (guard.retrace_region(1) if guard is not None
                  and guard.error_on_retrace else contextlib.nullcontext()):
                if mesh:
                    state_exec = mesh_mod.get_mesh_executor(
                        plan, self._mesh, axes=self._mesh_axes,
                        loss=self.problem.loss,
                        use_kernel=self._mesh_use_kernel, carry_state=True,
                        sync=self._mesh_sync, accelerated=accelerated)
                else:
                    state_exec = host_mod.get_host_executor(
                        plan, loss=self.problem.loss,
                        record_history=False, backend=self.backend,
                        carry_state=True, accelerated=accelerated)
        if guard is not None and guard.error_on_retrace:
            # strict revalidation: the compiled program this session bound
            # at compile time must still be cache-resident -- a re-fetch
            # has a ZERO miss budget, so an LRU eviction (or a fingerprint
            # that drifted mid-session) raises here instead of silently
            # rebuilding inside the chunk loop
            method_name = "sdca_acc" if accelerated else "sdca"
            with guard.retrace_region(0):
                if mesh:
                    get_method(method_name).executor(
                        plan=plan, backend="mesh", mesh=self._mesh,
                        axes=self._mesh_axes, loss=self.problem.loss,
                        use_kernel=self._mesh_use_kernel,
                        sync=self._mesh_sync)
                else:
                    get_method(method_name).executor(
                        plan=plan, backend=self.backend,
                        loss=self.problem.loss, record_history=False)
        if mesh:
            a_carry = jnp.asarray(alpha, X.dtype).reshape(
                plan.n_leaves, plan.m_b)
        else:
            a_carry = jnp.asarray(alpha, X.dtype)
        w = jnp.asarray(w, X.dtype)

        history: list = []
        clock = {"async": t0_time, "sync": t0_time}

        # history recording is DEFERRED: entries hold the objective's
        # device scalars (the tiny _objective dispatch queues behind the
        # chunk dispatches) and one EXPLICIT jax.device_get materializes
        # them -- at stream points (on_round), at checkpoint-metadata
        # builds, and once at run end -- instead of an implicit float()
        # sync per recorded round.  Under strict mode the record call runs
        # INSIDE the host-sync guard, so a reintroduced implicit transfer
        # raises HostSyncError.
        def record(t: int, a_flat: Array, extra: Optional[dict] = None):
            if not record_history:
                return
            dv, pv = _objective(a_flat, X, y, loss, float(lam))
            time = clock["async"] if straggler is not None else \
                t0_time + t * dt
            record_round(history, t0_round + t, time, dv, pv)
            if extra:
                history[-1].update(extra)
            if on_round is not None:
                materialize_history(history)     # streaming needs host values
                on_round(history[-1])

        # the all-ones mask is loop-invariant: convert (and, on mesh,
        # device_put) it once instead of per round
        if mesh:
            part_ones = jax.device_put(
                jnp.asarray(plan_mod.full_participation(plan), X.dtype).T,
                self._spec_sharding)
        else:
            part_ones = jnp.asarray(plan_mod.full_participation(plan))

        # the runtime schedule: a step mask per chunk.  Loop-invariant
        # unless an adaptive straggler policy replans H mid-run -- then
        # only this INPUT array changes, never the compiled program.
        def steps_dev(h):
            arr = plan_mod.full_steps(plan) if h is None else \
                plan_mod.steps_for_h(plan, h)
            if mesh:
                return jax.device_put(
                    jnp.asarray(arr.transpose(1, 0, 2), X.dtype),
                    self._spec_sharding)
            return jnp.asarray(arr)

        def h_effective(h):
            """Per-leaf step counts a chunk actually runs (clamped to the
            compiled capacity, per-slot specs reduced to their max)."""
            if h is None:
                return plan.leaf_h.astype(np.int64)
            return np.minimum(leaf_h_spec(h, plan.n_leaves), plan.leaf_h)

        steps_now = steps_dev(h_run)
        h_eff_now = h_effective(h_run)
        h_now = int(h_eff_now.max())
        adaptive = straggler is not None and \
            getattr(straggler, "adaptive", None) is not None
        next_h = None
        state = None
        if state_exec is not None:
            state = state_exec.init(X, a_carry, w)
            if _ef_state:
                # restore path: substitute the checkpointed error-feedback
                # residuals (the one piece of the blocked carry that does
                # not collapse into (alpha, w) at a root-round boundary)
                from repro.runtime import fault as fault_mod
                state = fault_mod.with_ef_residuals(self, state, _ef_state)

        # strict mode: by loop entry every executor is cached (compile
        # built them, the revalidation above proved it), so each chunk
        # dispatch runs under a ZERO-miss retrace budget; the host-sync
        # guard starts at the second chunk (the first call's jit compile
        # legally uploads baked constants)
        def _dispatch_ctx(t):
            if guard is None:
                return contextlib.nullcontext()
            stack = contextlib.ExitStack()
            if guard.error_on_retrace:
                stack.enter_context(guard.retrace_region())
            if guard.guard_host_sync and t > 1:
                stack.enter_context(guard.dispatch_region())
            return stack

        # all rounds' keys in one walk of the equivalent monolithic tree
        # (the legacy chain), so the chunk loop does no host RNG work
        keys_all = plan_mod.chunked_key_plan(chunk_tree, plan, k, T)
        if record_initial:
            record(0, a_carry.reshape(m) if mesh else a_carry)
        for t in range(1, T + 1):
            keys = keys_all[t - 1]
            extra = None
            prt = part_ones
            # apply last chunk's adaptive H suggestion (observed-delay
            # replanning feeds the NEXT chunk): a new input array only.
            # Compared on the EFFECTIVE per-leaf counts so a scalar
            # suggestion always replaces a heterogeneous mask, and the
            # policy's simulated compute clock is retimed to the new H.
            if next_h is not None:
                eff_next = h_effective(next_h)
                if not np.array_equal(eff_next, h_eff_now):
                    h_eff_now = eff_next
                    h_now = int(eff_next.max())
                    steps_now = steps_dev(next_h)
                    straggler.retime(tree_mod.strip_delays(
                        runtime_tree(chunk_tree, next_h)).solve_time())
                next_h = None
            # history decimation: every k-th round, plus always the last
            rec_now = record_history and (t % every == 0 or t == T)
            if straggler is not None:
                step = straggler.step(final=(t == T))
                part = plan_mod.chunk_participation(plan, step.mask)
                prt = jax.device_put(
                    jnp.asarray(part, X.dtype).T, self._spec_sharding) \
                    if mesh else jnp.asarray(part)
                clock["async"] += step.dt_async
                clock["sync"] += step.dt_sync
                extra = {"time_sync": clock["sync"],
                         "participants": int(step.mask.sum())}
                if adaptive:
                    extra["h"] = h_now
                    if step.h_suggest is not None:
                        next_h = int(min(max(step.h_suggest, 1),
                                         plan.h_max))
            if mesh:
                kys = jax.device_put(
                    jnp.asarray(keys.transpose(1, 0, 2)),
                    self._spec_sharding)
                if state_exec is None:
                    with _dispatch_ctx(t):
                        a_carry, wrows = self._fn(self._Xs, self._ys,
                                                  a_carry, w, kys, prt,
                                                  steps_now, lm_in)
                        w = wrows[0]
                        if rec_now:
                            record(t, a_carry.reshape(m), extra)
                else:
                    with _dispatch_ctx(t):
                        state = state_exec.step(self._Xs, self._ys, state,
                                                kys, prt, steps_now, lm_in,
                                                *acc_args)
                        if rec_now:
                            record(t, state[0].reshape(m), extra)
            elif state_exec is None:
                # operand conversion stays OUTSIDE the guarded region:
                # inside it every implicit host transfer is an error
                kys = jnp.asarray(keys)
                with _dispatch_ctx(t):
                    a_carry, w = self._fn(X, y, kys, a_carry, w,
                                          prt, steps_now, lm_in)
                    if rec_now:
                        record(t, a_carry, extra)
            else:
                kys = jnp.asarray(keys)
                with _dispatch_ctx(t):
                    state = state_exec.step(X, y, kys, state,
                                            prt, steps_now, lm_in,
                                            *acc_args)
                    if rec_now:
                        record(t, state_exec.finalize(state)[0], extra)
            if guard is not None and guard.sanitize:
                guard.check_carry(
                    state if state_exec is not None else (a_carry, w),
                    f"chunk[{t}]")
            if ckpt_mgr is not None:
                k_lag += 1
                # period alignment is on the GLOBAL round cursor, so a
                # resumed leg checkpoints at the same rounds the
                # uninterrupted run would have
                if ((t0_round + t) % ck_every == 0
                        or (t == T and _final_save)):
                    from repro.runtime import fault as fault_mod
                    # the RNG chain advances lazily: one dispatch per
                    # snapshot instead of one per round (a handful of
                    # static lag values -> a handful of compiles)
                    k_cur = plan_mod.advance_root_key(k_cur, k_lag, K_root)
                    k_lag = 0
                    if state_exec is not None:
                        af, wf = state_exec.finalize(state)
                    else:
                        af, wf = a_carry, w
                    payload = {
                        "alpha": af.reshape(m) if mesh else af,
                        "w": wf,
                        "key": k_cur,
                        # the carry is donated on the next chunk step, and
                        # this payload outlives it (the write lags one
                        # period) -- copy the residual leaves out first
                        "res": jax.tree.map(
                            jnp.copy, fault_mod.ef_residuals(self, state)),
                    }
                    # snapshot metadata is JSON: materialize any deferred
                    # device scalars in the recorded history first
                    materialize_history(history)
                    meta = {
                        "version": fault_mod.PAYLOAD_VERSION,
                        "round": t0_round + t,
                        "sim_time": t0_time + t * dt,
                        "rounds_total": t0_round + T,
                        "lam": float(lam),
                        "m": int(m), "d": int(self.problem.d),
                        "plan": plan.fingerprint,
                        "local_h": h_meta,
                        "history": list(_history_prefix) + history,
                    }
                    # the write lags one period: payload leaves stay device
                    # arrays until the NEXT snapshot point, when they have
                    # long materialized -- the host transfer never stalls
                    # the async round-dispatch pipeline
                    if ckpt_pending is not None:
                        ckpt_mgr.save(*ckpt_pending)
                    ckpt_pending = (t0_round + t, payload, meta)
        k = plan_mod.advance_root_key(k, T, K_root)
        if ckpt_mgr is not None:
            if ckpt_pending is not None:
                ckpt_mgr.save(*ckpt_pending)
            ckpt_mgr.wait()       # surface async-save failures before exit

        if state_exec is not None:
            alpha_out, w = state_exec.finalize(state)
            if mesh:
                alpha_out = alpha_out.reshape(m)
        else:
            alpha_out = a_carry.reshape(m) if mesh else a_carry
        if not _defer_history:
            materialize_history(history)
        return SolveResult(alpha=alpha_out, w=w, history=history,
                           next_key=k, lam=lam)

    # ------------------------------------------------------------------
    def resume(
        self,
        checkpoint,
        *,
        rounds: Optional[int] = None,
        record_history: bool = True,
        history_every: int = 1,
        on_round: Optional[Callable[[dict], None]] = None,
        lam: Optional[float] = None,
        local_h=None,
        _final_save: bool = True,
    ) -> SolveResult:
        """Restart a checkpointed solve from its newest complete snapshot,
        bit-identically to the uninterrupted run.

        ``checkpoint`` is the directory (or
        :class:`~repro.runtime.fault.CheckpointPolicy`) a previous
        ``run(checkpoint=...)`` wrote.  The restored payload is
        backend-portable: a carry saved by a vmap session resumes on a
        pallas or mesh session of the SAME problem/topology/schedule (the
        plan fingerprint is validated) -- on mesh the flat state is
        re-sharded onto the *current* mesh, so the device count may
        differ from the saving process.  Runs the remaining rounds
        (``rounds_total - step``, or ``rounds=`` to override), continues
        checkpointing into the same directory, and returns a result whose
        history is the full concatenated series from round 0.  ``lam`` /
        ``local_h`` default to the values recorded at save time -- only
        override them with the values the original run used if you want
        bit-identity."""
        from repro.runtime import fault as fault_mod
        policy, mgr, _ = fault_mod.bind_policy(checkpoint, self.resolved)
        step = mgr.latest_step()
        if step is None:
            raise FileNotFoundError(
                f"no complete checkpoints under {policy.directory!r}")
        meta = mgr.metadata(step)
        if meta.get("plan") != self.plan.fingerprint:
            raise ValueError(
                "checkpoint was written under a different plan "
                "(topology/schedule/weighting/compression changed between "
                "save and resume); compile a matching session")
        m, d = self.problem.m, self.problem.d
        if int(meta["m"]) != m or int(meta["d"]) != d:
            raise ValueError(
                f"checkpoint is for an (m={meta['m']}, d={meta['d']}) "
                f"problem; this session has (m={m}, d={d})")
        template = fault_mod.payload_template(
            self.plan, m, d, self.problem.X.dtype)
        step, payload = mgr.restore(template, step)
        remaining = int(meta["rounds_total"]) - step if rounds is None \
            else int(rounds)
        if remaining < 0:
            raise ValueError(f"rounds must be >= 0, got {remaining}")
        lam_run = float(meta["lam"]) if lam is None else float(lam)
        h_run = meta.get("local_h") if local_h is None else local_h
        prefix = [dict(e) for e in meta.get("history", [])]
        # the warm-start anchor continues the round/time axes from the
        # restored cursor (NOT from the last recorded entry -- decimation
        # may have skipped the checkpoint round)
        anchor = {"round": step, "time": float(meta["sim_time"]),
                  "dual": float("nan"), "primal": float("nan"),
                  "gap": float("nan")}
        ws = SolveResult(
            alpha=jnp.asarray(payload["alpha"]),
            w=jnp.asarray(payload["w"]),
            history=[anchor],
            next_key=jnp.asarray(np.asarray(payload["key"], np.uint32)),
            lam=lam_run)
        out = self.run(remaining, warm_start=ws,
                       record_history=record_history,
                       history_every=history_every, on_round=on_round,
                       lam=lam_run, local_h=h_run, checkpoint=policy,
                       _ef_state=[np.asarray(r) for r in payload["res"]],
                       _history_prefix=prefix, _final_save=_final_save)
        out.history = prefix + out.history
        return out

    # ------------------------------------------------------------------
    def straggler_policy(self, *, seed: int = 0, adaptive=None, **kw):
        """The :class:`~repro.runtime.straggler.StragglerPolicy` this
        session's straggler-aware auto-schedule planned: the jointly
        optimized :class:`BoundedSkip` threshold (``resolved.skip``) with
        the :class:`~repro.core.delay.StragglerModel` the planner was
        given.  Requires a schedule compiled with
        ``DelayModel(straggler=...)``; extra keyword arguments forward to
        the policy (``warmup=``, ``k_mad=``, ...)."""
        from repro.runtime.straggler import StragglerPolicy
        r = self.resolved
        if r.skip is None or r.straggler_model is None:
            raise ValueError(
                "this session's schedule was not planned with "
                "DelayModel(straggler=StragglerModel(...)); construct a "
                "StragglerPolicy explicitly instead")
        return StragglerPolicy(model=r.straggler_model,
                               max_consecutive=int(r.skip), seed=seed,
                               adaptive=adaptive, **kw)

    # ------------------------------------------------------------------
    def sweep(
        self,
        spec=None,
        *,
        lams=None,
        seeds=None,
        schedules=None,
        local_hs=None,
        mode: str = "grid",
        continuation: bool = False,
        rounds: Optional[int] = None,
        record_history: bool = True,
        history_every: int = 1,
        checkpoint=None,
    ):
        """Run a config grid through this session and return a
        :class:`~repro.api.sweep.RunSet`.

        Pass a :class:`~repro.api.sweep.Sweep` as ``spec``, or build one
        inline from ``lams=`` / ``seeds=`` / ``schedules=`` /
        ``local_hs=`` (``mode`` is ``"grid"`` -- the cartesian product --
        or ``"zip"``; ``continuation=True`` warm-starts a regularization
        path over the lambda axis, solved in descending-lambda order).

        On the host backends a (lambda x local-H x seed) grid within one
        schedule runs as ONE vmapped device program per chunk (lambda and
        the step-mask schedule are runtime executor inputs); schedule
        axes produce distinct plans but share the lambda-free executor
        cache.  An H axis (``local_hs``: scalars or per-leaf specs,
        clamped to the compiled capacity -- see ``Schedule(h_cap=...)``)
        batches over the step-mask operand in the SAME vmapped dispatch.
        Each member is bit-identical to the corresponding standalone
        :meth:`run`."""
        from repro.api.sweep import Sweep, run_sweep
        if spec is None:
            spec = Sweep(lams=lams, seeds=seeds, schedules=schedules,
                         local_hs=local_hs, mode=mode,
                         continuation=continuation)
        elif (any(a is not None for a in (lams, seeds, schedules,
                                          local_hs))
              or mode != "grid" or continuation):
            raise ValueError(
                "pass either a Sweep spec or inline axes/options (lams=/"
                "seeds=/schedules=/local_hs=/mode=/continuation=), not "
                "both")
        return run_sweep(self, spec, rounds=rounds,
                         record_history=record_history,
                         history_every=history_every,
                         checkpoint=checkpoint)

    # ------------------------------------------------------------------
    def _start_state(self, warm_start, key, lam_run):
        X = self.problem.X
        k = None if key is None else plan_mod._raw_key(key)
        if warm_start is None:
            alpha = jnp.zeros((self.problem.m,), X.dtype)
            w = jnp.zeros((self.problem.d,), X.dtype)
        elif isinstance(warm_start, SolveResult):
            alpha, w = warm_start.alpha, warm_start.w
            if (warm_start.lam is not None
                    and float(warm_start.lam) != float(lam_run)):
                # the carried primal satisfies w = X^T a / (lam_old m);
                # under a different lambda it must be rebuilt, or every
                # subsequent coordinate step works against an inconsistent
                # w and the run converges to wrong iterates
                w = dual_mod.w_of_alpha(alpha, X, float(lam_run))
            if k is None and warm_start.next_key is not None:
                k = plan_mod._raw_key(warm_start.next_key)
        else:
            alpha, w = warm_start
        if k is None:
            k = plan_mod._raw_key(jax.random.PRNGKey(0))
        alpha = jnp.asarray(alpha)
        w = jnp.asarray(w)
        if alpha.shape != (self.problem.m,):
            raise ValueError(
                f"warm-start alpha must be ({self.problem.m},), got "
                f"{alpha.shape}")
        if w.shape != (self.problem.d,):
            raise ValueError(
                f"warm-start w must be ({self.problem.d},), got {w.shape}")
        return alpha, w, k


def _calibrate_C(problem: Problem, topology: Topology, schedule: Schedule):
    """Resolve ``DelayModel(C="auto")``: run a short host-backend pilot
    under the topology's default schedule, fit eq. (11)'s improvement
    constant from the observed per-root-round gap contractions
    (:func:`repro.core.delay.fit_C`), and return (schedule with the fitted
    C, fitted C)."""
    import dataclasses

    from repro.core.delay import fit_C
    dm = schedule.delay
    pilot_sched = Schedule(weighting=schedule.weighting)
    pilot = Session.compile(problem, topology, pilot_sched, backend="vmap")
    res = pilot.run(rounds=int(dm.pilot_rounds),
                    key=jax.random.PRNGKey(0))
    plan = pilot.plan
    # one root round of the pilot schedule, seen as eq. (11)'s star round:
    # K = root fan-out, H = total coordinate passes one leaf runs per root
    # round, delta = one coordinate's share of a leaf block (the planner's
    # own delta when the DelayModel pins it).  The clip cap is the
    # SMALLEST group size across the topology's sync levels: the planner
    # checks the same C against every level's K.
    K = len(topology.tree.children)
    h_eff = int(plan.solve_mask[:, 0].sum()) * int(plan.leaf_h[0])
    delta = (dm.delta if dm.delta is not None
             else 1.0 / max(int(plan.leaf_sizes[0]), 1))
    c_max = min(lvl.group_size for lvl in topology.sync_levels())
    C = fit_C(res.history, K=K, H=h_eff, delta=delta, c_max=c_max)
    return dataclasses.replace(
        schedule, delay=dataclasses.replace(dm, C=C)), C


def solve(
    problem: Problem,
    topology: Topology,
    schedule: Optional[Schedule] = None,
    *,
    backend: str = "vmap",
    key: Optional[Array] = None,
    rounds: Optional[int] = None,
    warm_start: Union[SolveResult, Tuple[Array, Array], None] = None,
    record_history: bool = True,
    history_every: int = 1,
    mesh=None,
    mesh_axes: Optional[Sequence[str]] = None,
    mesh_use_kernel: bool = True,
    mesh_sync: str = "psum",
    on_round: Optional[Callable[[dict], None]] = None,
    straggler=None,
    lam: Optional[float] = None,
    local_h=None,
    checkpoint=None,
) -> SolveResult:
    """One-shot convenience: ``Session.compile(...).run(...)``.  Forwards
    the full ``run`` surface -- including ``warm_start``, ``straggler``,
    ``checkpoint`` and the ``lam``/``local_h`` overrides -- so the
    one-shot path has feature parity with a session."""
    sess = Session.compile(problem, topology, schedule, backend=backend,
                           mesh=mesh, mesh_axes=mesh_axes,
                           mesh_use_kernel=mesh_use_kernel,
                           mesh_sync=mesh_sync)
    return sess.run(rounds, key=key, warm_start=warm_start,
                    record_history=record_history,
                    history_every=history_every, on_round=on_round,
                    straggler=straggler, lam=lam, local_h=local_h,
                    checkpoint=checkpoint)
