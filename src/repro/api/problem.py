"""The :class:`Problem` object: data + loss + regularization (paper eq. (1)).

A Problem is pure *what*: the (m, d) design matrix, labels, a loss (by name
via the ``repro.core.dual`` registry, or a :class:`~repro.core.dual.Loss`
instance), and the ridge parameter lambda.  *Where* it runs is a
:class:`~repro.api.topology.Topology`, *how* is a
:class:`~repro.api.schedule.Schedule`; the three meet in
:class:`~repro.api.session.Session`.
"""
from __future__ import annotations

import dataclasses
from typing import Union

import jax
import jax.numpy as jnp

from repro.core.dual import Loss, get_loss

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class Problem:
    """A regularized loss-minimization instance.

    ``loss`` accepts a registry name (``"squared"``, ``"hinge"``,
    ``"logistic"``, ``"smooth_hinge_1"``, parametric ``"smooth_hinge_<g>"``)
    or a :class:`Loss`; it is resolved at construction.
    """
    X: Array
    y: Array
    loss: Union[Loss, str] = "squared"
    lam: float = 0.1

    def __post_init__(self):
        object.__setattr__(self, "X", jnp.asarray(self.X))
        object.__setattr__(self, "y", jnp.asarray(self.y))
        object.__setattr__(self, "loss", get_loss(self.loss))
        if self.X.ndim != 2:
            raise ValueError(f"X must be (m, d), got shape {self.X.shape}")
        if self.y.shape != (self.X.shape[0],):
            raise ValueError(
                f"y must be ({self.X.shape[0]},), got {self.y.shape}")
        if not self.lam > 0:
            raise ValueError(f"lam must be > 0, got {self.lam}")

    @property
    def m(self) -> int:
        return self.X.shape[0]

    @property
    def d(self) -> int:
        return self.X.shape[1]

    # ---- common instantiations -----------------------------------------
    @classmethod
    def ridge(cls, X, y, *, lam: float = 0.1) -> "Problem":
        return cls(X, y, loss="squared", lam=lam)

    @classmethod
    def svm(cls, X, y, *, lam: float = 0.1, smoothing: float = 1.0
            ) -> "Problem":
        """Smoothed-hinge SVM (``smoothing=0`` selects the non-smooth
        hinge)."""
        name = "hinge" if smoothing == 0 else f"smooth_hinge_{smoothing:g}"
        return cls(X, y, loss=name, lam=lam)

    @classmethod
    def logistic(cls, X, y, *, lam: float = 0.1) -> "Problem":
        return cls(X, y, loss="logistic", lam=lam)
