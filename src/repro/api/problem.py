"""The :class:`Problem` object: data + loss + regularization (paper eq. (1)).

A Problem is pure *what*: the (m, d) design matrix, labels, a loss (by name
via the ``repro.core.dual`` registry, or a :class:`~repro.core.dual.Loss`
instance), and the ridge parameter lambda.  *Where* it runs is a
:class:`~repro.api.topology.Topology`, *how* is a
:class:`~repro.api.schedule.Schedule`; the three meet in
:class:`~repro.api.session.Session`.
"""
from __future__ import annotations

import dataclasses
from typing import Union

import jax
import jax.numpy as jnp

from repro.core.dual import Loss, get_loss

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class Problem:
    """A regularized loss-minimization instance.

    ``loss`` accepts a registry name (``"squared"``, ``"hinge"``,
    ``"logistic"``, ``"smooth_hinge_1"``, parametric ``"smooth_hinge_<g>"``)
    or a :class:`Loss`; it is resolved at construction.
    """
    X: Array
    y: Array
    loss: Union[Loss, str] = "squared"
    lam: float = 0.1

    def __post_init__(self):
        object.__setattr__(self, "X", jnp.asarray(self.X))
        object.__setattr__(self, "y", jnp.asarray(self.y))
        object.__setattr__(self, "loss", get_loss(self.loss))
        if self.X.ndim != 2:
            raise ValueError(f"X must be (m, d), got shape {self.X.shape}")
        if self.y.shape != (self.X.shape[0],):
            raise ValueError(
                f"y must be ({self.X.shape[0]},), got {self.y.shape}")
        if not self.lam > 0:
            raise ValueError(f"lam must be > 0, got {self.lam}")

    @property
    def m(self) -> int:
        return self.X.shape[0]

    @property
    def d(self) -> int:
        return self.X.shape[1]

    # ---- common instantiations -----------------------------------------
    @classmethod
    def ridge(cls, X, y, *, lam: float = 0.1) -> "Problem":
        return cls(X, y, loss="squared", lam=lam)

    @classmethod
    def svm(cls, X, y, *, lam: float = 0.1, smoothing: float = 1.0
            ) -> "Problem":
        """Smoothed-hinge SVM (``smoothing=0`` selects the non-smooth
        hinge)."""
        name = "hinge" if smoothing == 0 else f"smooth_hinge_{smoothing:g}"
        return cls(X, y, loss=name, lam=lam)

    @classmethod
    def logistic(cls, X, y, *, lam: float = 0.1) -> "Problem":
        return cls(X, y, loss="logistic", lam=lam)

    # ---- the second workload -------------------------------------------
    @staticmethod
    def lm(cfg, optimizer, *, batch: int, seq: int, seed: int = 0,
           average_opt_state: bool = True) -> "LMProblem":
        """Data-parallel LM training on the same schedule engine.

        Returns an :class:`LMProblem` that :meth:`Session.compile
        <repro.api.session.Session.compile>` dispatches to the
        ``"lm_treesync"`` method (mesh backend): the local step is one
        ``optimizer`` update on a synthetic-LM batch, the per-level
        combine a parameter/opt-state mean over the level's mesh axis.
        """
        return LMProblem(cfg=cfg, optimizer=optimizer, batch=batch, seq=seq,
                         seed=seed, average_opt_state=average_opt_state)


@dataclasses.dataclass(frozen=True)
class LMProblem:
    """LM-training *what*: model config + optimizer + deterministic data
    stream (``repro.data.lm.lm_batch`` is a pure function of
    ``(seed, step)``, so resume = restore state + continue the stream).

    Where/how stay :class:`~repro.api.topology.Topology` /
    :class:`~repro.api.schedule.Schedule`, exactly as for SDCA; the
    ``method`` marker routes :meth:`Session.compile
    <repro.api.session.Session.compile>` to
    :class:`repro.api.lm.LMSession`.
    """
    cfg: "object"            # repro.configs.base.ModelConfig (frozen)
    optimizer: "object"      # repro.optim.Optimizer (frozen)
    batch: int = 8
    seq: int = 128
    seed: int = 0
    average_opt_state: bool = True
    method: str = dataclasses.field(default="lm_treesync")

    def __post_init__(self):
        if self.batch <= 0 or self.seq <= 0:
            raise ValueError(
                f"batch/seq must be positive, got {self.batch}/{self.seq}")
