"""Perf-iteration driver for the §Perf hillclimb.

Named sharding/step variants applied to one cell; each run prints the
three roofline terms so hypothesis → change → measure cycles are one
command:

    PYTHONPATH=src python -m repro.launch.perf \
        --arch qwen3-32b --shape train_4k --mesh single \
        --variant baseline zero1 mb2 replicate_embed_in

TreeSync variants lower the *local* and *sync* phases separately (a
lax.cond would double-count in cost_analysis) and report the
cadence-amortized step: (H-1)/H * local + 1/H * sync.
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse
import dataclasses
import json
from pathlib import Path
from typing import Any, Dict, Optional

from repro.launch import sharding as sh

RESULTS = Path(__file__).resolve().parents[3] / "results" / "perf"


def _rules(**kw) -> sh.AxisRules:
    base = dataclasses.replace(sh.DEFAULT_RULES, act_seq=("model",))
    return dataclasses.replace(base, **kw)


# name -> dict(rules=..., microbatches=..., cfg_overrides=...)
VARIANTS: Dict[str, Dict[str, Any]] = {
    # the §Dry-run baseline (train: seq-parallel boundaries + 4 microbatches)
    "baseline": dict(),
    # fewer grad-accumulation passes => fewer FSDP weight gathers
    "mb2": dict(microbatches=2),
    "mb1": dict(microbatches=1),
    # replicate the embedding table across "model" (kills the vocab-gather
    # collective at the input; table is small once data-sharded on d_model)
    "replicate_embed_in": dict(rules=_rules(vocab_in=None)),
    # ZeRO-1: params replicated over "data" (no per-pass weight
    # all-gathers); optimizer state sharded over data (zero1 axis); grads
    # still reduce over data
    "zero1": dict(rules=_rules(embed=None, zero1=("data",))),
    "zero1_mb1": dict(rules=_rules(embed=None, zero1=("data",)),
                      microbatches=1),
    "zero1_mb2": dict(rules=_rules(embed=None, zero1=("data",)),
                      microbatches=2),
    "zero1_re": dict(rules=_rules(embed=None, zero1=("data",),
                                  vocab_in=None)),
    "zero1_re_mb2": dict(rules=_rules(embed=None, zero1=("data",),
                                      vocab_in=None), microbatches=2),
    "zero1_re_mb1": dict(rules=_rules(embed=None, zero1=("data",),
                                      vocab_in=None), microbatches=1),
    # no sequence parallelism (ablation of §Perf iteration 2)
    "no_seqpar": dict(rules=dataclasses.replace(sh.DEFAULT_RULES)),
    # pure FSDP/ZeRO-3: batch over BOTH mesh axes (no tensor parallelism);
    # weights fully sharded on d_model over 256 chips and gathered per
    # pass. Kills the per-layer activation all-reduces entirely at the
    # price of 3 full weight gathers (fwd, remat-recompute, bwd).
    "fsdp_pure": dict(rules=dataclasses.replace(
        sh.DEFAULT_RULES,
        embed=("data", "model"), heads=None, kv_heads=None, ffn=None,
        vocab_in=("data", "model"),
        act_batch=("pod", "data", "model"), act_seq=None,
        act_heads=None,
        cache_batch=("pod", "data", "model")), microbatches=1),
    "fsdp_pure_mb2": dict(rules=dataclasses.replace(
        sh.DEFAULT_RULES,
        embed=("data", "model"), heads=None, kv_heads=None, ffn=None,
        vocab_in=("data", "model"),
        act_batch=("pod", "data", "model"), act_seq=None,
        act_heads=None,
        cache_batch=("pod", "data", "model")), microbatches=2),
    # fsdp_pure + embedding table sharded on d_model only (vocab dim
    # replicated): kills the involuntary-full-remat reshard at the
    # embedding gather boundary
    "fsdp_pure_re": dict(rules=dataclasses.replace(
        sh.DEFAULT_RULES,
        embed=("data", "model"), heads=None, kv_heads=None, ffn=None,
        vocab_in=None,
        act_batch=("pod", "data", "model"), act_seq=None,
        act_heads=None,
        cache_batch=("pod", "data", "model")), microbatches=1),
    # + smaller q-chunks: halves the peak attention-score transient
    "fsdp_pure_re_qc512": dict(rules=dataclasses.replace(
        sh.DEFAULT_RULES,
        embed=("data", "model"), heads=None, kv_heads=None, ffn=None,
        vocab_in=None,
        act_batch=("pod", "data", "model"), act_seq=None,
        act_heads=None,
        cache_batch=("pod", "data", "model")), microbatches=1,
        cfg_overrides={"q_chunk_size": 512}),
    # inference variants
    "serve_seqpar": dict(rules=dataclasses.replace(
        sh.DEFAULT_RULES, act_seq=("model",))),
    "serve_headdata": dict(rules=dataclasses.replace(
        sh.DEFAULT_RULES, act_heads=("model", "data"),
        cache_batch=("pod",))),
}


def run_variant(arch: str, shape: str, mesh: str, variant: str,
                save: bool = True) -> Dict[str, Any]:
    from repro.launch.dryrun import run_cell
    v = VARIANTS[variant]
    rec = run_cell(arch, shape, mesh, rules=v.get("rules"),
                   microbatches=v.get("microbatches"),
                   cfg_overrides=v.get("cfg_overrides"), verbose=False)
    rec["variant"] = variant
    if rec["status"] == "ok":
        r = rec["roofline"]
        print(f"  {variant:<18} comp={r['compute_s']*1e3:7.2f}ms "
              f"mem={r['memory_s']*1e3:7.2f}ms "
              f"coll={r['collective_s']*1e3:7.2f}ms "
              f"dom={r['dominant'][:-2]:<10} frac={r['roofline_fraction']:.3f} "
              f"useful={r['useful_ratio']:.2f} "
              f"{rec['memory']['peak_gib_per_device']:.1f}GiB",
              flush=True)
    else:
        print(f"  {variant:<18} {rec['status']}: "
              f"{str(rec.get('error'))[:200]}", flush=True)
    if save:
        RESULTS.mkdir(parents=True, exist_ok=True)
        safe = arch.replace(".", "_")
        (RESULTS / f"{safe}__{shape}__{mesh}__{variant}.json").write_text(
            json.dumps(rec, indent=1))
    return rec


def run_flash_adjustment(arch: str, shape_name: str, mesh_name: str,
                         variant: str = "baseline") -> Dict[str, Any]:
    """Quantify the flash-attention kernel's effect on the memory roofline
    term WITHOUT hand-waving: HLO bytes per layer decompose as
    b(S) = a*S + c*S^2; the quadratic part is exactly the attention
    score-chain traffic that the Pallas kernel keeps in VMEM (the kernel
    preserves the flops and the linear q/k/v/o streams). We compile the
    1-block unrolled model at S and S/2 (same batch), solve for c, and
    report the memory term with c*S^2 removed.

    (The kernel itself cannot lower through GSPMD on the CPU backend;
    interpret mode would re-expand to the same jnp graph. This measured
    subtraction is the honest CPU-container alternative.)"""
    import jax
    from repro.configs.registry import get_config
    from repro.configs.shapes import SHAPES, ShapeSpec
    from repro.launch import roofline as rf
    from repro.launch.dryrun import (MESHES, _analyze, _compile_once,
                                     _pattern_len, baseline_settings)
    from repro.launch.mesh import make_production_mesh

    v = VARIANTS[variant]
    shape = SHAPES[shape_name]
    base = baseline_settings(shape.kind)
    rules = v.get("rules") or base["rules"]
    mb = v.get("microbatches") or base["microbatches"]
    mb = mb if shape.kind == "train" else 1
    cfg0 = get_config(arch)
    if v.get("cfg_overrides"):
        cfg0 = dataclasses.replace(cfg0, **v["cfg_overrides"])
    mesh = make_production_mesh(multi_pod=MESHES[mesh_name])
    p = _pattern_len(cfg0)
    tail = cfg0.num_layers % p
    n_target = cfg0.num_layers // p

    def bytes_per_block(S):
        sh_spec = ShapeSpec(shape.name, S, shape.global_batch, shape.kind)
        out = {}
        for nb in (1, 2):
            cfg = dataclasses.replace(cfg0, num_layers=nb * p + tail,
                                      scan_layers=False,
                                      q_chunk_size=min(cfg0.q_chunk_size,
                                                       S))
            comp, _, _ = _compile_once(cfg, sh_spec, mesh, rules, mb)
            out[nb] = _analyze(comp)
        return {m: out[2][m] - out[1][m] for m in ("flops", "bytes", "wire")}

    S = shape.seq_len
    b_full = bytes_per_block(S)
    b_half = bytes_per_block(S // 2)
    report = {"arch": arch, "shape": shape_name, "variant": variant}
    for metric in ("bytes", "wire", "flops"):
        c = 2.0 * (b_full[metric] - 2.0 * b_half[metric]) / (S * S)
        quad_total = c * S * S * n_target
        report[metric] = {"per_block_S": b_full[metric],
                          "quad_coeff": c, "quad_total": quad_total}
    quad_bytes = max(report["bytes"]["quad_total"], 0.0)
    report["memory_term_flash_s"] = None
    print(f"  flash-adjust {arch} x {shape_name} ({variant}): "
          f"quadratic HBM bytes = {quad_bytes / 2**40:.2f} TiB/chip "
          f"(= {quad_bytes / 819e9:.2f}s of the memory term); "
          f"quad wire = {report['wire']['quad_total'] / 2**30:.2f} GiB "
          f"(should be ~0)", flush=True)
    RESULTS.mkdir(parents=True, exist_ok=True)
    safe = arch.replace(".", "_")
    (RESULTS / f"{safe}__{shape_name}__{mesh_name}__flashadj_{variant}"
     ".json").write_text(json.dumps(report, indent=1))
    return report


def run_treesync(arch: str, mesh_name: str = "multi",
                 period: int = 16, compression: str = "none",
                 save: bool = True) -> Dict[str, Any]:
    """Cell-3 measurement: the paper's schedule applied at the POD level.

    Replica = one pod (FSDP x TP inside, exactly the single-pod program);
    TreeSync syncs params over the "pod" axis every `period` steps,
    optionally int8-compressed with error feedback. We measure:

      * the sync-DP multi-pod baseline's per-step wire, split into
        intra-pod vs cross-pod (pod-axis collectives have group_size 2
        with 256 groups -- identifiable in the parsed HLO),
      * the TreeSync sync-phase wire (params averaged over "pod"),
      * the compressed sync-phase wire (int8 codes move, f32 stays local).

    and report amortized cross-pod bytes/step + step-time models under
    per-chip cross-pod bandwidth scenarios (ICI-like 50 GB/s and
    DCI-like 0.5 GB/s), with the eq.-(12)-optimal period for each.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs.registry import get_config
    from repro.core import compression as comp_mod
    from repro.core.delay import optimal_h
    from repro.launch import hw
    from repro.launch import roofline as rf
    from repro.launch import steps as steps_mod
    from repro.launch.dryrun import MESHES, run_cell
    from repro.launch.mesh import make_production_mesh
    from repro.launch import sharding as shm

    assert mesh_name == "multi"
    mesh = make_production_mesh(multi_pod=True)
    cfg = get_config(arch)

    # 1) sync-DP baseline (mb1 = best known multi-pod variant)
    base = run_cell(arch, "train_4k", mesh_name, microbatches=1,
                    verbose=False)
    assert base["status"] == "ok", base.get("error")
    by_op = base["collectives"]["by_op"]
    total_wire = base["collectives"]["wire_bytes_per_chip"]

    # cross-pod share: re-parse cell HLO is gone; use the sync-phase
    # measurement below as the cross-pod bytes (the baseline moves the
    # same gradient bytes across pods every step, all-reduce vs our
    # parameter mean -- byte-identical payloads).

    # 2) TreeSync sync phase: mean of FSDP-sharded params over "pod"
    pshape = steps_mod.params_shape(cfg)
    pspecs = shm.param_specs(cfg, pshape, mesh)
    psh = shm.to_named(pspecs, mesh)

    def sync_phase(params):
        return jax.tree.map(
            lambda t: jax.lax.pmean(t, "pod") if False else t, params)

    # express the pod-mean without shard_map: params are replicated over
    # "pod" in their NamedSharding, so a jit mean needs the pod dim
    # explicit: stack a leading (2,) pod dim sharded over "pod".
    def stack_spec(spec):
        return NamedSharding(mesh, P("pod", *spec))

    psh_stacked = jax.tree.map(
        lambda s: stack_spec(s.spec), psh,
        is_leaf=lambda x: isinstance(x, NamedSharding))
    pshape_stacked = jax.tree.map(
        lambda t: jax.ShapeDtypeStruct((2,) + t.shape, t.dtype), pshape)

    def mean_pods(params):
        return jax.tree.map(
            lambda t: jnp.broadcast_to(jnp.mean(t, axis=0, keepdims=True),
                                       t.shape), params)

    comp_sync = jax.jit(mean_pods, in_shardings=(psh_stacked,),  # analysis: allow(jit-outside-engine) AOT-lowered for collective analysis, never dispatched
                        out_shardings=psh_stacked,
                        donate_argnums=(0,)).lower(pshape_stacked).compile()
    sync_an = rf.collective_summary(
        rf.parse_collectives(comp_sync.as_text()))
    sync_wire = sync_an["wire_bytes_per_chip"]

    # 3) compressed sync phase: int8-quantize the delta to the pod mean,
    # exchange codes, dequantize+average (error feedback residual local)
    compressor = comp_mod.Int8Compressor()

    def mean_pods_int8(params, residual, anchor):
        BLK = 32

        def one(t, r, a):
            # anchor = last consensus (pod-replicated input, no comm)
            anchor = jnp.broadcast_to(a[None], t.shape)
            delta = t.astype(jnp.float32) - anchor.astype(jnp.float32) + r
            # blockwise int8 along the LAST dim only: every other dim's
            # sharding propagates untouched (a global flatten would force
            # GSPMD to reshard the whole tensor before quantizing)
            D = delta.shape[-1]
            if D % BLK:
                # tiny tensors: skip compression (negligible bytes)
                avg = jnp.broadcast_to(
                    jnp.mean(delta, axis=0, keepdims=True), t.shape)
                return ((anchor.astype(jnp.float32) + avg).astype(t.dtype),
                        jnp.zeros_like(delta))
            blocks = delta.reshape(delta.shape[:-1] + (D // BLK, BLK))
            scale = jnp.max(jnp.abs(blocks), axis=-1) / 127.0
            codes = jnp.round(
                blocks / jnp.maximum(scale[..., None], 1e-12)
            ).astype(jnp.int8)
            deq_local = (codes.astype(jnp.float32) * scale[..., None]
                         ).reshape(delta.shape)
            new_r = delta - deq_local
            # force INT8 on the wire: replicate codes over "pod" (int8
            # all-gather), everything else unconstrained; dequantize and
            # average locally. Without the pin GSPMD moves f32.
            un = P.UNCONSTRAINED
            codes_g = jax.lax.with_sharding_constraint(
                codes, NamedSharding(
                    mesh, P(None, *([un] * (codes.ndim - 1)))))
            scale_g = jax.lax.with_sharding_constraint(
                scale, NamedSharding(
                    mesh, P(None, *([un] * (scale.ndim - 1)))))
            deq = (codes_g.astype(jnp.float32) * scale_g[..., None]
                   ).reshape(delta.shape)
            avg = jnp.broadcast_to(jnp.mean(deq, axis=0, keepdims=True),
                                   t.shape)
            out = (anchor.astype(jnp.float32) + avg).astype(t.dtype)
            return out, new_r

        flat_t, tdef = jax.tree.flatten(params)
        flat_r = jax.tree.leaves(residual)
        flat_a = jax.tree.leaves(anchor)
        outs = [one(t, r, a) for t, r, a in zip(flat_t, flat_r, flat_a,
                                                strict=True)]
        return (tdef.unflatten([o[0] for o in outs]),
                tdef.unflatten([o[1] for o in outs]))

    rshape = jax.tree.map(
        lambda t: jax.ShapeDtypeStruct(t.shape, jnp.float32),
        pshape_stacked)
    rsh = jax.tree.map(
        lambda s: s, psh_stacked,
        is_leaf=lambda x: isinstance(x, NamedSharding))
    comp_sync8 = jax.jit(  # analysis: allow(jit-outside-engine) AOT-lowered for collective analysis, never dispatched
        mean_pods_int8, in_shardings=(psh_stacked, rsh, psh),
        out_shardings=(psh_stacked, rsh),
        donate_argnums=(0, 1)).lower(pshape_stacked, rshape,
                                     pshape).compile()
    sync8_an = rf.collective_summary(
        rf.parse_collectives(comp_sync8.as_text()))
    sync8_wire = sync8_an["wire_bytes_per_chip"]

    # 4) step-time model under cross-pod bandwidth scenarios
    r = base["roofline"]
    local_s = max(r["compute_s"], r["memory_s"])  # intra-pod floor
    intra_coll_s = max(r["collective_s"] - sync_wire / hw.ICI_BW, 0.0)
    report = {
        "arch": arch, "mesh": mesh_name, "period": period,
        "baseline_total_wire_per_chip": total_wire,
        "grad_sync_wire_per_chip": sync_wire,
        "treesync_sync_wire_per_chip": sync_wire,
        "treesync_int8_wire_per_chip": sync8_wire,
        "scenarios": {},
    }
    for name, bw in (("ici_50GBps", hw.ICI_BW),
                     ("dci_6.25GBps", hw.DCI_BW),
                     ("dci_0.5GBps", 0.5e9)):
        base_step = (local_s + intra_coll_s + sync_wire / bw)
        ts_step = (local_s + intra_coll_s + sync_wire / (bw * period))
        ts8_step = (local_s + intra_coll_s + sync8_wire / (bw * period))
        # eq. (12): the optimal period given these costs
        h_star, _ = optimal_h(
            C=0.5, K=2, delta=1e-3, t_total=3600.0,
            t_lp=local_s + intra_coll_s, t_delay=sync_wire / bw,
            t_cp=0.0, h_max=10**4)
        report["scenarios"][name] = {
            "sync_dp_step_s": base_step,
            "treesync_step_s": ts_step,
            "treesync_int8_step_s": ts8_step,
            "speedup": base_step / ts_step,
            "speedup_int8": base_step / ts8_step,
            "eq12_optimal_period": h_star,
        }
        print(f"  [{name}] sync-DP {base_step:.2f}s/step; "
              f"TreeSync(H={period}) {ts_step:.2f}s ({base_step/ts_step:.2f}x); "
              f"+int8 {ts8_step:.2f}s ({base_step/ts8_step:.2f}x); "
              f"eq12 H*={h_star}", flush=True)
    print(f"  cross-pod bytes/step: sync-DP {sync_wire/2**30:.2f} GiB -> "
          f"TreeSync {sync_wire/period/2**30:.3f} GiB -> "
          f"+int8 {sync8_wire/period/2**30:.3f} GiB", flush=True)
    if save:
        RESULTS.mkdir(parents=True, exist_ok=True)
        safe = arch.replace(".", "_")
        (RESULTS / f"{safe}__treesync_pod_H{period}.json").write_text(
            json.dumps(report, indent=1))
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--variant", nargs="+", default=["baseline"])
    ap.add_argument("--treesync", action="store_true")
    ap.add_argument("--periods", type=int, nargs=2, default=[4, 16])
    ap.add_argument("--compression", default="none")
    ap.add_argument("--flash-adjust", action="store_true")
    args = ap.parse_args()
    print(f"{args.arch} x {args.shape} x {args.mesh}:")
    if args.treesync:
        run_treesync(args.arch, args.mesh, args.periods[0],
                     args.compression)
        return
    if args.flash_adjust:
        for v in args.variant:
            run_flash_adjustment(args.arch, args.shape, args.mesh, v)
        return
    for v in args.variant:
        run_variant(args.arch, args.shape, args.mesh, v)


if __name__ == "__main__":
    main()
