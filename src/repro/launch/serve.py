"""Serving driver: batched prefill + greedy decode with the sharded cache.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --smoke \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCHS
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.models import transformer


def generate(cfg, params, prompts: Dict[str, jax.Array], gen_tokens: int,
             max_len: Optional[int] = None):
    """Prefill the prompt batch then greedily decode `gen_tokens` tokens."""
    B = (prompts.get("tokens", prompts.get("embeds"))).shape[0]
    S = (prompts.get("tokens", prompts.get("embeds"))).shape[1]
    max_len = max_len or (S + gen_tokens)
    prefill = jax.jit(make_prefill_step(cfg, max_len=max_len))  # analysis: allow(jit-outside-engine) inference entry point, outside the training cache discipline
    serve = jax.jit(make_serve_step(cfg))  # analysis: allow(jit-outside-engine) inference entry point, outside the training cache discipline

    t0 = time.time()
    logits, cache = prefill(params, prompts)
    first = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    t_prefill = time.time() - t0

    toks = [first]
    t0 = time.time()
    tok = first
    for _ in range(gen_tokens - 1):
        tok, cache = serve(params, cache, tok)
        toks.append(tok)
    out = jnp.concatenate(toks, axis=1)
    out.block_until_ready()
    t_decode = time.time() - t0
    return out, {"prefill_s": t_prefill, "decode_s": t_decode,
                 "tok_per_s": B * (gen_tokens - 1) / max(t_decode, 1e-9)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    mod = ARCHS[args.arch]
    cfg = mod.SMOKE if args.smoke else mod.FULL
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(cfg, key)
    if cfg.input_mode == "embeddings":
        prompts = {"embeds": 0.02 * jax.random.normal(
            key, (args.batch, args.prompt_len, cfg.d_model), jnp.bfloat16)}
    else:
        prompts = {"tokens": jax.random.randint(
            key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    out, stats = generate(cfg, params, prompts, args.gen)
    print("generated:", out.shape, out[0, :8].tolist())
    print({k: round(v, 4) for k, v in stats.items()})


if __name__ == "__main__":
    main()
