"""jit-able step functions + ShapeDtypeStruct input specs for every
(architecture x shape) cell. These are what the dry-run lowers and what the
real train/serve drivers run.

  train_4k     -> train_step(params, opt_state, batch)
  prefill_32k  -> prefill_step(params, batch)           (builds the cache)
  decode_32k   -> serve_step(params, cache, tokens)     (one new token)
  long_500k    -> serve_step with a 512k-token cache    (sub-quadratic only)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeSpec
from repro.launch import sharding as sh
from repro.launch.mesh import data_axes
from repro.models import transformer
from repro.optim import Optimizer, get_optimizer

PyTree = Any


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStructs -- no allocation; dry-run stand-ins)
# ---------------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Model-input stand-ins for one shape cell.

    [audio]/[vlm] backbones take precomputed frame/patch embeddings for
    full-sequence passes (the modality frontend is a stub per assignment);
    decode always feeds tokens through the text embedding table.
    """
    B, S = shape.global_batch, shape.seq_len
    ii32 = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.int32)
    if shape.kind == "decode":
        return {"tokens": ii32((B, 1))}
    batch: Dict[str, Any] = {}
    if cfg.input_mode == "embeddings":
        batch["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                               jnp.bfloat16)
    else:
        batch["tokens"] = ii32((B, S))
    if shape.kind == "train":
        batch["labels"] = ii32((B, S))
    return batch


def params_shape(cfg: ModelConfig) -> PyTree:
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(lambda k: transformer.init_params(cfg, k), key)


def cache_shape(cfg: ModelConfig, batch: int, max_len: int) -> PyTree:
    return jax.eval_shape(
        lambda: transformer.init_cache(cfg, batch, max_len))


def opt_shape(cfg: ModelConfig, optimizer: Optimizer) -> PyTree:
    return jax.eval_shape(optimizer.init, params_shape(cfg))


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------
def _shard_scope(shard_ctx):
    """Context entered INSIDE the traced step so model-level
    `constrain(...)` calls resolve; no-op when shard_ctx is None."""
    import contextlib
    if shard_ctx is None:
        return contextlib.nullcontext()
    from repro.models.shardctx import activation_sharding
    return activation_sharding(*shard_ctx)


def make_train_step(cfg: ModelConfig, optimizer: Optional[Optimizer] = None,
                    shard_ctx=None, microbatches: int = 1,
                    unroll_microbatches: bool = False) -> Callable:
    """microbatches > 1 = gradient accumulation: the global batch is split
    along dim 0 and grads are averaged across sequential microbatch passes.
    Activation working set (incl. remat-saved layer inputs) shrinks by the
    microbatch factor; FLOPs are unchanged. unroll_microbatches=True emits
    the accumulation loop unrolled (analysis-grade HLO for the dry-run)."""
    optimizer = optimizer or get_optimizer(cfg)

    def grads_of(params, mb):
        def loss_fn(p):
            total, metrics = transformer.forward_train(cfg, p, mb)
            return total, metrics

        (_, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        return grads, metrics

    def train_step(params, opt_state, batch):
        with _shard_scope(shard_ctx):
            if microbatches == 1:
                grads, metrics = grads_of(params, batch)
            else:
                mbs = {k: v.reshape((microbatches,
                                     v.shape[0] // microbatches)
                                    + v.shape[1:])
                       for k, v in batch.items()}
                if unroll_microbatches:
                    acc, metrics = grads_of(
                        params, {k: v[0] for k, v in mbs.items()})
                    for i in range(1, microbatches):
                        g_i, m_i = grads_of(
                            params, {k: v[i] for k, v in mbs.items()})
                        acc = jax.tree.map(jnp.add, acc, g_i)
                        metrics = jax.tree.map(jnp.add, metrics, m_i)
                else:
                    def body(carry, mb):
                        acc, mets = carry
                        g_i, m_i = grads_of(params, mb)
                        return (jax.tree.map(jnp.add, acc, g_i),
                                jax.tree.map(jnp.add, mets, m_i)), None

                    g0, m0 = grads_of(params,
                                      {k: v[0] for k, v in mbs.items()})
                    (acc, metrics), _ = jax.lax.scan(
                        body, (g0, m0),
                        {k: v[1:] for k, v in mbs.items()})
                grads = jax.tree.map(lambda g: g / microbatches, acc)
                metrics = jax.tree.map(lambda m: m / microbatches, metrics)
            params, opt_state = optimizer.update(params, grads, opt_state)
            return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, max_len: Optional[int] = None,
                      shard_ctx=None) -> Callable:
    def prefill_step(params, batch):
        with _shard_scope(shard_ctx):
            return transformer.prefill(cfg, params, batch, max_len=max_len)

    return prefill_step


def make_serve_step(cfg: ModelConfig, shard_ctx=None) -> Callable:
    """One decode step: greedy next token + updated cache."""

    def serve_step(params, cache, tokens):
        with _shard_scope(shard_ctx):
            logits, cache = transformer.decode_step(cfg, params, cache,
                                                    tokens)
            next_tokens = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            return next_tokens, cache

    return serve_step


# ---------------------------------------------------------------------------
# fully-sharded jit wrappers for one (cfg x shape x mesh) cell
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class CellProgram:
    """Everything needed to lower/compile/run one cell."""
    kind: str
    jitted: Any                 # jax.jit-wrapped fn (shardings applied)
    arg_shapes: Tuple[Any, ...]  # ShapeDtypeStructs (lower(*arg_shapes))
    in_shardings: Tuple[Any, ...]
    notes: Dict[str, Any]

    def lower(self):
        return self.jitted.lower(*self.arg_shapes)


def build_cell(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
               rules: sh.AxisRules = sh.DEFAULT_RULES,
               optimizer: Optional[Optimizer] = None,
               microbatches: int = 1) -> CellProgram:
    """Construct the jitted step + shardings + abstract inputs for a cell."""
    pshape = params_shape(cfg)
    pspecs = sh.param_specs(cfg, pshape, mesh, rules)
    psh = sh.to_named(pspecs, mesh)
    batch = input_specs(cfg, shape)
    bspecs = sh.batch_specs(cfg, mesh, batch)
    bsh = {k: NamedSharding(mesh, s) for k, s in bspecs.items()}
    notes: Dict[str, Any] = {"mesh": dict(mesh.shape)}

    shard_ctx = (mesh, rules)
    if shape.kind == "train":
        optimizer = optimizer or get_optimizer(cfg)
        oshape = jax.eval_shape(optimizer.init, pshape)
        ospecs = sh.opt_state_specs(cfg, oshape, pshape, mesh, rules)
        osh = sh.to_named(ospecs, mesh)
        step = make_train_step(
            cfg, optimizer, shard_ctx=shard_ctx, microbatches=microbatches,
            # scans under-count in cost_analysis; unroll when analyzing
            unroll_microbatches=not cfg.scan_layers)
        metrics_sh = NamedSharding(mesh, P())
        jitted = jax.jit(  # analysis: allow(jit-outside-engine) CellProgram owns its one jitted step; cached on the program object
            step,
            in_shardings=(psh, osh, bsh),
            out_shardings=(psh, osh, metrics_sh),
            donate_argnums=(0, 1),
        )
        return CellProgram("train", jitted, (pshape, oshape, batch),
                           (psh, osh, bsh), notes)

    if shape.kind == "prefill":
        step = make_prefill_step(cfg, max_len=shape.seq_len,
                                 shard_ctx=shard_ctx)
        cshape = cache_shape(cfg, shape.global_batch, shape.seq_len)
        csh = sh.to_named(sh.cache_specs(cfg, cshape, mesh, rules), mesh)
        logits_sh = NamedSharding(
            mesh, P(sh._batch_axes(mesh, rules, shape.global_batch), None))
        jitted = jax.jit(step, in_shardings=(psh, bsh),  # analysis: allow(jit-outside-engine) CellProgram owns its one jitted step; cached on the program object
                         out_shardings=(logits_sh, csh))
        return CellProgram("prefill", jitted, (pshape, batch),
                           (psh, bsh), notes)

    # decode: one new token against a seq_len-deep cache
    step = make_serve_step(cfg, shard_ctx=shard_ctx)
    cshape = cache_shape(cfg, shape.global_batch, shape.seq_len)
    csh = sh.to_named(sh.cache_specs(cfg, cshape, mesh, rules), mesh)
    tok_sh = NamedSharding(
        mesh, P(sh._batch_axes(mesh, rules, shape.global_batch), None))
    jitted = jax.jit(step, in_shardings=(psh, csh, tok_sh),  # analysis: allow(jit-outside-engine) CellProgram owns its one jitted step; cached on the program object
                     out_shardings=(tok_sh, csh), donate_argnums=(1,))
    return CellProgram("decode", jitted, (pshape, cshape, batch["tokens"]),
                       (psh, csh, tok_sh), notes)


def cell_is_supported(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """long_500k needs sub-quadratic sequence mixing (see DESIGN.md §5)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention arch: 512k dense-KV decode skipped "
                       "(DESIGN.md §5 Arch-applicability)")
    return True, ""
