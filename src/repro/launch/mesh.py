"""Production mesh construction.

The target is TPU v5e: one pod = a 16x16 slice (256 chips); multi-pod = 2
pods (512 chips) joined over the slow DCI/network hop. Axes:

    single-pod:  ("data", "model")        = (16, 16)
    multi-pod :  ("pod", "data", "model") = (2, 16, 16)

``make_production_mesh`` is a function (never a module constant) so importing
this module never touches jax device state; the dry-run sets
``--xla_force_host_platform_device_count=512`` before any jax import.
"""
from __future__ import annotations

from typing import Tuple

import jax

SINGLE_POD_SHAPE: Tuple[int, ...] = (16, 16)
SINGLE_POD_AXES: Tuple[str, ...] = ("data", "model")
MULTI_POD_SHAPE: Tuple[int, ...] = (2, 16, 16)
MULTI_POD_AXES: Tuple[str, ...] = ("pod", "data", "model")


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = MULTI_POD_SHAPE if multi_pod else SINGLE_POD_SHAPE
    axes = MULTI_POD_AXES if multi_pod else SINGLE_POD_AXES
    return jax.make_mesh(shape, axes)


def make_abstract_mesh(shape: Tuple[int, ...],
                       axes: Tuple[str, ...]) -> "jax.sharding.AbstractMesh":
    """Version-portable ``AbstractMesh`` construction.

    Newer jax takes ``AbstractMesh(axis_sizes, axis_names)``; jax 0.4.37
    takes a single ``shape_tuple`` of ``(name, size)`` pairs.  Accepts the
    modern ``(shape, axes)`` calling convention either way."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(shape), tuple(axes))
    except TypeError:
        return AbstractMesh(tuple(zip(axes, shape, strict=True)))


def make_host_mesh(model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / smoke runs)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), SINGLE_POD_AXES)


def data_axes(mesh: jax.sharding.Mesh) -> Tuple[str, ...]:
    """Axes the global batch is sharded over (pod included when present)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def axis_size(mesh: jax.sharding.Mesh, name: str) -> int:
    # mesh.shape works for both Mesh and AbstractMesh
    return dict(mesh.shape).get(name, 1)
