"""Training driver: a thin CLI over the Session-driven LM program.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-32b --smoke \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Everything here is plumbing: ``Problem.lm`` + ``Session.compile`` build
the replica-stacked train program (``repro.api.lm.LMSession``), the
unified ``CheckpointPolicy``/``resume`` path handles restart (one code
path, any periods), ``--sync`` is just ``periods=(1, ...)`` on the SAME
program (with SGD bit-identical to plain DP -- tested), and ``--adapt-h``
attaches a straggler policy whose eq.-(12) replanning feeds the runtime
periods operand.
"""
from __future__ import annotations

import argparse
import warnings
from typing import Any, Dict, Optional, Sequence

from repro.api import CheckpointPolicy, Problem, Session, Topology
from repro.configs.registry import ARCHS
from repro.launch.mesh import make_host_mesh
from repro.optim import get_optimizer


def train(cfg, *, steps: int, batch: int, seq: int, mesh=None,
          mode: Optional[str] = None, sync: bool = False,
          periods: Sequence[int] = (4,),
          ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
          lr: float = 3e-4, adapt_h: bool = False,
          log_every: int = 10, seed: int = 0) -> Dict[str, Any]:
    """Train ``cfg`` for ``steps`` optimizer steps; returns
    ``{"history", "final_loss", "wall_s"}`` (history entries
    ``{"step", "loss", "sec"}``, as before).

    ``mode=`` is a deprecated shim: ``mode="sync"`` means ``sync=True``
    (all periods 1 -- every step a full barrier), ``mode="treesync"`` the
    default schedule.  ``ckpt_every`` is in optimizer steps; snapshots
    land on outer-round boundaries."""
    if mode is not None:
        warnings.warn(
            "train(mode=...) is deprecated: both modes are ONE program "
            "now -- use sync=True (periods all 1) or periods=",
            DeprecationWarning, stacklevel=2)
        if mode not in ("treesync", "sync"):
            raise ValueError(f"unknown mode {mode!r}")
        sync = mode == "sync"

    mesh = mesh or make_host_mesh()
    opt = get_optimizer(cfg, lr=lr)
    prob = Problem.lm(cfg, opt, batch=batch, seq=seq, seed=seed)

    # fit the period list to the mesh's present sync axes (pad with the
    # last value / truncate), then lower the tree once
    from repro.core.engine.lm import present_axes
    axes = present_axes(mesh, ("data", "pod"))
    L = max(len(axes), 1)
    ps = [1] * L if sync else (
        list(periods) + [periods[-1]] * (L - len(periods)))[:L]
    topo = Topology.from_mesh(mesh, sync_axes=("data", "pod"), periods=ps)
    sess = Session.compile(prob, topo, backend="mesh", mesh=mesh)
    spr = sess.steps_per_round

    def on_step(entry):
        if entry["step"] % log_every == 0:
            print(f"[train] step {entry['step']}: loss={entry['loss']:.4f} "
                  f"{entry['sec']*1e3:.0f}ms", flush=True)

    straggler = None
    if adapt_h:
        if ckpt_dir:
            raise ValueError("--adapt-h does not compose with --ckpt-dir "
                             "(straggler-adaptive runs are not "
                             "checkpointable); pick one")
        from repro.runtime.straggler import AdaptiveSchedule, StragglerPolicy
        straggler = StragglerPolicy(seed=seed, adaptive=AdaptiveSchedule())

    if ckpt_dir:
        policy = CheckpointPolicy(directory=ckpt_dir, keep=3,
                                  every=max(1, int(ckpt_every) // spr))
        last = policy.manager().latest_step()
        if last is not None:
            # continue toward THIS call's step target; report only the
            # newly run steps (the prefix is the previous run's history)
            res = sess.resume(policy, steps=max(steps - last, 0),
                              on_step=on_step)
            print(f"[train] resumed from step {last}; "
                  f"ran to step {int(res.state.step)}")
            history = [e for e in res.history if e["step"] > last]
        else:
            res = sess.run(steps=steps, checkpoint=policy, on_step=on_step)
            history = res.history
    else:
        res = sess.run(steps=steps, straggler=straggler, on_step=on_step)
        history = res.history

    return {"history": history, "final_loss": res.final_loss,
            "wall_s": res.wall_s}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCHS))
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--sync", action="store_true",
                    help="all periods 1: every step a full barrier "
                         "(the star special case; DP-equivalent)")
    ap.add_argument("--mode", default=None, choices=["treesync", "sync"],
                    help="deprecated: use --sync / --periods")
    ap.add_argument("--periods", type=int, nargs="+", default=[4])
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--adapt-h", action="store_true")
    args = ap.parse_args()

    mod = ARCHS[args.arch]
    cfg = mod.SMOKE if args.smoke else mod.FULL
    out = train(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                mode=args.mode, sync=args.sync, periods=args.periods,
                lr=args.lr, ckpt_dir=args.ckpt_dir,
                ckpt_every=args.ckpt_every, adapt_h=args.adapt_h)
    print(f"[train] done: final loss {out['final_loss']:.4f} "
          f"in {out['wall_s']:.1f}s")


if __name__ == "__main__":
    main()
