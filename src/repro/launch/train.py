"""Training driver: config-driven, sharded, fault-tolerant.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-32b --smoke \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Features wired here:
  * mesh + sharding from the same rules the dry-run validates,
  * TreeSync (paper schedule) or plain synchronous DP (--sync),
  * checkpoint/restart (atomic, keep-k, auto-resume),
  * straggler-adaptive H re-planning (paper eq. (12)) from observed timings.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCHS
from repro.core import treesync as tsy
from repro.data.lm import synthetic_lm_batches
from repro.launch import sharding as sh
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_host_mesh
from repro.models import transformer
from repro.optim import get_optimizer
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.straggler import AdaptiveSchedule, StepTimer


def train(cfg, *, steps: int, batch: int, seq: int, mesh=None,
          mode: str = "treesync", periods=(4,),
          ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
          lr: float = 3e-4, adapt_h: bool = False,
          log_every: int = 10, seed: int = 0) -> Dict[str, Any]:
    mesh = mesh or make_host_mesh()
    opt = get_optimizer(cfg, lr=lr)
    key = jax.random.PRNGKey(seed)

    mgr = CheckpointManager(ckpt_dir, keep=3) if ckpt_dir else None
    start_step = 0

    if mode == "treesync":
        ts = tsy.TreeSyncConfig(sync_axes=("data", "pod"),
                                periods=tuple(periods))
        n_rep = tsy.replica_count(ts, mesh)
        state = tsy.init_state(cfg, opt, key, mesh, ts)
        if mgr and mgr.latest_step() is not None:
            start_step, state = mgr.restore(state)
            print(f"[train] resumed from step {start_step}")
        step_fn = jax.jit(tsy.make_treesync_step(cfg, opt, ts, mesh))
    else:
        params = transformer.init_params(cfg, key)
        opt_state = opt.init(params)
        if mgr and mgr.latest_step() is not None:
            start_step, (params, opt_state) = mgr.restore(
                (params, opt_state))
            print(f"[train] resumed from step {start_step}")
        pshape = jax.eval_shape(lambda: params)
        psh = sh.param_shardings(cfg, pshape, mesh)
        osh = sh.to_named(sh.opt_state_specs(
            cfg, jax.eval_shape(lambda: opt_state), pshape, mesh), mesh)
        step_fn = jax.jit(steps_mod.make_train_step(cfg, opt),
                          in_shardings=(psh, osh, None),
                          out_shardings=(psh, osh, None))
        n_rep = 1

    timer = StepTimer()
    sched = AdaptiveSchedule() if adapt_h else None
    data = synthetic_lm_batches(cfg, batch, seq, seed=seed,
                                start=start_step)
    history = []
    t_start = time.time()
    for i, raw in zip(range(start_step, steps), data):
        t0 = time.time()
        if mode == "treesync":
            state, metrics = step_fn(state, tsy.split_batch(raw, n_rep))
        else:
            params, opt_state, metrics = step_fn(params, opt_state, raw)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        timer.observe(dt)
        history.append({"step": i + 1, "loss": loss, "sec": dt})
        if (i + 1) % log_every == 0:
            print(f"[train] step {i+1}: loss={loss:.4f} {dt*1e3:.0f}ms",
                  flush=True)
        if mgr and (i + 1) % ckpt_every == 0:
            payload = state if mode == "treesync" else (params, opt_state)
            mgr.save(i + 1, payload, metadata={"loss": loss})
        if sched is not None and len(timer.samples) >= 8:
            sched.replan(t_lp=timer.median, t_delay=0.0)

    if mgr:
        payload = state if mode == "treesync" else (params, opt_state)
        mgr.save(steps, payload)
        mgr.wait()
    wall = time.time() - t_start
    return {"history": history, "final_loss": history[-1]["loss"]
            if history else None, "wall_s": wall}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCHS))
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mode", default="treesync",
                    choices=["treesync", "sync"])
    ap.add_argument("--periods", type=int, nargs="+", default=[4])
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--adapt-h", action="store_true")
    args = ap.parse_args()

    mod = ARCHS[args.arch]
    cfg = mod.SMOKE if args.smoke else mod.FULL
    out = train(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                mode=args.mode, periods=args.periods, lr=args.lr,
                ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                adapt_h=args.adapt_h)
    print(f"[train] done: final loss {out['final_loss']:.4f} "
          f"in {out['wall_s']:.1f}s")


if __name__ == "__main__":
    main()
