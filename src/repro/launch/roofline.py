"""Roofline analysis from the compiled dry-run artifact.

Three terms per (arch x shape x mesh) cell, all in seconds (lower = the
floor set by that resource):

  compute    = HLO_FLOPs / (chips * 197e12)
  memory     = HLO_bytes / (chips * 819e9)
  collective = collective wire bytes per chip / 50e9 (ICI link bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` (whole-program,
so divided by chip count). Collective bytes are NOT in cost_analysis: we
parse the *post-SPMD-partitioning* HLO (``compiled.as_text()``) and apply
ring-collective wire formulas per op:

  all-reduce          2 (n-1)/n * bytes     (reduce-scatter + all-gather)
  all-gather            (n-1)/n * result bytes
  reduce-scatter        (n-1)/n * operand bytes
  all-to-all            (n-1)/n * bytes
  collective-permute              bytes

with n = replica-group size parsed from the op's replica_groups.
MODEL_FLOPS = 6 N D (train) / 2 N D (forward-only), N = active params --
the usefulness ratio MODEL_FLOPS/HLO_FLOPs exposes remat/redundant compute.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Any, Dict, List, Optional

from repro.launch import hw

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all",
)

# one HLO shape like bf16[16,1024]{1,0} or f32[] ; layout suffix optional
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\](?:\{[^}]*\})?")
# an instruction line:  %name = SHAPE-or-tuple opname(...)
_INSTR_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"((?:all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute|ragged-all-to-all)(?:-start|-done)?)\(")
_GROUPS_RE = re.compile(
    r"replica_groups=(?:\{(.*?)\}\}|\[(\d+),(\d+)\])")
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{")


def shape_bytes(shape_str: str) -> int:
    """Total bytes of one HLO shape string (or tuple of shapes)."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    """Parse replica-group size: {{0,1},{2,3}} or iota [ngroups,gsize]<=..."""
    m = _GROUPS_RE.search(line)
    if m:
        if m.group(1) is not None:
            first = m.group(1).split("}")[0]
            return max(first.count(",") + 1, 1)
        return int(m.group(3))
    # collective-permute has source_target_pairs instead
    return 2


@dataclasses.dataclass
class CollectiveOp:
    op: str
    result_bytes: int
    group_size: int
    count: int = 1

    def wire_bytes_per_chip(self) -> float:
        """Ring-collective bytes each chip must push over its link."""
        n = max(self.group_size, 1)
        b = self.result_bytes
        if n == 1:
            return 0.0
        if self.op.startswith("all-reduce"):
            return 2.0 * (n - 1) / n * b
        if self.op.startswith("all-gather"):
            return (n - 1) / n * b
        if self.op.startswith("reduce-scatter"):
            # result is the scattered shard; operand was n x larger
            return (n - 1) * b
        if self.op.startswith(("all-to-all", "ragged-all-to-all")):
            return (n - 1) / n * b
        if self.op.startswith("collective-permute"):
            return float(b)
        return float(b)


def parse_collectives(hlo_text: str) -> List[CollectiveOp]:
    """All collective instructions of the post-partitioning HLO module."""
    agg: Dict[tuple, CollectiveOp] = {}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        if op.endswith("-done"):
            continue  # counted at -start
        base = op[:-6] if op.endswith("-start") else op
        b = shape_bytes(shape_str)
        n = _group_size(line)
        key = (base, b, n)
        if key in agg:
            agg[key].count += 1
        else:
            agg[key] = CollectiveOp(base, b, n)
    return list(agg.values())


def collective_summary(ops: List[CollectiveOp]) -> Dict[str, Any]:
    by_op: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"count": 0, "result_bytes": 0, "wire_bytes_per_chip": 0.0})
    for c in ops:
        d = by_op[c.op]
        d["count"] += c.count
        d["result_bytes"] += c.result_bytes * c.count
        d["wire_bytes_per_chip"] += c.wire_bytes_per_chip() * c.count
    total_wire = sum(d["wire_bytes_per_chip"] for d in by_op.values())
    total_result = sum(d["result_bytes"] for d in by_op.values())
    return {"by_op": dict(by_op), "wire_bytes_per_chip": total_wire,
            "result_bytes": total_result,
            "n_ops": sum(d["count"] for d in by_op.values())}


# ---------------------------------------------------------------------------
# MODEL_FLOPS
# ---------------------------------------------------------------------------
def model_flops(cfg, shape) -> float:
    """6 N D for training, 2 N D forward-only; N = active params,
    D = tokens processed by the step."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


# ---------------------------------------------------------------------------
# the three terms
# ---------------------------------------------------------------------------
def roofline(cost: Dict[str, float], collectives: Dict[str, Any],
             n_chips: int, mflops: float) -> Dict[str, Any]:
    # cost_analysis() under SPMD reports the ONE-partition program, i.e.
    # numbers are already per-chip (verified: sharded matmul reports
    # total/chips). So: per-chip time = per-chip work / per-chip rate, which
    # equals the spec's HLO_total/(chips * rate).
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    compute_s = flops / hw.PEAK_FLOPS_BF16
    memory_s = nbytes / hw.HBM_BW
    collective_s = collectives["wire_bytes_per_chip"] / hw.ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    step_s = max(terms.values())
    ideal_s = mflops / (n_chips * hw.PEAK_FLOPS_BF16)
    return {
        **terms,
        "dominant": dominant,
        "model_flops": mflops,
        "hlo_flops": flops,
        "hlo_bytes": nbytes,
        "useful_ratio": (mflops / (flops * n_chips)
                         if flops else 0.0),
        "roofline_fraction": ideal_s / step_s if step_s else 0.0,
        "step_time_lower_bound_s": step_s,
    }
