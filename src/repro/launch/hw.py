"""TPU v5e hardware constants used by the roofline analysis (target
hardware; this container is CPU-only so these are never 'measured')."""

PEAK_FLOPS_BF16 = 197e12      # per chip, bf16 MXU
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link (intra-pod)
DCI_BW = 6.25e9               # bytes/s cross-pod (data-center network)
ICI_LATENCY = 1e-5            # s per hop
DCI_LATENCY = 1e-3            # s per hop
HBM_PER_CHIP = 16 * 2**30     # 16 GiB
VMEM_PER_CHIP = 128 * 2**20   # ~128 MiB vector memory
