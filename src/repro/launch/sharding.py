"""Sharding rules: map every parameter / activation / cache tensor onto the
production mesh.

Scheme (MaxText-flavored 2D "FSDP x TP"):
  * "model" axis  -- tensor parallelism: attention heads, FFN hidden dim,
    MoE expert dim, vocab dim, recurrent channel dim.
  * "data" axis   -- batch parallelism for activations AND fully-sharded
    (FSDP/ZeRO-3) parameter+optimizer-state storage along d_model.
  * "pod" axis    -- pure data parallelism across pods (params replicated);
    this is the slow link that the paper's TreeSync schedule optimizes.

Every rule is *guarded by divisibility*: an axis is applied to a tensor dim
only if the dim divides evenly (and, for attention-head dims, only if the
head count itself divides, so shards stay head-aligned). Otherwise that dim
falls back to replication -- recorded by `explain_shardings` so the roofline
report can show what was left on the table.

Logical-axis indirection (`AxisRules`) lets the perf loop re-map logical axes
(e.g. ffn -> ("data","model") for 2D sharding) without touching the rules.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch.mesh import axis_size, data_axes

PyTree = Any

MeshAxes = Optional[Tuple[str, ...]]  # value of one logical axis


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """logical axis name -> mesh axes (None = replicate)."""
    embed: MeshAxes = ("data",)        # d_model dim of weights (FSDP)
    heads: MeshAxes = ("model",)       # fused q-heads dim
    kv_heads: MeshAxes = ("model",)    # fused kv-heads dim
    ffn: MeshAxes = ("model",)         # MLP hidden
    vocab_in: MeshAxes = ("model",)    # embedding-table vocab dim
    vocab_out: MeshAxes = ("model",)   # unembedding vocab dim
    expert: MeshAxes = ("model",)      # MoE expert dim
    ffn_moe: MeshAxes = None           # per-expert hidden (after expert split)
    lru: MeshAxes = ("model",)         # RG-LRU channel dim
    rwkv_out: MeshAxes = ("model",)    # RWKV projection output dim
    layers: MeshAxes = None            # stacked-layer dim of scanned blocks
    # activations
    act_batch: MeshAxes = ("pod", "data")  # filtered per-mesh automatically
    act_seq: MeshAxes = None           # sequence dim (sequence parallelism)
    act_embed: MeshAxes = None         # activation d_model dim
    act_heads: MeshAxes = ("model",)   # activation heads dim
    # kv-cache
    cache_batch: MeshAxes = ("pod", "data")
    cache_seq: MeshAxes = ("model",)   # context slots (decode memory)
    cache_heads: MeshAxes = None
    # ZeRO-1: optimizer state gets an extra shard axis beyond its param's
    # (used with embed=None: params replicated over "data", states sharded)
    zero1: MeshAxes = None

    def get(self, name: str) -> MeshAxes:
        return getattr(self, name)


DEFAULT_RULES = AxisRules()


# ---------------------------------------------------------------------------
# parameter rules: leaf name -> logical axes of its trailing dims.
# Leading (stacked-layer) dims get the `layers` logical axis (default: none).
# ---------------------------------------------------------------------------
_PARAM_LOGICAL: Dict[str, Tuple[Optional[str], ...]] = {
    # top level
    "embed": ("vocab_in", "embed"),
    "unembed": ("embed", "vocab_out"),
    # attention
    "wq": ("embed", "heads"),
    "wk": ("embed", "kv_heads"),
    "wv": ("embed", "kv_heads"),
    "wo": ("heads", "embed"),
    "bq": ("heads",),
    "bk": ("kv_heads",),
    "bv": ("kv_heads",),
    # dense MLP
    "w_gate": ("embed", "ffn"),
    "w_up": ("embed", "ffn"),
    "w_down": ("ffn", "embed"),
    # MoE (3-D expert-stacked weights override the dense names by ndim)
    "router": ("embed", None),
    # RG-LRU
    "w_in": ("embed", "lru"),
    "conv": (None, "lru"),
    "w_a": ("embed", "lru"),
    "w_x": ("embed", "lru"),
    "w_out": ("lru", "embed"),
    # RWKV6
    "wr": ("embed", "rwkv_out"),
    "wg": ("embed", "rwkv_out"),
    "mix_lora_a": ("embed", None),
    "cm_wk": ("embed", "ffn"),
    "cm_wv": ("ffn", "embed"),
    "cm_wr": ("embed", "rwkv_out"),
}
# names resolved by surrounding context
_MOE_LOGICAL: Dict[str, Tuple[Optional[str], ...]] = {
    "w_gate": ("expert", "embed", "ffn_moe"),
    "w_up": ("expert", "embed", "ffn_moe"),
    "w_down": ("expert", "ffn_moe", "embed"),
}
_RWKV_SHARED = {"wk": ("embed", "rwkv_out"), "wv": ("embed", "rwkv_out"),
                "wo": ("rwkv_out", "embed")}


def _head_counts(cfg: ModelConfig) -> Dict[str, int]:
    return {"heads": max(cfg.num_heads, 1), "kv_heads": max(cfg.num_kv_heads, 1)}


def _resolve(
    logical: Sequence[Optional[str]],
    shape: Tuple[int, ...],
    mesh: Mesh,
    rules: AxisRules,
    cfg: ModelConfig,
    dropped: Optional[list] = None,
    path: str = "",
) -> P:
    """Turn trailing-dim logical axes into a full PartitionSpec with guards."""
    n_lead = len(shape) - len(logical)
    spec: list = []
    lead_axes = rules.get("layers")
    for i in range(n_lead):
        spec.append(None if not lead_axes else _fit(
            shape[i], lead_axes, mesh, set(), None))
    used: set = {a for s in spec if s
                 for a in (s if isinstance(s, tuple) else (s,))}
    heads = _head_counts(cfg)
    for dim, name in zip(shape[n_lead:], logical, strict=False):
        if name is None:
            spec.append(None)
            continue
        axes = rules.get(name)
        if axes is None:
            spec.append(None)
            continue
        head_align = heads.get(name)
        got = _fit(dim, axes, mesh, used, head_align)
        if got is None and dropped is not None:
            dropped.append((path, name, dim, axes))
        spec.append(got)
        if got:
            used.update(got if isinstance(got, tuple) else (got,))
    return P(*spec)


def _fit(dim: int, axes: Tuple[str, ...], mesh: Mesh, used: set,
         head_align: Optional[int]):
    """Largest prefix of `axes` that evenly divides `dim` (and head count)."""
    ok = []
    prod = 1
    for a in axes:
        if a not in mesh.axis_names or a in used:
            continue
        n = axis_size(mesh, a)
        if n == 1:
            continue
        if dim % (prod * n) != 0:
            break
        if head_align is not None and head_align % (prod * n) != 0:
            break
        ok.append(a)
        prod *= n
    if not ok:
        return None
    return tuple(ok) if len(ok) > 1 else ok[0]


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------
def param_specs(cfg: ModelConfig, params_shape: PyTree, mesh: Mesh,
                rules: AxisRules = DEFAULT_RULES,
                dropped: Optional[list] = None) -> PyTree:
    """PartitionSpec pytree matching `params_shape` (a ShapeDtypeStruct tree)."""

    def visit(path, leaf):
        keys = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
        name = keys[-1]
        pstr = "/".join(str(k) for k in keys)
        in_moe = cfg.is_moe and "ffn" in keys and "dense" not in keys
        if in_moe and name in _MOE_LOGICAL:
            logical = _MOE_LOGICAL[name]
        elif cfg.is_rwkv and name in _RWKV_SHARED:
            logical = _RWKV_SHARED[name]
        elif name in _PARAM_LOGICAL:
            logical = _PARAM_LOGICAL[name]
        else:
            # norms, biases, scalars, loras: replicate trailing dims
            logical = tuple(None for _ in leaf.shape)
        # guard: logical longer than shape (e.g. unstacked smoke shapes)
        logical = logical[-len(leaf.shape):] if leaf.shape else ()
        return _resolve(logical, leaf.shape, mesh, rules, cfg, dropped, pstr)

    return jax.tree_util.tree_map_with_path(visit, params_shape)


def to_named(spec_tree: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def param_shardings(cfg: ModelConfig, params_shape: PyTree, mesh: Mesh,
                    rules: AxisRules = DEFAULT_RULES,
                    dropped: Optional[list] = None) -> PyTree:
    return to_named(param_specs(cfg, params_shape, mesh, rules, dropped), mesh)


def opt_state_specs(cfg: ModelConfig, opt_shape: PyTree, params_shape: PyTree,
                    mesh: Mesh, rules: AxisRules = DEFAULT_RULES) -> PyTree:
    """Optimizer-state specs: moments inherit their parameter's spec;
    Adafactor factored vectors inherit the spec minus the reduced dim;
    scalars replicate."""
    pspecs = param_specs(cfg, params_shape, mesh, rules)
    flat_p = {tuple(_keystr(k) for k in path): spec
              for path, spec in _flat_with_path(pspecs)}
    flat_shapes = {tuple(_keystr(k) for k in path): l.shape
                   for path, l in _flat_with_path(params_shape)}

    def zero1_extend(spec: P, shape) -> P:
        """Add the zero1 axes to the first unsharded, divisible dim."""
        z = rules.get("zero1")
        if not z:
            return spec
        out = list(spec) + [None] * (len(shape) - len(spec))
        used = {a for s in out if s
                for a in (s if isinstance(s, tuple) else (s,))}
        for i, (dim, s) in enumerate(zip(shape, out, strict=False)):
            if s is not None:
                continue
            got = _fit(dim, z, mesh, used, None)
            if got is not None:
                out[i] = got
                return P(*out)
        return P(*out)

    def visit(path, leaf):
        keys = tuple(_keystr(k) for k in path)
        if not leaf.shape:
            return P()
        # strip the state-kind prefix ('mu'/'nu'/'v'/'mom') to find the param
        for start in range(len(keys)):
            cand = keys[start + 1:]
            if cand in flat_p:
                spec, pshape = flat_p[cand], flat_shapes[cand]
                if leaf.shape == pshape:
                    return zero1_extend(spec, leaf.shape)
                if keys[-1] == "vr" and leaf.shape == pshape[:-1]:
                    return zero1_extend(P(*spec[:-1]), leaf.shape)
                if keys[-1] == "vc" and leaf.shape == pshape[:-2] + pshape[-1:]:
                    return zero1_extend(P(*(spec[:-2] + spec[-1:])),
                                        leaf.shape)
        # vr/vc live one level deeper than the param name
        for start in range(len(keys)):
            cand = keys[start + 1:-1]
            if cand in flat_p:
                spec, pshape = flat_p[cand], flat_shapes[cand]
                if leaf.shape == pshape:
                    return zero1_extend(spec, leaf.shape)
                if keys[-1] == "vr" and leaf.shape == pshape[:-1]:
                    return zero1_extend(P(*spec[:-1]), leaf.shape)
                if keys[-1] == "vc" and leaf.shape == pshape[:-2] + pshape[-1:]:
                    return zero1_extend(P(*(spec[:-2] + spec[-1:])),
                                        leaf.shape)
        return P(*(None for _ in leaf.shape))

    return jax.tree_util.tree_map_with_path(visit, opt_shape)


def _keystr(k):
    return getattr(k, "key", getattr(k, "idx", None))


def _flat_with_path(tree):
    return jax.tree_util.tree_flatten_with_path(tree)[0]


# ---------------------------------------------------------------------------
# activations / batches / caches
# ---------------------------------------------------------------------------
def _batch_axes(mesh: Mesh, rules: AxisRules, b: int,
                which: str = "act_batch") -> MeshAxes:
    axes = tuple(a for a in (rules.get(which) or ()) if a in mesh.axis_names)
    got = _fit(b, axes, mesh, set(), None)
    return got


def batch_specs(cfg: ModelConfig, mesh: Mesh, batch_shape: Dict[str, Any],
                rules: AxisRules = DEFAULT_RULES) -> Dict[str, P]:
    """PartitionSpecs for a train/prefill/decode input batch dict."""
    out = {}
    for k, v in batch_shape.items():
        b_ax = _batch_axes(mesh, rules, v.shape[0])
        trailing = [None] * (len(v.shape) - 1)
        if k == "embeds" and len(v.shape) == 3:
            trailing = [rules.get("act_seq") and _fit(
                v.shape[1], rules.get("act_seq"), mesh, set(), None), None]
        out[k] = P(b_ax, *trailing)
    return out


def cache_specs(cfg: ModelConfig, cache_shape: PyTree, mesh: Mesh,
                rules: AxisRules = DEFAULT_RULES) -> PyTree:
    """Decode-cache specs. Attention caches (stacked: (L, B, n, kv, hd)):
    batch over data axes, context slots over `cache_seq`; recurrent states
    (L, B, W)/(L, B, H, N, N): batch over data, channel/head over model."""

    def visit(path, leaf):
        keys = [_keystr(k) for k in path]
        name = keys[-1]
        shape = leaf.shape
        if not shape:
            return P()
        stacked = "blocks" in keys  # leading L dim present
        lead = 1 if stacked else 0
        spec: list = [None] * len(shape)
        if name in ("k", "v"):
            spec[lead] = _batch_axes(mesh, rules, shape[lead], "cache_batch")
            cs = rules.get("cache_seq")
            if cs:
                spec[lead + 1] = _fit(shape[lead + 1], cs, mesh, set(), None)
            ch = rules.get("cache_heads")
            if ch:
                spec[lead + 2] = _fit(shape[lead + 2], ch, mesh, set(),
                                      cfg.num_kv_heads)
        elif name == "slot_pos":
            cs = rules.get("cache_seq")
            if cs:
                spec[lead] = _fit(shape[lead], cs, mesh, set(), None)
        elif name in ("h", "conv", "wkv", "tm_prev", "cm_prev"):
            spec[lead] = _batch_axes(mesh, rules, shape[lead], "cache_batch")
            # trailing channel dim over model when divisible
            got = _fit(shape[-1], ("model",), mesh, set(), None)
            if name == "wkv" and len(shape) > lead + 1:
                # (L, B, H, N, N): shard heads
                spec[lead + 1] = _fit(shape[lead + 1], ("model",), mesh,
                                      set(), None)
            elif got is not None and len(shape) - 1 > lead:
                spec[-1] = got
        return P(*spec)

    return jax.tree_util.tree_map_with_path(visit, cache_shape)


def explain_shardings(cfg: ModelConfig, params_shape: PyTree, mesh: Mesh,
                      rules: AxisRules = DEFAULT_RULES) -> Dict[str, Any]:
    """Report what was sharded and what fell back to replication."""
    dropped: list = []
    specs = param_specs(cfg, params_shape, mesh, rules, dropped)
    total = 0
    sharded = 0
    for (_path, leaf), (_, spec) in zip(
            _flat_with_path(params_shape), _flat_with_path(specs),
            strict=True):
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        denom = 1
        for s in spec:
            for a in (s if isinstance(s, tuple) else (s,)) if s else ():
                denom *= axis_size(mesh, a)
        sharded += n // denom
    return {
        "params_total": total,
        "params_per_device_max": sharded,
        "replicated_fallbacks": [
            {"path": p, "logical": n, "dim": d, "axes": list(a)}
            for p, n, d, a in dropped
        ],
    }
