import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile EVERY (architecture x input-shape)
cell for the production meshes, record memory/cost/collective analysis.

The two lines above must run before ANY jax import (jax locks the device
count at first backend init), hence the unusual module layout.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                 # everything
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b \
        --shape train_4k --mesh single --force
    PYTHONPATH=src python -m repro.launch.dryrun --list

Results are written incrementally to results/dryrun/<arch>__<shape>__<mesh>.json
so an interrupted sweep resumes where it stopped (fault tolerance for the
analysis itself).

(No ``from __future__`` import here: the XLA_FLAGS lines must be the very
first statements of the module.)
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path
from typing import Any, Dict, Optional

import jax

from repro.configs.registry import ARCHS, get_config
from repro.configs.shapes import SHAPES
from repro.launch import roofline as rf
from repro.launch import sharding as sh
from repro.launch import steps
from repro.launch.mesh import make_production_mesh

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"

MESHES = {"single": False, "multi": True}

# Baseline settings per shape kind (chosen so every cell fits 16 GiB HBM;
# see EXPERIMENTS.md §Perf for the measurements behind them):
#   * train: sequence-parallel residual boundaries (act_seq -> model) +
#     4-way gradient accumulation.
#   * prefill/decode: default rules (no remat-saved activations).
def baseline_settings(kind: str) -> Dict[str, Any]:
    if kind == "train":
        return {
            "rules": dataclasses.replace(sh.DEFAULT_RULES,
                                         act_seq=("model",)),
            "microbatches": 4,
        }
    return {"rules": sh.DEFAULT_RULES, "microbatches": 1}


def _pattern_len(cfg) -> int:
    if cfg.is_rwkv or not cfg.block_pattern:
        return 1
    return len(cfg.block_pattern)


def _compile_once(cfg, shape, mesh, rules, microbatches=1):
    """(compiled, lower_s, compile_s) for one cell variant."""
    t0 = time.time()
    cell = steps.build_cell(cfg, shape, mesh, rules=rules,
                            microbatches=microbatches)
    lowered = cell.lower()
    t_lower = time.time() - t0
    compiled = lowered.compile()
    return compiled, t_lower, time.time() - t0 - t_lower


def _analyze(compiled) -> Dict[str, Any]:
    cost = compiled.cost_analysis()
    csum = rf.collective_summary(rf.parse_collectives(compiled.as_text()))
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "wire": float(csum["wire_bytes_per_chip"]),
            "csum": csum}


def _affine(v1: float, v2: float, n1: int, n2: int, n: int) -> float:
    """Fit v = a + b*n through (n1,v1),(n2,v2); evaluate at n."""
    b = (v2 - v1) / (n2 - n1)
    return v1 + b * (n - n1)


def run_cell(arch: str, shape_name: str, mesh_name: str,
             rules: Optional[sh.AxisRules] = None,
             verbose: bool = True,
             cfg_overrides: Optional[Dict[str, Any]] = None,
             microbatches: Optional[int] = None) -> Dict[str, Any]:
    """Lower+compile one cell; return the full analysis record.

    XLA's cost_analysis counts a while (lax.scan) body ONCE, so the scanned
    full-depth compile under-reports per-layer flops/bytes/collectives. All
    of those are exactly affine in the number of layer blocks, so we:
      1. compile the TRUE config (layer scan) -> memory analysis + the
         deliverable 'this program compiles on this mesh',
      2. compile UNROLLED 1-block and 2-block variants (cheap) and fit
         a + b*n per metric, evaluated at the true depth.
    """
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": shape.kind,
    }
    ok, why = steps.cell_is_supported(cfg, shape)
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec

    mesh = make_production_mesh(multi_pod=MESHES[mesh_name])
    n_chips = mesh.devices.size
    base = baseline_settings(shape.kind)
    rules = rules or base["rules"]
    mb = microbatches if microbatches else base["microbatches"]
    mb = mb if shape.kind == "train" else 1
    try:
        # (1) true config, scanned layers -- proves the cell compiles
        compiled, t_lower, t_compile = _compile_once(cfg, shape, mesh,
                                                     rules, mb)
        mem = compiled.memory_analysis()
        scan_metrics = _analyze(compiled)

        # (2) affine fit on unrolled 1-block / 2-block variants
        p = _pattern_len(cfg)
        tail = cfg.num_layers % p
        n_target = cfg.num_layers // p
        n1, n2 = 1, 2
        fits: Dict[int, Dict[str, Any]] = {}
        for nb in (n1, n2):
            c_small = dataclasses.replace(
                cfg, num_layers=nb * p + tail, scan_layers=False)
            comp_s, _, _ = _compile_once(c_small, shape, mesh, rules, mb)
            fits[nb] = _analyze(comp_s)

        flops = _affine(fits[n1]["flops"], fits[n2]["flops"], n1, n2,
                        n_target)
        nbytes = _affine(fits[n1]["bytes"], fits[n2]["bytes"], n1, n2,
                         n_target)
        wire = _affine(fits[n1]["wire"], fits[n2]["wire"], n1, n2, n_target)
        # per-op wire-byte breakdown, extrapolated the same way
        by_op = {}
        ops = set(fits[n1]["csum"]["by_op"]) | set(fits[n2]["csum"]["by_op"])
        for op in ops:
            w1 = fits[n1]["csum"]["by_op"].get(op, {}).get(
                "wire_bytes_per_chip", 0.0)
            w2 = fits[n2]["csum"]["by_op"].get(op, {}).get(
                "wire_bytes_per_chip", 0.0)
            c1 = fits[n1]["csum"]["by_op"].get(op, {}).get("count", 0)
            c2 = fits[n2]["csum"]["by_op"].get(op, {}).get("count", 0)
            by_op[op] = {
                "wire_bytes_per_chip": _affine(w1, w2, n1, n2, n_target),
                "count": round(_affine(c1, c2, n1, n2, n_target)),
            }
    except Exception as e:  # a failure here is a bug in our sharding
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
        return rec

    csum = {"by_op": by_op, "wire_bytes_per_chip": wire}
    mflops = rf.model_flops(cfg, shape)
    roof = rf.roofline({"flops": flops, "bytes accessed": nbytes}, csum,
                       n_chips, mflops)

    peak_bytes = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                  + mem.output_size_in_bytes - mem.alias_size_in_bytes)
    rec.update(
        status="ok",
        n_chips=n_chips,
        analysis_mode="scan-compile + unrolled 1/2-block affine fit",
        timings={"lower_s": round(t_lower, 2),
                 "compile_s": round(t_compile, 2)},
        memory={
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes_per_device": peak_bytes,
            "peak_gib_per_device": round(peak_bytes / 2**30, 3),
            "fits_hbm_16gib": bool(peak_bytes < 16 * 2**30),
        },
        cost={"flops_per_chip": flops, "bytes_per_chip": nbytes,
              "scan_compile_flops": scan_metrics["flops"]},
        collectives=csum,
        roofline=roof,
        params_total=cfg.param_count(),
        params_active=cfg.active_param_count(),
    )
    if verbose:
        print(f"  {arch} x {shape_name} x {mesh_name}: "
              f"{rec['memory']['peak_gib_per_device']} GiB/dev, "
              f"dominant={roof['dominant']}, "
              f"roofline_frac={roof['roofline_fraction']:.3f}, "
              f"useful={roof['useful_ratio']:.2f}, "
              f"compile={t_compile:.0f}s", flush=True)
    return rec


def cell_path(arch: str, shape: str, mesh: str) -> Path:
    safe = arch.replace(".", "_")
    return RESULTS / f"{safe}__{shape}__{mesh}.json"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="architecture id or 'all'")
    ap.add_argument("--shape", default="all", help="shape name or 'all'")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--force", action="store_true",
                    help="recompute cells that already have results")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    global RESULTS
    if args.out:
        RESULTS = Path(args.out)
    RESULTS.mkdir(parents=True, exist_ok=True)

    archs = list(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    if args.list:
        for a in archs:
            for s in shapes:
                ok, why = steps.cell_is_supported(get_config(a), SHAPES[s])
                print(a, s, "OK" if ok else f"SKIP ({why})")
        return

    n_dev = len(jax.devices())
    assert n_dev == 512, f"expected 512 forced host devices, got {n_dev}"

    failures = []
    for a in archs:
        for s in shapes:
            for m in meshes:
                p = cell_path(a, s, m)
                if p.exists() and not args.force:
                    rec = json.loads(p.read_text())
                    print(f"  [cached] {a} x {s} x {m}: {rec['status']}")
                    if rec["status"] == "error":
                        failures.append((a, s, m))
                    continue
                rec = run_cell(a, s, m)
                p.write_text(json.dumps(rec, indent=1))
                if rec["status"] == "error":
                    failures.append((a, s, m))
                    print(f"  ERROR {a} x {s} x {m}: {rec['error']}",
                          flush=True)

    print(f"\ndone; {len(failures)} failures")
    for f in failures:
        print("  FAILED:", *f)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
