"""Deterministic synthetic LM data pipeline.

Host-invariant: batch t is a pure function of (seed, t), so every process
in a multi-host job generates identical global batches and slices its own
shard -- no data service needed for the dry-run scale, and restarts resume
the stream exactly (the pipeline is stateless given the step index).

The token stream is a mixture of Zipf-distributed unigrams and short
repeated motifs so a small model has learnable structure (loss decreases
measurably within a few hundred steps).
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp

Array = jax.Array


def lm_batch(cfg, batch: int, seq: int, step: int, seed: int = 0
             ) -> Dict[str, Array]:
    """Batch `step` of the deterministic stream."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    kz, km, kpos, kmask = jax.random.split(key, 4)
    V = cfg.vocab_size

    # Zipf-ish unigram: p(v) ~ 1/(v+10)
    ranks = jnp.arange(V, dtype=jnp.float32)
    logits = -jnp.log(ranks + 10.0)
    toks = jax.random.categorical(kz, logits, shape=(batch, seq + 1))

    # overlay repeated motifs (period-8 structure the model can learn)
    motif = jax.random.randint(km, (batch, 8), 0, V)
    tiled = jnp.tile(motif, (1, (seq + 1) // 8 + 1))[:, : seq + 1]
    use_motif = jax.random.bernoulli(kmask, 0.5, (batch, 1))
    toks = jnp.where(use_motif, tiled, toks)

    batch_d: Dict[str, Array] = {
        "tokens": toks[:, :-1].astype(jnp.int32),
        "labels": toks[:, 1:].astype(jnp.int32),
    }
    if cfg.input_mode == "embeddings":
        # modality-frontend stub: pretend tokens were already embedded
        emb_key = jax.random.fold_in(kpos, 1)
        table = jax.random.normal(emb_key, (256, cfg.d_model),
                                  jnp.bfloat16) * 0.02
        batch_d["embeds"] = table[batch_d["tokens"] % 256]
    return batch_d


def synthetic_lm_batches(cfg, batch: int, seq: int, seed: int = 0,
                         start: int = 0) -> Iterator[Dict[str, Array]]:
    step = start
    while True:
        yield lm_batch(cfg, batch, seq, step, seed)
        step += 1
