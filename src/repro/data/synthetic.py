"""Deterministic synthetic datasets for the DCA experiments (paper SS7).

The container is offline, so the UCI wine-quality set is replaced by a
statistically similar synthetic regression problem (11 physico-chemical
features, integer quality scores); the paper's synthetic experiment
(A in R^{100x600}, iid N(0,1)) is reproduced exactly.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def gaussian_regression(
    m: int = 600, d: int = 100, key: Array | None = None,
    noise: float = 0.1,
) -> Tuple[Array, Array]:
    """Paper SS7: X rows iid N(0,1); y from a planted linear model + noise."""
    key = jax.random.PRNGKey(7) if key is None else key
    kx, kw, kn = jax.random.split(key, 3)
    X = jax.random.normal(kx, (m, d))
    w_star = jax.random.normal(kw, (d,)) / jnp.sqrt(d)
    y = X @ w_star + noise * jax.random.normal(kn, (m,))
    return X, y


def gaussian_classification(
    m: int = 600, d: int = 100, key: Array | None = None, margin: float = 0.5,
) -> Tuple[Array, Array]:
    """Linearly separable-ish binary labels in {-1, +1} for SVM tests."""
    key = jax.random.PRNGKey(11) if key is None else key
    kx, kw, kn = jax.random.split(key, 3)
    X = jax.random.normal(kx, (m, d))
    w_star = jax.random.normal(kw, (d,)) / jnp.sqrt(d)
    score = X @ w_star + margin * jax.random.normal(kn, (m,))
    y = jnp.where(score >= 0, 1.0, -1.0)
    return X, y


def wine_like(m: int = 1596, key: Array | None = None) -> Tuple[Array, Array]:
    """Synthetic stand-in for the wine-quality set (offline container).

    11 correlated positive features, integer-ish quality target in [3, 8],
    standardized features (as one would for ridge regression).
    """
    key = jax.random.PRNGKey(17) if key is None else key
    d = 11
    kz, kmix, kw, kn = jax.random.split(key, 4)
    z = jax.random.normal(kz, (m, d))
    mix = jax.random.normal(kmix, (d, d)) / jnp.sqrt(d)
    X = z @ (jnp.eye(d) + 0.5 * mix)  # correlated features
    w_star = jax.random.normal(kw, (d,))
    q = 5.5 + 1.2 * jnp.tanh(X @ w_star / jnp.sqrt(d))
    y = jnp.clip(jnp.round(q + 0.3 * jax.random.normal(kn, (m,))), 3.0, 8.0)
    # standardize
    X = (X - X.mean(0)) / (X.std(0) + 1e-8)
    return X, y
