"""Communication-delay model and delay-aware tuning of local iterations H
(paper SS6, eq. (9)-(12)) plus the TPU per-level link models used by TreeSync.

eq. (9):  t_total = (t_lp*H + t_delay + t_cp) * T
eq. (11): gap factor after T rounds = (1 - (1 - (1-delta)^H) * C/K)^T
eq. (12): minimize over H the bound with T = t_total/(t_lp*H + t_delay + t_cp)

All bound evaluations are done in log-space for numerical stability
(H up to 1e6 and T up to 1e9 appear in the paper's sweeps).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Optional, Sequence, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# paper SS6: star-network bound as a function of H
# ---------------------------------------------------------------------------
def rounds_for_budget(t_total: float, H: float, t_lp: float, t_delay: float,
                      t_cp: float) -> float:
    """eq. (10): T = t_total / (t_lp H + t_delay + t_cp)."""
    return t_total / (t_lp * H + t_delay + t_cp)


def _check_improvement_constant(C: float, K: int) -> None:
    """eq. (11)'s per-round factor g(H) = 1 - (1 - (1-delta)^H) C/K is a
    contraction only for 0 < C <= K; outside that range the "factor" goes
    negative for large H and the log-space bound silently clamps it, so the
    planners reject bad constants up front instead of optimizing garbage."""
    if not 0 < C <= K:
        raise ValueError(
            f"the improvement constant must satisfy 0 < C <= K so eq. (11)'s "
            f"per-round factor stays in (0, 1]; got C={C} with K={K}")


def _check_acceleration(acceleration: float) -> float:
    a = float(acceleration)
    if not 0.0 <= a <= 1.0:
        raise ValueError(
            f"acceleration must be in [0, 1] (0 = plain SDCA, 1 = full "
            f"Nesterov rate); got {acceleration}")
    return a


def per_round_factor(H: float, C: float, K: int, delta: float,
                     acceleration: float = 0.0) -> float:
    """eq. (11) base: g(H) = 1 - (1 - (1-delta)^H) * C/K.

    ``acceleration`` models the accelerated primal-dual flavor (Ma et al.,
    arXiv 1711.05305): momentum on the server combine improves the
    dependence on the per-round progress s = (1-(1-delta)^H) C/K toward
    its square root, so g = 1 - s^(1 - acceleration/2).  ``acceleration=0``
    recovers the plain rate exactly; ``acceleration=1`` is the full
    Nesterov exponent 1/2."""
    s = (1.0 - (1.0 - delta) ** H) * C / K
    a = _check_acceleration(acceleration)
    if a > 0.0 and s > 0.0:
        s = s ** (1.0 - 0.5 * a)
    return 1.0 - s


def log_bound(
    H: float, *, C: float, K: int, delta: float, t_total: float,
    t_lp: float, t_delay: float, t_cp: float, acceleration: float = 0.0,
) -> float:
    """log of eq. (12)'s objective: T(H) * log g(H). Lower is better (< 0)."""
    g = per_round_factor(H, C, K, delta, acceleration)
    T = rounds_for_budget(t_total, H, t_lp, t_delay, t_cp)
    # g in (0,1]; log(g) <= 0
    return T * math.log(max(g, 1e-300))


def optimal_h(
    *, C: float, K: int, delta: float, t_total: float, t_lp: float,
    t_delay: float, t_cp: float, h_min: int = 1, h_max: int = 10**7,
    acceleration: float = 0.0,
) -> Tuple[int, float]:
    """Integer minimizer of eq. (12) by coarse log-grid + local refinement.

    Returns (H*, log_bound(H*)).
    """
    _check_improvement_constant(C, K)
    _check_acceleration(acceleration)
    # coarse: log-spaced candidates
    grid = sorted(
        {int(h) for h in np.unique(np.round(
            np.logspace(math.log10(h_min), math.log10(h_max), 200)))}
    )
    vals = [
        log_bound(h, C=C, K=K, delta=delta, t_total=t_total, t_lp=t_lp,
                  t_delay=t_delay, t_cp=t_cp, acceleration=acceleration)
        for h in grid
    ]
    i = int(np.argmin(vals))
    lo = grid[max(i - 1, 0)]
    hi = grid[min(i + 1, len(grid) - 1)]
    # exact integer scan in the bracket (bracket widths are ~5% of H, cheap
    # up to ~1e6; subsample if enormous)
    if hi - lo > 200_000:
        cand: Iterable[int] = np.unique(
            np.round(np.linspace(lo, hi, 100_000)).astype(np.int64))
    else:
        cand = range(lo, hi + 1)
    best_h, best_v = grid[i], vals[i]
    for h in cand:
        v = log_bound(int(h), C=C, K=K, delta=delta, t_total=t_total,
                      t_lp=t_lp, t_delay=t_delay, t_cp=t_cp,
                      acceleration=acceleration)
        if v < best_v:
            best_h, best_v = int(h), v
    return best_h, best_v


def optimal_h_vs_delay(
    rs: Sequence[float], *, C: float, K: int, delta: float, t_total: float,
    t_lp: float, t_cp: float, h_max: int = 10**7,
) -> np.ndarray:
    """Fig. 4(b): optimal H for t_delay = r * t_lp over a sweep of r."""
    out = []
    for r in rs:
        h, _ = optimal_h(C=C, K=K, delta=delta, t_total=t_total, t_lp=t_lp,
                         t_delay=r * t_lp, t_cp=t_cp, h_max=h_max)
        out.append(h)
    return np.array(out)


# ---------------------------------------------------------------------------
# TPU link models: used to instantiate the paper's delay model per mesh level
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class LinkModel:
    """One network level: latency + inverse-bandwidth delay for a message."""
    name: str
    latency_s: float
    bw_bytes_per_s: float

    def delay(self, msg_bytes: float) -> float:
        return self.latency_s + msg_bytes / self.bw_bytes_per_s


# v5e-flavored defaults (per DESIGN.md SS3); DCI is the slow cross-pod hop.
ICI_LINK = LinkModel("ici", latency_s=1e-5, bw_bytes_per_s=50e9)
DCI_LINK = LinkModel("dci", latency_s=1e-3, bw_bytes_per_s=6.25e9)


def ring_allreduce_delay(link: LinkModel, msg_bytes: float, n: int) -> float:
    """Ring all-reduce cost over n participants: 2(n-1)/n of the bytes per
    link plus 2(n-1) latency hops."""
    if n <= 1:
        return 0.0
    return 2 * (n - 1) * link.latency_s + (
        2.0 * (n - 1) / n * msg_bytes / link.bw_bytes_per_s
    )


@dataclasses.dataclass(frozen=True)
class SyncLevel:
    """One level of a hierarchical (tree) synchronization schedule."""
    name: str
    group_size: int          # K at this level
    link: LinkModel
    msg_bytes: float         # size of the averaged state

    def round_delay(self, wire_ratio: float = 1.0) -> float:
        """Per-round collective cost; ``wire_ratio`` scales the *bytes* on the
        wire (delta compression), leaving the latency hops untouched."""
        return ring_allreduce_delay(
            self.link, self.msg_bytes * wire_ratio, self.group_size)


@dataclasses.dataclass(frozen=True)
class FixedLevel:
    """A sync level with an explicitly-given per-round delay (seconds), as
    carried by ``TreeNode.up_delay`` -- interchangeable with
    :class:`SyncLevel` wherever only ``group_size``/``round_delay`` are used
    (``plan_hierarchical_h``).

    ``latency_s`` is the part of ``delay_s`` that is pure latency (per-hop
    setup cost): compression shrinks only the bandwidth-proportional
    remainder, so ``round_delay(r) = latency_s + (delay_s - latency_s)*r``.
    The default (0) treats the whole delay as bandwidth-bound -- the most
    optimistic view of compression, matching ``TreeNode.up_delay`` which
    does not split the two."""
    name: str
    group_size: int
    delay_s: float
    latency_s: float = 0.0

    def round_delay(self, wire_ratio: float = 1.0) -> float:
        return self.latency_s + (self.delay_s - self.latency_s) * wire_ratio


def simulate_bounded_skip(
    base_delays,
    model: "StragglerModel",
    *,
    max_consecutive: int,
    rel_floor: float = 0.5,
    k_mad: float = 5.0,
    warmup: int = 1,
    n_rounds: int = 512,
    seed: int = 0,
) -> Tuple[float, float]:
    """Monte-carlo the bounded-skip barrier over sampled per-leaf delays.

    Replays the ACTUAL runtime decision machinery of
    ``repro.runtime.straggler`` -- the fleet :class:`StepTimer` window
    (median + ``k_mad`` MAD, ``rel_floor`` relative slowdown, ``warmup``
    rounds before skips kick in) and one :class:`BoundedSkip` per leaf --
    over delays drawn from ``model`` around ``base_delays``, so the
    planner optimizes the same policy the session will execute.  Returns
    ``(mean per-round barrier delay -- the max over PARTICIPATING leaves
    --, mean participation fraction)``; ``max_consecutive=0`` never skips
    and reproduces the synchronous barrier (mean max over ALL leaves)."""
    # runtime decision classes; imported lazily (runtime.straggler imports
    # this module for its model/planner types)
    from repro.runtime.straggler import BoundedSkip, StepTimer
    base = np.atleast_1d(np.asarray(base_delays, np.float64))
    n = base.size
    rng = np.random.default_rng(seed)
    timer = StepTimer()
    skips = [BoundedSkip(max_consecutive=max_consecutive)
             for _ in range(n)]
    delay_sum = 0.0
    part_sum = 0
    for r in range(int(n_rounds)):
        d = model.sample(base, rng)
        warm = r >= warmup
        skip = np.array([
            skips[i].decide(warm and timer.is_straggling(
                float(d[i]), k=k_mad, rel_floor=rel_floor))
            for i in range(n)
        ])
        for i in range(n):
            timer.observe(float(d[i]))
        part = ~skip
        if part.any():
            delay_sum += float(d[part].max())
        part_sum += int(part.sum())
    return delay_sum / n_rounds, part_sum / (n_rounds * n)


def optimal_h_bounded_skip(
    *,
    C: float,
    K: int,
    delta: float,
    t_total: float,
    t_lp: float,
    t_cp: float,
    base_delays,
    model: "StragglerModel",
    skip_max: int = 3,
    h_max: int = 10**6,
    rel_floor: float = 0.5,
    n_rounds: int = 512,
    seed: int = 0,
    acceleration: float = 0.0,
) -> dict:
    """The straggler-aware eq. (12): jointly optimize the local iteration
    count H and the :class:`~repro.runtime.straggler.BoundedSkip`
    threshold ``s``.

    For each candidate ``s in 0..skip_max`` the bounded-skip barrier is
    simulated over the observed/nominal per-leaf delays
    (:func:`simulate_bounded_skip`), which yields the *effective* per-round
    delay (the straggler's uplink no longer gates the round) and the mean
    participation fraction ``rho``; a dropped leaf contributes no work to
    the round, so eq. (11)'s improvement constant dilutes to ``C * rho``.
    Each ``s`` then gets its own eq.-(12) optimal H, and the (H, s) pair
    with the best log-bound wins.  Returns ``{H, skip, t_delay,
    participation, log_bound}``."""
    _check_improvement_constant(C, K)
    if skip_max < 0:
        raise ValueError(f"skip_max must be >= 0, got {skip_max}")
    best: Optional[dict] = None
    for s in range(int(skip_max) + 1):
        t_delay, rho = simulate_bounded_skip(
            base_delays, model, max_consecutive=s, rel_floor=rel_floor,
            n_rounds=n_rounds, seed=seed)
        c_eff = max(C * rho, 1e-12)
        h, v = optimal_h(C=c_eff, K=K, delta=delta, t_total=t_total,
                         t_lp=t_lp, t_delay=t_delay, t_cp=t_cp, h_max=h_max,
                         acceleration=acceleration)
        if best is None or v < best["log_bound"]:
            best = {"H": h, "skip": s, "t_delay": t_delay,
                    "participation": rho, "log_bound": v}
    return best


def _compression_mods(spec) -> Tuple[float, float]:
    """(wire_ratio, quality) of a compression spec; (1, 1) for ``None``."""
    if spec is None:
        return 1.0, 1.0
    from repro.core import compression as _comp
    kind, frac = _comp.parse_spec(spec)
    return _comp.wire_ratio(kind, frac), _comp.quality(kind, frac)


def plan_hierarchical_h(
    levels: Sequence[SyncLevel],
    *,
    C: float,
    delta: float,
    t_total: float,
    t_lp: float,
    t_cp: float = 0.0,
    h_max: int = 10**6,
    h_max0: Optional[int] = None,
    straggler: Optional["StragglerModel"] = None,
    base_delays=None,
    skip_max: int = 3,
    rel_floor: float = 0.5,
    sim_rounds: int = 512,
    seed: int = 0,
    compression: Optional[Sequence] = None,
    acceleration: float = 0.0,
) -> list[dict]:
    """Choose per-level local-round counts bottom-up with eq. (12).

    ``h_max0`` additionally caps the INNERMOST level's H (the leaves'
    local steps) -- the compiled H capacity when the schedule declares an
    ``h_cap`` -- so the whole plan (round times, the root-round budget)
    is optimized under, and stays consistent with, what the executors can
    actually run.

    Level 0 is the innermost (fastest link). For level i, the 'local
    iteration' cost is the full inner-level round time, and the 'delay' is
    this level's collective cost. Returns [{name, H, round_time}] bottom-up.

    This is the paper's SS6 applied recursively: each level treats the level
    below it as its LocalDualMethod.

    ``straggler`` switches the innermost level (the one whose barrier the
    per-leaf straggler tail actually gates) to the straggler-aware joint
    (H, skip-threshold) optimization (:func:`optimal_h_bounded_skip`) over
    ``base_delays`` (default: the level's own nominal delay per group
    member; sessions pass the per-leaf sync-PATH delays over the whole
    fleet -- the barrier the runtime ``StragglerPolicy`` actually
    operates, since it drops leaves at root-chunk granularity; exact for
    stars, a deliberate fleet-level approximation of the innermost
    barrier on deeper trees); its plan row gains ``skip``/
    ``participation`` and its ``round_time``/``delay`` use the
    bounded-skip effective barrier cost, which the outer levels then
    amortize.

    ``compression`` is an optional per-level (bottom-up, same order as
    ``levels``) list of delta-compression specs (``None``/``"none"``/
    ``"int8"``/``"topk_<frac>"``): a compressed level's delay shrinks by
    ``wire_ratio`` (via ``round_delay(wire_ratio)``) while its improvement
    constant is diluted to ``C*quality`` -- the error-feedback loop re-sends
    the truncated mass over later rounds, so each round contracts a bit
    less.  Use :func:`choose_compression` to pick the specs automatically.

    ``acceleration`` plans under the accelerated per-round factor (see
    :func:`per_round_factor`): every level contracts faster, so eq. (12)
    settles on fewer, cheaper rounds to the same bound -- the planner-side
    counterpart of ``Schedule(acceleration=)``.
    """
    _check_acceleration(acceleration)
    for lvl in levels:
        try:
            _check_improvement_constant(C, lvl.group_size)
        except ValueError as e:
            raise ValueError(f"level {lvl.name!r}: {e}") from None
    plan = []
    inner_iter_time = t_lp
    inner_delta = delta
    for i, lvl in enumerate(levels):
        spec = None
        if compression is not None and i < len(compression):
            spec = compression[i]
        ratio, qual = _compression_mods(spec)
        c_in = max(C * qual, 1e-12)
        c_lvl = c_in
        hm = h_max if (i > 0 or h_max0 is None) else min(h_max, int(h_max0))
        if i == 0 and straggler is not None:
            base = (base_delays if base_delays is not None
                    else [lvl.round_delay(ratio)] * lvl.group_size)
            row = optimal_h_bounded_skip(
                C=c_in, K=lvl.group_size, delta=inner_delta, t_total=t_total,
                t_lp=inner_iter_time, t_cp=t_cp, base_delays=base,
                model=straggler, skip_max=skip_max, h_max=hm,
                rel_floor=rel_floor, n_rounds=sim_rounds, seed=seed,
                acceleration=acceleration)
            h, t_delay = row["H"], row["t_delay"]
            c_lvl = max(c_in * row["participation"], 1e-12)
            extra = {"skip": row["skip"],
                     "participation": row["participation"]}
        else:
            t_delay = lvl.round_delay(ratio)
            h, _ = optimal_h(
                C=c_in, K=lvl.group_size, delta=inner_delta, t_total=t_total,
                t_lp=inner_iter_time, t_delay=t_delay, t_cp=t_cp,
                h_max=hm, acceleration=acceleration,
            )
            extra = {}
        if spec is not None:
            extra["compress"] = str(spec)
        round_time = inner_iter_time * h + t_delay + t_cp
        plan.append({"name": lvl.name, "H": h, "round_time": round_time,
                     "delay": t_delay, **extra})
        # the level above sees one of our rounds as its local iteration, and
        # its effective per-iteration improvement shrinks geometrically
        inner_iter_time = round_time
        inner_delta = 1.0 - per_round_factor(h, c_lvl, lvl.group_size,
                                             inner_delta, acceleration)
    return plan


#: candidate specs ``choose_compression`` evaluates per level; "none" first
#: so ties (e.g. zero-delay levels) fall back to the exact path.
DEFAULT_COMPRESSION_CANDIDATES: Tuple[str, ...] = ("none", "int8", "topk")


def choose_compression(
    levels: Sequence[SyncLevel],
    *,
    C: float,
    delta: float,
    t_total: float,
    t_lp: float,
    t_cp: float = 0.0,
    h_max: int = 10**6,
    candidates: Sequence[str] = DEFAULT_COMPRESSION_CANDIDATES,
    acceleration: float = 0.0,
) -> list[dict]:
    """Delay-aware per-level compression selection (eq. (12) extended).

    Walks the levels bottom-up like :func:`plan_hierarchical_h`, but at each
    level evaluates eq. (12)'s bound for every candidate spec: compression
    scales the level's on-wire bytes by ``wire_ratio(spec)`` (so a slow,
    bandwidth-bound hop gets cheaper rounds and can afford more of them)
    while diluting the improvement constant to ``C*quality(spec)`` (the
    error-feedback loop re-sends the truncated mass later).  The spec with
    the lowest bound wins; the level above then amortizes the *chosen*
    round time and contraction.  The net effect is the paper's trade
    automated: fast intra-pod levels keep ``"none"`` (nothing to win, only
    quality to lose), slow cross-pod levels pick ``"int8"``/``"topk"``.

    Returns ``[{name, spec, H, round_time, delay, bound}]`` bottom-up.  Feed
    the ``spec`` column (bottom-up = innermost-first) to
    ``Schedule(compression=[...])`` or reverse it for ``compile_tree``'s
    root-first per-depth form.

    ``acceleration`` evaluates every candidate under the accelerated
    per-round factor (:func:`per_round_factor`), matching the rate the
    ``"sdca_acc"`` method actually runs.
    """
    _check_acceleration(acceleration)
    for lvl in levels:
        try:
            _check_improvement_constant(C, lvl.group_size)
        except ValueError as e:
            raise ValueError(f"level {lvl.name!r}: {e}") from None
    if not candidates:
        raise ValueError("need at least one candidate compression spec")
    plan = []
    inner_iter_time = t_lp
    inner_delta = delta
    for lvl in levels:
        best = None
        for spec in candidates:
            ratio, qual = _compression_mods(spec)
            c_eff = max(C * qual, 1e-12)
            t_delay = lvl.round_delay(ratio)
            h, bound = optimal_h(
                C=c_eff, K=lvl.group_size, delta=inner_delta,
                t_total=t_total, t_lp=inner_iter_time, t_delay=t_delay,
                t_cp=t_cp, h_max=h_max, acceleration=acceleration,
            )
            if best is None or bound < best["bound"]:
                best = {"name": lvl.name, "spec": str(spec), "H": h,
                        "round_time": inner_iter_time * h + t_delay + t_cp,
                        "delay": t_delay, "bound": bound, "_c": c_eff}
        c_eff = best.pop("_c")
        plan.append(best)
        inner_iter_time = best["round_time"]
        inner_delta = 1.0 - per_round_factor(best["H"], c_eff,
                                             lvl.group_size, inner_delta,
                                             acceleration)
    return plan


# ---------------------------------------------------------------------------
# eq. (11) calibration: estimate C from an observed run
# ---------------------------------------------------------------------------
def fit_C(history, *, K: int, H: float, delta: float,
          floor: float = 1e-3, c_max: Optional[float] = None) -> float:
    """Estimate eq. (11)'s improvement constant C from observed per-round
    duality-gap contractions.

    eq. (11) predicts ``gap_{t+1} / gap_t ~= g = 1 - (1 - (1-delta)^H) C/K``
    per round; inverting with the (robust) median observed ratio gives
    ``C = (1 - g) K / (1 - (1-delta)^H)``.  ``history`` is a solver history
    (list of ``{..., "gap"}`` dicts, a :class:`~repro.core.instrument.
    SolveResult`, or a plain gap sequence) with at least two entries.  The
    estimate is clipped to ``[floor, c_max]`` (default ``c_max=K``) so
    downstream planners (:func:`plan_hierarchical_h`) always receive an
    admissible constant -- hierarchical planners must pass the SMALLEST
    group size over their levels as ``c_max``, since the same C is checked
    against every level's K."""
    cap = float(K) if c_max is None else float(c_max)
    if hasattr(history, "history"):
        history = history.history
    gaps = [float(h["gap"]) if isinstance(h, dict) else float(h)
            for h in history]
    gaps = [g for g in gaps if math.isfinite(g) and g > 0.0]
    if len(gaps) < 2:
        raise ValueError(
            "fit_C needs at least two positive finite gap observations; "
            f"got {len(gaps)} (record a longer pilot history)")
    ratios = [b / a for a, b in zip(gaps, gaps[1:], strict=False) if b < a]
    if not ratios:
        return floor          # no contraction observed at all
    g = float(np.median(ratios))
    eff = 1.0 - (1.0 - delta) ** H          # -> 1 for large H
    if eff <= 0.0:
        raise ValueError(f"delta={delta}, H={H} give no per-round progress")
    C = (1.0 - g) * K / eff
    return float(min(max(C, floor), cap))


# ---------------------------------------------------------------------------
# checkpoint-period planning: write cost vs. expected rework after a crash
# ---------------------------------------------------------------------------
def checkpoint_period(t_round: float, t_write: float, mtbf: float, *,
                      max_period: Optional[int] = None) -> int:
    """The checkpoint period (in ROOT ROUNDS) minimizing expected lost +
    overhead time on preemptible hardware: the Young/Daly optimum
    ``tau = sqrt(2 * t_write * MTBF)`` converted to rounds of length
    ``t_round`` and clamped to ``[1, max_period]``.

    Checkpointing every round pays ``t_write`` per round; never
    checkpointing loses half the run (in expectation) per failure.  The
    square-root optimum balances the amortized write cost
    (``t_write / tau``) against the expected rework (``tau / (2 MTBF)``).
    This is the term the eq.-(12) round-time model adds when a
    ``DelayModel`` declares ``ckpt_write``/``mtbf``: the per-round charge
    becomes ``t_round + t_write / period``, so ``rounds="auto"``'s time
    budget accounts the checkpoint overhead it planned."""
    if not t_round > 0:
        raise ValueError(f"t_round must be > 0, got {t_round}")
    if t_write < 0 or mtbf <= 0:
        raise ValueError(
            f"need t_write >= 0 and mtbf > 0, got {t_write}, {mtbf}")
    if t_write == 0:
        return 1                      # free writes: checkpoint every round
    tau = math.sqrt(2.0 * t_write * mtbf)
    period = max(1, int(round(tau / t_round)))
    if max_period is not None:
        period = min(period, int(max_period))
    return period


# ---------------------------------------------------------------------------
# straggler delay sampling: randomized per-leaf sync-path delays
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class StragglerModel:
    """Randomized per-leaf uplink delays around the topology's nominal ones.

    The paper's SS6 model treats the link delay as a constant; real networks
    have a heavy straggler tail on top.  Each round, a leaf's sync-path
    delay is its nominal base (the topology's up-link delays, typically
    derived from a :class:`LinkModel`'s ``delay(msg_bytes)``) with
    log-normal ``jitter``, and with probability ``slow_prob`` the leaf
    straggles: its delay is multiplied by ``slow_factor``.  This is the
    observation side that feeds ``repro.runtime.straggler``'s decision
    policies in simulated (containerized) runs."""
    slow_prob: float = 0.1
    slow_factor: float = 20.0
    jitter: float = 0.05

    def __post_init__(self):
        if not 0.0 <= self.slow_prob <= 1.0:
            raise ValueError(f"slow_prob must be in [0, 1]: {self.slow_prob}")
        if self.slow_factor < 1.0:
            raise ValueError(
                f"slow_factor must be >= 1 (a straggler is slower, not "
                f"faster): {self.slow_factor}")

    def sample(self, base, rng: np.random.Generator) -> np.ndarray:
        """One round's per-leaf delays: ``base`` is the (n,) nominal
        sync-path delay per leaf (seconds)."""
        base = np.asarray(base, dtype=np.float64)
        d = base * np.exp(rng.normal(0.0, self.jitter, size=base.shape))
        slow = rng.random(base.shape) < self.slow_prob
        return np.where(slow, d * self.slow_factor, d)

    @classmethod
    def for_link(cls, link: LinkModel, msg_bytes: float, **kw) -> tuple:
        """Convenience: (nominal delay of one message on ``link``, model) --
        the base to hand :meth:`sample` when the topology's ``up_delay``
        values came from this link."""
        return link.delay(msg_bytes), cls(**kw)
