"""TreeSync: the paper's tree-structured synchronization schedule as a
first-class feature for data-parallel LM training.

TreeDualMethod's structure (leaf does H local iterations; every tree level
averages its children's deltas with weight 1/K; rounds nest per level) maps
onto a TPU multi-pod system as:

  level 0  local optimizer steps on every replica      (H_0 = period between
           level-1 syncs)
  level 1  average replicas over the intra-pod "data" axis  (fast ICI)
  level 2  average over the cross-pod "pod" axis            (slow DCI),
           optionally int8-compressed with error feedback

Replicas are expressed as a leading replica dim R = prod(sync axis sizes)
sharded over ("pod", "data") -- each chip group holds exactly one replica, so
per-chip memory matches plain DP. Local steps are a vmap of the base train
step over R; a level-l sync is a mean over that level's sub-axis of the
reshaped (pod, data, ...) replica dim, which GSPMD lowers to an all-reduce
over exactly that mesh axis. periods=(1, 1) makes every step fully
synchronous: with a linear optimizer (SGD) this is bit-identical to standard
DP (tested), which is the paper's star-network special case.

The per-level periods are chosen by repro.core.delay.plan_hierarchical_h --
the paper's eq. (12) applied recursively (slow link => larger period).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import compression as comp_mod
from repro.launch import sharding as sh
from repro.launch.mesh import axis_size
from repro.models import transformer
from repro.optim import Optimizer

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TreeSyncConfig:
    """sync_axes are bottom-up (fastest link first). periods[i] = number of
    level-(i-1) rounds per level-i sync (paper: H at each tree level);
    level i fires every prod(periods[:i+1]) local steps."""
    sync_axes: Tuple[str, ...] = ("data", "pod")
    periods: Tuple[int, ...] = (4, 16)
    compression: str = "none"     # outermost-level delta compression
    average_opt_state: bool = True

    def cum_periods(self) -> Tuple[int, ...]:
        out, p = [], 1
        for h in self.periods:
            p *= h
            out.append(p)
        return tuple(out)


def _present_axes(ts: TreeSyncConfig, mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ts.sync_axes if a in mesh.axis_names
                 and axis_size(mesh, a) > 1)


def replica_count(ts: TreeSyncConfig, mesh: Mesh) -> int:
    n = 1
    for a in _present_axes(ts, mesh):
        n *= axis_size(mesh, a)
    return n


def tp_rules() -> sh.AxisRules:
    """Param sharding inside one replica: TP over "model" only (the "data"
    axis is occupied by the replica dim, so no FSDP)."""
    return dataclasses.replace(sh.DEFAULT_RULES, embed=None,
                               act_batch=("pod", "data"))


# ---------------------------------------------------------------------------
# replica-stacked state
# ---------------------------------------------------------------------------
def stack_replicas(tree: PyTree, n: int) -> PyTree:
    return jax.tree.map(
        lambda t: jnp.broadcast_to(t[None], (n,) + t.shape), tree)


def replica_specs(cfg: ModelConfig, tree_shape: PyTree, mesh: Mesh,
                  ts: TreeSyncConfig, base_rules: Optional[sh.AxisRules] = None
                  ) -> PyTree:
    """Specs for an (R, ...)-stacked tree: replica dim over the sync axes
    (outermost level first, matching reshape order), rest per tp_rules."""
    rules = base_rules or tp_rules()
    base = sh.param_specs(cfg, tree_shape, mesh, rules)
    rep_axes = tuple(reversed(_present_axes(ts, mesh)))  # (pod, data)

    def add_rep(spec):
        return P(rep_axes if len(rep_axes) > 1 else
                 (rep_axes[0] if rep_axes else None), *spec)

    return jax.tree.map(add_rep, base, is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# per-level averaging
# ---------------------------------------------------------------------------
def _mean_over_level(tree: PyTree, level_sizes: Sequence[int], level: int
                     ) -> PyTree:
    """Average the (R, ...) replica dim over sub-axis `level` of its
    (s_{L-1}, ..., s_0) factorization (level 0 = innermost/fastest)."""
    idx = len(level_sizes) - 1 - level  # position in the reshaped tuple

    def one(t):
        if t.ndim == 0 or jnp.issubdtype(t.dtype, jnp.integer):
            return t  # step counters etc: identical across replicas
        shp = t.shape
        r = t.reshape(tuple(level_sizes) + shp[1:])
        r = jnp.mean(r.astype(jnp.float32), axis=idx, keepdims=True)
        r = jnp.broadcast_to(
            r, tuple(level_sizes) + shp[1:])
        return r.reshape(shp).astype(t.dtype)

    return jax.tree.map(one, tree)


def _mean_over_prefix(tree: PyTree, level_sizes: Sequence[int], upto: int
                      ) -> PyTree:
    """Average over levels 0..upto simultaneously (one fused collective)."""
    keep = len(level_sizes) - 1 - upto  # leading dims to keep

    def one(t):
        if t.ndim == 0 or jnp.issubdtype(t.dtype, jnp.integer):
            return t
        shp = t.shape
        r = t.reshape(tuple(level_sizes) + shp[1:])
        axes = tuple(range(keep, len(level_sizes)))
        r = jnp.mean(r.astype(jnp.float32), axis=axes, keepdims=True)
        r = jnp.broadcast_to(r, tuple(level_sizes) + shp[1:])
        return r.reshape(shp).astype(t.dtype)

    return jax.tree.map(one, tree)


# ---------------------------------------------------------------------------
# the TreeSync step
# ---------------------------------------------------------------------------
@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["params", "opt_state", "step", "residual"], meta_fields=[])
@dataclasses.dataclass
class TreeSyncState:
    params: PyTree      # (R, ...) replica-stacked
    opt_state: PyTree   # (R, ...)
    step: jax.Array     # scalar int32
    residual: Optional[PyTree] = None  # error feedback (compressed mode)


def init_state(cfg: ModelConfig, optimizer: Optimizer, key, mesh: Mesh,
               ts: TreeSyncConfig) -> TreeSyncState:
    n = replica_count(ts, mesh)
    params = transformer.init_params(cfg, key)
    opt = optimizer.init(params)
    state = TreeSyncState(
        params=stack_replicas(params, n),
        opt_state=stack_replicas(opt, n),
        step=jnp.zeros((), jnp.int32),
    )
    if ts.compression != "none":
        compressor = comp_mod.COMPRESSORS[ts.compression]()
        state.residual = stack_replicas(compressor.init_residual(params), n)
    return state


def make_treesync_step(cfg: ModelConfig, optimizer: Optimizer,
                       ts: TreeSyncConfig, mesh: Mesh) -> Callable:
    """Returns step(state, batch) -> (state, metrics).

    batch leaves are (R, local_B, ...): the global batch pre-split by
    replica. Local steps are vmapped; sync levels fire on their periods.
    """
    axes = _present_axes(ts, mesh)
    level_sizes = tuple(axis_size(mesh, a) for a in reversed(axes))
    cum = ts.cum_periods()[: len(axes)]
    use_comp = ts.compression != "none"
    compressor = (comp_mod.COMPRESSORS[ts.compression]()
                  if use_comp else None)

    def local_step(params, opt_state, batch):
        def loss_fn(p):
            total, metrics = transformer.forward_train(cfg, p, batch)
            return total, metrics

        (_, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        params, opt_state = optimizer.update(params, grads, opt_state)
        return params, opt_state, metrics

    vstep = jax.vmap(local_step)

    def sync_level(params, opt_state, level):
        params = _mean_over_level(params, level_sizes, level)
        if ts.average_opt_state:
            opt_state = jax.tree.map(
                lambda t: (_mean_over_level({"x": t}, level_sizes, level)["x"]
                           if t.ndim > 0 else t),
                opt_state)
        return params, opt_state

    def compressed_outer_sync(params, residual):
        """Cross-outermost-level averaging of int8/topk-compressed deltas
        with error feedback. The anchor is the current inner-level mean
        (already identical within each outer group after the inner sync)."""
        inner_mean = _mean_over_prefix(params, level_sizes, len(axes) - 2) \
            if len(axes) > 1 else params
        delta = jax.tree.map(lambda p, a: p.astype(jnp.float32) - a.astype(
            jnp.float32), params, inner_mean)
        wire, residual = compressor.compress(delta, residual)
        deq = compressor.decompress(wire)
        avg_delta = _mean_over_level(deq, level_sizes, len(axes) - 1)
        avg_inner = _mean_over_level(inner_mean, level_sizes, len(axes) - 1)
        params = jax.tree.map(
            lambda a, d, p: (a.astype(jnp.float32) + d).astype(p.dtype),
            avg_inner, avg_delta, params)
        return params, residual

    def step(state: TreeSyncState, batch) -> Tuple[TreeSyncState, Dict]:
        params, opt_state, residual = (state.params, state.opt_state,
                                       state.residual)
        params, opt_state, metrics = vstep(params, opt_state, batch)
        step_no = state.step + 1

        for level in range(len(axes)):
            is_outer = level == len(axes) - 1
            due = (step_no % cum[level]) == 0

            if is_outer and use_comp:
                def do(ps, os, res):
                    ps, res = compressed_outer_sync(ps, res)
                    return ps, os, res

                def skip(ps, os, res):
                    return ps, os, res

                params, opt_state, residual = jax.lax.cond(
                    due, do, skip, params, opt_state, residual)
            else:
                params, opt_state = jax.lax.cond(
                    due,
                    functools.partial(sync_level, level=level),
                    lambda ps, os: (ps, os),
                    params, opt_state)

        new_state = TreeSyncState(params=params, opt_state=opt_state,
                                  step=step_no, residual=residual)
        mmean = jax.tree.map(lambda m: jnp.mean(m, axis=0), metrics)
        return new_state, mmean

    return step


def consensus_params(state: TreeSyncState, level_sizes=None) -> PyTree:
    """The fully-averaged model (what you checkpoint / serve)."""
    return jax.tree.map(lambda t: jnp.mean(t.astype(jnp.float32), axis=0),
                        state.params)


# ---------------------------------------------------------------------------
# batch splitting
# ---------------------------------------------------------------------------
def split_batch(batch: Dict[str, jax.Array], n_replicas: int
                ) -> Dict[str, jax.Array]:
    """(B, ...) -> (R, B/R, ...)."""
    def one(t):
        B = t.shape[0]
        assert B % n_replicas == 0, (B, n_replicas)
        return t.reshape((n_replicas, B // n_replicas) + t.shape[1:])

    return {k: one(v) for k, v in batch.items()}
