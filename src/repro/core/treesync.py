"""TreeSync: the paper's tree-structured synchronization schedule as a
first-class feature for data-parallel LM training.

TreeDualMethod's structure (leaf does H local iterations; every tree level
averages its children's deltas with weight 1/K; rounds nest per level) maps
onto a TPU multi-pod system as:

  level 0  local optimizer steps on every replica      (H_0 = period between
           level-1 syncs)
  level 1  average replicas over the intra-pod "data" axis  (fast ICI)
  level 2  average over the cross-pod "pod" axis            (slow DCI),
           optionally int8-compressed with error feedback

Replicas are expressed as a leading replica dim R = prod(sync axis sizes)
sharded over ("pod", "data") -- each chip group holds exactly one replica, so
per-chip memory matches plain DP. Local steps are a vmap of the base train
step over R; a level-l sync is a mean over that level's sub-axis of the
reshaped (pod, data, ...) replica dim, which GSPMD lowers to an all-reduce
over exactly that mesh axis. periods=(1, 1) makes every step fully
synchronous: with a linear optimizer (SGD) this is bit-identical to standard
DP (tested), which is the paper's star-network special case.

The per-level periods are chosen by repro.core.delay.plan_hierarchical_h --
the paper's eq. (12) applied recursively (slow link => larger period).

The implementation lives in ``repro.core.engine.lm`` as the LM side of the
Method protocol (``engine.method``); since PR 8 the step there takes the
periods as a runtime operand and is driven by Session/Schedule/Sweep
(``repro.api.lm.LMSession``).  This module keeps the legacy static-periods
surface as thin shims: ``make_treesync_step`` is deprecated in favor of
``Problem.lm(...)`` + ``Session.compile(backend="mesh")``.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Optional, Tuple

import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import compression as comp_mod
from repro.core.engine import lm as lm_mod
# legacy import surface (re-exported; implementation moved to engine.lm)
from repro.core.engine.lm import (  # noqa: F401
    PyTree,
    TreeSyncState,
    _masked_mean_over_level,
    _masked_mean_over_prefix,
    _mean_over_level,
    _mean_over_prefix,
    consensus_params,
    split_batch,
    stack_replicas,
)
from repro.launch import sharding as sh
from repro.launch.mesh import axis_size
from repro.optim import Optimizer


@dataclasses.dataclass(frozen=True)
class TreeSyncConfig:
    """sync_axes are bottom-up (fastest link first). periods[i] = number of
    level-(i-1) rounds per level-i sync (paper: H at each tree level);
    level i fires every prod(periods[:i+1]) local steps."""
    sync_axes: Tuple[str, ...] = ("data", "pod")
    periods: Tuple[int, ...] = (4, 16)
    compression: str = "none"     # outermost-level delta compression
    average_opt_state: bool = True

    def __post_init__(self):
        if len(set(self.sync_axes)) != len(self.sync_axes):
            raise ValueError(
                f"duplicate sync_axes {self.sync_axes}: each mesh axis is "
                "one tree level and can appear once")
        if not self.periods or any(
                not isinstance(p, int) or p <= 0 for p in self.periods):
            raise ValueError(
                f"periods must be positive ints, got {self.periods}")
        if len(self.periods) > len(self.sync_axes):
            raise ValueError(
                f"{len(self.periods)} periods for {len(self.sync_axes)} "
                "sync_axes: periods[i] schedules level i+1, one per axis")
        try:
            comp_mod.parse_spec(self.compression)
        except (KeyError, ValueError):
            raise ValueError(
                f"unknown compression {self.compression!r}; use one of "
                f"{sorted(comp_mod.COMPRESSORS)} or 'topk_<frac>'") from None

    def cum_periods(self) -> Tuple[int, ...]:
        out, p = [], 1
        for h in self.periods:
            p *= h
            out.append(p)
        return tuple(out)


def _present_axes(ts: TreeSyncConfig, mesh: Mesh) -> Tuple[str, ...]:
    return lm_mod.present_axes(mesh, ts.sync_axes)


def replica_count(ts: TreeSyncConfig, mesh: Mesh) -> int:
    n = 1
    for a in _present_axes(ts, mesh):
        n *= axis_size(mesh, a)
    return n


def tp_rules() -> sh.AxisRules:
    """Param sharding inside one replica: TP over "model" only (the "data"
    axis is occupied by the replica dim, so no FSDP)."""
    return dataclasses.replace(sh.DEFAULT_RULES, embed=None,
                               act_batch=("pod", "data"))


def replica_specs(cfg: ModelConfig, tree_shape: PyTree, mesh: Mesh,
                  ts: TreeSyncConfig, base_rules: Optional[sh.AxisRules] = None
                  ) -> PyTree:
    """Specs for an (R, ...)-stacked tree: replica dim over the sync axes
    (outermost level first, matching reshape order), rest per tp_rules."""
    import jax

    rules = base_rules or tp_rules()
    base = sh.param_specs(cfg, tree_shape, mesh, rules)
    rep_axes = tuple(reversed(_present_axes(ts, mesh)))  # (pod, data)

    def add_rep(spec):
        return P(rep_axes if len(rep_axes) > 1 else
                 (rep_axes[0] if rep_axes else None), *spec)

    return jax.tree.map(add_rep, base, is_leaf=lambda x: isinstance(x, P))


def init_state(cfg: ModelConfig, optimizer: Optimizer, key, mesh: Mesh,
               ts: TreeSyncConfig) -> TreeSyncState:
    n = replica_count(ts, mesh)
    return lm_mod.init_lm_state(cfg, optimizer, key, n,
                                compression=ts.compression)


def make_treesync_step(cfg: ModelConfig, optimizer: Optimizer,
                       ts: TreeSyncConfig, mesh: Mesh) -> Callable:
    """DEPRECATED shim: returns step(state, batch) -> (state, metrics) with
    the periods baked in.  Use ``Problem.lm(cfg, optimizer, ...)`` +
    ``Session.compile(backend="mesh")`` for the Session-driven program
    (runtime periods, straggler masks, checkpoint/resume, fused sweeps).

    batch leaves are (R, local_B, ...): the global batch pre-split by
    replica. Local steps are vmapped; sync levels fire on their periods.
    """
    warnings.warn(
        "make_treesync_step is deprecated; use Problem.lm(...) + "
        "Session.compile(backend='mesh') (repro.api) for the "
        "Session-driven LM program", DeprecationWarning, stacklevel=2)
    axes = _present_axes(ts, mesh)
    level_sizes = tuple(axis_size(mesh, a) for a in reversed(axes))
    periods = jnp.asarray(ts.periods[: len(axes)], jnp.int32)
    base = lm_mod.build_lm_step(
        cfg, optimizer, level_sizes=level_sizes,
        compression=ts.compression,
        average_opt_state=ts.average_opt_state)

    def step(state, batch):
        return base(state, batch, periods)

    return step
