"""Instrumentation for solver runs, factored out of the solvers themselves
so the compiled engine, the legacy reference recursion, the delay-planning
tools (``repro.core.delay``) and the figure benchmarks all share one
history/timing layer.

* simulated wall-clock: the tree's own delay model (``TreeNode.solve_time``,
  the generalization of paper eq. (9)) gives the per-root-round time;
* history: a list of ``{round, time, dual, primal, gap}`` dicts wrapped in
  :class:`SolveResult` (array accessors for plotting/benchmarks).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import jax
import numpy as np

from repro.core.tree import TreeNode

Array = jax.Array


@dataclasses.dataclass
class SolveResult:
    """A solver run: final iterates + per-root-round instrumentation.

    ``next_key`` (set by ``repro.api.Session.run``) is the root RNG chain
    state after the run, so a warm-restarted continuation reproduces the
    exact iterates of one longer run."""
    alpha: Array
    w: Array
    history: List[dict]  # per root round: round, time, dual, primal, gap
    next_key: Array = None

    @property
    def times(self) -> np.ndarray:
        return np.array([h["time"] for h in self.history])

    @property
    def gaps(self) -> np.ndarray:
        return np.array([h["gap"] for h in self.history])

    @property
    def duals(self) -> np.ndarray:
        return np.array([h["dual"] for h in self.history])


def per_round_time(tree: TreeNode) -> float:
    """Simulated wall-clock of ONE root round (children in parallel,
    synchronous barrier; paper eq. (9) when the tree is a star)."""
    return tree.solve_time() / max(tree.rounds, 1)


def round_times(tree: TreeNode) -> np.ndarray:
    """Times of rounds 0..T (round 0 is the start-of-run record)."""
    return np.arange(tree.rounds + 1) * per_round_time(tree)


def history_from_series(
    times: Sequence[float],
    duals: Sequence[float],
    primals: Sequence[float],
) -> List[dict]:
    """Assemble the legacy history-dict list from aligned series."""
    out = []
    for t, (tm, dv, pv) in enumerate(zip(times, duals, primals)):
        out.append({"round": t, "time": float(tm), "dual": float(dv),
                    "primal": float(pv), "gap": float(pv) - float(dv)})
    return out


def record_round(history: List[dict], t: int, time: float, dual: float,
                 primal: float) -> None:
    """Append one legacy-format history entry (used by the reference
    recursion, which records on the host as it goes)."""
    history.append({"round": t, "time": time, "dual": dual,
                    "primal": primal, "gap": primal - dual})
