"""Instrumentation for solver runs, factored out of the solvers themselves
so the compiled engine, the legacy reference recursion, the delay-planning
tools (``repro.core.delay``) and the figure benchmarks all share one
history/timing layer.

* simulated wall-clock: the tree's own delay model (``TreeNode.solve_time``,
  the generalization of paper eq. (9)) gives the per-root-round time;
* history: a list of ``{round, time, dual, primal, gap}`` dicts wrapped in
  :class:`SolveResult` (array accessors for plotting/benchmarks);
* batched histories: the sweep layer (``repro.api.sweep``) stores a config
  batch's series as ``(B, T)`` arrays -- :func:`stack_histories` /
  :func:`history_row` convert between that schema and the per-run dict
  lists (NaN-padded where members recorded fewer rounds).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import jax
import numpy as np

from repro.core.tree import TreeNode

Array = jax.Array

HISTORY_FIELDS = ("round", "time", "dual", "primal", "gap")


@dataclasses.dataclass
class SolveResult:
    """A solver run: final iterates + per-root-round instrumentation.

    ``next_key`` (set by ``repro.api.Session.run``) is the root RNG chain
    state after the run, so a warm-restarted continuation reproduces the
    exact iterates of one longer run.  ``lam`` (also session-set) records
    the regularization the run used, so a warm restart under a DIFFERENT
    lambda knows to rebuild the primal (``w = X^T alpha / (lam m)``)
    instead of carrying an inconsistent ``w``."""
    alpha: Array
    w: Array
    history: List[dict]  # per root round: round, time, dual, primal, gap
    next_key: Array = None
    lam: float = None

    @property
    def times(self) -> np.ndarray:
        return np.array([h["time"] for h in self.history])

    @property
    def gaps(self) -> np.ndarray:
        return np.array([h["gap"] for h in self.history])

    @property
    def duals(self) -> np.ndarray:
        return np.array([h["dual"] for h in self.history])

    @property
    def primals(self) -> np.ndarray:
        return np.array([h["primal"] for h in self.history])

    def to_dict(self) -> dict:
        """JSON-serializable form (iterates as lists, history as-is)."""
        return {
            "alpha": np.asarray(self.alpha).tolist(),
            "w": np.asarray(self.w).tolist(),
            "history": [dict(h) for h in self.history],
            "next_key": (None if self.next_key is None
                         else np.asarray(self.next_key).tolist()),
            "lam": None if self.lam is None else float(self.lam),
        }


def per_round_time(tree: TreeNode) -> float:
    """Simulated wall-clock of ONE root round (children in parallel,
    synchronous barrier; paper eq. (9) when the tree is a star)."""
    return tree.solve_time() / max(tree.rounds, 1)


def round_times(tree: TreeNode) -> np.ndarray:
    """Times of rounds 0..T (round 0 is the start-of-run record)."""
    return np.arange(tree.rounds + 1) * per_round_time(tree)


def history_from_series(
    times: Sequence[float],
    duals: Sequence[float],
    primals: Sequence[float],
) -> List[dict]:
    """Assemble the legacy history-dict list from aligned series."""
    out = []
    for t, (tm, dv, pv) in enumerate(zip(times, duals, primals,
                                         strict=True)):
        out.append({"round": t, "time": float(tm), "dual": float(dv),
                    "primal": float(pv), "gap": float(pv) - float(dv)})
    return out


def record_round(history: List[dict], t: int, time: float, dual: float,
                 primal: float) -> None:
    """Append one legacy-format history entry (used by the reference
    recursion, which records on the host as it goes)."""
    history.append({"round": t, "time": time, "dual": dual,
                    "primal": primal, "gap": primal - dual})


# ---------------------------------------------------------------------------
# batched-history schema (the sweep layer's (B, T) representation)
# ---------------------------------------------------------------------------
def stack_histories(histories: Sequence[List[dict]]) -> Dict[str, np.ndarray]:
    """Stack B per-run history dict-lists into ``{field: (B, T_max)}``
    float arrays (one per :data:`HISTORY_FIELDS`), NaN-padding members that
    recorded fewer rounds -- the :class:`~repro.api.sweep.RunSet` history
    schema.  Extra per-entry keys (async instrumentation) are dropped."""
    B = len(histories)
    t_max = max((len(h) for h in histories), default=0)
    out = {f: np.full((B, t_max), np.nan) for f in HISTORY_FIELDS}
    for b, hist in enumerate(histories):
        for t, entry in enumerate(hist):
            for f in HISTORY_FIELDS:
                out[f][b, t] = float(entry[f])
    return out


def history_row(stacked: Dict[str, np.ndarray], b: int) -> List[dict]:
    """Reconstruct member ``b``'s history dict-list from a
    :func:`stack_histories` batch (NaN padding rows are dropped)."""
    out: List[dict] = []
    rounds = stacked["round"]
    for t in range(rounds.shape[1]):
        if not np.isfinite(rounds[b, t]):
            continue
        out.append({
            "round": int(rounds[b, t]),
            "time": float(stacked["time"][b, t]),
            "dual": float(stacked["dual"][b, t]),
            "primal": float(stacked["primal"][b, t]),
            "gap": float(stacked["gap"][b, t]),
        })
    return out
