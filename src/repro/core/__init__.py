# The paper's primary contribution: tree-network distributed dual coordinate
# ascent (TreeDualMethod), its convergence-rate recursion (Theorem 2), the
# communication-delay model with the optimal local-iteration count H
# (eq. (12)), and the TreeSync hierarchical synchronization schedule that
# applies the same machinery to large-model data-parallel training.
from repro.core import convergence, delay, dual, local_sdca, tree, treedual  # noqa: F401
from repro.core.dual import LOSSES, duality_gap, dual_value, primal_value  # noqa: F401
from repro.core.tree import TreeNode, star, two_level  # noqa: F401
from repro.core.treedual import cocoa_star_solve, tree_dual_solve  # noqa: F401
