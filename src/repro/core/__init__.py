# The paper's primary contribution: tree-network distributed dual coordinate
# ascent (TreeDualMethod), its convergence-rate recursion (Theorem 2), the
# communication-delay model with the optimal local-iteration count H
# (eq. (12)), and the TreeSync hierarchical synchronization schedule that
# applies the same machinery to large-model data-parallel training.
#
# TreeDualMethod runs through the unified tree-schedule engine
# (repro.core.engine): any TreeNode topology is compiled to a flat static
# plan and executed as one jit/scan program with pluggable host (vmap),
# Pallas-leaf, and shard_map mesh backends; repro.core.treedual keeps the
# original recursion as a cross-check oracle.
from repro.core import convergence, delay, dual, instrument, local_sdca  # noqa: F401
from repro.core import tree, treedual  # noqa: F401
from repro.core import engine  # noqa: F401
from repro.core.dual import LOSSES, duality_gap, dual_value, primal_value  # noqa: F401
from repro.core.instrument import SolveResult  # noqa: F401
from repro.core.tree import TreeNode, star, two_level  # noqa: F401
from repro.core.treedual import (cocoa_star_solve, tree_dual_solve,  # noqa: F401
                                 tree_dual_solve_reference)
