"""Unified tree-schedule engine.

One entry point -- :func:`solve` -- runs the paper's TreeDualMethod
(Algorithms 1-3) on ANY ``TreeNode`` topology (star, multi-level, deep,
imbalanced, heterogeneous per-node rounds) as a single compiled program:

    plan  = compile_tree(tree)          # flat static schedule (the IR)
    keys  = key_plan(tree, plan, key)   # legacy-RNG per-solve key replay
    run   = get_host_executor(plan, ...)  # ONE jit'd lax.scan
    alpha, w, duals, primals = run(X, y, keys)

Backends:
  * ``backend="vmap"``   -- host/XLA: batched leaf solves via vmapped
    Procedure P (default).
  * ``backend="pallas"`` -- leaf solves via the Pallas blocked-SDCA kernel
    (per-block w + step masks; interpret mode off-TPU).
  * ``engine.mesh.execute_plan_mesh`` -- shard_map device program for
    level-homogeneous plans (mesh axes = one admissible grouping of the
    plan); used by ``repro.core.treedual_mesh``.

All backends consume the same coordinate-index plan, so the retained legacy
recursion (``repro.core.treedual.tree_dual_solve_reference``) is a
bit-comparable oracle for every path.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np

from repro.core.dual import Loss
from repro.core.engine.host import execute_plan, get_host_executor  # noqa: F401
from repro.core.engine.plan import (  # noqa: F401
    LevelSpec, TreePlan, balanced_tree, compile_tree, index_plan, key_plan,
    tree_from_level_plan,
)
from repro.core.instrument import (SolveResult, history_from_series,
                                   round_times)
from repro.core.tree import TreeNode

Array = jax.Array


def solve(
    tree: TreeNode,
    X: Array,
    y: Array,
    *,
    loss: Loss,
    lam: float,
    key: Optional[Array] = None,
    record_history: bool = True,
    backend: str = "vmap",
    weighting: str = "uniform",
) -> SolveResult:
    """Algorithm 3 at the root of ``tree``, compiled: one jit/scan program."""
    m = X.shape[0]
    assert tree.total_data() == m, (
        f"tree data sizes {tree.total_data()} != m={m}")
    plan = compile_tree(tree, weighting=weighting)
    keys = key_plan(tree, plan, key)
    fn = get_host_executor(plan, loss=loss, lam=lam,
                           record_history=record_history, backend=backend)
    out = fn(X, y, keys)
    if not record_history:
        alpha, w = out
        return SolveResult(alpha=alpha, w=w, history=[])
    alpha, w, duals, primals = out
    duals = np.asarray(duals)
    primals = np.asarray(primals)
    # duals[0] is the start-of-run record; entries 1.. align with ticks and
    # carry NaN except at root-sync ticks.
    sel = np.concatenate([[True], plan.root_sync])
    history = history_from_series(round_times(tree), duals[sel], primals[sel])
    return SolveResult(alpha=alpha, w=w, history=history)
