"""Unified tree-schedule engine.

One entry point -- :func:`solve` -- runs the paper's TreeDualMethod
(Algorithms 1-3) on ANY ``TreeNode`` topology (star, multi-level, deep,
imbalanced, heterogeneous per-node rounds) as a single compiled program:

    plan  = compile_tree(tree)          # flat static schedule (the IR)
    keys  = key_plan(tree, plan, key)   # legacy-RNG per-solve key replay
    run   = get_host_executor(plan, ...)  # ONE jit'd lax.scan
    alpha, w[, duals, primals] = run(X, y, keys, alpha0, w0,
                                     participation, steps, lm)

``participation`` is the runtime (S, n) sync-attendance mask
(``full_participation(plan)`` = the synchronous schedule, bit-identical
to masks absent; see ``engine.plan`` for the async / stale-sync
semantics and ``get_host_executor(..., carry_state=True)`` for the
state-threading variant async sessions use); ``steps`` is the runtime
(S, n, h_max) step mask (``full_steps(plan)`` = the static-H schedule,
``steps_for_h(plan, h)`` = heterogeneous / replanned local-H schedules
through the same compiled program); ``lm`` the runtime lambda*m scalar.

Backends:
  * ``backend="vmap"``   -- host/XLA: batched leaf solves via vmapped
    Procedure P (default).
  * ``backend="pallas"`` -- leaf solves via the Pallas blocked-SDCA kernel
    (per-block w + step masks; interpret mode off-TPU).
  * ``engine.mesh.execute_plan_mesh`` -- shard_map device program for
    level-homogeneous plans (mesh axes = one admissible grouping of the
    plan); used by ``repro.core.treedual_mesh``.

All backends consume the same coordinate-index plan, so the retained legacy
recursion (``repro.core.treedual.tree_dual_solve_reference``) is a
bit-comparable oracle for every path.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.core.dual import Loss
from repro.core.engine.host import (  # noqa: F401
    execute_plan, executor_cache_stats, get_host_executor)
from repro.core.engine.plan import (  # noqa: F401
    LevelSpec, TreePlan, balanced_tree, chunk_participation, compile_tree,
    full_participation, full_steps, index_plan, key_plan, steps_for_h,
    tree_from_level_plan,
)
from repro.core.instrument import SolveResult
from repro.core.tree import TreeNode

Array = jax.Array


def solve(
    tree: TreeNode,
    X: Array,
    y: Array,
    *,
    loss: Loss,
    lam: float,
    key: Optional[Array] = None,
    record_history: bool = True,
    backend: str = "vmap",
    weighting: str = "uniform",
) -> SolveResult:
    """Algorithm 3 at the root of ``tree`` -- a shim over the sessionized
    surface (``repro.api``): the tree runs as per-root-round chunks of one
    compiled program, which is also what every other entry point lowers
    to."""
    from repro import api  # local import: api is layered above the engine
    m = X.shape[0]
    assert tree.total_data() == m, (
        f"tree data sizes {tree.total_data()} != m={m}")
    return api.solve(
        api.Problem(X, y, loss=loss, lam=lam),
        api.Topology.from_tree(tree),
        api.Schedule(weighting=weighting),
        backend=backend, key=key, record_history=record_history)
