"""Host backend: execute a :class:`~repro.core.engine.plan.TreePlan` as ONE
jit-compiled ``lax.scan`` over ticks.

Per tick: a batched leaf solve (vmapped Procedure P, or the Pallas
``sdca_block_kernel`` with per-block w and step masks), then the tick's sync
events bottom-up (per-leaf alpha rescale against the depth snapshot and a
segment-sum weighted w-average), then snapshot refreshes.  The whole nested
recursion therefore costs one compile and zero per-child Python dispatch --
compare the legacy recursion's O(tree x rounds) jit calls and full-vector
``alpha.at[sl].add`` copies.

Async / stale sync: the executor takes a runtime ``(S, n)`` participation
mask (see ``engine.plan``).  A leaf whose mask is 0 at a tick is absent
from that tick's syncs: present children's weights are renormalized, the
absent leaf's state, snapshots, and pending delta are left untouched, and a
per-depth *server* ``w`` carry (``srvW`` -- the post-sync aggregate each
group last agreed on, kept group-coherent even for absent leaves) lets it
re-join later: its delta since its last participation is folded into the
CURRENT server state, exactly the bounded-staleness aggregation of delayed
distributed methods.  With an all-ones mask every gate reduces to the
synchronous path bit-for-bit (``x/1.0 == x``, ``srvW == snapW``).

Runtime schedules: the executor also takes a ``(S, n, h_max)`` step mask
(see ``engine.plan.steps_for_h``).  Coordinate draws always happen at the
plan's per-leaf H capacity; the mask zeroes the deltas of trailing steps,
so per-leaf / per-slot heterogeneous H is a runtime input of the SAME
compiled program (H-axis sweeps and delay-adaptive replanning never
retrace).  An all-ones step mask multiplies the static per-leaf H gate by
exactly 1.0 -- bit-identical to the static-H schedule.

Optionally records the (dual, primal) series at root-sync ticks inside the
same program (a ``lax.cond`` so the objective is only evaluated T_root
times, as the legacy history recording did on the host).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import on_tpu
from repro.core import compression as comp_mod
from repro.core.dual import Loss
from repro.core.engine.plan import TreePlan

Array = jax.Array

# Executors are cached per (plan structure, loss, flags) so repeated solves
# with the same topology reuse one compiled program; lambda is a RUNTIME
# input (an entire regularization grid shares one executor).  LRU-bounded
# because schedule sweeps (fig4/fig5-style) still generate a fresh plan per
# configuration.
_EXEC_CACHE: OrderedDict = OrderedDict()
_EXEC_CACHE_MAX = 32
# field names of the cache-key tuple, in order -- the trace guard's
# structured miss diffs name the offending component instead of dumping
# an anonymous tuple
EXEC_KEY_FIELDS = ("plan_fingerprint", "loss", "gamma", "record_history",
                   "backend", "carry_state", "batched", "accelerated")
_EXEC_CACHE_STATS = {"hits": 0, "misses": 0}
# per-backend breakdown ("vmap" / "pallas"; the mesh and LM caches report
# their own columns through executor_cache_stats) so strict sessions and
# the benchmarks can hold a zero-unexpected-miss budget PER BACKEND
_BACKEND_STATS = {"vmap": {"hits": 0, "misses": 0},
                  "pallas": {"hits": 0, "misses": 0}}
# bounded log of recent cache misses: (backend, named key dict).  The
# trace guard reads it to attach the offending keys -- and their diff
# against the nearest cached key -- to UnexpectedRetraceError.
_MISS_LOG: list = []
_MISS_LOG_MAX = 64


def _named_key(fields, key) -> dict:
    return dict(zip(fields, key, strict=True))


def _log_miss(backend: str, named: dict):
    _MISS_LOG.append({"backend": backend, "key": named})
    del _MISS_LOG[:-_MISS_LOG_MAX]


def regularizer_scale(lam: float, m_total: int, dtype) -> jnp.ndarray:
    """The runtime regularization scalar the executors consume: lambda * m
    computed in host double precision and THEN cast, so the traced value is
    bit-identical to the one the legacy static-lambda executors closed
    over (``lm = lam * m`` as a Python float)."""
    return jnp.asarray(float(lam) * m_total, dtype)


def executor_cache_stats() -> dict:
    """Cumulative executor-cache counters across ALL engine executor
    caches: top-level ``{hits, misses, size}`` aggregate the host cache
    (back-compatible with older callers) PLUS the mesh and LM caches, and
    ``by_backend`` breaks hits/misses down per backend
    (``vmap`` / ``pallas`` / ``mesh`` / ``lm``) so a strict session or a
    benchmark can assert a zero-unexpected-miss budget for exactly the
    backend it runs on.

    Note the aggregation itself fixes a double-counting-adjacent bug: the
    mesh cache used to keep NO counters at all, so a mesh executor rebuild
    was invisible to ``Session.cache_stats()`` miss assertions."""
    from repro.core.engine import lm as lm_mod
    from repro.core.engine import mesh as mesh_mod
    mesh_stats = mesh_mod.mesh_executor_cache_stats()
    lm_stats = lm_mod.lm_executor_cache_stats()
    by_backend = {k: dict(v) for k, v in _BACKEND_STATS.items()}
    by_backend["mesh"] = {"hits": mesh_stats["hits"],
                          "misses": mesh_stats["misses"]}
    by_backend["lm"] = {"hits": lm_stats["hits"],
                        "misses": lm_stats["misses"]}
    return {
        "hits": sum(v["hits"] for v in by_backend.values()),
        "misses": sum(v["misses"] for v in by_backend.values()),
        "size": len(_EXEC_CACHE) + mesh_stats["size"] + lm_stats["size"],
        "by_backend": by_backend,
    }


def executor_cache_keys() -> list:
    """The host cache's current keys as named dicts (see
    ``EXEC_KEY_FIELDS``) -- what the trace guard diffs a miss against."""
    return [_named_key(EXEC_KEY_FIELDS, k) for k in _EXEC_CACHE]


def executor_miss_log() -> list:
    """Recent cache misses across the host + mesh caches, newest last:
    ``{"backend": ..., "key": {field: value}}`` entries."""
    from repro.core.engine import mesh as mesh_mod
    return list(_MISS_LOG) + list(mesh_mod._MISS_LOG)


def get_host_executor(
    plan: TreePlan,
    *,
    loss: Loss,
    record_history: bool = True,
    backend: str = "vmap",
    carry_state: bool = False,
    batched: bool = False,
    accelerated: bool = False,
):
    """Build (or fetch from cache) the jitted executor for ``plan``.

    The default executor has signature ``fn(X, y, keys, alpha0, w0,
    participation, steps, lm) -> (alpha, w[, duals, primals])`` with
    ``keys`` the (S, n, 2) per-solve key plan (``plan.key_plan``),
    ``(alpha0, w0)`` the flat (m,) / (d,) warm-start state (zeros for a
    cold start), ``participation`` the (S, n) 0/1 sync-attendance mask
    (``plan.full_participation`` for the synchronous schedule), ``steps``
    the (S, n, h_max) 0/1 runtime step mask (``plan.full_steps`` for the
    static-H schedule; ``plan.steps_for_h`` for heterogeneous / replanned
    H), and ``lm`` the RUNTIME regularization scalar lambda*m
    (:func:`regularizer_scale`) -- a whole lambda grid AND a whole H grid
    share one compiled program; coordinate draws happen inside it at the
    per-leaf H capacity, independent of the step mask.  The executor is
    specialized to the plan structure but re-usable across
    keys/data/start-state/masks/schedules/lambdas of the same shape.

    ``carry_state=True`` instead returns a :class:`StateExecutor` whose
    ``step(X, y, keys, state, participation, steps, lm) -> state`` threads
    the FULL blocked carry ``(a, w, snapA, snapW, srvW)`` across
    invocations: with participation masks the flat ``(alpha, w)`` pair is
    no longer a complete chunk carry (absent leaves hold divergent
    replicas and stale snapshots), so async sessions must thread this
    state instead.  Under all-ones masks ``init -> step^T -> finalize`` is
    bit-identical to the flat executor chunked the same way.

    ``batched=True`` returns the vmapped variant: one device program for a
    leading config axis B over (keys, alpha0, w0, steps, lm) -- a lambda
    grid, an RNG-seed grid, an H grid, and per-config warm-start states
    fuse into a single dispatch per chunk (``fn(X, y, keys (B,S,n,2),
    alpha0 (B,m), w0 (B,d), participation (S,n) shared,
    steps (B,S,n,h_max), lm (B,))``).  Composes with ``carry_state``
    (init/step/finalize all carry the leading B axis).

    ``accelerated=True`` builds the ``sdca_acc`` flavor: Nesterov-style
    momentum on every server combination step.  The executor signature
    gains one trailing RUNTIME scalar ``acceleration`` (shared across a
    batch), the carry gains per-depth momentum anchors (``srvP`` for the
    server w, ``srvA`` for the combined alpha) right after ``srvW``, and
    each sync extrapolates BOTH sides of the primal-dual pair with the
    same coefficient -- ``x = base + acceleration * (base - prev)`` --
    along the un-extrapolated combination sequence, preserving
    ``w == X^T alpha / (lambda m)`` exactly (the map is linear).  ``acceleration`` is a runtime operand -- sweeping the
    momentum coefficient never retraces -- and ``acceleration == 0``
    selects the un-extrapolated base through a ``jnp.where``, so it is
    bit-identical to the plain SDCA executor."""
    if backend not in ("vmap", "pallas"):
        raise ValueError(f"unknown backend {backend!r} (use 'vmap' or "
                         "'pallas'; the mesh backend is engine.mesh)")
    # loss keyed by (name, gamma): Loss names encode their parameters (e.g.
    # 'smooth_hinge_1'), so per-call constructed losses still hit the cache
    cache_key = (plan.fingerprint, loss.name, loss.gamma,
                 bool(record_history), backend, bool(carry_state),
                 bool(batched), bool(accelerated))
    fn = _EXEC_CACHE.get(cache_key)
    if fn is None:
        fn = _build_host_executor(plan, loss=loss,
                                  record_history=record_history,
                                  backend=backend, carry_state=carry_state,
                                  batched=batched, accelerated=accelerated)
        # count the miss only once the build SUCCEEDED: incrementing
        # before the build double-counted a failing configuration (every
        # retry after the raise re-counted a miss that never populated
        # the cache, skewing the hit/miss budgets strict mode enforces)
        _EXEC_CACHE_STATS["misses"] += 1
        _BACKEND_STATS[backend]["misses"] += 1
        _log_miss(backend, _named_key(EXEC_KEY_FIELDS, cache_key))
        _EXEC_CACHE[cache_key] = fn
        while len(_EXEC_CACHE) > _EXEC_CACHE_MAX:
            _EXEC_CACHE.popitem(last=False)
    else:
        _EXEC_CACHE_STATS["hits"] += 1
        _BACKEND_STATS[backend]["hits"] += 1
        _EXEC_CACHE.move_to_end(cache_key)
    return fn


class StateExecutor(NamedTuple):
    """The state-threading executor triple (see ``get_host_executor``):
    ``init(X, alpha0, w0) -> state``, ``step(X, y, keys, state,
    participation, steps, lm) -> state``, ``finalize(state) ->
    (alpha, w)``."""
    init: Callable
    step: Callable
    finalize: Callable


def _build_host_executor(plan: TreePlan, *, loss, record_history,
                         backend, carry_state=False, batched=False,
                         accelerated=False):
    n, m_b, S, D = plan.n_leaves, plan.m_b, plan.n_ticks, plan.depth
    h_max, m = plan.h_max, plan.m_total

    # ---- static layout maps (host numpy -> closed-over constants) ------
    j = np.arange(m_b)
    gather_idx = np.minimum(plan.leaf_offsets[:, None] + j[None, :], m - 1)
    valid = (j[None, :] < plan.leaf_sizes[:, None])           # (n, m_b)
    flat_map = np.zeros((m,), np.int64)                       # i -> blocked pos
    for li in range(n):
        o, s = int(plan.leaf_offsets[li]), int(plan.leaf_sizes[li])
        flat_map[o:o + s] = li * m_b + np.arange(s)
    hmask = (np.arange(h_max)[None, :] < plan.leaf_h[:, None])  # (n, h_max)
    # leaves grouped by H so each group draws its exact randint shape (the
    # legacy draw has no prefix property, so the shape must match per leaf)
    h_groups = [
        (h, tuple(np.nonzero(plan.leaf_h == h)[0].tolist()))
        for h in sorted({int(v) for v in plan.leaf_h})
    ]
    leaf_mb = jnp.asarray(plan.leaf_sizes.astype(np.int32))

    gather_idx = jnp.asarray(gather_idx)
    valid_f = jnp.asarray(valid, jnp.float32)
    flat_map = jnp.asarray(flat_map)
    hmask = jnp.asarray(hmask, jnp.float32)
    ascale = jnp.asarray(plan.alpha_scale)                    # (D, n)
    wcoef = jnp.asarray(plan.w_coeff)                         # (D, n)
    gids = jnp.asarray(plan.group_ids)                        # (D, n)
    ngroups = plan.n_groups
    cids = jnp.asarray(plan.child_ids)                        # (D, n)
    csize = jnp.asarray(plan.child_sizes)                     # (D, n)
    nchildren = plan.n_children
    # per-tick xs
    solve_mask = jnp.asarray(plan.solve_mask)                 # (S, n)
    sync_mask = jnp.asarray(plan.sync_mask)                   # (S, D, n)
    refresh_mask = jnp.asarray(plan.refresh_mask)             # (S, D, n)
    root_sync = jnp.asarray(plan.root_sync)                   # (S,) bool

    use_kernel = backend == "pallas"
    if use_kernel:
        from repro.kernels.sdca.kernel import sdca_block_kernel
    else:
        from repro.kernels.sdca.ref import sdca_block_ref

    # ---- static edge-compression structure (tentpole) ------------------
    # executors branch STATICALLY on has_comp: compression-free plans trace
    # the exact pre-compression program (bit-identity by construction).
    # Compressed depths carry an error-feedback residual (n, d) in the scan
    # carry; leaves are grouped by (kind, frac) so every roundtrip is a
    # shape-static op (scan-safe), with per-leaf rows = per-edge messages
    # (all leaves of one child subtree hold the child's identical delta).
    has_comp = plan.has_compression
    comp_depths = [dd for dd in range(D)
                   if (plan.compress_kind[dd] != comp_mod.KIND_NONE).any()]
    comp_idx = {dd: i for i, dd in enumerate(comp_depths)}
    comp_groups = {}
    for dd in comp_depths:
        groups = {}
        for li in range(n):
            k = int(plan.compress_kind[dd, li])
            if k == comp_mod.KIND_NONE:
                continue
            f = float(plan.compress_frac[dd, li])
            groups.setdefault((k, f), []).append(li)
        comp_groups[dd] = [(k, f, tuple(ls))
                           for (k, f), ls in sorted(groups.items())]
    comp_mask = {dd: jnp.asarray(
        (plan.compress_kind[dd] != comp_mod.KIND_NONE)[:, None])
        for dd in comp_depths}

    def _scan(X: Array, y: Array, keys: Array, carry0, participation: Array,
              steps: Array, lm: Array, acceleration=None):
        """Trace the full tick scan from an explicit blocked carry; returns
        (final carry, history stack, the objective closure).  ``steps`` is
        the (S, n, h_max) runtime step mask, ``lm`` the runtime lambda*m
        scalar (:func:`regularizer_scale`), ``acceleration`` the runtime
        server-momentum scalar (accelerated executors only)."""
        dtype = X.dtype
        if accelerated:
            acceleration = jnp.asarray(acceleration, dtype)
        lam = lm / m                     # only the in-program objective
        vmask = valid_f.astype(dtype)
        Xb = X[gather_idx] * vmask[:, :, None]                # (n, m_b, d)
        yb = y[gather_idx] * vmask                            # (n, m_b)

        def draw_idx(keys_s):
            """The tick's (n, h_max) coordinate draws, exactly as the legacy
            recursion would: randint(key_l, (H_l,), 0, m_b_l) per leaf."""
            idx_s = jnp.zeros((n, h_max), jnp.int32)
            for h, leaf_list in h_groups:
                rows = jnp.asarray(leaf_list)
                draws = jax.vmap(
                    lambda k, mb, h=h: jax.random.randint(k, (h,), 0, mb)
                )(keys_s[rows], leaf_mb[rows])
                idx_s = idx_s.at[rows, :h].set(draws)
            return idx_s

        def leaf_batch(a, w, keys_s, smask, steps_s):
            idx_s = draw_idx(keys_s)
            # the static per-leaf H-capacity gate x the solve slot x the
            # runtime step mask; all-ones steps multiply by exactly 1.0
            mk = (hmask * smask[:, None] * steps_s).astype(dtype)
            if use_kernel:
                return sdca_block_kernel(
                    Xb, yb, a, w, idx_s, loss=loss, lm=lm, step_mask=mk,
                    interpret=not on_tpu())
            return sdca_block_ref(Xb, yb, a, w, idx_s, loss=loss, lm=lm,
                                  step_mask=mk)

        def objective(a, w):
            """(dual, primal) at a root sync, where w rows are all equal."""
            w0 = w[0]
            reg = 0.5 * lam * jnp.dot(w0, w0)
            dv = -reg - jnp.sum(vmask * loss.conj_neg(a, yb)) / m
            margins = jnp.einsum("nbd,d->nb", Xb, w0)
            pv = reg + jnp.sum(vmask * loss.value(margins, yb)) / m
            return dv, pv

        def roundtrip(dd, target):
            """The receiver's view of this depth's per-edge messages: each
            compressed leaf row goes through its edge's (quantize +
            dequantize) in one traced op; uncompressed rows pass through."""
            approx = target
            for kind, frac, rows in comp_groups[dd]:
                rows_a = jnp.asarray(rows)
                sub = target[rows_a]
                if kind == comp_mod.KIND_INT8:
                    rt = comp_mod.int8_roundtrip(sub, keep_leading=1)
                else:
                    k = comp_mod.topk_count(sub.shape[-1], frac)
                    rt = comp_mod.topk_roundtrip(sub, k)
                approx = approx.at[rows_a].set(rt)
            return approx

        def tick(carry, xs):
            # carry layout: (a, w, snapA, snapW, srvW[, srvP][, res]) --
            # the previous-server momentum slot exists only in accelerated
            # executors, the EF residual tuple only in compressed plans
            a, w, snapA, snapW, srvW = carry[:5]
            rest = carry[5:]
            if accelerated:
                (srvP, srvA), rest = rest[:2], rest[2:]
            res = rest[0] if has_comp else ()
            keys_s, smask, sync_s, ref_s, hflag, part_s, steps_s = xs
            da, dw = leaf_batch(a, w, keys_s, smask, steps_s)
            a = a + da
            w = w + dw
            # syncs bottom-up; a leaf with part_s == 0 is absent from every
            # event of this tick.  `srvW[dd]` is the group's server state;
            # it advances (and later rebases) GROUP-wide so an absent
            # leaf's copy stays coherent with its group's.
            act_of: list = [None] * D
            for dd in range(D - 1, -1, -1):
                ev = sync_s[dd]                               # (n,) event
                e = ev * part_s                               # participants
                wc = wcoef[dd].astype(dtype)
                absent_g = jax.ops.segment_sum(
                    (ev - e) * wc, gids[dd], num_segments=ngroups[dd])
                present_g = jax.ops.segment_sum(
                    e * wc, gids[dd], num_segments=ngroups[dd])
                # exact 1.0 under full participation => x/denom is x/1.0,
                # bit-identical to the synchronous path
                denom_g = jnp.where(
                    absent_g == 0, jnp.ones((), dtype),
                    jnp.where(present_g > 0, present_g, jnp.ones((), dtype)))
                denom = denom_g[gids[dd]]                     # (n,)
                act = (ev > 0) & (present_g > 0)[gids[dd]]    # group live
                eb = (e > 0)[:, None]                         # leaf attends
                base_a = (snapA[dd]
                          + (ascale[dd] / denom)[:, None] * (a - snapA[dd]))
                if accelerated:
                    # extrapolate alpha along its own combined sequence with
                    # the SAME coefficient as the server w below: w is the
                    # linear image X^T alpha / (lambda m) of alpha, so a
                    # shared extrapolation keeps the primal-dual pair
                    # consistent (momentum on w alone would decouple them)
                    ext_a = base_a + acceleration * (base_a - srvA[dd])
                    new_a = jnp.where(acceleration != 0, ext_a, base_a)
                    srvA = srvA.at[dd].set(jnp.where(eb, base_a, srvA[dd]))
                    a = jnp.where(eb, new_a, a)
                else:
                    a = jnp.where(eb, base_a, a)
                # a partially-present child is represented by its surviving
                # leaves (all carrying the child's full delta), so their
                # per-leaf coefficients scale up by |child| / |present|;
                # fully-present children multiply by exactly 1.0
                cnt_c = jax.ops.segment_sum(e, cids[dd],
                                            num_segments=nchildren[dd])
                corr = (csize[dd]
                        / jnp.maximum(cnt_c, 1.0)[cids[dd]]).astype(dtype)
                delta_w = w - snapW[dd]
                if dd in comp_idx:
                    # error feedback: compress(delta + residual); the
                    # residual advances only for leaves that actually
                    # deliver at this event (e > 0)
                    ri = comp_idx[dd]
                    r_prev = res[ri]
                    target = delta_w.astype(jnp.float32) + r_prev
                    approx = roundtrip(dd, target)
                    e_col = (e > 0)[:, None]
                    res = (res[:ri]
                           + (jnp.where(e_col, target - approx, r_prev),)
                           + res[ri + 1:])
                    delta_w = jnp.where(comp_mask[dd],
                                        approx.astype(dtype), delta_w)
                contrib = ((((wcoef[dd] * e) / denom) * corr)
                           .astype(dtype)[:, None] * delta_w)
                tot = jax.ops.segment_sum(contrib, gids[dd],
                                          num_segments=ngroups[dd])
                srv_base = srvW[dd] + tot[gids[dd]]
                if accelerated:
                    # Nesterov-style server momentum: extrapolate along the
                    # un-extrapolated combination sequence x_t (= srv_base,
                    # kept in srvP); the leaves work from the lookahead
                    # y_t = x_t + acc (x_t - x_{t-1}).  acceleration == 0
                    # selects srv_base exactly (bit-identical to plain
                    # SDCA -- a where, not a multiply, so even signed
                    # zeros survive).
                    srv_ext = srv_base + acceleration * (srv_base - srvP[dd])
                    srv_new = jnp.where(acceleration != 0, srv_ext, srv_base)
                    srvP = srvP.at[dd].set(
                        jnp.where(act[:, None], srv_base, srvP[dd]))
                else:
                    srv_new = srv_base
                srvW = srvW.at[dd].set(
                    jnp.where(act[:, None], srv_new, srvW[dd]))
                w = jnp.where(eb, srv_new, w)
                act_of[dd] = act
            # rebase deeper servers onto the shallowest live sync's result
            # (group-wide, absent leaves included): after a depth-dd pull
            # the subtree's deeper groups restart from the pulled state
            for dd in range(D - 1, -1, -1):                   # shallow wins
                src = srvW[dd]
                for d2 in range(dd + 1, D):
                    srvW = srvW.at[d2].set(
                        jnp.where(act_of[dd][:, None], src, srvW[d2]))
                    if accelerated:
                        # deeper momentum anchors restart from the pulled
                        # state too (zero velocity after a rebase); the
                        # alpha anchor restarts from the post-sync alpha
                        srvP = srvP.at[d2].set(
                            jnp.where(act_of[dd][:, None], src, srvP[d2]))
                        srvA = srvA.at[d2].set(
                            jnp.where(act_of[dd][:, None], a, srvA[d2]))
            # snapshot refresh is per-leaf private state: participants only.
            # Depths shallower than the leaf's shallowest attended sync
            # fast-forward to the server baseline instead: the pulled group
            # state embeds the CURRENT shallow servers (a re-joining leaf's
            # next shallow delta must not re-deliver content the server
            # already has).  Under full participation srvW == snapW, so the
            # fast-forward is a bitwise no-op.
            refb = ((ref_s * part_s[None, :]) > 0)[..., None]  # (D, n, 1)
            attended = ((jnp.max(sync_s, axis=0) * part_s) > 0)  # (n,)
            ffwd = jnp.logical_not(refb) & attended[None, :, None]
            snapA = jnp.where(refb, a[None], snapA)
            snapW = jnp.where(refb, w[None],
                             jnp.where(ffwd, srvW, snapW))
            if record_history:
                out = jax.lax.cond(
                    hflag, lambda aw: objective(*aw),
                    lambda aw: (jnp.array(jnp.nan, dtype),
                                jnp.array(jnp.nan, dtype)),
                    (a, w))
            else:
                out = None
            carry_out = (a, w, snapA, snapW, srvW)
            if accelerated:
                carry_out = carry_out + (srvP, srvA)
            if has_comp:
                carry_out = carry_out + (res,)
            return carry_out, out

        xs = (keys, solve_mask.astype(dtype), sync_mask.astype(dtype),
              refresh_mask.astype(dtype), root_sync,
              participation.astype(dtype), steps.astype(dtype))
        carry, hist = jax.lax.scan(tick, carry0, xs)
        return carry, hist, objective

    def _init_carry(X: Array, alpha0: Array, w0_in: Array):
        """The blocked run-start carry from flat state; snapshots and the
        group servers start at the run-start state (for a cold start that
        is all-zeros, the pre-warm-start behavior).  Compressed plans
        append the per-compressed-depth error-feedback residuals (zeros at
        run start)."""
        dtype = X.dtype
        d_feat = X.shape[1]
        a0 = jnp.zeros((n * m_b,), dtype).at[flat_map].set(
            alpha0.astype(dtype)).reshape(n, m_b)
        w0 = jnp.broadcast_to(w0_in.astype(dtype)[None], (n, d_feat))
        carry = (a0, w0, jnp.broadcast_to(a0[None], (D, n, m_b)),
                 jnp.broadcast_to(w0[None], (D, n, d_feat)),
                 jnp.broadcast_to(w0[None], (D, n, d_feat)))
        if accelerated:
            # momentum anchors (srvP for w, srvA for alpha) start at the
            # run-start state: the first sync of a run (or of a resumed
            # chunk carry) extrapolates along its own first combination
            # delta
            carry = carry + (jnp.broadcast_to(w0[None], (D, n, d_feat)),
                             jnp.broadcast_to(a0[None], (D, n, m_b)))
        if has_comp:
            carry = carry + (tuple(
                jnp.zeros((n, d_feat), jnp.float32) for _ in comp_depths),)
        return carry

    def _solve(X, y, keys, alpha0, w0_in, participation, steps, lm,
               acceleration=None):
        carry0 = _init_carry(X, alpha0, w0_in)
        carry, hist, objective = _scan(X, y, keys, carry0,
                                       participation, steps, lm, acceleration)
        a, w = carry[0], carry[1]
        alpha = a.reshape(-1)[flat_map]
        if record_history:
            d0, p0 = objective(carry0[0], carry0[1])
            duals = jnp.concatenate([d0[None], hist[0]])
            primals = jnp.concatenate([p0[None], hist[1]])
            return alpha, w[0], duals, primals
        return alpha, w[0]

    if carry_state:
        if accelerated:
            def step_fn(X, y, keys, state, participation, steps, lm,
                        acceleration):
                carry, _, _ = _scan(X, y, keys, state, participation,
                                    steps, lm, acceleration)
                return carry
        else:
            def step_fn(X, y, keys, state, participation, steps, lm):
                carry, _, _ = _scan(X, y, keys, state, participation,
                                    steps, lm)
                return carry

        def finalize(state):
            return state[0].reshape(-1)[flat_map], state[1][0]

        if batched:
            # leading config axis B over (state, keys, steps, lm); X/y, the
            # participation mask, and the momentum scalar are shared across
            # the batch.  The chunk carry is DONATED: callers rebind
            # ``state = step(...)`` every chunk, so the previous chunk's
            # blocked state buffers are reused in place.
            step_axes = (None, None, 0, 0, None, 0, 0)
            if accelerated:
                step_axes = step_axes + (None,)
            return StateExecutor(
                init=jax.jit(jax.vmap(_init_carry, in_axes=(None, 0, 0))),
                step=jax.jit(jax.vmap(step_fn, in_axes=step_axes),
                             donate_argnums=(3,)),
                finalize=jax.jit(jax.vmap(finalize)))
        return StateExecutor(init=jax.jit(_init_carry),
                             step=jax.jit(step_fn, donate_argnums=(3,)),
                             finalize=jax.jit(finalize))
    if accelerated:
        def solve_acc(X, y, keys, alpha0, w0_in, participation, steps, lm,
                      acceleration):
            return _solve(X, y, keys, alpha0, w0_in, participation, steps,
                          lm, acceleration)
        if batched:
            return jax.jit(jax.vmap(
                solve_acc, in_axes=(None, None, 0, 0, 0, None, 0, 0, None)))
        return jax.jit(solve_acc)

    def solve_fn(X, y, keys, alpha0, w0_in, participation, steps, lm):
        return _solve(X, y, keys, alpha0, w0_in, participation, steps, lm)

    if batched:
        return jax.jit(jax.vmap(solve_fn,
                                in_axes=(None, None, 0, 0, 0, None, 0, 0)))
    return jax.jit(solve_fn)


def execute_plan(
    plan: TreePlan,
    X: Array,
    y: Array,
    keys,
    *,
    loss: Loss,
    lam: float,
    record_history: bool = True,
    backend: str = "vmap",
    alpha0: Array = None,
    w0: Array = None,
    participation: Array = None,
    steps: Array = None,
) -> Tuple:
    """Convenience: build/fetch the executor and run it once (``keys`` is
    the (S, n, 2) per-solve key plan from ``plan.key_plan``; ``alpha0``/
    ``w0`` warm-start the run, defaulting to the cold all-zeros state;
    ``participation`` is the (S, n) sync-attendance mask, all-ones --
    the synchronous schedule -- by default; ``steps`` the (S, n, h_max)
    runtime step mask, all-ones -- the static-H schedule -- by default).
    ``lam`` is a runtime input of the (lambda-free) cached executor, not
    a cache key."""
    from repro.core.engine.plan import full_participation, full_steps
    fn = get_host_executor(plan, loss=loss,
                           record_history=record_history, backend=backend)
    if alpha0 is None:
        alpha0 = jnp.zeros((plan.m_total,), X.dtype)
    if w0 is None:
        w0 = jnp.zeros((X.shape[1],), X.dtype)
    if participation is None:
        participation = full_participation(plan)
    if steps is None:
        steps = full_steps(plan)
    return fn(X, y, jnp.asarray(keys), alpha0, w0,
              jnp.asarray(participation), jnp.asarray(steps),
              regularizer_scale(lam, plan.m_total, X.dtype))
