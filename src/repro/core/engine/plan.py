"""Tree-schedule plan IR: lower an arbitrary ``TreeNode`` topology into a
flat, static execution plan that a single jit-compiled ``lax.scan`` program
can run (see ``engine.host``) or a ``shard_map`` mesh program can consume
(see ``engine.mesh``).

The paper's TreeDualMethod (Algorithms 1-3) is a nested recursion: every
internal node runs T rounds; each round runs all children's full solves in
parallel from the round-start state and then combines the children's
(delta_alpha, delta_w) with weights summing to 1 (1/K in the paper).  The
whole recursion is *statically determined* by the tree, so it compiles to a
sequence of S "ticks":

  * tick = one batched leaf-solve slot.  ``span(node)`` ticks cover one full
    solve of ``node``: ``span(leaf) = 1``,
    ``span(internal) = rounds * max_k span(child_k)``.  Children are aligned
    at the *start* of the parent round; a child with a smaller span solves
    early and then idles (its per-tick ``solve_mask`` is 0), exactly
    reproducing the recursion where every child starts from the round-start
    snapshot.
  * at the last tick of each internal round the node "syncs": for every leaf
    under it, ``alpha <- snap + alpha_scale * (alpha - snap)`` and
    ``w <- snap + sum_leaves w_coeff * (w_leaf - snap)`` (a segment-sum over
    the node's leaf group).  Syncs within one tick apply bottom-up
    (deepest ancestor first), as in the recursion.
  * snapshots: one per internal *depth* per leaf-column.  ``snap[d]`` for
    leaf ``l`` holds the state at the start of the current round of ``l``'s
    depth-d ancestor; it is refreshed at the end of any tick where an
    ancestor at depth <= d synced (``refresh_mask``).

The dual vector lives in a blocked ``(n_leaves, m_b)`` layout (``m_b`` = the
largest leaf block, smaller leaves zero-padded); each leaf carries its own
``w`` replica, so sibling subtrees evolve independent primal iterates between
syncs -- the same semantics as the recursion and the mesh program.

Aggregation weights are a plan knob (the CoCoA-style variants of
arXiv:1409.1458): ``weighting="uniform"`` gives the paper's 1/K;
``weighting="size"`` weights children by their data fraction.  Any convex
combination preserves the ``w = A alpha`` invariant (paper eq. (13)).

RNG: leaf coordinate choices replay the *legacy host recursion's* key
derivation exactly (``jax.random.split(key, 1+K)`` per internal round,
``jax.random.randint(leaf_key, (H,), 0, m_b)`` at each leaf solve), so the
retained reference recursion in ``repro.core.treedual`` is a bit-comparable
oracle for every backend.

Participation masks (async / stale sync): the static plan says *when* syncs
happen; a runtime ``(S, n)`` participation mask says *who shows up*.  At a
tick where leaf ``l``'s mask is 0, ``l`` is absent from every sync event of
that tick: its delta is dropped, the remaining children's aggregation
weights are renormalized (``omega' = omega / sum_present omega``), and the
absent leaf keeps solving on its stale snapshots -- the bounded-staleness
regime of delayed distributed methods (arXiv:1708.03277) with CoCoA-style
flexible aggregation (arXiv:1409.1458).  The masks are an executor *input*
(an extra ``lax.scan`` xs), so ONE compiled program serves every skip
pattern; an all-ones mask is bit-identical to the synchronous schedule.
The ``w = A alpha`` invariant is preserved exactly for whole-chunk leaf
masks (constant over each root-round chunk -- what
``repro.api.Session.run(straggler=...)`` emits; on depth-1 stars any
per-tick mask is safe), because then an absent leaf's pending work can
never leak into a participant's delta; see :func:`full_participation` /
:func:`chunk_participation`.

Step masks (runtime heterogeneous H): the same recipe applied to the
LOCAL iteration count.  ``plan.leaf_h`` is now an H *capacity*: every
solve slot draws its full ``randint(key_l, (leaf_h[l],), 0, m_b_l)``
coordinate stream (so the key replay -- and therefore bit-identity with
the legacy recursion -- never depends on the runtime schedule), and a
runtime ``(S, n, h_max)`` 0/1 **step mask** -- another executor input,
see :func:`full_steps` / :func:`steps_for_h` -- zeroes the coordinate
deltas of the trailing steps a leaf should not run at that sync slot.
ONE compiled program therefore serves every per-leaf / per-slot H
schedule up to the capacity: delay-adaptive sessions replan H between
chunks (paper eq. (12) under drifting delays) and H-axis sweeps batch
over the mask operand, all with zero retraces.  An all-ones step mask is
bit-identical to the static-H program (the mask multiplies the existing
per-leaf H gate by exactly 1.0).
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compression as comp_mod
from repro.core.tree import TreeNode


@dataclasses.dataclass(frozen=True)
class LevelSpec:
    """One internal depth of a level-homogeneous (mesh-compatible) plan."""
    depth: int        # 0 = root
    group_size: int   # K: children per node at this depth
    rounds: int       # T: rounds every node at this depth runs


# ---------------------------------------------------------------------------
# fingerprint field registry
# ---------------------------------------------------------------------------
# Every field of :class:`TreePlan` MUST be classified below; the executor
# caches key on ``plan.fingerprint``, so a compiled-behavior field that is
# not hashed lets two semantically distinct plans share one compiled
# program (the cache-key bug class fixed ad hoc in PR 4 -- lambda -- and
# PR 6 -- compression).  ``repro.analysis.plan_check.audit_fingerprint``
# statically checks this registry against ``dataclasses.fields(TreePlan)``
# and fails on any unclassified field, so adding a field without deciding
# its cache-key status no longer compiles silently.
#
#   * BEHAVIOR fields are hashed into the fingerprint (arrays as raw
#     bytes, scalars through ``repr``).
#   * DERIVED fields are pure functions of the behavior fields (verified
#     numerically by the plan checker), so hashing them would be
#     redundant -- a derived field can never distinguish two plans whose
#     behavior fields agree.
#   * METADATA fields never reach a trace (display / diff bookkeeping
#     only) and are deliberately outside the fingerprint: renaming a leaf
#     must NOT retrace.
FINGERPRINT_ARRAY_FIELDS: Tuple[str, ...] = (
    "solve_mask", "sync_mask", "refresh_mask", "alpha_scale", "w_coeff",
    "group_ids", "child_ids", "child_sizes", "leaf_sizes", "leaf_offsets",
    "leaf_h", "compress_kind", "compress_frac")
FINGERPRINT_SCALAR_FIELDS: Tuple[str, ...] = (
    "n_leaves", "m_b", "m_total", "n_ticks", "depth", "h_max",
    "weighting", "n_groups")
DERIVED_FIELDS: Tuple[str, ...] = (
    "root_sync",     # == sync_mask[:, 0, :].max(axis=1) > 0
    "n_children",    # == per-depth max(child_ids) + 1
    "levels",        # re-detectable from the masks/group structure
    "fingerprint",   # the hash itself
)
METADATA_FIELDS: Tuple[str, ...] = ("leaf_names",)


def fingerprint_payload(plan: "TreePlan") -> bytes:
    """The canonical byte serialization of every compiled-behavior field
    of ``plan`` (the registry above), in registry order.  This is the
    exact payload :func:`compute_fingerprint` hashes -- exposed so the
    analysis layer can audit coverage and collision-freedom."""
    chunks = []
    for name in FINGERPRINT_ARRAY_FIELDS:
        a = np.ascontiguousarray(getattr(plan, name))
        # shape + dtype are part of the serialization: two arrays with
        # identical bytes but different shapes must not collide
        chunks.append(repr((name, a.shape, a.dtype.str)).encode())
        chunks.append(a.tobytes())
    chunks.append(repr(tuple(
        (name, getattr(plan, name))
        for name in FINGERPRINT_SCALAR_FIELDS)).encode())
    return b"".join(chunks)


def compute_fingerprint(plan: "TreePlan") -> str:
    """SHA-1 over :func:`fingerprint_payload` -- the executor cache key."""
    return hashlib.sha1(fingerprint_payload(plan)).hexdigest()


@dataclasses.dataclass(frozen=True)
class TreePlan:
    """The lowered schedule.  All arrays are host numpy; executors convert."""
    # ---- geometry ------------------------------------------------------
    n_leaves: int
    m_b: int                      # padded block size (max leaf data size)
    m_total: int
    n_ticks: int                  # S
    depth: int                    # D: number of internal depths (0..D-1)
    h_max: int
    leaf_names: Tuple[str, ...]
    leaf_sizes: np.ndarray        # (n,) int
    leaf_offsets: np.ndarray      # (n,) int: start of each block in flat alpha
    leaf_h: np.ndarray            # (n,) int: per-leaf H capacity (leaf.rounds)
    # ---- per-tick schedule --------------------------------------------
    solve_mask: np.ndarray        # (S, n) f32: leaf solves at this tick
    sync_mask: np.ndarray         # (S, D, n) f32: leaf's depth-d ancestor syncs
    refresh_mask: np.ndarray      # (S, D, n) f32: re-snapshot depth d after tick
    root_sync: np.ndarray         # (S,) bool: a root round ends at this tick
    # ---- static per-(depth, leaf) aggregation --------------------------
    alpha_scale: np.ndarray       # (D, n) f32: child weight at the sync
    w_coeff: np.ndarray           # (D, n) f32: per-leaf weight in the w-average
    group_ids: np.ndarray         # (D, n) int32: leaf -> depth-d ancestor id
    n_groups: Tuple[int, ...]     # segments per depth
    # child segmentation: which depth-d CHILD subtree a leaf belongs to,
    # and that subtree's leaf count -- participation masks renormalize a
    # partially-present child's per-leaf w-weights by |child| / |present|
    child_ids: np.ndarray         # (D, n) int32: leaf -> depth-d child id
    child_sizes: np.ndarray       # (D, n) f32: leaves in that child
    n_children: Tuple[int, ...]   # child segments per depth
    # ---- metadata ------------------------------------------------------
    weighting: str
    levels: Optional[Tuple[LevelSpec, ...]]  # set iff level-homogeneous
    # ---- per-(depth, leaf) edge compression ----------------------------
    # entry [d, l]: the spec of the up-link from leaf l's depth-(d+1)-side
    # child subtree into its depth-d ancestor (every leaf of one child
    # shares the edge, so per-edge == per-leaf-range); kind codes are
    # ``repro.core.compression.KIND_*``, frac the top-k fraction.
    compress_kind: Optional[np.ndarray] = None   # (D, n) int8
    compress_frac: Optional[np.ndarray] = None   # (D, n) f32
    fingerprint: str = ""

    def __post_init__(self):
        if self.compress_kind is None:
            object.__setattr__(
                self, "compress_kind",
                np.zeros((self.depth, self.n_leaves), np.int8))
        if self.compress_frac is None:
            object.__setattr__(
                self, "compress_frac",
                np.zeros((self.depth, self.n_leaves), np.float32))
        if not self.fingerprint:
            # hash the canonical serialization of the behavior-field
            # registry (fingerprint_payload) -- the analysis layer audits
            # that the registry covers every compiled-behavior field
            object.__setattr__(self, "fingerprint",
                               compute_fingerprint(self))

    @property
    def has_compression(self) -> bool:
        """True iff any edge compresses -- executors branch STATICALLY on
        this, so ``compression=None`` programs are structurally untouched
        (and bit-identical to pre-compression executors)."""
        return bool((self.compress_kind != comp_mod.KIND_NONE).any())


# ---------------------------------------------------------------------------
# spans and child weights
# ---------------------------------------------------------------------------
def _span(node: TreeNode) -> int:
    if node.is_leaf:
        return 1
    return node.rounds * max(_span(c) for c in node.children)


def _child_weights(node: TreeNode, weighting: str) -> List[float]:
    K = len(node.children)
    if weighting == "uniform":
        return [1.0 / K] * K
    if weighting == "size":
        tot = node.total_data()
        return [c.total_data() / tot for c in node.children]
    raise ValueError(f"unknown weighting {weighting!r}")


# ---------------------------------------------------------------------------
# the walk: shared between plan compilation and RNG replay
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnums=(1, 2))
def _split_chain(key, T: int, K: int):
    """The legacy per-round key threading, batched into one dispatch:
    round t does ``key, *subkeys = jax.random.split(key, 1 + K)``.
    Returns the (T, K) stacked subkeys."""
    def step(k, _):
        ks = jax.random.split(k, 1 + K)
        return ks[0], ks[1:]
    _, subs = jax.lax.scan(step, key, None, length=T)
    return subs


def _raw_key(key):
    """Accept both legacy uint32 ``PRNGKey`` arrays and new-style typed
    keys; the replay stores raw key data (same draws either way, since both
    drive the same threefry impl)."""
    arr = jnp.asarray(key)
    if jnp.issubdtype(arr.dtype, jax.dtypes.prng_key):
        return jax.random.key_data(arr)
    return arr


def _walk(tree: TreeNode, key, on_solve, on_sync):
    """Drive the recursion symbolically.  ``on_solve(tick, leaf_path, key)``
    is called for every leaf solve (key is None when ``key`` is None);
    ``on_sync(tick, depth, path)`` for every internal-node aggregation.
    Event order matches the legacy recursion exactly."""
    def walk(node, path, t0, depth, k):
        if node.is_leaf:
            on_solve(t0, path, k)
            return
        K = len(node.children)
        sub = max(_span(c) for c in node.children)
        subkeys = None
        if k is not None and node.rounds > 0:
            subkeys = np.asarray(_split_chain(k, node.rounds, K))
        for t in range(node.rounds):
            start = t0 + t * sub
            for ci, c in enumerate(node.children):
                ck = None if subkeys is None else subkeys[t, ci]
                walk(c, path + (ci,), start, depth + 1, ck)
            on_sync(start + sub - 1, depth, path)
    walk(tree, (), 0, 0, key)


# ---------------------------------------------------------------------------
# plan compilation
# ---------------------------------------------------------------------------
def compile_tree(tree: TreeNode, *, weighting: str = "uniform",
                 compression=None) -> TreePlan:
    """Lower ``tree`` into a :class:`TreePlan`.

    ``compression`` sets the per-depth edge-compression default: ``None``
    (no compression), one spec string applied to every depth, or a
    top-down per-depth sequence (entry ``d`` compresses the up-links INTO
    depth-``d`` nodes; specs as in ``repro.core.compression.parse_spec``).
    A node's own ``up_compress`` (when non-empty) overrides the default
    for that edge.

    Memoized on the (frozen, hashable) tree so sweep workloads that re-solve
    the same topology skip plan construction; treat the returned plan's
    arrays as read-only."""
    if compression is None or isinstance(compression, str):
        comp = compression
    else:
        comp = tuple(None if c in (None, "") else str(c)
                     for c in compression)
    return _compile_tree_cached(tree, weighting, comp)


@functools.lru_cache(maxsize=64)
def _compile_tree_cached(tree: TreeNode, weighting: str,
                         compression) -> TreePlan:
    assert not tree.is_leaf, "the root must be an internal node"
    leaves = tree.leaves()
    names = tuple(l.name for l in leaves)
    assert len(set(names)) == len(names), "leaf names must be unique"
    n = len(leaves)
    sizes = np.array([l.data_size for l in leaves], dtype=np.int64)
    offsets = np.concatenate([[0], np.cumsum(sizes)[:-1]])
    m_total = int(sizes.sum())
    m_b = int(sizes.max())
    leaf_h = np.array([l.rounds for l in leaves], dtype=np.int64)
    h_max = int(leaf_h.max())

    # leaf path -> index, node path -> (node, depth, leaf index range)
    leaf_of_path: Dict[tuple, int] = {}
    node_info: Dict[tuple, tuple] = {}
    counter = [0]

    def index(node, path, depth):
        if node.is_leaf:
            leaf_of_path[path] = counter[0]
            counter[0] += 1
            return
        lo = counter[0]
        for ci, c in enumerate(node.children):
            index(c, path + (ci,), depth + 1)
        node_info[path] = (node, depth, lo, counter[0])
    index(tree, (), 0)

    D = max(depth for (_, depth, _, _) in node_info.values()) + 1
    S = _span(tree)

    solve_mask = np.zeros((S, n), np.float32)
    sync_mask = np.zeros((S, D, n), np.float32)
    alpha_scale = np.ones((D, n), np.float32)
    w_coeff = np.zeros((D, n), np.float32)
    group_ids = np.zeros((D, n), np.int32)
    child_ids = np.zeros((D, n), np.int32)
    child_sizes = np.ones((D, n), np.float32)
    gid_of: List[Dict[tuple, int]] = [dict() for _ in range(D)]
    cid_count = [0] * D

    # per-depth edge-compression defaults (top-down); a child node's own
    # ``up_compress`` overrides the default for its edge below
    if compression is None:
        level_spec: List = [None] * D
    elif isinstance(compression, str):
        level_spec = [compression] * D
    else:
        if len(compression) != D:
            raise ValueError(
                f"per-depth compression must list all {D} internal depths "
                f"top-down, got {len(compression)} entries")
        level_spec = list(compression)
    compress_kind = np.zeros((D, n), np.int8)
    compress_frac = np.zeros((D, n), np.float32)

    # static per-(depth, leaf) aggregation coefficients
    for path, (node, depth, lo, hi) in node_info.items():
        if path not in gid_of[depth]:
            gid_of[depth][path] = len(gid_of[depth])
        gid = gid_of[depth][path]
        group_ids[depth, lo:hi] = gid
        omegas = _child_weights(node, weighting)
        for ci, c in enumerate(node.children):
            if c.is_leaf:
                clo = leaf_of_path[path + (ci,)]
                chi = clo + 1
            else:
                _, _, clo, chi = node_info[path + (ci,)]
            alpha_scale[depth, clo:chi] = omegas[ci]
            w_coeff[depth, clo:chi] = omegas[ci] / (chi - clo)
            child_ids[depth, clo:chi] = cid_count[depth]
            child_sizes[depth, clo:chi] = chi - clo
            cid_count[depth] += 1
            ck, cf = comp_mod.parse_spec(c.up_compress or level_spec[depth])
            compress_kind[depth, clo:chi] = ck
            compress_frac[depth, clo:chi] = cf

    def on_solve(tick, path, _key):
        solve_mask[tick, leaf_of_path[path]] = 1.0

    def on_sync(tick, depth, path):
        _, _, lo, hi = node_info[path]
        sync_mask[tick, depth, lo:hi] = 1.0

    _walk(tree, None, on_solve, on_sync)

    # refresh depth d when any ancestor at depth <= d synced this tick
    refresh_mask = np.maximum.accumulate(sync_mask, axis=1)
    root_sync = sync_mask[:, 0, :].max(axis=1) > 0.0

    levels = _detect_levels(tree, leaves, D)
    return TreePlan(
        n_leaves=n, m_b=m_b, m_total=m_total, n_ticks=S, depth=D,
        h_max=h_max, leaf_names=names, leaf_sizes=sizes,
        leaf_offsets=offsets, leaf_h=leaf_h,
        solve_mask=solve_mask, sync_mask=sync_mask,
        refresh_mask=refresh_mask, root_sync=root_sync,
        alpha_scale=alpha_scale, w_coeff=w_coeff, group_ids=group_ids,
        n_groups=tuple(max(len(g), 1) for g in gid_of),
        child_ids=child_ids, child_sizes=child_sizes,
        n_children=tuple(max(c, 1) for c in cid_count),
        weighting=weighting, levels=levels,
        compress_kind=compress_kind, compress_frac=compress_frac,
    )


def _detect_levels(tree: TreeNode, leaves, D) -> Optional[Tuple[LevelSpec, ...]]:
    """A plan is level-homogeneous (mesh-lowerable) when all internal nodes
    at each depth share (rounds, fan-out), every leaf sits at depth D and
    all leaves share (data_size, H)."""
    by_depth: Dict[int, set] = {}
    leaf_depths = set()

    def visit(node, depth):
        if node.is_leaf:
            leaf_depths.add(depth)
            return
        by_depth.setdefault(depth, set()).add(
            (node.rounds, len(node.children)))
        for c in node.children:
            visit(c, depth + 1)
    visit(tree, 0)

    if leaf_depths != {D}:
        return None
    if len({(l.data_size, l.rounds) for l in leaves}) != 1:
        return None
    if any(len(v) != 1 for v in by_depth.values()):
        return None
    return tuple(
        LevelSpec(depth=d, rounds=next(iter(by_depth[d]))[0],
                  group_size=next(iter(by_depth[d]))[1])
        for d in range(D)
    )


# ---------------------------------------------------------------------------
# RNG replay -> per-solve key arrays (draws happen inside the executors)
# ---------------------------------------------------------------------------
def key_plan(tree: TreeNode, plan: TreePlan, key=None) -> np.ndarray:
    """Replay the legacy recursion's key derivation over ``tree`` and return
    the (S, n_leaves, 2) uint32 per-solve key array: entry [s, l] is the
    exact key the legacy recursion would hand ``local_sdca`` for leaf l's
    solve at tick s (zeros at idle ticks -- those solves are masked out, so
    their draws are never applied).

    Executors draw ``randint(key, (H_l,), 0, m_b_l)`` *inside* the compiled
    program, so only O(S x n) keys are materialized on the host, not the
    O(S x n x H) coordinate choices themselves.  Accepts legacy uint32
    ``PRNGKey`` arrays or new-style typed keys."""
    key = jax.random.PRNGKey(0) if key is None else _raw_key(key)
    leaf_of_path: Dict[tuple, int] = {}
    counter = [0]

    def index(node, path):
        if node.is_leaf:
            leaf_of_path[path] = counter[0]
            counter[0] += 1
            return
        for ci, c in enumerate(node.children):
            index(c, path + (ci,))
    index(tree, ())

    keys = np.zeros((plan.n_ticks, plan.n_leaves, 2), np.uint32)

    def on_solve(tick, path, k):
        keys[tick, leaf_of_path[path]] = np.asarray(k)

    _walk(tree, key, on_solve, lambda *a: None)
    return keys


def chunked_key_plan(chunk_tree: TreeNode, plan: TreePlan, key,
                     rounds: int) -> np.ndarray:
    """The per-solve key arrays for ``rounds`` consecutive root rounds of
    ``chunk_tree`` (whose root runs ONE round; ``plan`` is its compiled
    plan), derived in a single walk of the equivalent monolithic tree --
    exactly the keys a root-rounds=``rounds`` solve would use, shaped
    ``(rounds, S_chunk, n, 2)`` so chunked executors index round ``t`` as
    ``keys[t]``.  This keeps the per-round driver loop free of host-side
    RNG re-derivation."""
    assert chunk_tree.rounds == 1, chunk_tree.rounds
    if rounds == 0:
        return np.zeros((0, plan.n_ticks, plan.n_leaves, 2), np.uint32)
    full = dataclasses.replace(chunk_tree, rounds=rounds)
    key = jax.random.PRNGKey(0) if key is None else _raw_key(key)
    leaf_of_path: Dict[tuple, int] = {}
    counter = [0]

    def index(node, path):
        if node.is_leaf:
            leaf_of_path[path] = counter[0]
            counter[0] += 1
            return
        for ci, c in enumerate(node.children):
            index(c, path + (ci,))
    index(full, ())

    keys = np.zeros((rounds * plan.n_ticks, plan.n_leaves, 2), np.uint32)

    def on_solve(tick, path, k):
        keys[tick, leaf_of_path[path]] = np.asarray(k)

    _walk(full, key, on_solve, lambda *a: None)
    return keys.reshape(rounds, plan.n_ticks, plan.n_leaves, 2)


@functools.partial(jax.jit, static_argnums=(1, 2))
def advance_root_key(key, rounds: int, K: int):
    """The root RNG-chain state after ``rounds`` rounds of a K-child root
    (each round consumes ``key, *_ = jax.random.split(key, 1 + K)``), in
    one dispatch."""
    def step(k, _):
        return jax.random.split(k, 1 + K)[0], None
    k_end, _ = jax.lax.scan(step, key, None, length=rounds)
    return k_end


@functools.partial(jax.jit, static_argnums=(1, 2))
def _batched_randint(keys, H: int, m_b: int):
    return jax.vmap(lambda k: jax.random.randint(k, (H,), 0, m_b))(keys)


def index_plan(tree: TreeNode, plan: TreePlan, key=None,
               local_h=None) -> np.ndarray:
    """Materialize the (S, n_leaves, h_max) int32 coordinate choices the
    executors will draw from :func:`key_plan` (debug/test helper; the
    executors never build this array).

    Draws ALWAYS happen at the plan's per-leaf H capacity
    (``randint(key_l, (leaf_h[l],), 0, m_b_l)``), so a runtime schedule
    never perturbs the key stream; ``local_h`` (scalar or per-leaf) zeroes
    the trailing entries a runtime step mask would gate off -- the masked
    steps' draws still happen, their deltas just never apply."""
    keys = key_plan(tree, plan, key)
    idx = np.zeros((plan.n_ticks, plan.n_leaves, plan.h_max), np.int32)
    h_run = None
    if local_h is not None:
        h_run = np.broadcast_to(
            np.asarray(local_h, np.int64), (plan.n_leaves,))
    for li in range(plan.n_leaves):
        ticks = np.nonzero(plan.solve_mask[:, li])[0]
        if len(ticks) == 0:
            continue
        h = int(plan.leaf_h[li])
        mb = int(plan.leaf_sizes[li])
        draws = np.asarray(_batched_randint(keys[ticks, li], h, mb))
        idx[ticks, li, :h] = draws
        if h_run is not None:
            idx[ticks, li, min(int(h_run[li]), h):] = 0
    return idx


# ---------------------------------------------------------------------------
# participation masks (async / stale-sync execution)
# ---------------------------------------------------------------------------
def full_participation(plan: TreePlan) -> np.ndarray:
    """The all-ones ``(S, n)`` participation mask: every leaf attends every
    sync -- the executors are bit-identical to the synchronous schedule
    under this mask."""
    return np.ones((plan.n_ticks, plan.n_leaves), np.float32)


def chunk_participation(plan: TreePlan, leaf_mask) -> np.ndarray:
    """Broadcast a per-leaf ``(n,)`` 0/1 decision over every tick of one
    chunk: the whole-chunk granularity under which masked syncs preserve
    ``w = A alpha`` exactly on any tree (a leaf absent for the whole chunk
    never delivers work that a participant's delta could double-carry)."""
    leaf_mask = np.asarray(leaf_mask, np.float32).reshape(plan.n_leaves)
    return np.broadcast_to(
        leaf_mask[None, :], (plan.n_ticks, plan.n_leaves)).copy()


# ---------------------------------------------------------------------------
# step masks (runtime heterogeneous H)
# ---------------------------------------------------------------------------
def full_steps(plan: TreePlan) -> np.ndarray:
    """The all-ones ``(S, n, h_max)`` step mask: every solve slot runs its
    full per-leaf H capacity -- the executors are bit-identical to the
    static-H schedule under this mask."""
    return np.ones((plan.n_ticks, plan.n_leaves, plan.h_max), np.float32)


def steps_for_h(plan: TreePlan, h) -> np.ndarray:
    """The ``(S, n, h_max)`` step mask running ``h`` local iterations per
    solve slot.  ``h`` is a scalar, a per-leaf ``(n,)`` vector (the
    imbalanced-data regime of arXiv:2308.14783: leaves with more data run
    more local steps), or a per-slot ``(S, n)`` array (fully heterogeneous
    schedules).  Values are clamped to ``[0, plan.leaf_h]`` per leaf: the
    executed step count can never exceed the drawn H capacity (compile
    the plan with a larger capacity -- ``Schedule(h_cap=...)`` -- to leave
    runtime headroom)."""
    S, n, h_max = plan.n_ticks, plan.n_leaves, plan.h_max
    h = np.asarray(h, np.int64)
    if h.ndim == 0:
        h = np.full((n,), int(h), np.int64)
    if h.shape == (n,):
        h = np.broadcast_to(h[None, :], (S, n))
    if h.shape != (S, n):
        raise ValueError(
            f"local h must be a scalar, ({n},) per leaf, or ({S}, {n}) "
            f"per slot; got shape {h.shape}")
    h_eff = np.minimum(np.maximum(h, 0), plan.leaf_h[None, :])
    j = np.arange(h_max)
    return (j[None, None, :] < h_eff[:, :, None]).astype(np.float32)


# ---------------------------------------------------------------------------
# simulated communication accounting
# ---------------------------------------------------------------------------
def plan_bytes_per_round(plan: TreePlan, d_feat: int, *,
                         dtype_bytes: int = 4) -> float:
    """Simulated UPLINK bytes one root round ships: every sync event in
    the plan delivers one ``d``-vector delta per distinct child edge,
    scaled by that edge's compression wire ratio
    (:func:`repro.core.compression.wire_ratio`); the plan's total is
    normalized by its root-round count.  This is the quantity the delay
    model's bandwidth terms charge -- the ``BENCH_engine.json``
    ``compression`` scenario records it compressed vs. uncompressed."""
    total = 0.0
    for s in range(plan.n_ticks):
        for dd in range(plan.depth):
            ev = plan.sync_mask[s, dd] > 0
            if not ev.any():
                continue
            seen = set()
            for li in np.nonzero(ev)[0]:
                cid = int(plan.child_ids[dd, li])
                if cid in seen:
                    continue
                seen.add(cid)
                ratio = comp_mod.wire_ratio(
                    int(plan.compress_kind[dd, li]),
                    float(plan.compress_frac[dd, li]))
                total += float(d_feat) * dtype_bytes * ratio
    return total / max(int(plan.root_sync.sum()), 1)


# ---------------------------------------------------------------------------
# plan diffing (elastic membership: recompile bookkeeping)
# ---------------------------------------------------------------------------
def plan_diff(old: TreePlan, new: TreePlan) -> Dict[str, object]:
    """Structural diff between two compiled plans, keyed by leaf NAME (the
    stable identity across membership events -- leaf *indices* shift when
    leaves leave/join).

    Drives the elastic-session recompile story: the executor caches key on
    ``plan.fingerprint``, so ``fingerprint_changed`` says whether a
    membership event costs a retrace at all, and the per-leaf entries say
    *which* plan slices moved -- ``weights_changed`` lists surviving leaves
    whose aggregation column (alpha_scale / w_coeff / compression / size /
    H capacity) was re-weighted, the imbalanced-data rule of
    arXiv:2308.14783 recomputing |child| ratios from the surviving leaves.
    """
    old_idx = {nm: i for i, nm in enumerate(old.leaf_names)}
    new_idx = {nm: i for i, nm in enumerate(new.leaf_names)}
    added = [nm for nm in new.leaf_names if nm not in old_idx]
    removed = [nm for nm in old.leaf_names if nm not in new_idx]
    structure_changed = (old.depth != new.depth
                         or old.n_ticks != new.n_ticks
                         or old.n_groups != new.n_groups
                         or old.n_children != new.n_children)
    weights_changed = []
    for nm in new.leaf_names:
        if nm not in old_idx:
            continue
        oi, ni = old_idx[nm], new_idx[nm]
        same = (old.depth == new.depth
                and int(old.leaf_sizes[oi]) == int(new.leaf_sizes[ni])
                and int(old.leaf_h[oi]) == int(new.leaf_h[ni])
                and np.array_equal(old.alpha_scale[:, oi],
                                   new.alpha_scale[:, ni])
                and np.array_equal(old.w_coeff[:, oi], new.w_coeff[:, ni])
                and np.array_equal(old.compress_kind[:, oi],
                                   new.compress_kind[:, ni])
                and np.array_equal(old.compress_frac[:, oi],
                                   new.compress_frac[:, ni]))
        if not same:
            weights_changed.append(nm)
    return {
        "fingerprint_changed": old.fingerprint != new.fingerprint,
        "leaves_added": added,
        "leaves_removed": removed,
        "weights_changed": weights_changed,
        "structure_changed": structure_changed,
        "unchanged": (not added and not removed and not weights_changed
                      and not structure_changed),
    }


# ---------------------------------------------------------------------------
# tree constructors for plan-driven workflows
# ---------------------------------------------------------------------------
def balanced_tree(
    branching: Sequence[int],
    rounds: Sequence[int],
    *,
    local_steps: int,
    m_leaf: int,
    t_lp: float = 0.0,
) -> TreeNode:
    """A level-homogeneous tree, top-down: ``branching[0]`` children at the
    root running ``rounds[0]`` rounds, and so on; leaves run ``local_steps``
    coordinate steps over ``m_leaf`` examples each."""
    assert len(branching) == len(rounds) and len(branching) >= 1

    def build(d, path):
        tag = "-".join(str(p) for p in path)  # separator: fan-out >= 10 safe
        if d == len(branching):
            return TreeNode(name=f"L{tag}", rounds=local_steps,
                            data_size=m_leaf, t_lp=t_lp)
        kids = tuple(build(d + 1, path + (k,))
                     for k in range(branching[d]))
        name = "root" if d == 0 else f"N{tag}"
        return TreeNode(name=name, children=kids, rounds=rounds[d])
    return build(0, ())


def tree_from_level_plan(
    level_plan: Sequence[dict],
    branching: Sequence[int],
    *,
    m_leaf: int,
    root_rounds: int,
    t_lp: float = 0.0,
) -> TreeNode:
    """Bridge from ``repro.core.delay.plan_hierarchical_h`` (paper eq. (12)
    applied per level, innermost first) to an engine-runnable tree:
    ``level_plan[0]["H"]`` becomes the leaf local-step count, higher levels'
    H become the per-depth round counts, and the root runs ``root_rounds``.
    ``branching`` is top-down (root fan-out first)."""
    hs = [int(row["H"]) for row in level_plan]
    assert len(branching) == len(hs), (len(branching), len(hs))
    # top-down internal rounds: root, then H of the outer levels inward
    rounds = [root_rounds] + list(reversed(hs[1:]))
    return balanced_tree(branching, rounds, local_steps=hs[0],
                         m_leaf=m_leaf, t_lp=t_lp)


# ---------------------------------------------------------------------------
# the method-agnostic schedule view
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SchedulePlan:
    """What a *Method* (``engine.method``) consumes from a level-homogeneous
    ``TreePlan``: tree shape and per-level periods, with no reference to
    the local step or the combine.  Bottom-up convention (level 0 =
    leaves/fastest link), matching ``TreeSyncConfig.periods`` and
    ``delay.plan_hierarchical_h``:

      * ``periods[0]``      local steps per level-1 sync (leaf H),
      * ``periods[i]``      level-(i-1) rounds per level-i sync,
      * ``group_sizes[i]``  fan-out of the level-(i+1) node over its
        level-i children (= the mesh sub-axis size the LM combine
        averages over),
      * ``compression[i]``  codec spec of the up-link into level i+1.
    """
    periods: Tuple[int, ...]
    group_sizes: Tuple[int, ...]
    compression: Tuple[str, ...]
    fingerprint: str

    @property
    def depth(self) -> int:
        return len(self.group_sizes)

    def cum_periods(self) -> Tuple[int, ...]:
        out, p = [], 1
        for h in self.periods:
            p *= h
            out.append(p)
        return tuple(out)


def schedule_view(plan: TreePlan) -> SchedulePlan:
    """Extract the method-agnostic schedule layer from a lowered plan.

    Requires a level-homogeneous plan (``plan.levels`` set) with uniform
    leaf H -- the replica-stacked LM method needs one period per mesh
    axis, and the SDCA mesh backend has the same constraint.
    """
    if plan.levels is None:
        raise ValueError(
            "schedule_view needs a level-homogeneous plan (uniform "
            "per-depth fan-out/rounds, congruent leaves)")
    leaf_h = np.asarray(plan.leaf_h)
    if plan.n_leaves and not (leaf_h == leaf_h[0]).all():
        raise ValueError(
            "schedule_view needs uniform leaf H (per-leaf heterogeneous H "
            "is a runtime step-mask input, not part of the static view)")
    D = plan.depth
    # bottom-up: leaf H, then rounds of each internal depth from the
    # innermost (depth D-1) up to just below the root (depth 1); the
    # root's own rounds are the run length, not a period.
    periods = [int(leaf_h[0]) if plan.n_leaves else 1]
    periods += [int(plan.levels[d].rounds) for d in range(D - 1, 0, -1)]
    group_sizes = [int(plan.levels[d].group_size)
                   for d in range(D - 1, -1, -1)]
    # per-depth codec of the up-link into bottom-up level i+1 == the edge
    # into top-down depth D-1-i; per-edge specs are uniform per depth in a
    # level-homogeneous plan, so leaf 0's column is representative
    comp = []
    for i in range(D):
        d = D - 1 - i
        kind = int(plan.compress_kind[d, 0]) if plan.n_leaves else 0
        frac = float(plan.compress_frac[d, 0]) if plan.n_leaves else 0.0
        comp.append(comp_mod.spec_name(kind, frac))
    return SchedulePlan(periods=tuple(periods),
                        group_sizes=tuple(group_sizes),
                        compression=tuple(comp),
                        fingerprint=plan.fingerprint)
