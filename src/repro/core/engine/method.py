"""The Method protocol: what a workload plugs into the schedule IR.

The plan IR (``engine.plan``) is method-agnostic -- tree shape, per-level
rounds/periods, step masks, participation, compression specs, RNG
chaining.  A *Method* supplies the two method-specific pieces the paper's
TreeDualMethod leaves open:

  * the **local step** a leaf runs H times between syncs, and
  * the **per-level combine** a tree level applies to its children.

Two methods ship today:

  ``"sdca"``         -- the paper's dual coordinate ascent: local step =
                        Procedure P over a coordinate block, combine =
                        (dalpha keep-own, dw sum/average).  Executors in
                        ``engine.host`` (vmap/pallas) and ``engine.mesh``.
  ``"lm_treesync"``  -- data-parallel LM training: local step = one
                        optimizer update per replica, combine = (masked)
                        parameter/opt-state mean over the level's mesh
                        sub-axis.  Executor in ``engine.lm``.
  ``"sdca_acc"``     -- ROADMAP item 5, the accelerated primal-dual
                        flavor (Ma et al., arXiv 1711.05305): the same
                        local step, but every server combine applies
                        Nesterov-style momentum to BOTH sides of the
                        primal-dual pair (the coefficient is a runtime
                        scalar operand; ``acceleration=0`` is
                        bit-identical to ``"sdca"``).  Same executors,
                        built with ``accelerated=True``.

ROADMAP item 4 (gossip combine) is an additional Method on the same IR.
"""
from __future__ import annotations

from typing import Callable, Dict


class Method:
    """A workload on the schedule IR.  ``executor(**kw)`` returns the
    compiled step/run program for one (plan, backend, variant) tuple;
    implementations memoize so sweeps and sessions share compiles."""

    name: str = "?"

    def executor(self, **kw) -> Callable:
        raise NotImplementedError

    def cache_stats(self) -> Dict[str, int]:
        raise NotImplementedError


class SDCAMethod(Method):
    """Paper's tree-DCA.  Backends: host vmap / Pallas leaves / shard_map
    mesh; see ``engine.host`` / ``engine.mesh``."""

    name = "sdca"

    def executor(self, *, plan, backend="vmap", mesh=None, **kw) -> Callable:
        if backend in ("vmap", "pallas"):
            from repro.core.engine import host as host_mod
            return host_mod.get_host_executor(plan, backend=backend, **kw)
        if backend == "mesh":
            from repro.core.engine import mesh as mesh_mod
            return mesh_mod.get_mesh_executor(plan, mesh, **kw)
        raise ValueError(f"sdca: unknown backend {backend!r}")

    def cache_stats(self) -> Dict[str, int]:
        from repro.core.engine import host as host_mod
        return host_mod.executor_cache_stats()


class SDCAAccMethod(SDCAMethod):
    """Accelerated tree-DCA: the ``"sdca"`` executors built with
    ``accelerated=True`` -- executor signatures gain one trailing runtime
    ``acceleration`` scalar, carries gain the per-depth momentum anchors.
    Selected by ``Schedule(acceleration=...)``."""

    name = "sdca_acc"

    def executor(self, *, plan, backend="vmap", mesh=None, **kw) -> Callable:
        kw["accelerated"] = True
        return super().executor(plan=plan, backend=backend, mesh=mesh, **kw)


class LMTreeSyncMethod(Method):
    """Replica-stacked LM training (mesh backend only: the replica dim is
    sharded over the sync axes, so the combine is a GSPMD all-reduce)."""

    name = "lm_treesync"

    def executor(self, **kw) -> Callable:
        from repro.core.engine import lm as lm_mod
        return lm_mod.get_lm_executor(**kw)

    def cache_stats(self) -> Dict[str, int]:
        from repro.core.engine import lm as lm_mod
        return lm_mod.lm_executor_cache_stats()


_REGISTRY: Dict[str, Method] = {}


def register_method(method: Method) -> Method:
    _REGISTRY[method.name] = method
    return method


register_method(SDCAMethod())
register_method(SDCAAccMethod())
register_method(LMTreeSyncMethod())


def get_method(name: str) -> Method:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown method {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None
