"""Mesh backend: run a level-homogeneous :class:`TreePlan` as a sharded
device program (``shard_map`` + ``lax`` collectives), with the Pallas
blocked-SDCA kernel at the leaves.

The mesh axes are one *admissible grouping* of the plan: internal depth d
of the tree maps onto mesh axis ``axes[L-1-d]`` (axes listed innermost
first), so every depth-d sync group is exactly the set of devices sharing
coordinates on the axes above.  Because mesh plans are level-homogeneous
(every node at a depth shares (rounds, fan-out) and all leaves are
congruent), the flat tick schedule factors back into nested ``fori_loop``s
with one ``psum`` per sync -- the natural lowering on a device mesh, and
bit-compatible with the host backend because both consume the same
per-solve key plan (the legacy-RNG replay from ``engine.plan``).

Like the host backend, the compiled program is memoized on
(plan fingerprint, mesh, axes, loss, flags) and takes the warm-start
state ``(alpha0, w0)`` -- and the regularization scalar ``lm`` = lambda*m
-- as runtime inputs, so ``repro.api.Session`` can run it in
per-root-round chunks without retracing, and a lambda grid shares one
device program.

Async / stale sync: the program also takes the ``(n, S)`` leaf-major
participation mask (see ``engine.plan``).  Each depth's sync weights every
*leaf* shard by ``p / prod(K_d..K_L-1)`` and psums over ALL axes at that
depth and deeper (so partially-present subtrees renormalize exactly like
the host backend), carrying explicit per-depth snapshots and the
group-coherent server ``w`` (``srvW``) that bounded-staleness re-joins fold
into.  An all-ones mask reduces every gate to the synchronous program.

Runtime schedules: the program also takes the ``(n, S, h_max)`` leaf-major
step mask (see ``engine.plan.steps_for_h``).  Every solve slot draws the
full H-capacity coordinate stream; the mask gates the trailing deltas in
the Pallas kernel (its ``step_mask`` operand), so heterogeneous / replanned
H is a runtime input of the one cached device program.  All-ones step
masks multiply the deltas by exactly 1.0 -- bit-identical to the static-H
program.
"""
from __future__ import annotations

import math
from collections import OrderedDict
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import on_tpu, shard_map
from repro.core.dual import Loss
from repro.core.engine.plan import (
    TreePlan, full_participation, full_steps, key_plan)
from repro.core.tree import TreeNode

Array = jax.Array

_MESH_EXEC_CACHE: OrderedDict = OrderedDict()
_MESH_EXEC_CACHE_MAX = 16


def _check_plan_mesh(plan: TreePlan, mesh: Mesh, axes: Sequence[str]):
    assert plan.levels is not None, (
        "the mesh backend needs a level-homogeneous plan (balanced tree, "
        "uniform per-depth rounds); use the host backend otherwise")
    assert plan.weighting == "uniform", (
        "mesh lowering uses per-level psum/K averaging (uniform weights)")
    L = len(axes)
    assert plan.depth == L, (plan.depth, L)
    sizes = [dict(mesh.shape)[a] for a in axes]
    for d in range(L):
        assert plan.levels[d].group_size == sizes[L - 1 - d], (
            f"depth {d} fan-out {plan.levels[d].group_size} != mesh axis "
            f"{axes[L - 1 - d]} size {sizes[L - 1 - d]}")
    assert int(plan.leaf_sizes.min()) == plan.m_b, \
        "mesh backend needs equal blocks"


def get_mesh_executor(
    plan: TreePlan,
    mesh: Mesh,
    *,
    axes: Sequence[str],
    loss: Loss,
    use_kernel: bool = True,
    carry_state: bool = False,
):
    """Build (or fetch from cache) the jitted ``shard_map`` program for
    ``plan`` on ``mesh``.

    Signature: ``fn(Xs, ys, a0, w0, kys, part, steps, lm) ->
    (alpha_blocked, w_rows)`` with ``Xs (n, m_b, d)``, ``a0 (n, m_b)``
    sharded over the (reversed) axes, ``w0 (d,)`` replicated, ``kys
    (n, S, 2)`` the leaf-major per-solve key plan, ``part (n, S)`` the
    leaf-major participation mask (all-ones for the synchronous schedule),
    ``steps (n, S, h_max)`` the leaf-major runtime step mask (all-ones for
    the static-H schedule), and ``lm`` the replicated RUNTIME
    regularization scalar lambda*m
    (:func:`repro.core.engine.host.regularizer_scale`) -- neither lambda
    nor the H schedule is a cache key, so regularization AND local-H grids
    reuse one device program.

    ``carry_state=True`` returns a :class:`~repro.core.engine.host.
    StateExecutor` threading the full per-leaf state (replica ``w``,
    per-depth snapshots, group servers) across chunk invocations -- the
    complete carry async sessions need (the flat ``(alpha, w)`` pair drops
    absent leaves' divergent replicas)."""
    _check_plan_mesh(plan, mesh, axes)
    cache_key = (plan.fingerprint, loss.name, loss.gamma,
                 tuple(axes), mesh, bool(use_kernel), bool(carry_state))
    fn = _MESH_EXEC_CACHE.get(cache_key)
    if fn is not None:
        _MESH_EXEC_CACHE.move_to_end(cache_key)
        return fn

    L = len(axes)
    m_b = plan.m_b
    rounds = [plan.levels[d].rounds for d in range(L)]
    ks = [plan.levels[d].group_size for d in range(L)]
    axis_of_depth = [axes[L - 1 - d] for d in range(L)]
    # a depth-d sync spans this axis and every deeper one: psum over the
    # whole leaf set of the group, so partially-present subtrees weight
    # per-LEAF exactly like the host backend's segment sums
    axes_from = [tuple(axis_of_depth[d:]) for d in range(L)]
    # uniform per-leaf w-weight at depth d: (1/K_d) / leaves-per-child
    wcoef_leaf = [1.0 / math.prod(ks[d:]) for d in range(L)]
    H = plan.h_max

    def leaf_solve(Xs, ys, a, w, k_t, st_t, lm):
        """One Procedure-P call on this shard's (1, m_b) block, drawing the
        tick's coordinates from the replayed per-solve key; ``st_t`` is the
        slot's (1, H) runtime step mask (all-ones => the static-H solve,
        bit-for-bit: the mask multiplies each delta by 1.0)."""
        ix = jax.random.randint(k_t, (H,), 0, m_b)[None]  # legacy draw shape
        if use_kernel:
            from repro.kernels.sdca.kernel import sdca_block_kernel
            da, dw = sdca_block_kernel(Xs, ys, a, w, ix, loss=loss, lm=lm,
                                       step_mask=st_t,
                                       interpret=not on_tpu())
        else:
            from repro.kernels.sdca.ref import sdca_block_ref
            da, dw = sdca_block_ref(Xs, ys, a, w, ix, loss=loss, lm=lm,
                                    step_mask=st_t)
        return da, dw[0]

    def make_run(Xs, ys, kys, part, steps, lm):
        """Build the recursive rounds-driver over this shard's inputs:
        Xs (1, m_b, d), kys (1, S, 2), part (1, S), steps (1, S, H);
        ``lm`` is the replicated runtime lambda*m scalar."""
        dt = Xs.dtype
        one = jnp.ones((), dt)

        def sync(depth, a, w, t_c, snapA, snapW, srvW, parent_sync):
            """The depth-`depth` aggregation at tick ``t_c - 1`` with
            participation-renormalized weights; absent shards keep their
            state/snapshots, the group server stays coherent for them.
            ``parent_sync`` flags that the parent also syncs at this tick
            (its own call handles the shallower bookkeeping then)."""
            K = ks[depth]
            wc = jnp.asarray(wcoef_leaf[depth], dt)
            p = jax.lax.dynamic_index_in_dim(part, t_c - 1, axis=1,
                                             keepdims=False)[0].astype(dt)
            absent = jax.lax.psum((one - p) * wc, axes_from[depth])
            present = jax.lax.psum(p * wc, axes_from[depth])
            denom = jnp.where(absent == 0, one,
                              jnp.where(present > 0, present, one))
            act = present > 0
            attend = (p > 0) & act
            # a partially-present child subtree is represented by its
            # surviving shards (all carrying the child's full delta): their
            # per-leaf weight scales up by |child| / |present in child|
            if depth < L - 1:
                cnt = jax.lax.psum(p, axes_from[depth + 1])
                size = jnp.asarray(float(math.prod(ks[depth + 1:])), dt)
                corr = size / jnp.maximum(cnt, one)
            else:
                corr = one
            tot = jax.lax.psum((p * wc / denom) * corr * (w - snapW[depth]),
                               axes_from[depth])
            srv_new = srvW[depth] + tot
            a = jnp.where(attend,
                          snapA[depth] + (a - snapA[depth]) / (denom * K), a)
            w = jnp.where(attend, srv_new, w)
            # server advance at this depth + deeper rebase, group-wide
            for d2 in range(depth, L):
                srvW = srvW.at[d2].set(jnp.where(act, srv_new, srvW[d2]))
            # snapshots are per-shard private state: participants only;
            # depths shallower than this sync fast-forward to the server
            # baseline the pulled state embeds -- unless the parent syncs
            # at this very tick and refreshes them itself
            for d2 in range(depth, L):
                snapA = snapA.at[d2].set(jnp.where(attend, a, snapA[d2]))
                snapW = snapW.at[d2].set(jnp.where(attend, w, snapW[d2]))
            ff = attend & jnp.logical_not(parent_sync)
            for d2 in range(depth):
                snapW = snapW.at[d2].set(jnp.where(ff, srvW[d2], snapW[d2]))
            return a, w, snapA, snapW, srvW

        def run(depth, a, w, t, snapA, snapW, srvW):
            """One full solve of a depth-`depth` node: rounds[depth] rounds,
            each recursing below then aggregating over this depth's group
            (Algorithm 2)."""
            T = rounds[depth]

            def one_round(i, carry):
                a_c, w_c, t_c, sA, sW, sV = carry
                if depth == L - 1:
                    k_t = jax.lax.dynamic_index_in_dim(kys, t_c, axis=1,
                                                       keepdims=False)[0]
                    st_t = jax.lax.dynamic_index_in_dim(steps, t_c, axis=1,
                                                        keepdims=False)
                    da, dw = leaf_solve(Xs, ys, a_c, w_c, k_t, st_t, lm)
                    a_c, w_c = a_c + da, w_c + dw
                    t_c = t_c + 1
                else:
                    a_c, w_c, t_c, sA, sW, sV = run(
                        depth + 1, a_c, w_c, t_c, sA, sW, sV)
                parent_sync = (i == T - 1) if depth > 0 else jnp.bool_(False)
                a_c, w_c, sA, sW, sV = sync(depth, a_c, w_c, t_c, sA, sW,
                                            sV, parent_sync)
                return a_c, w_c, t_c, sA, sW, sV
            return jax.lax.fori_loop(0, T, one_round,
                                     (a, w, t, snapA, snapW, srvW))

        return run

    def program(Xs, ys, a0, w0, kys, part, steps, lm):
        # Xs (1, m_b, d), a0 (1, m_b), w0 (d,), kys (1, S, 2),
        # part (1, S), steps (1, S, H) on this shard; lm replicated scalar
        d_feat = Xs.shape[-1]
        run = make_run(Xs, ys, kys, part, steps, lm)
        snapA0 = jnp.broadcast_to(a0[None], (L,) + a0.shape)
        snapW0 = jnp.broadcast_to(w0[None], (L, d_feat))
        a_end, w_end, _, _, _, _ = run(0, a0, w0, jnp.int32(0),
                                       snapA0, snapW0, snapW0)
        return a_end, jnp.broadcast_to(w_end[None], (1, d_feat))

    def program_state(Xs, ys, a0, wrows, sA, sW, sV, kys, part, steps, lm):
        # state is leaf-major: a0 (1, m_b), wrows (1, d), sA (1, L, m_b),
        # sW/sV (1, L, d) on this shard; lm replicated scalar
        run = make_run(Xs, ys, kys, part, steps, lm)
        a_end, w_end, _, sA2, sW2, sV2 = run(
            0, a0, wrows[0], jnp.int32(0), sA[0][:, None, :], sW[0], sV[0])
        return (a_end, w_end[None], sA2[:, 0, :][None], sW2[None],
                sV2[None])

    spec_in = P(tuple(reversed(axes)))
    if carry_state:
        from repro.core.engine.host import StateExecutor
        n = plan.n_leaves
        sharding = NamedSharding(mesh, spec_in)
        step = jax.jit(shard_map(
            program_state, mesh=mesh,
            in_specs=(spec_in,) * 10 + (P(),), out_specs=(spec_in,) * 5))

        def init(X, alpha, w):
            dt = X.dtype
            d_feat = X.shape[1]
            a0 = jnp.asarray(alpha, dt).reshape(n, m_b)
            wr = jnp.broadcast_to(jnp.asarray(w, dt)[None], (n, d_feat))
            sA = jnp.broadcast_to(a0[:, None, :], (n, L, m_b))
            sW = jnp.broadcast_to(wr[:, None, :], (n, L, d_feat))
            return tuple(jax.device_put(x, sharding)
                         for x in (a0, wr, sA, sW, sW))

        def finalize(state):
            return state[0].reshape(-1), state[1][0]

        fn = StateExecutor(init=init, step=step, finalize=finalize)
    else:
        fn = jax.jit(shard_map(
            program, mesh=mesh,
            in_specs=(spec_in, spec_in, spec_in, P(), spec_in, spec_in,
                      spec_in, P()),
            out_specs=(spec_in, spec_in),
        ))
    _MESH_EXEC_CACHE[cache_key] = fn
    while len(_MESH_EXEC_CACHE) > _MESH_EXEC_CACHE_MAX:
        _MESH_EXEC_CACHE.popitem(last=False)
    return fn


def execute_plan_mesh(
    plan: TreePlan,
    tree: TreeNode,
    X: Array,
    y: Array,
    mesh: Mesh,
    *,
    axes: Sequence[str],
    loss: Loss,
    lam: float,
    key=None,
    use_kernel: bool = True,
    alpha0: Array = None,
    w0: Array = None,
    participation: Array = None,
    steps: Array = None,
) -> Tuple[Array, Array]:
    """Run the plan on ``mesh``; returns (alpha (m,), w (d,)).  ``alpha0``/
    ``w0`` warm-start the run (cold all-zeros by default);
    ``participation`` is the (S, n) sync-attendance mask (all-ones -- the
    synchronous schedule -- by default); ``steps`` the (S, n, h_max)
    runtime step mask (all-ones -- the static-H schedule -- by
    default)."""
    _check_plan_mesh(plan, mesh, axes)
    n, m_b = plan.n_leaves, plan.m_b
    m, d_feat = X.shape
    assert n * m_b == m, (n, m_b, m)

    fn = get_mesh_executor(plan, mesh, axes=axes, loss=loss,
                           use_kernel=use_kernel)
    keys = key_plan(tree, plan, key)                        # (S, n, 2)
    keys_leaf = jnp.asarray(keys.transpose(1, 0, 2))        # (n, S, 2)
    if participation is None:
        participation = full_participation(plan)
    part_leaf = jnp.asarray(participation, X.dtype).T       # (n, S)
    if steps is None:
        steps = full_steps(plan)
    steps_leaf = jnp.asarray(                               # (n, S, h_max)
        np.asarray(steps, np.float32).transpose(1, 0, 2), X.dtype)

    a0 = jnp.zeros((n, m_b), X.dtype) if alpha0 is None else \
        jnp.asarray(alpha0, X.dtype).reshape(n, m_b)
    w_start = jnp.zeros((d_feat,), X.dtype) if w0 is None else \
        jnp.asarray(w0, X.dtype)
    spec_in = P(tuple(reversed(axes)))
    Xs = jax.device_put(X.reshape(n, m_b, d_feat), NamedSharding(mesh, spec_in))
    ys = jax.device_put(y.reshape(n, m_b), NamedSharding(mesh, spec_in))
    kys = jax.device_put(keys_leaf, NamedSharding(mesh, spec_in))
    part = jax.device_put(part_leaf, NamedSharding(mesh, spec_in))
    stp = jax.device_put(steps_leaf, NamedSharding(mesh, spec_in))
    from repro.core.engine.host import regularizer_scale
    alpha, w = fn(Xs, ys, a0, w_start, kys, part, stp,
                  regularizer_scale(lam, plan.m_total, X.dtype))
    return alpha.reshape(m), w[0]


def tree_from_mesh_axes(
    mesh: Mesh,
    axes: Sequence[str],
    rounds: Sequence[int],
    *,
    local_steps: int,
    m_leaf: int,
) -> TreeNode:
    """The tree whose recursion IS the mesh-axis hierarchy: ``axes`` are
    listed innermost (leaf level) first, so the root fans out over
    ``axes[-1]`` and runs ``rounds[-1]`` rounds."""
    from repro.core.engine.plan import balanced_tree
    sizes = [dict(mesh.shape)[a] for a in axes]
    return balanced_tree(
        list(reversed(sizes)), list(reversed(rounds)),
        local_steps=local_steps, m_leaf=m_leaf)
