"""Mesh backend: run a level-homogeneous :class:`TreePlan` as a sharded
device program (``shard_map`` + ``lax`` collectives), with the Pallas
blocked-SDCA kernel at the leaves.

The mesh axes are one *admissible grouping* of the plan: internal depth d
of the tree maps onto mesh axis ``axes[L-1-d]`` (axes listed innermost
first), so every depth-d sync group is exactly the set of devices sharing
coordinates on the axes above.  Because mesh plans are level-homogeneous
(every node at a depth shares (rounds, fan-out) and all leaves are
congruent), the flat tick schedule factors back into nested ``fori_loop``s
with one ``psum`` per sync -- the natural lowering on a device mesh, and
bit-compatible with the host backend because both consume the same
per-solve key plan (the legacy-RNG replay from ``engine.plan``).

Like the host backend, the compiled program is memoized on
(plan fingerprint, mesh, axes, loss, lam, flags) and takes the warm-start
state ``(alpha0, w0)`` as inputs, so ``repro.api.Session`` can run it in
per-root-round chunks without retracing.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import on_tpu, shard_map
from repro.core.dual import Loss
from repro.core.engine.plan import TreePlan, key_plan
from repro.core.tree import TreeNode

Array = jax.Array

_MESH_EXEC_CACHE: OrderedDict = OrderedDict()
_MESH_EXEC_CACHE_MAX = 16


def _check_plan_mesh(plan: TreePlan, mesh: Mesh, axes: Sequence[str]):
    assert plan.levels is not None, (
        "the mesh backend needs a level-homogeneous plan (balanced tree, "
        "uniform per-depth rounds); use the host backend otherwise")
    assert plan.weighting == "uniform", (
        "mesh lowering uses per-level psum/K averaging (uniform weights)")
    L = len(axes)
    assert plan.depth == L, (plan.depth, L)
    sizes = [dict(mesh.shape)[a] for a in axes]
    for d in range(L):
        assert plan.levels[d].group_size == sizes[L - 1 - d], (
            f"depth {d} fan-out {plan.levels[d].group_size} != mesh axis "
            f"{axes[L - 1 - d]} size {sizes[L - 1 - d]}")
    assert int(plan.leaf_sizes.min()) == plan.m_b, \
        "mesh backend needs equal blocks"


def get_mesh_executor(
    plan: TreePlan,
    mesh: Mesh,
    *,
    axes: Sequence[str],
    loss: Loss,
    lam: float,
    use_kernel: bool = True,
):
    """Build (or fetch from cache) the jitted ``shard_map`` program for
    ``plan`` on ``mesh``.

    Signature: ``fn(Xs, ys, a0, w0, kys) -> (alpha_blocked, w_rows)`` with
    ``Xs (n, m_b, d)``, ``a0 (n, m_b)`` sharded over the (reversed) axes,
    ``w0 (d,)`` replicated, and ``kys (n, S, 2)`` the leaf-major per-solve
    key plan."""
    _check_plan_mesh(plan, mesh, axes)
    cache_key = (plan.fingerprint, loss.name, loss.gamma, float(lam),
                 tuple(axes), mesh, bool(use_kernel))
    fn = _MESH_EXEC_CACHE.get(cache_key)
    if fn is not None:
        _MESH_EXEC_CACHE.move_to_end(cache_key)
        return fn

    L = len(axes)
    m_b = plan.m_b
    lm = lam * plan.m_total
    rounds = [plan.levels[d].rounds for d in range(L)]
    ks = [plan.levels[d].group_size for d in range(L)]
    axis_of_depth = [axes[L - 1 - d] for d in range(L)]
    H = plan.h_max

    def leaf_solve(Xs, ys, a, w, k_t):
        """One Procedure-P call on this shard's (1, m_b) block, drawing the
        tick's coordinates from the replayed per-solve key."""
        ix = jax.random.randint(k_t, (H,), 0, m_b)[None]  # legacy draw shape
        if use_kernel:
            from repro.kernels.sdca.kernel import sdca_block_kernel
            da, dw = sdca_block_kernel(Xs, ys, a, w, ix, loss=loss, lm=lm,
                                       interpret=not on_tpu())
        else:
            from repro.kernels.sdca.ref import sdca_block_ref
            da, dw = sdca_block_ref(Xs, ys, a, w, ix, loss=loss, lm=lm)
        return da, dw[0]

    def program(Xs, ys, a0, w0, kys):
        # Xs (1, m_b, d), a0 (1, m_b), w0 (d,), kys (1, S, 2) on this shard
        d_feat = Xs.shape[-1]

        def run(depth, a, w, t):
            """One full solve of a depth-`depth` node: rounds[depth] rounds,
            each recursing below then psum-averaging over this depth's
            axis (Algorithm 2)."""
            T, K, axis = rounds[depth], ks[depth], axis_of_depth[depth]

            def one_round(_, carry):
                a_c, w_c, t_c = carry
                if depth == L - 1:
                    k_t = jax.lax.dynamic_index_in_dim(kys, t_c, axis=1,
                                                       keepdims=False)[0]
                    da, dw = leaf_solve(Xs, ys, a_c, w_c, k_t)
                    t_c = t_c + 1
                else:
                    a_lo, w_lo, t_c = run(depth + 1, a_c, w_c, t_c)
                    da, dw = a_lo - a_c, w_lo - w_c
                a_c = a_c + da / K
                w_c = w_c + jax.lax.psum(dw, axis) / K
                return a_c, w_c, t_c
            return jax.lax.fori_loop(0, T, one_round, (a, w, t))

        a_end, w_end, _ = run(0, a0, w0, jnp.int32(0))
        return a_end, jnp.broadcast_to(w_end[None], (1, d_feat))

    spec_in = P(tuple(reversed(axes)))
    fn = jax.jit(shard_map(
        program, mesh=mesh,
        in_specs=(spec_in, spec_in, spec_in, P(), spec_in),
        out_specs=(spec_in, spec_in),
    ))
    _MESH_EXEC_CACHE[cache_key] = fn
    while len(_MESH_EXEC_CACHE) > _MESH_EXEC_CACHE_MAX:
        _MESH_EXEC_CACHE.popitem(last=False)
    return fn


def execute_plan_mesh(
    plan: TreePlan,
    tree: TreeNode,
    X: Array,
    y: Array,
    mesh: Mesh,
    *,
    axes: Sequence[str],
    loss: Loss,
    lam: float,
    key=None,
    use_kernel: bool = True,
    alpha0: Array = None,
    w0: Array = None,
) -> Tuple[Array, Array]:
    """Run the plan on ``mesh``; returns (alpha (m,), w (d,)).  ``alpha0``/
    ``w0`` warm-start the run (cold all-zeros by default)."""
    _check_plan_mesh(plan, mesh, axes)
    n, m_b = plan.n_leaves, plan.m_b
    m, d_feat = X.shape
    assert n * m_b == m, (n, m_b, m)

    fn = get_mesh_executor(plan, mesh, axes=axes, loss=loss, lam=lam,
                           use_kernel=use_kernel)
    keys = key_plan(tree, plan, key)                        # (S, n, 2)
    keys_leaf = jnp.asarray(keys.transpose(1, 0, 2))        # (n, S, 2)

    a0 = jnp.zeros((n, m_b), X.dtype) if alpha0 is None else \
        jnp.asarray(alpha0, X.dtype).reshape(n, m_b)
    w_start = jnp.zeros((d_feat,), X.dtype) if w0 is None else \
        jnp.asarray(w0, X.dtype)
    spec_in = P(tuple(reversed(axes)))
    Xs = jax.device_put(X.reshape(n, m_b, d_feat), NamedSharding(mesh, spec_in))
    ys = jax.device_put(y.reshape(n, m_b), NamedSharding(mesh, spec_in))
    kys = jax.device_put(keys_leaf, NamedSharding(mesh, spec_in))
    alpha, w = fn(Xs, ys, a0, w_start, kys)
    return alpha.reshape(m), w[0]


def tree_from_mesh_axes(
    mesh: Mesh,
    axes: Sequence[str],
    rounds: Sequence[int],
    *,
    local_steps: int,
    m_leaf: int,
) -> TreeNode:
    """The tree whose recursion IS the mesh-axis hierarchy: ``axes`` are
    listed innermost (leaf level) first, so the root fans out over
    ``axes[-1]`` and runs ``rounds[-1]`` rounds."""
    from repro.core.engine.plan import balanced_tree
    sizes = [dict(mesh.shape)[a] for a in axes]
    return balanced_tree(
        list(reversed(sizes)), list(reversed(rounds)),
        local_steps=local_steps, m_leaf=m_leaf)
