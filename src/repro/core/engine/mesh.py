"""Mesh backend: run a level-homogeneous :class:`TreePlan` as a sharded
device program (``shard_map`` + ``lax`` collectives), with the Pallas
blocked-SDCA kernel at the leaves.

The mesh axes are one *admissible grouping* of the plan: internal depth d
of the tree maps onto mesh axis ``axes[L-1-d]`` (axes listed innermost
first), so every depth-d sync group is exactly the set of devices sharing
coordinates on the axes above.  Because mesh plans are level-homogeneous
(every node at a depth shares (rounds, fan-out) and all leaves are
congruent), the flat tick schedule factors back into nested ``fori_loop``s
with one collective per sync -- the natural lowering on a device mesh, and
bit-compatible with the host backend because both consume the same
per-solve key plan (the legacy-RNG replay from ``engine.plan``).

Like the host backend, the compiled program is memoized on
(plan fingerprint, mesh, axes, loss, flags) and takes the warm-start
state ``(alpha0, w0)`` -- and the regularization scalar ``lm`` = lambda*m
-- as runtime inputs, so ``repro.api.Session`` can run it in
per-root-round chunks without retracing, and a lambda grid shares one
device program.

Async / stale sync: the program also takes the ``(n, S)`` leaf-major
participation mask (see ``engine.plan``).  Each depth's sync weights every
*leaf* shard by ``p / prod(K_d..K_L-1)`` and psums over ALL axes at that
depth and deeper (so partially-present subtrees renormalize exactly like
the host backend), carrying explicit per-depth snapshots and the
group-coherent server ``w`` (``srvW``) that bounded-staleness re-joins fold
into.  An all-ones mask reduces every gate to the synchronous program.

Runtime schedules: the program also takes the ``(n, S, h_max)`` leaf-major
step mask (see ``engine.plan.steps_for_h``).  Every solve slot draws the
full H-capacity coordinate stream; the mask gates the trailing deltas in
the Pallas kernel (its ``step_mask`` operand), so heterogeneous / replanned
H is a runtime input of the one cached device program.  All-ones step
masks multiply the deltas by exactly 1.0 -- bit-identical to the static-H
program.

Edge compression (tentpole): a plan whose per-depth compression specs are
non-trivial routes every sync's ``w``-delta through the edge's
(quantize + dequantize) roundtrip with an error-feedback residual carried
in the program state, exactly like the host backend -- mesh plans need ONE
spec per depth (level-homogeneous compression).  ``compression=None``
plans trace the pre-compression program unchanged.

Sync lowering (``sync=``):

* ``"psum"`` (default): replicated server state -- every device carries
  the full per-depth ``snapW``/``srvW`` ``d``-vectors and each sync is one
  ``psum``.  Bit-identical to the host backend.
* ``"reduce_scatter"``: the big-``d`` path.  Per-depth server state lives
  SHARDED over the depth's sync group (each device owns a
  ``ceil(d / G_d)`` chunk, ``G_d`` the group's device count): a sync is
  ``psum_scatter`` of the (optionally compressed) local delta into the
  shard, then one ``all_gather`` to rebuild the full ``w`` the leaf solve
  needs.  Chunk placement is whatever tiled ``psum_scatter``/``all_gather``
  agree on, so the lowering never assumes (or computes) a device-ordering
  convention.  Per-device persistent
  server state drops from ``2 L d`` to ``2 sum_d ceil(d/G_d)``
  (:func:`mesh_state_floats`), which is what lets ``d >> VMEM`` problems
  run.  Requires full participation (the sharded snapshot reconstruction
  assumes group-coherent server state); numerically equivalent to
  ``"psum"`` up to float reassociation of the sum.
"""
from __future__ import annotations

import math
from collections import OrderedDict
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import on_tpu, shard_map
from repro.core import compression as comp_mod
from repro.core.dual import Loss
from repro.core.engine.plan import (
    TreePlan, full_participation, full_steps, key_plan)
from repro.core.tree import TreeNode

Array = jax.Array

_MESH_EXEC_CACHE: OrderedDict = OrderedDict()
_MESH_EXEC_CACHE_MAX = 16
# hit/miss counters + named key fields + bounded miss log, mirroring
# engine.host: the mesh column of executor_cache_stats()["by_backend"]
# (mesh rebuilds used to be invisible to cache-stats assertions)
MESH_KEY_FIELDS = ("plan_fingerprint", "loss", "gamma", "axes", "mesh",
                   "use_kernel", "carry_state", "sync", "batched",
                   "accelerated")
_MESH_CACHE_STATS = {"hits": 0, "misses": 0}
_MISS_LOG: list = []
_MISS_LOG_MAX = 64

SYNC_MODES = ("psum", "reduce_scatter")


def mesh_executor_cache_stats() -> dict:
    """Mesh executor-cache counters: {hits, misses, size}."""
    return dict(_MESH_CACHE_STATS, size=len(_MESH_EXEC_CACHE))


def mesh_executor_cache_keys() -> list:
    """Current mesh-cache keys as named dicts (see ``MESH_KEY_FIELDS``)."""
    from repro.core.engine.host import _named_key
    return [_named_key(MESH_KEY_FIELDS, k) for k in _MESH_EXEC_CACHE]


def _check_plan_mesh(plan: TreePlan, mesh: Mesh, axes: Sequence[str]):
    assert plan.levels is not None, (
        "the mesh backend needs a level-homogeneous plan (balanced tree, "
        "uniform per-depth rounds); use the host backend otherwise")
    assert plan.weighting == "uniform", (
        "mesh lowering uses per-level psum/K averaging (uniform weights)")
    L = len(axes)
    assert plan.depth == L, (plan.depth, L)
    sizes = [dict(mesh.shape)[a] for a in axes]
    for d in range(L):
        assert plan.levels[d].group_size == sizes[L - 1 - d], (
            f"depth {d} fan-out {plan.levels[d].group_size} != mesh axis "
            f"{axes[L - 1 - d]} size {sizes[L - 1 - d]}")
    assert int(plan.leaf_sizes.min()) == plan.m_b, \
        "mesh backend needs equal blocks"


def _comp_specs(plan: TreePlan):
    """The per-depth (kind, frac) compression spec of a mesh-lowerable
    plan; raises when a depth mixes specs across edges (mesh lowering is
    one collective per depth, so the spec must be level-uniform)."""
    specs = []
    for dd in range(plan.depth):
        pairs = {(int(k), float(f)) for k, f in
                 zip(plan.compress_kind[dd], plan.compress_frac[dd],
                     strict=True)}
        if len(pairs) != 1:
            raise ValueError(
                f"mesh backend needs ONE compression spec per depth; depth "
                f"{dd} mixes "
                f"{sorted(comp_mod.spec_name(*p) for p in pairs)}")
        specs.append(next(iter(pairs)))
    return specs


def mesh_state_floats(plan: TreePlan, d_feat: int, *,
                      sync: str = "psum") -> int:
    """Per-device PERSISTENT carry floats of the mesh program (the state a
    chunked/carry_state session threads: blocked alpha, the ``w`` replica,
    per-depth snapshots/servers, error-feedback residuals).  The
    ``reduce_scatter`` lowering keeps per-depth server state sharded over
    the depth's sync group, which is its big-``d`` memory win."""
    if sync not in SYNC_MODES:
        raise ValueError(f"sync must be one of {SYNC_MODES}, got {sync!r}")
    L, m_b = plan.depth, plan.m_b
    ks = [plan.levels[d].group_size for d in range(L)]
    specs = _comp_specs(plan)
    n_res = sum(1 for k, _ in specs if k != comp_mod.KIND_NONE)
    base = m_b + d_feat + L * m_b + n_res * d_feat
    if sync == "psum":
        return base + 2 * L * d_feat          # snapW + srvW, replicated
    shard = sum(-(-d_feat // math.prod(ks[d:])) for d in range(L))
    return base + shard                       # sharded server (snap == srv)


def get_mesh_executor(
    plan: TreePlan,
    mesh: Mesh,
    *,
    axes: Sequence[str],
    loss: Loss,
    use_kernel: bool = True,
    carry_state: bool = False,
    sync: str = "psum",
    batched: bool = False,
    accelerated: bool = False,
):
    """Build (or fetch from cache) the jitted ``shard_map`` program for
    ``plan`` on ``mesh``.

    Signature: ``fn(Xs, ys, a0, w0, kys, part, steps, lm) ->
    (alpha_blocked, w_rows)`` with ``Xs (n, m_b, d)``, ``a0 (n, m_b)``
    sharded over the (reversed) axes, ``w0 (d,)`` replicated, ``kys
    (n, S, 2)`` the leaf-major per-solve key plan, ``part (n, S)`` the
    leaf-major participation mask (all-ones for the synchronous schedule),
    ``steps (n, S, h_max)`` the leaf-major runtime step mask (all-ones for
    the static-H schedule), and ``lm`` the replicated RUNTIME
    regularization scalar lambda*m
    (:func:`repro.core.engine.host.regularizer_scale`) -- neither lambda
    nor the H schedule is a cache key, so regularization AND local-H grids
    reuse one device program.

    ``sync`` picks the collective lowering: ``"psum"`` (replicated server
    state, bit-identical to the host backend) or ``"reduce_scatter"``
    (sharded server state for big ``d``; requires full participation --
    see the module docstring).

    ``carry_state=True`` returns a :class:`~repro.core.engine.host.
    StateExecutor` threading the full per-leaf state across chunk
    invocations as ONE opaque pytree: ``step(Xs, ys, state, kys, part,
    steps, lm) -> state`` -- the complete carry async and compressed
    sessions need (the flat ``(alpha, w)`` pair drops absent leaves'
    divergent replicas and the error-feedback residuals).

    ``batched=True`` returns the fused-sweep flavor: the per-shard program
    is ``jax.vmap``-ped over a leading config axis B INSIDE the
    ``shard_map`` (collectives batch elementwise under vmap, so every
    member's psum / tiled ``psum_scatter`` / ``all_gather`` is bitwise the
    standalone one).  Batched operands gain a leading B over the leaf-
    sharded dimension -- ``a0 (B, n, m_b)``, ``w0 (B, d)``, ``kys
    (B, n, S, 2)``, ``steps (B, n, S, h_max)``, ``lm (B,)`` -- while
    ``Xs``/``ys``/``part`` stay shared.  Composes with ``carry_state``
    (every state leaf carries the leading B axis) and both sync modes.

    ``accelerated=True`` is the ``sdca_acc`` flavor (see
    :func:`repro.core.engine.host.get_host_executor`): one trailing
    runtime scalar ``acceleration`` (shared across a batch), per-depth
    momentum anchors in the carry, and the server combine extrapolates
    both sides of the primal-dual pair; ``acceleration == 0`` is
    bit-identical to the plain program."""
    _check_plan_mesh(plan, mesh, axes)
    if sync not in SYNC_MODES:
        raise ValueError(f"sync must be one of {SYNC_MODES}, got {sync!r}")
    cache_key = (plan.fingerprint, loss.name, loss.gamma,
                 tuple(axes), mesh, bool(use_kernel), bool(carry_state),
                 sync, bool(batched), bool(accelerated))
    fn = _MESH_EXEC_CACHE.get(cache_key)
    if fn is not None:
        _MESH_CACHE_STATS["hits"] += 1
        _MESH_EXEC_CACHE.move_to_end(cache_key)
        return fn

    L = len(axes)
    m_b = plan.m_b
    rounds = [plan.levels[d].rounds for d in range(L)]
    ks = [plan.levels[d].group_size for d in range(L)]
    axis_of_depth = [axes[L - 1 - d] for d in range(L)]
    # a depth-d sync spans this axis and every deeper one: psum over the
    # whole leaf set of the group, so partially-present subtrees weight
    # per-LEAF exactly like the host backend's segment sums
    axes_from = [tuple(axis_of_depth[d:]) for d in range(L)]
    # uniform per-leaf w-weight at depth d: (1/K_d) / leaves-per-child
    wcoef_leaf = [1.0 / math.prod(ks[d:]) for d in range(L)]
    group_dev = [math.prod(ks[d:]) for d in range(L)]   # G_d per depth
    H = plan.h_max
    rs = sync == "reduce_scatter"

    specs = _comp_specs(plan)
    comp_depths = [dd for dd in range(L)
                   if specs[dd][0] != comp_mod.KIND_NONE]
    comp_idx = {dd: i for i, dd in enumerate(comp_depths)}

    def roundtrip_vec(depth, target):
        """The receiver's view of this depth's compressed (d,) delta."""
        kind, frac = specs[depth]
        if kind == comp_mod.KIND_INT8:
            return comp_mod.int8_roundtrip(target)
        k = comp_mod.topk_count(target.shape[-1], frac)
        return comp_mod.topk_roundtrip(target, k)

    def leaf_solve(Xs, ys, a, w, k_t, st_t, lm):
        """One Procedure-P call on this shard's (1, m_b) block, drawing the
        tick's coordinates from the replayed per-solve key; ``st_t`` is the
        slot's (1, H) runtime step mask (all-ones => the static-H solve,
        bit-for-bit: the mask multiplies each delta by 1.0)."""
        ix = jax.random.randint(k_t, (H,), 0, m_b)[None]  # legacy draw shape
        if use_kernel:
            from repro.kernels.sdca.kernel import sdca_block_kernel
            da, dw = sdca_block_kernel(Xs, ys, a, w, ix, loss=loss, lm=lm,
                                       step_mask=st_t,
                                       interpret=not on_tpu())
        else:
            from repro.kernels.sdca.ref import sdca_block_ref
            da, dw = sdca_block_ref(Xs, ys, a, w, ix, loss=loss, lm=lm,
                                    step_mask=st_t)
        return da, dw[0]

    def _geom(d_feat):
        """Sharded-server geometry.  ``shard``/``gather`` are each other's
        inverse BY CONSTRUCTION: a shard is what tiled ``psum_scatter``
        assigns this device (contributing ``x / G`` from every member of
        the group, whose sum is ``x`` again for group-uniform ``x``), and
        ``gather`` is the matching tiled ``all_gather`` -- so chunk
        placement follows the collectives' own device order and the
        lowering never materializes a device-position index.  (That is
        deliberate: device-varying ``dynamic_slice`` offsets derived from
        ``axis_index``, and participation gates over tiled-collective
        values, both abort XLA's sharding-propagation pass when they feed
        a loop carry.)  ``pad_w``/``unpad`` round the leaf's ``w`` replica
        up to the largest group-padded size: the loop-carried replica must
        keep a collective-aligned length for the same reason."""
        p_sz = [-(-d_feat // g) for g in group_dev]
        d_pad = max(g * p for g, p in zip(group_dev, p_sz, strict=True))

        def shard(dd, x):
            # x must be uniform across the depth-dd group (server state is)
            xp = jnp.pad(x, (0, group_dev[dd] * p_sz[dd] - d_feat))
            return jax.lax.psum_scatter(
                xp * (1.0 / group_dev[dd]), axes_from[dd],
                scatter_dimension=0, tiled=True)

        def gather(dd, sh):
            return jax.lax.all_gather(
                sh, axes_from[dd], tiled=True)[:d_feat]

        def scatter_sum(dd, x):
            xp = jnp.pad(x, (0, group_dev[dd] * p_sz[dd] - d_feat))
            return jax.lax.psum_scatter(
                xp, axes_from[dd], scatter_dimension=0, tiled=True)

        def pad_w(x):
            return jnp.pad(x, (0, d_pad - d_feat))

        def unpad(x):
            return x[:d_feat]

        return shard, gather, scatter_sum, pad_w, unpad

    def make_run(Xs, ys, kys, part, steps, lm, acceleration=None):
        """Build the recursive rounds-driver over this shard's inputs:
        Xs (1, m_b, d), kys (1, S, 2), part (1, S), steps (1, S, H);
        ``lm`` is the replicated runtime lambda*m scalar, ``acceleration``
        the runtime server-momentum scalar (accelerated programs only).
        The carry is a tuple whose first three slots are always
        (a, w, t_c); the server tail is lowering-specific:

        * psum: ``(a, w, t_c, snapA, snapW, srvW[, srvP, srvA], res)``
        * reduce_scatter: ``(a, w, t_c, snapA, srv_sh[, srvP_sh, srvA],
          res)`` with ``srv_sh`` the per-depth sharded server/snapshot
          chunks (one vector under full participation -- snap == srv)

        where the bracketed momentum anchors exist only in accelerated
        programs (``srvP`` anchors the server w sequence, ``srvA`` the
        combined alpha -- both sides extrapolate with the same runtime
        coefficient, preserving the linear alpha -> w consistency)."""
        dt = Xs.dtype
        one = jnp.ones((), dt)
        acc = None
        if accelerated:
            acc = jnp.asarray(acceleration, dt)
        if rs:
            shard, gather, scatter_sum, pad_w, unpad = _geom(Xs.shape[-1])
        else:
            pad_w = unpad = lambda x: x

        def gates(depth, part, t_c):
            """Participation-renormalized weights of the tick's sync."""
            wc = jnp.asarray(wcoef_leaf[depth], dt)
            p = jax.lax.dynamic_index_in_dim(part, t_c - 1, axis=1,
                                             keepdims=False)[0].astype(dt)
            absent = jax.lax.psum((one - p) * wc, axes_from[depth])
            present = jax.lax.psum(p * wc, axes_from[depth])
            denom = jnp.where(absent == 0, one,
                              jnp.where(present > 0, present, one))
            act = present > 0
            attend = (p > 0) & act
            # a partially-present child subtree is represented by its
            # surviving shards (all carrying the child's full delta): their
            # per-leaf weight scales up by |child| / |present in child|
            if depth < L - 1:
                cnt = jax.lax.psum(p, axes_from[depth + 1])
                size = jnp.asarray(float(math.prod(ks[depth + 1:])), dt)
                corr = size / jnp.maximum(cnt, one)
            else:
                corr = one
            return p, wc, denom, act, attend, corr

        def compress_delta(depth, delta, res, attend=None):
            """Error feedback: compress(delta + residual), residual
            advancing only when this shard actually delivers (``attend``
            None -- the full-participation reduce_scatter path -- advances
            unconditionally)."""
            if depth not in comp_idx:
                return delta, res
            ri = comp_idx[depth]
            target = delta.astype(jnp.float32) + res[ri]
            approx = roundtrip_vec(depth, target)
            r_new = target - approx if attend is None else \
                jnp.where(attend, target - approx, res[ri])
            res = res[:ri] + (r_new,) + res[ri + 1:]
            return approx.astype(dt), res

        def sync_psum(depth, carry, parent_sync):
            """The depth-`depth` aggregation at tick ``t_c - 1`` with
            participation-renormalized weights; absent shards keep their
            state/snapshots, the group server stays coherent for them.
            ``parent_sync`` flags that the parent also syncs at this tick
            (its own call handles the shallower bookkeeping then)."""
            if accelerated:
                a, w, t_c, snapA, snapW, srvW, srvP, srvA, res = carry
            else:
                a, w, t_c, snapA, snapW, srvW, res = carry
            K = ks[depth]
            p, wc, denom, act, attend, corr = gates(depth, part, t_c)
            delta, res = compress_delta(depth, w - snapW[depth], res,
                                        attend)
            tot = jax.lax.psum((p * wc / denom) * corr * delta,
                               axes_from[depth])
            srv_base = srvW[depth] + tot
            base_a = snapA[depth] + (a - snapA[depth]) / (denom * K)
            if accelerated:
                # paired Nesterov-style extrapolation (see engine.host):
                # both sides move along their un-extrapolated combination
                # sequences with the same coefficient; acceleration == 0
                # selects the base exactly (a where, bit-identical)
                ext_w = srv_base + acc * (srv_base - srvP[depth])
                srv_new = jnp.where(acc != 0, ext_w, srv_base)
                ext_a = base_a + acc * (base_a - srvA[depth])
                new_a = jnp.where(acc != 0, ext_a, base_a)
                srvP = srvP.at[depth].set(
                    jnp.where(act, srv_base, srvP[depth]))
                srvA = srvA.at[depth].set(
                    jnp.where(attend, base_a, srvA[depth]))
            else:
                srv_new = srv_base
                new_a = base_a
            a = jnp.where(attend, new_a, a)
            w = jnp.where(attend, srv_new, w)
            # server advance at this depth + deeper rebase, group-wide
            for d2 in range(depth, L):
                srvW = srvW.at[d2].set(jnp.where(act, srv_new, srvW[d2]))
            if accelerated:
                # deeper momentum anchors restart from the pulled state
                # (zero velocity after a rebase), exactly as on the host
                for d2 in range(depth + 1, L):
                    srvP = srvP.at[d2].set(
                        jnp.where(act, srv_new, srvP[d2]))
                    srvA = srvA.at[d2].set(
                        jnp.where(attend, a, srvA[d2]))
            # snapshots are per-shard private state: participants only;
            # depths shallower than this sync fast-forward to the server
            # baseline the pulled state embeds -- unless the parent syncs
            # at this very tick and refreshes them itself
            for d2 in range(depth, L):
                snapA = snapA.at[d2].set(jnp.where(attend, a, snapA[d2]))
                snapW = snapW.at[d2].set(jnp.where(attend, w, snapW[d2]))
            ff = attend & jnp.logical_not(parent_sync)
            for d2 in range(depth):
                snapW = snapW.at[d2].set(jnp.where(ff, srvW[d2], snapW[d2]))
            if accelerated:
                return a, w, t_c, snapA, snapW, srvW, srvP, srvA, res
            return a, w, t_c, snapA, snapW, srvW, res

        def sync_rs(depth, carry, parent_sync):
            """The reduce_scatter lowering of the depth sync: reconstruct
            the (group-coherent) snapshot from this depth's server shards,
            ``psum_scatter`` the (optionally compressed) local delta into
            the shard, then one ``all_gather`` for the full post-sync
            ``w``.  Deeper server shards rebase by re-slicing that full
            vector; snap == srv under the full participation this path
            assumes (the participation mask is NOT consulted -- the
            session refuses to route partial-participation schedules
            here), which is also what lets the sync run ungated: XLA's
            sharding propagation aborts on participation-``where`` gates
            over tiled-collective values."""
            if accelerated:
                a, w, t_c, snapA, srv_sh, srvP_sh, srvA, res = carry
            else:
                a, w, t_c, snapA, srv_sh, res = carry
            K = ks[depth]
            wc = jnp.asarray(wcoef_leaf[depth], dt)
            snap_full = gather(depth, srv_sh[depth])
            delta, res = compress_delta(depth, unpad(w) - snap_full, res)
            tot_sh = scatter_sum(depth, wc * delta)
            base_sh = srv_sh[depth] + tot_sh
            base_a = snapA[depth] + (a - snapA[depth]) / K
            if accelerated:
                # paired extrapolation on the SHARDED server chunks (the
                # anchors live in shard layout, so momentum costs no extra
                # collective) and on the combined alpha
                ext_sh = base_sh + acc * (base_sh - srvP_sh[depth])
                new_sh = jnp.where(acc != 0, ext_sh, base_sh)
                ext_a = base_a + acc * (base_a - srvA[depth])
                a = jnp.where(acc != 0, ext_a, base_a)
                srvP_sh = (srvP_sh[:depth] + (base_sh,)
                           + srvP_sh[depth + 1:])
                srvA = srvA.at[depth].set(base_a)
            else:
                new_sh = base_sh
                a = base_a
            w_new = gather(depth, new_sh)
            w = pad_w(w_new)
            for d2 in range(depth, L):
                snapA = snapA.at[d2].set(a)
                srv_sh = (srv_sh[:d2] + (shard(d2, w_new),)
                          + srv_sh[d2 + 1:])
                if accelerated and d2 > depth:
                    # deeper anchors restart at the pulled state
                    srvP_sh = (srvP_sh[:d2] + (srv_sh[d2],)
                               + srvP_sh[d2 + 1:])
                    srvA = srvA.at[d2].set(a)
            if accelerated:
                return a, w, t_c, snapA, srv_sh, srvP_sh, srvA, res
            return a, w, t_c, snapA, srv_sh, res

        sync = sync_rs if rs else sync_psum

        def leaf_step(carry):
            a, w, t_c = carry[0], unpad(carry[1]), carry[2]
            k_t = jax.lax.dynamic_index_in_dim(kys, t_c, axis=1,
                                               keepdims=False)[0]
            st_t = jax.lax.dynamic_index_in_dim(steps, t_c, axis=1,
                                                keepdims=False)
            da, dw = leaf_solve(Xs, ys, a, w, k_t, st_t, lm)
            return (carry[0] + da, pad_w(w + dw), t_c + 1) + carry[3:]

        def run(depth, carry):
            """One full solve of a depth-`depth` node: rounds[depth] rounds,
            each recursing below then aggregating over this depth's group
            (Algorithm 2)."""
            T = rounds[depth]

            def one_round(i, c):
                c = leaf_step(c) if depth == L - 1 else run(depth + 1, c)
                parent_sync = (i == T - 1) if depth > 0 else jnp.bool_(False)
                return sync(depth, c, parent_sync)
            return jax.lax.fori_loop(0, T, one_round, carry)

        def init_tail(a0, w0):
            """The server tail + residuals of a run-start carry (leaf-level
            shapes: a0 (1, m_b), w0 (d,)).  Accelerated programs insert
            the momentum anchors (initialized at the run-start state, so
            the first sync extrapolates along its own first delta) between
            the server slots and the residuals."""
            d_feat = w0.shape[-1]
            snapA0 = jnp.broadcast_to(a0[None], (L,) + a0.shape)
            res0 = tuple(jnp.zeros((d_feat,), jnp.float32)
                         for _ in comp_depths)
            if rs:
                srv0 = tuple(shard(dd, w0) for dd in range(L))
                if accelerated:
                    return (snapA0, srv0, srv0, snapA0, res0)
                return (snapA0, srv0, res0)
            snapW0 = jnp.broadcast_to(w0[None], (L, d_feat))
            if accelerated:
                return (snapA0, snapW0, snapW0, snapW0, snapA0, res0)
            return (snapA0, snapW0, snapW0, res0)

        return run, init_tail, pad_w, unpad

    def program(Xs, ys, a0, w0, kys, part, steps, lm, acceleration=None):
        # Xs (1, m_b, d), a0 (1, m_b), w0 (d,), kys (1, S, 2),
        # part (1, S), steps (1, S, H) on this shard; lm (and the
        # accelerated flavor's momentum coefficient) replicated scalars
        d_feat = Xs.shape[-1]
        run, init_tail, pad_w, unpad = make_run(Xs, ys, kys, part, steps,
                                                lm, acceleration)
        carry = (a0, pad_w(w0), jnp.int32(0)) + init_tail(a0, w0)
        out = run(0, carry)
        a_end, w_end = out[0], unpad(out[1])
        return a_end, jnp.broadcast_to(w_end[None], (1, d_feat))

    def program_state(Xs, ys, state, kys, part, steps, lm,
                      acceleration=None):
        # state is leaf-major (every leaf owns dim 0 of each element):
        # a0 (1, m_b), wrows (1, d), sA (1, L, m_b), then the lowering's
        # server tail (psum: sW/sV (1, L, d), accelerated inserts the sP
        # (1, L, d) / sPA (1, L, m_b) anchors; rs: per-depth (1, p_d)
        # shards, accelerated inserts the anchor shards + sPA), then
        # per-compressed-depth residuals (1, d)
        run, _, pad_w, unpad = make_run(Xs, ys, kys, part, steps, lm,
                                        acceleration)
        a0, wrows, sA = state[0], state[1], state[2]
        n_res = len(comp_depths)
        if rs:
            srv = tuple(s[0] for s in state[3:3 + L])
            k = 3 + L
            if accelerated:
                srvP = tuple(s[0] for s in state[k:k + L])
                sPA = state[k + L]
                k = k + L + 1
            res = tuple(r[0] for r in state[k:])
            if accelerated:
                carry = (a0, pad_w(wrows[0]), jnp.int32(0),
                         sA[0][:, None, :], srv, srvP,
                         sPA[0][:, None, :], res)
                out = run(0, carry)
                a2, w2, _, sA2, srv2, srvP2, sPA2, res2 = out
                return ((a2, unpad(w2)[None], sA2[:, 0, :][None])
                        + tuple(s[None] for s in srv2)
                        + tuple(s[None] for s in srvP2)
                        + (sPA2[:, 0, :][None],)
                        + tuple(r[None] for r in res2))
            carry = (a0, pad_w(wrows[0]), jnp.int32(0),
                     sA[0][:, None, :], srv, res)
            out = run(0, carry)
            a2, w2, _, sA2, srv2, res2 = out
            return ((a2, unpad(w2)[None], sA2[:, 0, :][None])
                    + tuple(s[None] for s in srv2)
                    + tuple(r[None] for r in res2))
        sW, sV = state[3], state[4]
        if accelerated:
            sP, sPA = state[5], state[6]
            res = tuple(r[0] for r in state[7:7 + n_res])
            carry = (a0, wrows[0], jnp.int32(0), sA[0][:, None, :], sW[0],
                     sV[0], sP[0], sPA[0][:, None, :], res)
            out = run(0, carry)
            a2, w2, _, sA2, sW2, sV2, sP2, sPA2, res2 = out
            return ((a2, w2[None], sA2[:, 0, :][None], sW2[None],
                     sV2[None], sP2[None], sPA2[:, 0, :][None])
                    + tuple(r[None] for r in res2))
        res = tuple(r[0] for r in state[5:5 + n_res])
        carry = (a0, wrows[0], jnp.int32(0), sA[0][:, None, :], sW[0],
                 sV[0], res)
        out = run(0, carry)
        a2, w2, _, sA2, sW2, sV2, res2 = out
        return ((a2, w2[None], sA2[:, 0, :][None], sW2[None], sV2[None])
                + tuple(r[None] for r in res2))

    spec_in = P(tuple(reversed(axes)))
    # batched programs shard the SECOND dim (the leaf dim) and keep the
    # leading config axis B replicated; per-shard values then carry a
    # leading B the program vmaps over INSIDE the shard_map
    spec_b = P(None, tuple(reversed(axes)))
    if carry_state:
        from repro.core.engine.host import StateExecutor
        n = plan.n_leaves
        sharding = NamedSharding(mesh, spec_b if batched else spec_in)

        if batched:
            if accelerated:
                def program_state_b(Xs, ys, state, kys, part, steps, lm,
                                    acceleration):
                    return jax.vmap(
                        lambda st, ky, sp, l: program_state(
                            Xs, ys, st, ky, part, sp, l, acceleration)
                    )(state, kys, steps, lm)
            else:
                def program_state_b(Xs, ys, state, kys, part, steps, lm):
                    return jax.vmap(
                        lambda st, ky, sp, l: program_state(
                            Xs, ys, st, ky, part, sp, l)
                    )(state, kys, steps, lm)
            state_specs = (spec_in, spec_in, spec_b, spec_b, spec_in,
                           spec_b, P()) + ((P(),) if accelerated else ())
            # the chunk carry (arg 2) is DONATED: callers rebind
            # ``state = step(...)`` every chunk
            step = jax.jit(shard_map(
                program_state_b, mesh=mesh, in_specs=state_specs,
                out_specs=spec_b), donate_argnums=(2,))
        else:
            state_specs = (spec_in,) * 6 + (P(),) \
                + ((P(),) if accelerated else ())
            step = jax.jit(shard_map(
                program_state, mesh=mesh, in_specs=state_specs,
                out_specs=spec_in), donate_argnums=(2,))

        def init_state(a0, wr):
            # run-start server tail from replicated-per-leaf (a, w) rows;
            # a device computation because the rs shards are
            # position-dependent (the geometry lives inside shard_map)
            _, init_tail, _, _ = make_run(
                jnp.zeros((1, m_b, wr.shape[-1]), wr.dtype),
                None, None, None, None, None,
                0.0 if accelerated else None)
            tail = init_tail(a0, wr[0])
            sA = tail[0]
            flat = []
            for t in tail[1:]:
                for x in (t if isinstance(t, tuple) else (t,)):
                    if x.ndim == 3 and x.shape[1] == 1:
                        # (L, 1, m_b) alpha-shaped anchor -> (1, L, m_b)
                        flat.append(x[:, 0, :][None])
                    else:
                        flat.append(x[None])
            return (a0, wr, sA[:, 0, :][None]) + tuple(flat)

        if batched:
            init_prog = jax.jit(shard_map(
                lambda a0, wr: jax.vmap(init_state)(a0, wr),
                mesh=mesh, in_specs=(spec_b, spec_b), out_specs=spec_b))
        else:
            init_prog = jax.jit(shard_map(
                init_state, mesh=mesh, in_specs=(spec_in, spec_in),
                out_specs=spec_in))

        def init(X, alpha, w):
            dt = X.dtype
            d_feat = X.shape[1]
            if batched:
                B = alpha.shape[0]
                a0 = jnp.asarray(alpha, dt).reshape(B, n, m_b)
                wr = jnp.broadcast_to(
                    jnp.asarray(w, dt)[:, None, :], (B, n, d_feat))
            else:
                a0 = jnp.asarray(alpha, dt).reshape(n, m_b)
                wr = jnp.broadcast_to(jnp.asarray(w, dt)[None], (n, d_feat))
            a0 = jax.device_put(a0, sharding)
            wr = jax.device_put(wr, sharding)
            return init_prog(a0, wr)

        if batched:
            def finalize(state):
                return (state[0].reshape(state[0].shape[0], -1),
                        state[1][:, 0])
        else:
            def finalize(state):
                return state[0].reshape(-1), state[1][0]

        fn = StateExecutor(init=init, step=step, finalize=jax.jit(finalize))
    elif batched:
        if accelerated:
            def program_b(Xs, ys, a0, w0, kys, part, steps, lm,
                          acceleration):
                return jax.vmap(
                    lambda a, w, ky, sp, l: program(
                        Xs, ys, a, w, ky, part, sp, l, acceleration)
                )(a0, w0, kys, steps, lm)
        else:
            def program_b(Xs, ys, a0, w0, kys, part, steps, lm):
                return jax.vmap(
                    lambda a, w, ky, sp, l: program(
                        Xs, ys, a, w, ky, part, sp, l)
                )(a0, w0, kys, steps, lm)
        fn = jax.jit(shard_map(
            program_b, mesh=mesh,
            in_specs=(spec_in, spec_in, spec_b, P(), spec_b, spec_in,
                      spec_b, P()) + ((P(),) if accelerated else ()),
            out_specs=(spec_b, spec_b),
        ))
    elif accelerated:
        fn = jax.jit(shard_map(
            program, mesh=mesh,
            in_specs=(spec_in, spec_in, spec_in, P(), spec_in, spec_in,
                      spec_in, P(), P()),
            out_specs=(spec_in, spec_in),
        ))
    else:
        fn = jax.jit(shard_map(
            program, mesh=mesh,
            in_specs=(spec_in, spec_in, spec_in, P(), spec_in, spec_in,
                      spec_in, P()),
            out_specs=(spec_in, spec_in),
        ))
    # miss counted only after a successful build (see engine.host)
    from repro.core.engine.host import _named_key
    _MESH_CACHE_STATS["misses"] += 1
    _MISS_LOG.append({"backend": "mesh",
                      "key": _named_key(MESH_KEY_FIELDS, cache_key)})
    del _MISS_LOG[:-_MISS_LOG_MAX]
    _MESH_EXEC_CACHE[cache_key] = fn
    while len(_MESH_EXEC_CACHE) > _MESH_EXEC_CACHE_MAX:
        _MESH_EXEC_CACHE.popitem(last=False)
    return fn


def execute_plan_mesh(
    plan: TreePlan,
    tree: TreeNode,
    X: Array,
    y: Array,
    mesh: Mesh,
    *,
    axes: Sequence[str],
    loss: Loss,
    lam: float,
    key=None,
    use_kernel: bool = True,
    alpha0: Array = None,
    w0: Array = None,
    participation: Array = None,
    steps: Array = None,
    sync: str = "psum",
) -> Tuple[Array, Array]:
    """Run the plan on ``mesh``; returns (alpha (m,), w (d,)).  ``alpha0``/
    ``w0`` warm-start the run (cold all-zeros by default);
    ``participation`` is the (S, n) sync-attendance mask (all-ones -- the
    synchronous schedule -- by default); ``steps`` the (S, n, h_max)
    runtime step mask (all-ones -- the static-H schedule -- by default);
    ``sync`` the collective lowering (``"psum"`` / ``"reduce_scatter"``,
    see :func:`get_mesh_executor`)."""
    _check_plan_mesh(plan, mesh, axes)
    n, m_b = plan.n_leaves, plan.m_b
    m, d_feat = X.shape
    assert n * m_b == m, (n, m_b, m)

    fn = get_mesh_executor(plan, mesh, axes=axes, loss=loss,
                           use_kernel=use_kernel, sync=sync)
    keys = key_plan(tree, plan, key)                        # (S, n, 2)
    keys_leaf = jnp.asarray(keys.transpose(1, 0, 2))        # (n, S, 2)
    if participation is None:
        participation = full_participation(plan)
    part_leaf = jnp.asarray(participation, X.dtype).T       # (n, S)
    if steps is None:
        steps = full_steps(plan)
    steps_leaf = jnp.asarray(                               # (n, S, h_max)
        np.asarray(steps, np.float32).transpose(1, 0, 2), X.dtype)

    a0 = jnp.zeros((n, m_b), X.dtype) if alpha0 is None else \
        jnp.asarray(alpha0, X.dtype).reshape(n, m_b)
    w_start = jnp.zeros((d_feat,), X.dtype) if w0 is None else \
        jnp.asarray(w0, X.dtype)
    spec_in = P(tuple(reversed(axes)))
    Xs = jax.device_put(X.reshape(n, m_b, d_feat), NamedSharding(mesh, spec_in))
    ys = jax.device_put(y.reshape(n, m_b), NamedSharding(mesh, spec_in))
    kys = jax.device_put(keys_leaf, NamedSharding(mesh, spec_in))
    part = jax.device_put(part_leaf, NamedSharding(mesh, spec_in))
    stp = jax.device_put(steps_leaf, NamedSharding(mesh, spec_in))
    from repro.core.engine.host import regularizer_scale
    alpha, w = fn(Xs, ys, a0, w_start, kys, part, stp,
                  regularizer_scale(lam, plan.m_total, X.dtype))
    return alpha.reshape(m), w[0]


def tree_from_mesh_axes(
    mesh: Mesh,
    axes: Sequence[str],
    rounds: Sequence[int],
    *,
    local_steps: int,
    m_leaf: int,
) -> TreeNode:
    """The tree whose recursion IS the mesh-axis hierarchy: ``axes`` are
    listed innermost (leaf level) first, so the root fans out over
    ``axes[-1]`` and runs ``rounds[-1]`` rounds."""
    from repro.core.engine.plan import balanced_tree
    sizes = [dict(mesh.shape)[a] for a in axes]
    return balanced_tree(
        list(reversed(sizes)), list(reversed(rounds)),
        local_steps=local_steps, m_leaf=m_leaf)
