"""LM TreeSync as a mesh-backend *Method* on the schedule IR.

The paper's tree schedule (H local iterations per level, nested per-level
rounds) is method-agnostic; this module supplies the LM-training side of
the Method protocol (see ``engine.method``): the local step is one
optimizer update per replica and the per-level combine is a (masked)
mean over that level's sub-axis of the replica dim -- versus SDCA's
(dalpha, dw) aggregation in ``engine.host`` / ``engine.mesh``.

Unlike the legacy ``core.treesync.make_treesync_step`` (which bakes the
per-level periods into the trace), the step built here takes them as a
runtime ``(L,)`` int32 operand: ``cum = jnp.cumprod(periods)`` and
``(step_no % cum[level]) == 0`` produce exactly the same ``lax.cond``
structure as the legacy static path -- bit-identical at fixed periods,
zero retraces when an ``AdaptiveSchedule`` re-plans them mid-run.

Optional runtime operands (each a separate compiled variant, selected by
static flags so the plain path stays bit-identical to legacy):

  * ``masked=True``    -- a per-replica ``(R,)`` participation mask:
    participants within a sync group receive the group mean of the
    participants; absentees keep their own (stale) state and rejoin at a
    later sync, mirroring the SDCA stale-snapshot straggler semantics.
  * ``with_lr=True``   -- a traced scalar learning rate overriding the
    optimizer's built-in schedule, so an (lr x seed) sweep is one
    vmapped dispatch of one executor.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import ModelConfig
from repro.core import compression as comp_mod
from repro.launch.mesh import axis_size
from repro.models import transformer
from repro.optim import Optimizer

PyTree = Any


# ---------------------------------------------------------------------------
# replica-stacked state (moved here from core.treesync; re-exported there)
# ---------------------------------------------------------------------------
@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["params", "opt_state", "step", "residual"], meta_fields=[])
@dataclasses.dataclass
class TreeSyncState:
    params: PyTree      # (R, ...) replica-stacked
    opt_state: PyTree   # (R, ...)
    step: jax.Array     # scalar int32
    residual: Optional[PyTree] = None  # error feedback (compressed mode)


def stack_replicas(tree: PyTree, n: int) -> PyTree:
    return jax.tree.map(
        lambda t: jnp.broadcast_to(t[None], (n,) + t.shape), tree)


def init_lm_state(cfg: ModelConfig, optimizer: Optimizer, key, n_replicas: int,
                  compression: str = "none") -> TreeSyncState:
    params = transformer.init_params(cfg, key)
    opt = optimizer.init(params)
    state = TreeSyncState(
        params=stack_replicas(params, n_replicas),
        opt_state=stack_replicas(opt, n_replicas),
        step=jnp.zeros((), jnp.int32),
    )
    if comp_mod.spec_name(*comp_mod.parse_spec(compression)) != "none":
        compressor = comp_mod.get_compressor(compression)
        state.residual = stack_replicas(
            compressor.init_residual(params), n_replicas)
    return state


def consensus_params(state: TreeSyncState, level_sizes=None) -> PyTree:
    """The fully-averaged model (what you checkpoint / serve)."""
    return jax.tree.map(lambda t: jnp.mean(t.astype(jnp.float32), axis=0),
                        state.params)


def split_batch(batch: Dict[str, jax.Array], n_replicas: int
                ) -> Dict[str, jax.Array]:
    """(B, ...) -> (R, B/R, ...)."""
    def one(t):
        B = t.shape[0]
        assert B % n_replicas == 0, (B, n_replicas)
        return t.reshape((n_replicas, B // n_replicas) + t.shape[1:])

    return {k: one(v) for k, v in batch.items()}


# ---------------------------------------------------------------------------
# per-level combine: (masked) mean over one sub-axis of the replica dim
# ---------------------------------------------------------------------------
def _mean_over_level(tree: PyTree, level_sizes: Sequence[int], level: int
                     ) -> PyTree:
    """Average the (R, ...) replica dim over sub-axis `level` of its
    (s_{L-1}, ..., s_0) factorization (level 0 = innermost/fastest)."""
    idx = len(level_sizes) - 1 - level  # position in the reshaped tuple

    def one(t):
        if t.ndim == 0 or jnp.issubdtype(t.dtype, jnp.integer):
            return t  # step counters etc: identical across replicas
        shp = t.shape
        r = t.reshape(tuple(level_sizes) + shp[1:])
        r = jnp.mean(r.astype(jnp.float32), axis=idx, keepdims=True)
        r = jnp.broadcast_to(
            r, tuple(level_sizes) + shp[1:])
        return r.reshape(shp).astype(t.dtype)

    return jax.tree.map(one, tree)


def _mean_over_prefix(tree: PyTree, level_sizes: Sequence[int], upto: int
                      ) -> PyTree:
    """Average over levels 0..upto simultaneously (one fused collective)."""
    keep = len(level_sizes) - 1 - upto  # leading dims to keep

    def one(t):
        if t.ndim == 0 or jnp.issubdtype(t.dtype, jnp.integer):
            return t
        shp = t.shape
        r = t.reshape(tuple(level_sizes) + shp[1:])
        axes = tuple(range(keep, len(level_sizes)))
        r = jnp.mean(r.astype(jnp.float32), axis=axes, keepdims=True)
        r = jnp.broadcast_to(r, tuple(level_sizes) + shp[1:])
        return r.reshape(shp).astype(t.dtype)

    return jax.tree.map(one, tree)


def _masked_mean(tree: PyTree, mask: jax.Array, level_sizes: Sequence[int],
                 axes_idx: Tuple[int, ...]) -> PyTree:
    """Masked mean over sub-axes `axes_idx` of the replica factorization:
    participants get the mean of the participants in their group, absentees
    keep their own value (stale-snapshot rejoin)."""
    L = len(level_sizes)
    m = mask.astype(jnp.float32).reshape(tuple(level_sizes))

    def one(t):
        if t.ndim == 0 or jnp.issubdtype(t.dtype, jnp.integer):
            return t
        shp = t.shape
        r = t.reshape(tuple(level_sizes) + shp[1:]).astype(jnp.float32)
        mb = m.reshape(tuple(level_sizes) + (1,) * (len(shp) - 1))
        num = jnp.sum(r * mb, axis=axes_idx, keepdims=True)
        den = jnp.maximum(jnp.sum(mb, axis=axes_idx, keepdims=True), 1.0)
        mean = jnp.broadcast_to(num / den, tuple(level_sizes) + shp[1:])
        out = jnp.where(mb > 0.0, mean, r)
        return out.reshape(shp).astype(t.dtype)

    del L
    return jax.tree.map(one, tree)


def _masked_mean_over_level(tree: PyTree, mask: jax.Array,
                            level_sizes: Sequence[int], level: int) -> PyTree:
    idx = len(level_sizes) - 1 - level
    return _masked_mean(tree, mask, level_sizes, (idx,))


def _masked_mean_over_prefix(tree: PyTree, mask: jax.Array,
                             level_sizes: Sequence[int], upto: int) -> PyTree:
    keep = len(level_sizes) - 1 - upto
    return _masked_mean(tree, mask, level_sizes,
                        tuple(range(keep, len(level_sizes))))


# ---------------------------------------------------------------------------
# the step builder
# ---------------------------------------------------------------------------
def build_lm_step(cfg: ModelConfig, optimizer: Optimizer, *,
                  level_sizes: Tuple[int, ...], compression: str = "none",
                  average_opt_state: bool = True, masked: bool = False,
                  with_lr: bool = False) -> Callable:
    """Build the (unjitted) replica-stacked LM train step.

    Signature: ``step(state, batch, periods[, participation][, lr])``
    with ``periods`` a runtime (L,) int32 array (L = len(level_sizes)),
    ``participation`` a runtime (R,) float mask (masked=True only) and
    ``lr`` a traced scalar (with_lr=True only).
    """
    L = len(level_sizes)
    use_comp = comp_mod.spec_name(*comp_mod.parse_spec(compression)) != "none"
    compressor = comp_mod.get_compressor(compression) if use_comp else None

    def local_step(params, opt_state, batch, lr):
        def loss_fn(p):
            total, metrics = transformer.forward_train(cfg, p, batch)
            return total, metrics

        (_, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        if with_lr:
            params, opt_state = optimizer.update(
                params, grads, opt_state, lr=lr)
        else:
            params, opt_state = optimizer.update(params, grads, opt_state)
        return params, opt_state, metrics

    vstep = jax.vmap(local_step, in_axes=(0, 0, 0, None))

    def sync_level(params, opt_state, mask, level):
        if masked:
            params = _masked_mean_over_level(params, mask, level_sizes, level)
        else:
            params = _mean_over_level(params, level_sizes, level)
        if average_opt_state:
            def avg(t):
                if t.ndim == 0:
                    return t
                if masked:
                    return _masked_mean_over_level(
                        {"x": t}, mask, level_sizes, level)["x"]
                return _mean_over_level({"x": t}, level_sizes, level)["x"]

            opt_state = jax.tree.map(avg, opt_state)
        return params, opt_state

    def compressed_outer_sync(params, residual, mask):
        """Cross-outermost-level averaging of int8/topk-compressed deltas
        with error feedback. The anchor is the current inner-level mean
        (already identical within each outer group after the inner sync)."""
        if masked:
            inner_mean = _masked_mean_over_prefix(
                params, mask, level_sizes, L - 2) if L > 1 else params
        else:
            inner_mean = _mean_over_prefix(params, level_sizes, L - 2) \
                if L > 1 else params
        delta = jax.tree.map(lambda p, a: p.astype(jnp.float32) - a.astype(
            jnp.float32), params, inner_mean)
        wire, new_residual = compressor.compress(delta, residual)
        deq = compressor.decompress(wire)
        if masked:
            avg_delta = _masked_mean_over_level(deq, mask, level_sizes, L - 1)
            avg_inner = _masked_mean_over_level(
                inner_mean, mask, level_sizes, L - 1)
        else:
            avg_delta = _mean_over_level(deq, level_sizes, L - 1)
            avg_inner = _mean_over_level(inner_mean, level_sizes, L - 1)
        new_params = jax.tree.map(
            lambda a, d, p: (a.astype(jnp.float32) + d).astype(p.dtype),
            avg_inner, avg_delta, params)
        if masked:
            # absentees keep their pre-sync params and EF residual exactly
            def keep_own(new, old):
                mb = mask.reshape((-1,) + (1,) * (old.ndim - 1))
                return jnp.where(mb > 0.0, new, old)

            new_params = jax.tree.map(
                lambda n, o: keep_own(n, o) if o.ndim > 0 else n,
                new_params, params)
            new_residual = jax.tree.map(
                lambda n, o: keep_own(n, o) if o.ndim > 0 else n,
                new_residual, residual)
        return new_params, new_residual

    def step(state, batch, periods, participation=None, lr=None):
        params, opt_state, residual = (state.params, state.opt_state,
                                       state.residual)
        params, opt_state, metrics = vstep(params, opt_state, batch, lr)
        step_no = state.step + 1
        cum = jnp.cumprod(periods.astype(jnp.int32)) if L else None
        mask = participation

        for level in range(L):
            is_outer = level == L - 1
            due = (step_no % cum[level]) == 0

            if is_outer and use_comp:
                def do(ps, os, res):
                    ps, res = compressed_outer_sync(ps, res, mask)
                    return ps, os, res

                def skip(ps, os, res):
                    return ps, os, res

                params, opt_state, residual = jax.lax.cond(
                    due, do, skip, params, opt_state, residual)
            else:
                params, opt_state = jax.lax.cond(
                    due,
                    functools.partial(sync_level, mask=mask, level=level),
                    lambda ps, os: (ps, os),
                    params, opt_state)

        new_state = TreeSyncState(params=params, opt_state=opt_state,
                                  step=step_no, residual=residual)
        mmean = jax.tree.map(lambda m: jnp.mean(m, axis=0), metrics)
        return new_state, mmean

    return step


# ---------------------------------------------------------------------------
# cached executors (one compile per (config, variant); sweeps vmap on top)
# ---------------------------------------------------------------------------
_EXECUTOR_CACHE: Dict[Tuple, Callable] = {}
_CACHE_STATS = {"hits": 0, "misses": 0}


def present_axes(mesh: Mesh, sync_axes: Sequence[str]) -> Tuple[str, ...]:
    """Mesh axes actually present (size > 1), bottom-up (fastest first)."""
    return tuple(a for a in sync_axes
                 if a in mesh.axis_names and axis_size(mesh, a) > 1)


def level_sizes_for(mesh: Mesh, sync_axes: Sequence[str]) -> Tuple[int, ...]:
    """Replica-dim factorization (s_{L-1}, ..., s_0): outermost level
    first, matching the reshape order of the (R, ...) replica dim."""
    return tuple(axis_size(mesh, a)
                 for a in reversed(present_axes(mesh, sync_axes)))


def get_lm_executor(cfg: ModelConfig, optimizer: Optimizer, *,
                    level_sizes: Tuple[int, ...], compression: str = "none",
                    average_opt_state: bool = True, masked: bool = False,
                    with_lr: bool = False, batched: bool = False) -> Callable:
    """Memoized jitted LM step. ``batched=True`` returns the fused-sweep
    variant: state/batch/periods/lr gain a leading grid dim B via vmap
    (participation stays unbatched) -- one executor, one dispatch per grid.
    """
    key = (cfg, optimizer.name, optimizer.init, optimizer.update,
           tuple(level_sizes), compression, average_opt_state, masked,
           with_lr, batched)
    hit = key in _EXECUTOR_CACHE
    _CACHE_STATS["hits" if hit else "misses"] += 1
    if hit:
        return _EXECUTOR_CACHE[key]

    step = build_lm_step(cfg, optimizer, level_sizes=tuple(level_sizes),
                         compression=compression,
                         average_opt_state=average_opt_state, masked=masked,
                         with_lr=with_lr)
    if batched:
        # (B, R, ...) state, (R, ...) shared batch, (B, L) periods, (B,) lr
        step = jax.vmap(
            step, in_axes=(0, None, 0, None, 0 if with_lr else None))
    # the state carry is dead after each step -- donate it so XLA reuses the
    # parameter/opt-state buffers in place (callers that keep a reference,
    # e.g. warm_start, must copy before stepping)
    fn = jax.jit(step, donate_argnums=(0,))
    _EXECUTOR_CACHE[key] = fn
    return fn


def lm_executor_cache_stats() -> Dict[str, int]:
    return dict(_CACHE_STATS, size=len(_EXECUTOR_CACHE))


def clear_lm_executor_cache() -> None:
    _EXECUTOR_CACHE.clear()
    _CACHE_STATS.update(hits=0, misses=0)
