"""Procedure P: LocalSDCA at a leaf node, as a jit-able jax.lax loop.

Given the leaf's data block X (m_b x d), labels y, current dual block ``alpha``
and a w consistent with the *global* alpha (w = A alpha), performs H sequential
random-coordinate exact maximizations and returns (delta_alpha, delta_w).

The global problem size ``m_total`` (not the block size) enters through the
A-matrix scaling A_i = x_i/(lam * m_total).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.dual import Loss

Array = jax.Array


@functools.partial(  # analysis: allow(jit-outside-engine) reference local solver, jit'd standalone for tests/benchmarks
    jax.jit, static_argnames=("loss", "num_steps", "m_total", "step_size")
)
def local_sdca(
    X: Array,
    y: Array,
    alpha: Array,
    w: Array,
    key: Array,
    *,
    loss: Loss,
    lam: float,
    m_total: int,
    num_steps: int,
    step_size: float = 1.0,
) -> Tuple[Array, Array]:
    """Run H = num_steps coordinate steps; return (delta_alpha, delta_w)."""
    m_b = X.shape[0]
    lm = lam * m_total
    xsq_over_lm = jnp.sum(X * X, axis=1) / lm  # ||x_i||^2/(lam m), precomputed
    idx = jax.random.randint(key, (num_steps,), 0, m_b)

    def body(h, carry):
        alpha_c, w_c = carry
        i = idx[h]
        x_i = X[i]
        wx = jnp.dot(w_c, x_i)
        d = loss.coord_delta(wx, alpha_c[i], y[i], xsq_over_lm[i]) * step_size
        alpha_c = alpha_c.at[i].add(d)
        w_c = w_c + (d / lm) * x_i
        return (alpha_c, w_c)

    alpha_end, w_end = jax.lax.fori_loop(0, num_steps, body, (alpha, w))
    return alpha_end - alpha, w_end - w


def local_sdca_epochs(
    X: Array,
    y: Array,
    alpha: Array,
    w: Array,
    key: Array,
    *,
    loss: Loss,
    lam: float,
    m_total: int,
    epochs: int,
) -> Tuple[Array, Array]:
    """Convenience: H = epochs * m_b coordinate steps."""
    return local_sdca(
        X, y, alpha, w, key,
        loss=loss, lam=lam, m_total=m_total, num_steps=epochs * X.shape[0],
    )
