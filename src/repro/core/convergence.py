"""Convergence-rate calculators: Proposition 1, Theorem 1, Theorem 2.

The paper's Theorem 2 gives a *recursion*: a node Q with K children whose
geometric-improvement factors are Theta_1..Theta_K, run for T rounds, has

    Theta_Q = (1 - (1 - max_k Theta_k) * (1/K) * lam*m*gamma/(rho + lam*m*gamma))^T

with rho >= rho_min = max_alpha lam^2 m^2
        (sum_k ||A_[k] a_[k]||^2 - ||A_Q a_Q||^2) / ||a_Q||^2.

Leaves (Proposition 1):  Theta_leaf = (1 - (lam m gamma/(1+lam m gamma))/m_B)^H.

``tree_theta`` walks the tree bottom-up and returns the root's factor, i.e.
E[D* - D^(R)] <= Theta_root * (D* - D^(0)).
"""
from __future__ import annotations

from typing import Dict, Sequence

import numpy as np

from repro.core.tree import TreeNode


# ---------------------------------------------------------------------------
# rho_min: spectral quantity of the block decomposition
# ---------------------------------------------------------------------------
def rho_min(A: np.ndarray, blocks: Sequence[slice], lam: float, m: int) -> float:
    """Exact rho_min = lam^2 m^2 * lambda_max(blockdiag_k(A_k^T A_k) - A^T A).

    A is d x m (columns already scaled by 1/(lam m)); blocks partition columns.
    The matrix D - G (D = blockdiag of Gram blocks, G = full Gram) is PSD on
    the relevant subspace; we take the max eigenvalue (>= 0).
    """
    A = np.asarray(A)
    G = A.T @ A
    D = np.zeros_like(G)
    for sl in blocks:
        D[sl, sl] = G[sl, sl]
    evals = np.linalg.eigvalsh(D - G)
    return float(max(evals[-1], 0.0) * (lam * m) ** 2)


def rho_min_power(
    A: np.ndarray, blocks: Sequence[slice], lam: float, m: int,
    iters: int = 200, seed: int = 0,
) -> float:
    """Power-iteration estimate (for large m where eigh is infeasible).

    The operator M = D - G is indefinite; plain power iteration would find
    the largest-|.| eigenvalue, which may be the negative end. We iterate on
    the PSD shift M + sigma*I with sigma = ||A||_F^2 >= lambda_max(G) >=
    -lambda_min(M), then un-shift.
    """
    A = np.asarray(A)
    sigma = float(np.sum(A * A))  # ||A||_F^2 >= lambda_max(A^T A)
    rng = np.random.default_rng(seed)
    v = rng.normal(size=A.shape[1])
    v /= np.linalg.norm(v)
    lam_est = 0.0
    for _ in range(iters):
        # (D - G + sigma I) v  without materializing G
        Gv = A.T @ (A @ v)
        Dv = np.zeros_like(v)
        for sl in blocks:
            Dv[sl] = A[:, sl].T @ (A[:, sl] @ v[sl])
        u = Dv - Gv + sigma * v
        n = np.linalg.norm(u)
        if n < 1e-30:
            return 0.0
        lam_est = float(v @ u)  # Rayleigh quotient of the shifted operator
        v = u / n
    return float(max(lam_est - sigma, 0.0) * (lam * m) ** 2)


# ---------------------------------------------------------------------------
# Proposition 1 / Theorem 1 factors
# ---------------------------------------------------------------------------
def leaf_theta(lam: float, m: int, gamma: float, m_block: int, H: int) -> float:
    """Prop. 1: Theta = (1 - (lam m gamma/(1+lam m gamma)) / m_B)^H."""
    c = lam * m * gamma / (1.0 + lam * m * gamma)
    return float((1.0 - c / m_block) ** H)


def sdca_theta(s: float, m_tilde: int, H: int) -> float:
    """Theorem 1 / eq. (4): Theta = (1 - s/m~)^H, step size s in [0,1]."""
    return float((1.0 - s / m_tilde) ** H)


def node_theta(
    child_thetas: Sequence[float], lam: float, m: int, gamma: float,
    rho: float, T: int,
) -> float:
    """Theorem 2: the parent's geometric factor after T rounds."""
    K = len(child_thetas)
    theta = max(child_thetas)
    c = lam * m * gamma / (rho + lam * m * gamma)
    per_round = 1.0 - (1.0 - theta) * c / K
    return float(per_round**T)


def star_rate(
    lam: float, m: int, gamma: float, rho: float, K: int, theta_local: float,
    T: int,
) -> float:
    """Theorem 1 / eq. (3) end-to-end factor for a star after T rounds."""
    return node_theta([theta_local] * K, lam, m, gamma, rho, T)


# ---------------------------------------------------------------------------
# Theorem 2 recursion over a whole tree
# ---------------------------------------------------------------------------
def tree_theta(
    tree: TreeNode,
    A: np.ndarray,
    lam: float,
    gamma: float,
    *,
    rho_by_node: Dict[str, float] | None = None,
    use_power_iteration: bool = False,
) -> float:
    """Bottom-up Theorem-2 recursion; returns the root's overall factor.

    ``A`` is the scaled d x m data matrix; rho at each internal node is the
    exact (or power-iteration) rho_min of its children's block decomposition,
    overridable via ``rho_by_node``.
    """
    m = tree.total_data()
    slices = dict(tree.leaf_slices())

    def node_slice(n: TreeNode) -> slice:
        ls = n.leaves()
        return slice(slices[ls[0].name].start, slices[ls[-1].name].stop)

    def rec(n: TreeNode) -> float:
        if n.is_leaf:
            return leaf_theta(lam, m, gamma, n.data_size, n.rounds)
        thetas = [rec(c) for c in n.children]
        if rho_by_node and n.name in rho_by_node:
            rho = rho_by_node[n.name]
        else:
            child_blocks = [node_slice(c) for c in n.children]
            fn = rho_min_power if use_power_iteration else rho_min
            rho = fn(A, child_blocks, lam, m)
        return node_theta(thetas, lam, m, gamma, rho, n.rounds)

    return rec(tree)


def predicted_gap_curve(theta_per_round: float, initial_gap: float,
                        rounds: int) -> np.ndarray:
    """E[D* - D^(t)] <= theta^t (D* - D^(0)) for t = 0..rounds."""
    t = np.arange(rounds + 1)
    return initial_gap * theta_per_round**t
