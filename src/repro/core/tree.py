"""Tree-network topology description for TreeDualMethod.

A TreeNode is either a leaf (owns a contiguous block of data columns) or an
internal node with K children. Every node carries:
  * ``rounds``   -- T (internal; R at the root) or H (leaf: # LocalSDCA steps)
  * ``up_delay`` -- round-trip communication delay to its *parent* (seconds)
  * ``t_cp``     -- computation time of one aggregation at this node (internal)
  * ``t_lp``     -- computation time of one coordinate step (leaf)
  * ``up_compress`` -- delta-compression spec of the up-link to the parent
    (``""`` inherits the schedule's per-level default; otherwise ``"none"``,
    ``"int8"``, ``"topk"`` or ``"topk_<frac>"`` -- see
    ``repro.core.compression``)

Data assignment: leaves, in left-to-right order, own contiguous column blocks
whose sizes are given by ``data_size`` (leaf-only).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class TreeNode:
    name: str
    children: Tuple["TreeNode", ...] = ()
    rounds: int = 1
    up_delay: float = 0.0
    t_cp: float = 0.0
    t_lp: float = 0.0
    data_size: int = 0  # leaves only
    up_compress: str = ""  # per-edge compression override ("" = inherit)

    @property
    def is_leaf(self) -> bool:
        return not self.children

    # ---- structure -----------------------------------------------------
    def leaves(self) -> List["TreeNode"]:
        if self.is_leaf:
            return [self]
        out: List[TreeNode] = []
        for c in self.children:
            out.extend(c.leaves())
        return out

    def depth(self) -> int:
        if self.is_leaf:
            return 0
        return 1 + max(c.depth() for c in self.children)

    def total_data(self) -> int:
        return sum(l.data_size for l in self.leaves())

    def leaf_slices(self, start: int = 0) -> List[Tuple[str, slice]]:
        """(leaf name, column slice) pairs, left-to-right contiguous blocks."""
        out: List[Tuple[str, slice]] = []
        off = start
        for l in self.leaves():
            out.append((l.name, slice(off, off + l.data_size)))
            off += l.data_size
        return out

    # ---- timing (paper SS6 generalized to trees) -------------------------
    def round_time(self) -> float:
        """Wall-clock cost of ONE round at this node.

        leaf:     H * t_lp
        internal: max_k (child_k.round_time()*child_k.rounds + child_k.up_delay)
                  + t_cp
        Children run in parallel; the synchronous barrier waits for the
        slowest child including its uplink delay (paper eq. (9) when the
        tree is a star: H*t_lp + t_delay + t_cp).
        """
        if self.is_leaf:
            return self.rounds * self.t_lp
        slowest = max(c.round_time() * 1.0 + c.up_delay for c in self.children)
        return slowest + self.t_cp

    def child_phase_time(self) -> float:
        """Time for one *full child solve* (child rounds included)."""
        if self.is_leaf:
            return self.round_time()
        return (
            max(c.child_phase_time() * c.rounds_if_internal() + c.up_delay
                for c in self.children)
            + self.t_cp
        )

    def rounds_if_internal(self) -> int:
        # A leaf's "rounds" are its H coordinate steps, already inside
        # round_time(); an internal child re-runs its T rounds per parent call.
        return 1 if self.is_leaf else self.rounds

    def solve_time(self) -> float:
        """Total wall-clock for one full invocation of TreeDualMethod here."""
        if self.is_leaf:
            return self.rounds * self.t_lp
        per_round = (
            max(c.solve_time() + c.up_delay for c in self.children) + self.t_cp
        )
        return self.rounds * per_round


# ---------------------------------------------------------------------------
# constructors
# ---------------------------------------------------------------------------
def star(
    n_workers: int,
    m_per_worker: int,
    *,
    outer_rounds: int,
    local_steps: int,
    t_lp: float = 0.0,
    t_cp: float = 0.0,
    t_delay: float = 0.0,
) -> TreeNode:
    """The CoCoA star network (paper Fig. 1 / Algorithm 1)."""
    workers = tuple(
        TreeNode(
            name=f"W{k}", rounds=local_steps, up_delay=t_delay,
            t_lp=t_lp, data_size=m_per_worker,
        )
        for k in range(n_workers)
    )
    return TreeNode(name="root", children=workers, rounds=outer_rounds, t_cp=t_cp)


def two_level(
    n_groups: int,
    workers_per_group: int,
    m_per_worker: int,
    *,
    root_rounds: int,
    group_rounds: int,
    local_steps: int,
    t_lp: float = 0.0,
    t_cp: float = 0.0,
    root_delay: float = 0.0,
    group_delay: float = 0.0,
) -> TreeNode:
    """Paper Fig. 2: root -> sub-centers S_i -> workers W_ij."""
    groups = []
    for g in range(n_groups):
        ws = tuple(
            TreeNode(
                name=f"W{g}{j}", rounds=local_steps, up_delay=group_delay,
                t_lp=t_lp, data_size=m_per_worker,
            )
            for j in range(workers_per_group)
        )
        groups.append(
            TreeNode(
                name=f"S{g}", children=ws, rounds=group_rounds,
                up_delay=root_delay, t_cp=t_cp,
            )
        )
    return TreeNode(name="root", children=tuple(groups), rounds=root_rounds,
                    t_cp=t_cp)


def strip_delays(node: TreeNode) -> TreeNode:
    """A copy of the tree with every up-link delay zeroed: its
    ``solve_time`` is the compute-only component of a round, the base the
    straggler simulation adds sampled link delays on top of."""
    kids = tuple(strip_delays(c) for c in node.children)
    return dataclasses.replace(node, children=kids, up_delay=0.0)


def with_rounds(node: TreeNode, *, leaf_steps: Optional[int] = None,
                internal_rounds: Optional[int] = None) -> TreeNode:
    """Return a copy of the tree with round counts replaced."""
    if node.is_leaf:
        r = leaf_steps if leaf_steps is not None else node.rounds
        return dataclasses.replace(node, rounds=r)
    kids = tuple(
        with_rounds(c, leaf_steps=leaf_steps, internal_rounds=internal_rounds)
        for c in node.children
    )
    r = internal_rounds if internal_rounds is not None else node.rounds
    return dataclasses.replace(node, children=kids, rounds=r)
