"""TreeDualMethod (paper Algorithms 1-3): distributed dual coordinate ascent
over an arbitrary tree network.

:func:`tree_dual_solve` and :func:`cocoa_star_solve` are DEPRECATED thin
shims over the sessionized API (``repro.api``): prefer

    Session.compile(Problem(X, y, loss=..., lam=...),
                    Topology.from_tree(tree)).run(key=...)

which exposes the same compiled engine plus warm restarts, streamed
history, and the ``rounds="auto"`` delay planner (``docs/api.md`` has the
migration table).

The original host-side Python recursion is retained verbatim as
:func:`tree_dual_solve_reference` -- it is the cross-check oracle in the
tests (the engine replays its key derivation, so both produce the same
iterates up to float reassociation) and the baseline in
``benchmarks/bench_engine.py``.  The recursion is exact Algorithm 2:

    for t = 1..T:
        for children k = 1..K in parallel:
            (da_k, dw_k) = TreeDualMethod(child_k, alpha_[k], w)
            alpha_[k] += da_k / K
        w += (1/K) sum_k dw_k

Leaves run Procedure P (repro.core.local_sdca).  The root (Algorithm 3)
starts from alpha = 0, w = 0 and records a (simulated_time, dual, gap)
history using the tree's delay model (``repro.core.instrument``).
"""
from __future__ import annotations

import warnings
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import dual as dual_mod
from repro.core.dual import Loss
from repro.core.instrument import (SolveResult, per_round_time,  # noqa: F401
                                   record_round)
from repro.core.local_sdca import local_sdca
from repro.core.tree import TreeNode

Array = jax.Array


def tree_dual_solve(
    tree: TreeNode,
    X: Array,
    y: Array,
    *,
    loss: Loss,
    lam: float,
    key: Optional[Array] = None,
    record_history: bool = True,
    backend: str = "vmap",
    weighting: str = "uniform",
) -> SolveResult:
    """DEPRECATED shim: Algorithm 3 at the root of ``tree``, routed through
    ``repro.api`` (Problem/Topology/Schedule/Session)."""
    warnings.warn(
        "tree_dual_solve is a legacy shim; use repro.api.Session "
        "(Problem/Topology/Schedule) instead", DeprecationWarning,
        stacklevel=2)
    from repro import api
    return api.solve(
        api.Problem(X, y, loss=loss, lam=lam),
        api.Topology.from_tree(tree),
        api.Schedule(weighting=weighting),
        backend=backend, key=key, record_history=record_history)


def cocoa_star_solve(
    X: Array,
    y: Array,
    n_workers: int,
    *,
    loss: Loss,
    lam: float,
    outer_rounds: int,
    local_steps: int,
    key: Optional[Array] = None,
    t_lp: float = 0.0,
    t_cp: float = 0.0,
    t_delay: float = 0.0,
) -> SolveResult:
    """DEPRECATED shim: Algorithm 1 (CoCoA) as the star special case --
    identical to the sessionized API on a depth-1 star (tested
    bit-for-bit).  Use ``Topology.star`` + ``Session`` instead."""
    warnings.warn(
        "cocoa_star_solve is a legacy shim; use repro.api.Session with "
        "Topology.star instead", DeprecationWarning, stacklevel=2)
    from repro import api

    m = X.shape[0]
    assert m % n_workers == 0, "even split expected (paper setup)"
    topo = api.Topology.star(
        n_workers, m // n_workers, rounds=outer_rounds,
        local_steps=local_steps, t_lp=t_lp, t_cp=t_cp, t_delay=t_delay)
    return api.solve(api.Problem(X, y, loss=loss, lam=lam), topo, key=key)


# ---------------------------------------------------------------------------
# Legacy host recursion: retained as the engine's cross-check oracle.
# ---------------------------------------------------------------------------
def _solve_node(
    node: TreeNode,
    slices: Dict[str, slice],
    X: Array,
    y: Array,
    alpha: Array,
    w: Array,
    key: Array,
    *,
    loss: Loss,
    lam: float,
    m_total: int,
    node_slice: slice,
) -> Tuple[Array, Array]:
    """Return (new_alpha_full, new_w) after running `node.rounds` rounds.

    Only coordinates inside ``node_slice`` are modified. ``w`` stays globally
    consistent: w = A alpha throughout.
    """
    if node.is_leaf:
        sl = slices[node.name]
        da, dw = local_sdca(
            X[sl], y[sl], alpha[sl], w, key,
            loss=loss, lam=lam, m_total=m_total, num_steps=node.rounds,
        )
        return alpha.at[sl].add(da), w + dw

    K = len(node.children)
    for _t in range(node.rounds):
        key, *subkeys = jax.random.split(key, 1 + K)
        dws = []
        new_alpha = alpha
        for k, child in enumerate(node.children):
            csl = (
                slices[child.name]
                if child.is_leaf
                else slice(
                    slices[child.leaves()[0].name].start,
                    slices[child.leaves()[-1].name].stop,
                )
            )
            a_k, w_k = _solve_node(
                child, slices, X, y, alpha, w, subkeys[k],
                loss=loss, lam=lam, m_total=m_total, node_slice=csl,
            )
            # child returns full vectors; extract its delta
            da_k = a_k[csl] - alpha[csl]
            dw_k = w_k - w
            new_alpha = new_alpha.at[csl].add(da_k / K)
            dws.append(dw_k)
        alpha = new_alpha
        w = w + sum(dws) / K
    return alpha, w


def tree_dual_solve_reference(
    tree: TreeNode,
    X: Array,
    y: Array,
    *,
    loss: Loss,
    lam: float,
    key: Optional[Array] = None,
    record_history: bool = True,
) -> SolveResult:
    """The original O(tree x rounds) Python-dispatch recursion (oracle)."""
    m = X.shape[0]
    assert tree.total_data() == m, (
        f"tree data sizes {tree.total_data()} != m={m}"
    )
    slices = dict(tree.leaf_slices())
    if key is None:
        key = jax.random.PRNGKey(0)

    alpha = jnp.zeros((m,), dtype=X.dtype)
    w = jnp.zeros((X.shape[1],), dtype=X.dtype)

    # one root round's simulated wall-clock (children in parallel, barrier)
    per_round = per_round_time(tree)

    history: list = []

    def record(t: int):
        if not record_history:
            return
        dv = float(dual_mod.dual_value(alpha, X, y, loss, lam))
        pv = float(
            dual_mod.primal_value(
                dual_mod.w_of_alpha(alpha, X, lam), X, y, loss, lam
            )
        )
        record_round(history, t, t * per_round, dv, pv)

    record(0)
    K = len(tree.children)
    for t in range(1, tree.rounds + 1):
        key, *subkeys = jax.random.split(key, 1 + K)
        dws = []
        new_alpha = alpha
        for k, child in enumerate(tree.children):
            csl = (
                slices[child.name]
                if child.is_leaf
                else slice(
                    slices[child.leaves()[0].name].start,
                    slices[child.leaves()[-1].name].stop,
                )
            )
            a_k, w_k = _solve_node(
                child, slices, X, y, alpha, w, subkeys[k],
                loss=loss, lam=lam, m_total=m, node_slice=csl,
            )
            new_alpha = new_alpha.at[csl].add((a_k[csl] - alpha[csl]) / K)
            dws.append(w_k - w)
        alpha = new_alpha
        w = w + sum(dws) / K
        record(t)

    return SolveResult(alpha=alpha, w=w, history=history, lam=lam)
