"""Primal/dual objectives for regularized loss minimization (paper eq. (1)-(2)).

Primal:  min_w  P(w) = (lam/2)||w||^2 + (1/m) sum_i l_i(w^T x_i)
Dual:    max_a  D(a) = -(lam/2)||A a||^2 - (1/m) sum_i l*_i(-a_i),
         A_i = x_i / (lam * m),   w(a) = A a.

Each supported loss provides:
  * ``value(a, y)``          -- l_i(a)
  * ``conj_neg(alpha, y)``   -- l*_i(-alpha) (the term appearing in D)
  * ``coord_delta(wx, alpha, y, xsq_over_lm)``
        closed-form (or Newton) maximizer of the Procedure-P scalar subproblem
            max_d  -(lam m / 2)||w + d x_i/(lam m)||^2 - l*(-(alpha + d))
        where ``wx = w . x_i`` and ``xsq_over_lm = ||x_i||^2 / (lam m)``.
  * ``gamma``                -- smoothness: l is (1/gamma)-smooth (0 => non-smooth)
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class Loss:
    name: str
    value: Callable[[Array, Array], Array]
    conj_neg: Callable[[Array, Array], Array]
    coord_delta: Callable[[Array, Array, Array, Array], Array]
    gamma: float


# -----------------------------------------------------------------------------
# squared loss (ridge regression):  l(a) = (a - y)^2 / 2
#   l*(b) = b^2/2 + b y        =>  l*(-alpha) = alpha^2/2 - alpha y
#   argmax_d: d = (y - wx - alpha) / (1 + xsq_over_lm)
# -----------------------------------------------------------------------------
def _sq_value(a, y):
    return 0.5 * (a - y) ** 2


def _sq_conj_neg(alpha, y):
    return 0.5 * alpha**2 - alpha * y


def _sq_coord_delta(wx, alpha, y, xsq_over_lm):
    return (y - wx - alpha) / (1.0 + xsq_over_lm)


squared = Loss("squared", _sq_value, _sq_conj_neg, _sq_coord_delta, gamma=1.0)


# -----------------------------------------------------------------------------
# hinge loss (SVM):  l(a) = max(0, 1 - y a),  y in {-1, +1}
#   l*(-alpha) = -alpha y   for alpha y in [0, 1]  (+inf otherwise)
#   SDCA closed form: u = y - wx? standard update (Shalev-Shwartz & Zhang '13):
#     q = (1 - y wx) / xsq_over_lm + alpha y
#     alpha_new = y * clip(q, 0, 1);  d = alpha_new - alpha
# -----------------------------------------------------------------------------
def _hinge_value(a, y):
    return jnp.maximum(0.0, 1.0 - y * a)


def _hinge_conj_neg(alpha, y):
    # -alpha*y on the feasible set; feasibility is maintained by the update.
    return -alpha * y


def _hinge_coord_delta(wx, alpha, y, xsq_over_lm):
    q = (1.0 - y * wx) / jnp.maximum(xsq_over_lm, 1e-12) + alpha * y
    return y * jnp.clip(q, 0.0, 1.0) - alpha


hinge = Loss("hinge", _hinge_value, _hinge_conj_neg, _hinge_coord_delta, gamma=0.0)


# -----------------------------------------------------------------------------
# smoothed hinge (gamma-smoothed; Shalev-Shwartz & Zhang '13 eq. for smooth SDCA)
#   l(a) = 0                     if y a >= 1
#        = 1 - y a - g/2         if y a <= 1 - g
#        = (1 - y a)^2 / (2 g)   otherwise
#   l*(-alpha) = -alpha y + (g/2)(alpha y)^2   for alpha y in [0, 1]
#   closed form: q = (1 - y wx - g alpha y)/(xsq_over_lm + g) + alpha y
# -----------------------------------------------------------------------------
def _make_smooth_hinge(g: float) -> Loss:
    def value(a, y):
        z = 1.0 - y * a
        return jnp.where(
            z <= 0.0, 0.0, jnp.where(z >= g, z - g / 2.0, z**2 / (2.0 * g))
        )

    def conj_neg(alpha, y):
        ay = alpha * y
        return -ay + (g / 2.0) * ay**2

    def coord_delta(wx, alpha, y, xsq_over_lm):
        q = (1.0 - y * wx - g * alpha * y) / (xsq_over_lm + g) + alpha * y
        return y * jnp.clip(q, 0.0, 1.0) - alpha

    return Loss(f"smooth_hinge_{g:g}", value, conj_neg, coord_delta, gamma=g)


smooth_hinge = _make_smooth_hinge(1.0)
make_smooth_hinge = _make_smooth_hinge


# -----------------------------------------------------------------------------
# logistic loss:  l(a) = log(1 + exp(-y a))
#   l*(-alpha): finite for alpha y in [0,1]:
#      with u = alpha y:  l*(-alpha) = u log u + (1-u) log(1-u)
#   no closed form coordinate max -> damped Newton on the scalar dual.
# -----------------------------------------------------------------------------
def _log_value(a, y):
    return jnp.logaddexp(0.0, -y * a)


def _xlogx(u):
    return jnp.where(u > 0.0, u * jnp.log(jnp.maximum(u, 1e-30)), 0.0)


def _log_conj_neg(alpha, y):
    u = jnp.clip(alpha * y, 0.0, 1.0)
    return _xlogx(u) + _xlogx(1.0 - u)


def _log_coord_delta(wx, alpha, y, xsq_over_lm, newton_steps: int = 8):
    # maximize  f(d) = -(1/2) xsq_over_lm d^2 - wx d - l*(-(alpha+d))
    # substitute u = (alpha + d) y in (0,1):
    #   f'(d) = -xsq_over_lm d - wx + y log((1-u)/u) ... derivative of -l*(-(alpha+d))
    eps = 1e-6

    def body(_, d):
        u = jnp.clip((alpha + d) * y, eps, 1.0 - eps)
        grad = -xsq_over_lm * d - wx - y * (jnp.log(u) - jnp.log(1.0 - u))
        hess = -xsq_over_lm - 1.0 / (u * (1.0 - u))
        step = grad / hess
        d_new = d - step
        # keep iterate strictly feasible
        u_new = (alpha + d_new) * y
        d_new = jnp.where(
            (u_new <= 0.0) | (u_new >= 1.0),
            (jnp.clip(u_new, eps, 1.0 - eps)) * y - alpha,
            d_new,
        )
        return d_new

    d0 = (jnp.clip(alpha * y, 0.25, 0.75)) * y - alpha  # start inside the domain
    return jax.lax.fori_loop(0, newton_steps, body, d0)


logistic = Loss("logistic", _log_value, _log_conj_neg, _log_coord_delta, gamma=0.25)

LOSSES = {l.name: l for l in (squared, hinge, smooth_hinge, logistic)}


def register_loss(loss: Loss) -> Loss:
    """Add ``loss`` to the by-name registry (idempotent for equal names)."""
    LOSSES[loss.name] = loss
    return loss


def get_loss(loss) -> Loss:
    """Resolve a loss from a :class:`Loss` instance or a registry name.

    Names are the registry keys (``squared``, ``hinge``, ``logistic``,
    ``smooth_hinge_1``); the parametric family ``smooth_hinge_<g>`` is
    constructed (and registered) on demand, e.g. ``smooth_hinge_0.5``.
    """
    if isinstance(loss, Loss):
        return loss
    if not isinstance(loss, str):
        raise TypeError(f"loss must be a Loss or a name, got {type(loss)}")
    if loss in LOSSES:
        return LOSSES[loss]
    if loss.startswith("smooth_hinge_"):
        g = float(loss[len("smooth_hinge_"):])
        if g <= 0:
            raise ValueError(f"smooth_hinge smoothing must be > 0, got {g}")
        return register_loss(_make_smooth_hinge(g))
    raise KeyError(
        f"unknown loss {loss!r}; registered: {sorted(LOSSES)} "
        "(or parametric 'smooth_hinge_<g>')")


# -----------------------------------------------------------------------------
# Objectives
# -----------------------------------------------------------------------------
def data_matrix(X: Array, lam: float) -> Array:
    """A (d x m) with columns x_i/(lam m) from row-major X (m x d)."""
    m = X.shape[0]
    return X.T / (lam * m)


def primal_value(w: Array, X: Array, y: Array, loss: Loss, lam: float) -> Array:
    margins = X @ w
    return 0.5 * lam * jnp.dot(w, w) + jnp.mean(loss.value(margins, y))


def dual_value(alpha: Array, X: Array, y: Array, loss: Loss, lam: float) -> Array:
    m = X.shape[0]
    w = (X.T @ alpha) / (lam * m)  # w(alpha) = A alpha
    return -0.5 * lam * jnp.dot(w, w) - jnp.mean(loss.conj_neg(alpha, y))


def w_of_alpha(alpha: Array, X: Array, lam: float) -> Array:
    m = X.shape[0]
    return (X.T @ alpha) / (lam * m)


def duality_gap(alpha: Array, X: Array, y: Array, loss: Loss, lam: float) -> Array:
    w = w_of_alpha(alpha, X, lam)
    return primal_value(w, X, y, loss, lam) - dual_value(alpha, X, y, loss, lam)


def ridge_dual_optimum(X: Array, y: Array, lam: float) -> Array:
    """Closed-form dual optimum for the squared loss: (lam m A^T A + I) a = y."""
    m = X.shape[0]
    A = data_matrix(X, lam)
    G = lam * m * (A.T @ A) + jnp.eye(m, dtype=X.dtype)
    return jnp.linalg.solve(G, y)
