"""TreeDualMethod ON THE MESH: the paper's Algorithms 1-3 executed as a
sharded device program (shard_map + jax.lax collectives), with the leaf
solver running the Pallas blocked-SDCA kernel on each shard.

The tree is the mesh-axis hierarchy itself:

  leaves         = devices along the innermost sync axis (e.g. "data"),
                   each owning a contiguous block of the dual vector;
  level-l node   = the group of devices sharing coordinates on the axes
                   above axis l;
  level-l round  = H_l leaf solves + psum-averaging of delta_w over axis l.

E.g. axes=("data", "pod"), rounds=(3, R): each cross-pod round runs 3
intra-pod rounds (w averaged over "data" only -- pods evolve independent
w's), then averages w over "pod" -- exactly Algorithm 2 nesting with
K = axis size at each level, w-consistency w = A alpha preserved
throughout (tested).

Math note: with disjoint coordinate blocks, averaging *delta_w* with weight
1/K at every level while applying each worker's *own* delta_alpha scaled by
the same product of 1/K factors keeps w = A alpha exactly -- this is the
zero-padding argument in the paper's eq. (13).
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.dual import Loss
from repro.kernels.sdca.kernel import sdca_block_kernel

Array = jax.Array


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except RuntimeError:
        return False


def mesh_tree_dual_solve(
    X: Array,                    # (m, d) global data (rows = examples)
    y: Array,                    # (m,)
    mesh: Mesh,
    *,
    loss: Loss,
    lam: float,
    axes: Sequence[str] = ("data",),   # innermost (leaf) level first
    rounds: Sequence[int] = (10,),     # rounds per level, aligned to axes
    local_steps: int = 64,             # H at the leaves
    key: Optional[Array] = None,
    use_kernel: bool = True,
) -> Tuple[Array, Array]:
    """Run the full nested schedule; returns (alpha (m,), w (d,))."""
    assert len(axes) == len(rounds)
    m, d = X.shape
    sizes = [dict(mesh.shape)[a] for a in axes]
    n_leaves = 1
    for s in sizes:
        n_leaves *= s
    assert m % n_leaves == 0, (m, n_leaves)
    m_b = m // n_leaves
    if key is None:
        key = jax.random.PRNGKey(0)
    lm = lam * m

    # block layout: leaf (i_outer, ..., i_inner) owns block index
    # i_outer*inner_sizes + ... (row-major over reversed axes)
    Xb = X.reshape(n_leaves, m_b, d)
    yb = y.reshape(n_leaves, m_b)

    spec_in = P(tuple(reversed(axes)))  # leading block dim over all levels

    def leaf_solve(X_blk, y_blk, a_blk, w, k):
        """One LocalSDCA call on this leaf's block (shapes (1, m_b, ...))."""
        idx = jax.random.randint(k, (1, local_steps), 0, m_b)
        if use_kernel:
            da, dw = sdca_block_kernel(X_blk, y_blk, a_blk, w, idx,
                                       loss=loss, lm=lm,
                                       interpret=not _on_tpu())
        else:
            from repro.kernels.sdca.ref import sdca_block_ref
            da, dw = sdca_block_ref(X_blk, y_blk, a_blk, w, idx,
                                    loss=loss, lm=lm)
        return da, dw[0]

    def solve_level(level, X_blk, y_blk, a_blk, w, k):
        """Run `rounds[level]` rounds at `level`; each round recurses below
        then averages delta-w over this level's axis (Algorithm 2)."""
        axis = axes[level]
        K = sizes[level]
        T = rounds[level]

        def one_round(t, carry):
            a_c, w_c = carry
            kt = jax.random.fold_in(k, (level + 1) * 100003 + t)
            if level == 0:
                da, dw = leaf_solve(X_blk, y_blk, a_c, w_c, kt)
            else:
                a_lo, w_lo = solve_level(level - 1, X_blk, y_blk, a_c, w_c,
                                         kt)
                da, dw = a_lo - a_c, w_lo - w_c
            # Algorithm 2 updates: alpha_[k] += da/K ; w += psum(dw)/K
            a_c = a_c + da / K
            w_c = w_c + jax.lax.psum(dw, axis) / K
            return a_c, w_c

        return jax.lax.fori_loop(0, T, one_round, (a_blk, w))

    @functools.partial(
        jax.shard_map, mesh=mesh,
        in_specs=(spec_in, spec_in, spec_in, P()),
        out_specs=(spec_in, P()),
        check_vma=False,
    )
    def program(Xs, ys, a0, w0):
        # per-leaf rng: fold in this leaf's linear index
        lin = jnp.int32(0)
        for a in reversed(axes):
            lin = lin * dict(mesh.shape)[a] + jax.lax.axis_index(a)
        k_leaf = jax.random.fold_in(key, lin)
        a_end, w_end = solve_level(len(axes) - 1, Xs, ys, a0, w0, k_leaf)
        return a_end, w_end

    a0 = jnp.zeros((n_leaves, m_b), X.dtype)
    w0 = jnp.zeros((d,), X.dtype)
    Xs = jax.device_put(Xb, NamedSharding(mesh, spec_in))
    ys = jax.device_put(yb, NamedSharding(mesh, spec_in))
    alpha, w = jax.jit(program)(Xs, ys, a0, w0)
    return alpha.reshape(m), w
