"""TreeDualMethod ON THE MESH: the paper's Algorithms 1-3 executed as a
sharded device program, now expressed as the unified engine's ``shard_map``
backend (``repro.core.engine.mesh``) with the leaf solver running the Pallas
blocked-SDCA kernel on each shard.

The tree is the mesh-axis hierarchy itself:

  leaves         = devices along the innermost sync axis (e.g. "data"),
                   each owning a contiguous block of the dual vector;
  level-l node   = the group of devices sharing coordinates on the axes
                   above axis l;
  level-l round  = H_l leaf solves + psum-averaging of delta_w over axis l.

E.g. axes=("data", "pod"), rounds=(3, R): each cross-pod round runs 3
intra-pod rounds (w averaged over "data" only -- pods evolve independent
w's), then averages w over "pod" -- exactly Algorithm 2 nesting with
K = axis size at each level, w-consistency w = A alpha preserved
throughout (tested).

Math note: with disjoint coordinate blocks, averaging *delta_w* with weight
1/K at every level while applying each worker's *own* delta_alpha scaled by
the same product of 1/K factors keeps w = A alpha exactly -- this is the
zero-padding argument in the paper's eq. (13).

Because the mesh backend consumes the same compiled plan (and the same
legacy-RNG coordinate replay) as the host backend, ``mesh_tree_dual_solve``
produces the same iterates as ``tree_dual_solve`` on the equivalent
balanced tree, up to float reassociation.
"""
from __future__ import annotations

import warnings
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh

from repro.core.dual import Loss
from repro.core.engine.mesh import tree_from_mesh_axes  # noqa: F401

Array = jax.Array


def mesh_tree_dual_solve(
    X: Array,                    # (m, d) global data (rows = examples)
    y: Array,                    # (m,)
    mesh: Mesh,
    *,
    loss: Loss,
    lam: float,
    axes: Sequence[str] = ("data",),   # innermost (leaf) level first
    rounds: Sequence[int] = (10,),     # rounds per level, aligned to axes
    local_steps: int = 64,             # H at the leaves
    key: Optional[Array] = None,
    use_kernel: bool = True,
) -> Tuple[Array, Array]:
    """DEPRECATED shim: the mesh program behind the sessionized surface --
    ``Session.compile(..., backend="mesh", mesh=mesh)``.  Returns
    (alpha (m,), w (d,))."""
    warnings.warn(
        "mesh_tree_dual_solve is a legacy shim; use repro.api.Session with "
        "backend='mesh' instead", DeprecationWarning, stacklevel=2)
    from repro import api
    assert len(axes) == len(rounds)
    m, _ = X.shape
    sizes = [dict(mesh.shape)[a] for a in axes]
    n_leaves = 1
    for s in sizes:
        n_leaves *= s
    assert m % n_leaves == 0, (m, n_leaves)
    m_b = m // n_leaves

    tree = tree_from_mesh_axes(mesh, axes, rounds,
                               local_steps=local_steps, m_leaf=m_b)
    res = api.solve(
        api.Problem(X, y, loss=loss, lam=lam),
        api.Topology.from_tree(tree),
        backend="mesh", mesh=mesh, mesh_axes=tuple(axes), key=key,
        mesh_use_kernel=use_kernel, record_history=False)
    return res.alpha, res.w
