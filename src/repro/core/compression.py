"""Gradient/delta compression for the slow cross-pod hop (beyond-paper).

Two schemes, both with error feedback (the residual of the compression is
added back into the next message, so the compression error does not
accumulate -- Seide et al. 2014 / Stich et al. 2018):

  * int8 per-tensor blockwise quantization (32x1 blocks, absmax scaling):
    4x fewer bytes than f32 over the wire.
  * top-k magnitude sparsification: send the k largest-|.| entries.

Both are pure jax (no host callbacks) so they live inside the jitted
TreeSync step; the dry-run sees the reduced collective bytes directly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any

BLOCK = 32


# ---------------------------------------------------------------------------
# int8 blockwise
# ---------------------------------------------------------------------------
def quantize_int8(x: Array, keep_leading: int = 0) -> Tuple[Array, Array]:
    """x (float) -> (int8 codes, f32 block scales). Blocks along the last
    dim. ``keep_leading`` preserves that many leading dims un-flattened --
    essential under GSPMD when dim 0 is a mesh-sharded replica dim (mixing
    it into blocks forces a full cross-replica reshard)."""
    lead = x.shape[:keep_leading]
    flat = x.astype(jnp.float32).reshape(lead + (-1,))
    pad = (-flat.shape[-1]) % BLOCK
    flat = jnp.pad(flat, [(0, 0)] * keep_leading + [(0, pad)])
    blocks = flat.reshape(lead + (-1, BLOCK))
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    codes = jnp.round(blocks / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return codes, scale[..., 0]


def dequantize_int8(codes: Array, scale: Array, shape, dtype,
                    keep_leading: int = 0) -> Array:
    flat = (codes.astype(jnp.float32) * scale[..., None]).reshape(
        shape[:keep_leading] + (-1,))
    n = 1
    for d in shape[keep_leading:]:
        n *= d
    return flat[..., :n].reshape(shape).astype(dtype)


# ---------------------------------------------------------------------------
# top-k sparsification
# ---------------------------------------------------------------------------
def topk_sparsify(x: Array, frac: float) -> Tuple[Array, Array]:
    """Keep the `frac` largest-magnitude entries. Returns (values, indices)."""
    flat = x.astype(jnp.float32).reshape(-1)
    k = max(int(flat.size * frac), 1)
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx], idx


def topk_densify(vals: Array, idx: Array, shape, dtype) -> Array:
    n = 1
    for d in shape:
        n *= d
    flat = jnp.zeros((n,), jnp.float32).at[idx].set(vals)
    return flat.reshape(shape).astype(dtype)


# ---------------------------------------------------------------------------
# error-feedback compressor over pytrees
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Compressor:
    """compress(delta + residual) -> (wire, new_residual); decompress(wire)."""
    name: str
    ratio: float  # wire bytes / f32 bytes (approximate, for delay model)

    def init_residual(self, tree: PyTree) -> PyTree:
        return jax.tree.map(
            lambda t: jnp.zeros(t.shape, jnp.float32), tree)

    def compress(self, tree: PyTree, residual: PyTree
                 ) -> Tuple[PyTree, PyTree]:
        raise NotImplementedError

    def decompress(self, wire: PyTree) -> PyTree:
        raise NotImplementedError


class NoCompression(Compressor):
    def __init__(self):
        super().__init__(name="none", ratio=1.0)

    def compress(self, tree, residual):
        return tree, residual

    def decompress(self, wire):
        return wire


class Int8Compressor(Compressor):
    def __init__(self):
        super().__init__(name="int8", ratio=0.25 + 4.0 / BLOCK / 4.0)

    def compress(self, tree, residual):
        def one(t, r):
            target = t.astype(jnp.float32) + r
            codes, scale = quantize_int8(target)
            approx = dequantize_int8(codes, scale, t.shape, jnp.float32)
            return {"codes": codes, "scale": scale,
                    "shape": t.shape, "dtype": t.dtype}, target - approx

        flat_t, tdef = jax.tree.flatten(tree)
        flat_r = jax.tree.leaves(residual)
        out = [one(t, r) for t, r in zip(flat_t, flat_r)]
        return (tdef.unflatten([o[0] for o in out]),
                tdef.unflatten([o[1] for o in out]))

    def decompress(self, wire):
        is_msg = lambda x: isinstance(x, dict) and "codes" in x
        return jax.tree.map(
            lambda m: dequantize_int8(m["codes"], m["scale"], m["shape"],
                                      m["dtype"]),
            wire, is_leaf=is_msg)


class TopKCompressor(Compressor):
    def __init__(self, frac: float = 0.01):
        super().__init__(name=f"topk_{frac:g}", ratio=2.0 * frac)
        self.__dict__["frac"] = frac  # frozen dataclass workaround

    def compress(self, tree, residual):
        frac = self.__dict__["frac"]

        def one(t, r):
            target = t.astype(jnp.float32) + r
            vals, idx = topk_sparsify(target, frac)
            approx = topk_densify(vals, idx, t.shape, jnp.float32)
            return {"vals": vals, "idx": idx,
                    "shape": t.shape, "dtype": t.dtype}, target - approx

        flat_t, tdef = jax.tree.flatten(tree)
        flat_r = jax.tree.leaves(residual)
        out = [one(t, r) for t, r in zip(flat_t, flat_r)]
        return (tdef.unflatten([o[0] for o in out]),
                tdef.unflatten([o[1] for o in out]))

    def decompress(self, wire):
        is_msg = lambda x: isinstance(x, dict) and "vals" in x
        return jax.tree.map(
            lambda m: topk_densify(m["vals"], m["idx"], m["shape"],
                                   m["dtype"]),
            wire, is_leaf=is_msg)


COMPRESSORS = {
    "none": NoCompression,
    "int8": Int8Compressor,
    "topk": TopKCompressor,
}
