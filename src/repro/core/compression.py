"""Delta compression for slow tree edges (the CoCoA communication-
efficiency lineage, arXiv:1409.1458 / arXiv:1711.05305).

Two schemes, both with error feedback (the residual of the compression is
added back into the next message, so the compression error does not
accumulate -- Seide et al. 2014 / Stich et al. 2018):

  * int8 per-tensor blockwise quantization (32x1 blocks, absmax scaling):
    4x fewer bytes than f32 over the wire.
  * top-k magnitude sparsification: send the k largest-|.| entries.

Both are pure jax (no host callbacks) so they live inside jitted programs;
the dry-run sees the reduced collective bytes directly.  Two consumer
layers share this module:

  * the pytree :class:`Compressor` API (``compress``/``decompress`` with
    explicit wire messages) used by ``repro.core.treesync``;
  * the *shape-static roundtrip* helpers (:func:`int8_roundtrip`,
    :func:`topk_roundtrip`) the plan executors call inside ``lax.scan`` /
    ``fori_loop`` bodies -- compress-then-decompress in one traced op, so
    the compiled program models the receiver's view without materializing
    wire buffers (the delay model charges the wire bytes separately, via
    :func:`wire_ratio`).

Edge specs are strings: ``"none"``, ``"int8"``, ``"topk"`` (default
fraction) or ``"topk_<frac>"`` (e.g. ``"topk_0.05"``); :func:`parse_spec`
normalizes them to the ``(kind, frac)`` code pairs the plan IR stores
per (depth, leaf).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any

BLOCK = 32

# kind codes stored in the plan IR's (D, n) ``compress_kind`` array
KIND_NONE = 0
KIND_INT8 = 1
KIND_TOPK = 2

DEFAULT_TOPK_FRAC = 0.01

# wire bytes / f32 bytes: int8 codes + one f32 absmax scale per BLOCK
INT8_RATIO = 0.25 + 4.0 / BLOCK / 4.0


# ---------------------------------------------------------------------------
# spec parsing: "none" | "int8" | "topk" | "topk_<frac>" -> (kind, frac)
# ---------------------------------------------------------------------------
def parse_spec(spec) -> Tuple[int, float]:
    """Normalize an edge-compression spec to ``(kind, frac)``.  Accepts
    ``None`` (no compression), the registry names, ``"topk_<frac>"``, or an
    already-parsed ``(kind, frac)`` pair."""
    if spec is None or spec == "" or spec == "none":
        return KIND_NONE, 0.0
    if isinstance(spec, tuple):
        kind, frac = int(spec[0]), float(spec[1])
        if kind not in (KIND_NONE, KIND_INT8, KIND_TOPK):
            raise ValueError(f"unknown compression kind code {kind}")
        return kind, frac
    if not isinstance(spec, str):
        raise TypeError(f"compression spec must be a string, got {spec!r}")
    if spec == "int8":
        return KIND_INT8, 0.0
    if spec == "topk":
        return KIND_TOPK, DEFAULT_TOPK_FRAC
    if spec.startswith("topk_"):
        frac = float(spec[len("topk_"):])
        if not 0.0 < frac <= 1.0:
            raise ValueError(f"top-k fraction must be in (0, 1], got {frac}")
        return KIND_TOPK, frac
    raise ValueError(
        f"unknown compression spec {spec!r}; use 'none', 'int8', 'topk' "
        "or 'topk_<frac>'")


def spec_name(kind: int, frac: float = 0.0) -> str:
    """The canonical string form of a ``(kind, frac)`` pair."""
    if kind == KIND_NONE:
        return "none"
    if kind == KIND_INT8:
        return "int8"
    if kind == KIND_TOPK:
        return f"topk_{frac:g}"
    raise ValueError(f"unknown compression kind code {kind}")


def wire_ratio(kind: int, frac: float = 0.0) -> float:
    """Wire bytes / f32 bytes of one compressed message: the factor the
    delay model scales an edge's bandwidth term (and the dry-run its byte
    accounting) by.  Top-k ships (value, index) pairs: 2 * frac."""
    if kind == KIND_NONE:
        return 1.0
    if kind == KIND_INT8:
        return INT8_RATIO
    if kind == KIND_TOPK:
        return min(2.0 * frac, 1.0)
    raise ValueError(f"unknown compression kind code {kind}")


def quality(kind: int, frac: float = 0.0) -> float:
    """A modeling knob in (0, 1]: how much of one round's eq.-(11)
    improvement a compressed aggregation retains (error feedback keeps the
    asymptote, but each round's step is perturbed).  Used by
    :func:`repro.core.delay.choose_compression` to trade per-round quality
    against the cheaper round time; int8 is nearly lossless per round,
    top-k degrades with sparsity."""
    if kind == KIND_NONE:
        return 1.0
    if kind == KIND_INT8:
        return 0.95
    if kind == KIND_TOPK:
        return min(max(frac, 1e-6), 1.0) ** 0.5
    raise ValueError(f"unknown compression kind code {kind}")


# ---------------------------------------------------------------------------
# int8 blockwise
# ---------------------------------------------------------------------------
def quantize_int8(x: Array, keep_leading: int = 0) -> Tuple[Array, Array]:
    """x (float) -> (int8 codes, f32 block scales). Blocks along the last
    dim. ``keep_leading`` preserves that many leading dims un-flattened --
    essential under GSPMD when dim 0 is a mesh-sharded replica dim (mixing
    it into blocks forces a full cross-replica reshard)."""
    lead = x.shape[:keep_leading]
    flat = x.astype(jnp.float32).reshape(lead + (-1,))
    pad = (-flat.shape[-1]) % BLOCK
    flat = jnp.pad(flat, [(0, 0)] * keep_leading + [(0, pad)])
    blocks = flat.reshape(lead + (-1, BLOCK))
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    codes = jnp.round(blocks / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return codes, scale[..., 0]


def dequantize_int8(codes: Array, scale: Array, shape, dtype,
                    keep_leading: int = 0) -> Array:
    flat = (codes.astype(jnp.float32) * scale[..., None]).reshape(
        shape[:keep_leading] + (-1,))
    n = 1
    for d in shape[keep_leading:]:
        n *= d
    return flat[..., :n].reshape(shape).astype(dtype)


def int8_roundtrip(x: Array, keep_leading: int = 0) -> Array:
    """What the receiver reconstructs from an int8-quantized ``x``:
    quantize + dequantize in one traced op (shape- and dtype-preserving),
    the executors' in-program model of the compressed edge."""
    codes, scale = quantize_int8(x, keep_leading=keep_leading)
    return dequantize_int8(codes, scale, x.shape, x.dtype,
                           keep_leading=keep_leading)


# ---------------------------------------------------------------------------
# top-k sparsification
# ---------------------------------------------------------------------------
def topk_count(size: int, frac: float) -> int:
    """The k for a ``frac`` sparsification of a ``size`` vector: at least
    one entry (so tiny arrays still make progress), never more than the
    array holds."""
    if size <= 0:
        return 0
    return min(max(int(size * frac), 1), size)


def topk_sparsify(x: Array, frac: float) -> Tuple[Array, Array]:
    """Keep the `frac` largest-magnitude entries. Returns (values, indices).
    k is clamped to [1, size] (empty inputs return empty pairs)."""
    flat = x.astype(jnp.float32).reshape(-1)
    k = topk_count(flat.size, frac)
    if k == 0:
        return flat, jnp.zeros((0,), jnp.int32)
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx], idx


def topk_densify(vals: Array, idx: Array, shape, dtype) -> Array:
    n = 1
    for d in shape:
        n *= d
    flat = jnp.zeros((n,), jnp.float32).at[idx].set(vals)
    return flat.reshape(shape).astype(dtype)


def topk_roundtrip(x: Array, k: int) -> Array:
    """What the receiver reconstructs from a top-``k`` sparsification of
    each ROW of ``x`` (last axis; leading axes vmapped): the k
    largest-|.| entries survive, the rest are zeroed.  ``k`` is static
    (the executors derive it from the feature dimension at trace time), so
    the op is scan-safe."""
    k = min(max(int(k), 1), x.shape[-1])

    def one(row):
        _, idx = jax.lax.top_k(jnp.abs(row), k)
        return jnp.zeros_like(row).at[idx].set(row[idx])

    f = one
    for _ in range(x.ndim - 1):
        f = jax.vmap(f)
    return f(x)


# ---------------------------------------------------------------------------
# error-feedback compressor over pytrees
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Compressor:
    """compress(delta + residual) -> (wire, new_residual); decompress(wire).

    Subclasses are plain frozen dataclasses; ``name`` and ``ratio`` (wire
    bytes / f32 bytes, for the delay model) are derived fields each
    subclass pins in ``__post_init__``."""
    name: str = dataclasses.field(init=False, default="none")
    ratio: float = dataclasses.field(init=False, default=1.0)

    def init_residual(self, tree: PyTree) -> PyTree:
        return jax.tree.map(
            lambda t: jnp.zeros(t.shape, jnp.float32), tree)

    def compress(self, tree: PyTree, residual: PyTree
                 ) -> Tuple[PyTree, PyTree]:
        raise NotImplementedError

    def decompress(self, wire: PyTree) -> PyTree:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class NoCompression(Compressor):
    def __post_init__(self):
        object.__setattr__(self, "name", "none")
        object.__setattr__(self, "ratio", 1.0)

    def compress(self, tree, residual):
        return tree, residual

    def decompress(self, wire):
        return wire


@dataclasses.dataclass(frozen=True)
class Int8Compressor(Compressor):
    def __post_init__(self):
        object.__setattr__(self, "name", "int8")
        object.__setattr__(self, "ratio", INT8_RATIO)

    def compress(self, tree, residual):
        def one(t, r):
            target = t.astype(jnp.float32) + r
            codes, scale = quantize_int8(target)
            approx = dequantize_int8(codes, scale, t.shape, jnp.float32)
            return {"codes": codes, "scale": scale,
                    "shape": t.shape, "dtype": t.dtype}, target - approx

        flat_t, tdef = jax.tree.flatten(tree)
        flat_r = jax.tree.leaves(residual)
        out = [one(t, r) for t, r in zip(flat_t, flat_r, strict=True)]
        return (tdef.unflatten([o[0] for o in out]),
                tdef.unflatten([o[1] for o in out]))

    def decompress(self, wire):
        is_msg = lambda x: isinstance(x, dict) and "codes" in x
        return jax.tree.map(
            lambda m: dequantize_int8(m["codes"], m["scale"], m["shape"],
                                      m["dtype"]),
            wire, is_leaf=is_msg)


@dataclasses.dataclass(frozen=True)
class TopKCompressor(Compressor):
    frac: float = DEFAULT_TOPK_FRAC

    def __post_init__(self):
        if not 0.0 < self.frac <= 1.0:
            raise ValueError(
                f"top-k fraction must be in (0, 1], got {self.frac}")
        object.__setattr__(self, "name", f"topk_{self.frac:g}")
        object.__setattr__(self, "ratio", min(2.0 * self.frac, 1.0))

    def compress(self, tree, residual):
        def one(t, r):
            target = t.astype(jnp.float32) + r
            vals, idx = topk_sparsify(target, self.frac)
            approx = topk_densify(vals, idx, t.shape, jnp.float32)
            return {"vals": vals, "idx": idx,
                    "shape": t.shape, "dtype": t.dtype}, target - approx

        flat_t, tdef = jax.tree.flatten(tree)
        flat_r = jax.tree.leaves(residual)
        out = [one(t, r) for t, r in zip(flat_t, flat_r, strict=True)]
        return (tdef.unflatten([o[0] for o in out]),
                tdef.unflatten([o[1] for o in out]))

    def decompress(self, wire):
        is_msg = lambda x: isinstance(x, dict) and "vals" in x
        return jax.tree.map(
            lambda m: topk_densify(m["vals"], m["idx"], m["shape"],
                                   m["dtype"]),
            wire, is_leaf=is_msg)


COMPRESSORS = {
    "none": NoCompression,
    "int8": Int8Compressor,
    "topk": TopKCompressor,
}


def get_compressor(spec) -> Compressor:
    """Instantiate a :class:`Compressor` from an edge spec string
    (``"none"`` / ``"int8"`` / ``"topk"`` / ``"topk_<frac>"``)."""
    kind, frac = parse_spec(spec)
    if kind == KIND_TOPK:
        return TopKCompressor(frac)
    return COMPRESSORS[spec_name(kind)]()
