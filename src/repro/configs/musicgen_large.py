"""musicgen-large [audio]: decoder-only over EnCodec tokens
(arXiv:2306.05284). 48L d_model=2048 32H (MHA kv=32) d_ff=8192 vocab=2048.
The EnCodec frontend is a stub: input_specs() provides precomputed frame
embeddings; the backbone + 2048-way codebook head are modeled."""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    mlp_type="gelu",
    input_mode="embeddings",
    param_dtype="float32",
)

SMOKE = ModelConfig(
    name="musicgen-smoke",
    family="audio",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=128,
    mlp_type="gelu",
    input_mode="embeddings",
    q_chunk_size=32,
    logits_chunk=32,
)
