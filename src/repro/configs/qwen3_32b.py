"""qwen3-32b [dense]: qk_norm + GQA (hf:Qwen/Qwen3 family).
64L d_model=5120 64H (GQA kv=8, head_dim 128) d_ff=25600 vocab=151936."""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="qwen3-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=25_600,
    vocab_size=151_936,
    qk_norm=True,
    param_dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="qwen3-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=128,
    qk_norm=True,
    q_chunk_size=32,
    logits_chunk=32,
)
