"""yi-34b [dense]: llama-arch GQA (arXiv:2403.04652).
60L d_model=7168 56H (GQA kv=8, head_dim 128) d_ff=20480 vocab=64000."""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="yi-34b",
    family="dense",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=20_480,
    vocab_size=64_000,
    param_dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="yi-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=128,
    q_chunk_size=32,
    logits_chunk=32,
)
