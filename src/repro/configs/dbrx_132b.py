"""dbrx-132b [moe]: 16 experts top-4, fine-grained (hf:databricks/dbrx-base).
40L d_model=6144 48H (GQA kv=8, head_dim 128) d_ff=10752 vocab=100352."""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=10_752,
    vocab_size=100_352,
    num_experts=16,
    experts_per_token=4,
    param_dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="dbrx-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=128,
    num_experts=4,
    experts_per_token=2,
    q_chunk_size=32,
    logits_chunk=32,
)
