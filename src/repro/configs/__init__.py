from repro.configs.base import ModelConfig  # noqa: F401
from repro.configs.registry import ARCHS, get_config, get_smoke_config  # noqa: F401
from repro.configs.shapes import SHAPES, ShapeSpec  # noqa: F401
