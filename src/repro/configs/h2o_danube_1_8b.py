"""h2o-danube-1.8b [dense]: llama+mistral mix with sliding-window attention
(arXiv:2401.16818). 24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000,
window 4096."""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    num_layers=24,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6912,
    vocab_size=32_000,
    window=4096,
    param_dtype="float32",
)

SMOKE = ModelConfig(
    name="h2o-danube-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=128,
    window=16,
    q_chunk_size=32,
    logits_chunk=32,
)
