"""recurrentgemma-2b [hybrid]: RG-LRU + local attention, 1 attn : 2 recurrent
(arXiv:2402.19427). 26L d_model=2560 10H (GQA kv=1, head_dim 256) d_ff=7680
vocab=256000, local-attention window 2048, tied embeddings (Gemma-style)."""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    window=2048,
    block_pattern=("rec", "rec", "attn"),
    lru_width=2560,
    tie_embeddings=True,
    mlp_type="swiglu",
    param_dtype="float32",
)

SMOKE = ModelConfig(
    name="recurrentgemma-smoke",
    family="hybrid",
    num_layers=4,          # one full (rec, rec, attn) block + 1 tail rec
    d_model=64,
    num_heads=4,
    num_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab_size=128,
    window=16,
    block_pattern=("rec", "rec", "attn"),
    lru_width=64,
    tie_embeddings=True,
    q_chunk_size=32,
    logits_chunk=32,
)
