"""llava-next-34b [vlm]: yi-34b text backbone; anyres vision tiling is a
stub -- input_specs() provides precomputed patch embeddings
(hf:llava-hf/llava-v1.6). 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000."""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=20_480,
    vocab_size=64_000,
    input_mode="embeddings",
    param_dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="llava-smoke",
    family="vlm",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=128,
    input_mode="embeddings",
    q_chunk_size=32,
    logits_chunk=32,
)
