"""qwen2.5-32b [dense]: GQA + QKV bias (hf:Qwen/Qwen2.5 family).
64L d_model=5120 40H (GQA kv=8, head_dim 128) d_ff=27648 vocab=152064."""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=27_648,
    vocab_size=152_064,
    qkv_bias=True,
    param_dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="qwen2.5-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=128,
    qkv_bias=True,
    q_chunk_size=32,
    logits_chunk=32,
)
