"""Model configuration dataclass shared by every assigned architecture."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int          # query heads (0 for attention-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0       # 0 => d_model // num_heads

    # attention options
    qk_norm: bool = False
    qkv_bias: bool = False
    window: Optional[int] = None          # sliding-window size (None = full)
    rope_theta: float = 10_000.0
    q_chunk_size: int = 1024              # query-chunked attention for long S

    # MLP
    mlp_type: str = "swiglu"              # swiglu | gelu

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_dense_ff: int = 0                 # arctic-style parallel dense MLP
    capacity_factor: float = 1.25

    # hybrid (RG-LRU / Griffin): repeating block pattern, e.g.
    # ("rec", "rec", "attn"); empty tuple = pure attention stack
    block_pattern: Tuple[str, ...] = ()
    lru_width: int = 0                    # 0 => d_model
    conv_width: int = 4

    # RWKV6
    is_rwkv: bool = False
    rwkv_head_dim: int = 64

    # io
    input_mode: str = "tokens"            # tokens | embeddings (audio/vlm stub)
    tie_embeddings: bool = False

    # numerics / training
    param_dtype: str = "float32"
    activation_dtype: str = "bfloat16"
    remat: bool = True
    # scan_layers=True keeps the HLO compact (one while loop over the layer
    # stack); False unrolls -- needed for roofline analysis because XLA's
    # cost_analysis counts a while body ONCE, not x trip-count.
    scan_layers: bool = True
    optimizer: str = "adamw"              # adamw | adafactor
    logits_chunk: int = 512               # chunked xent over sequence

    # attention implementation: xla | xla_chunked | flash (Pallas, TPU)
    attention_impl: str = "xla_chunked"

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.num_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if not self.lru_width:
            object.__setattr__(self, "lru_width", self.d_model)

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_hybrid(self) -> bool:
        return bool(self.block_pattern)

    @property
    def attends(self) -> bool:
        return not self.is_rwkv

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch serve a 500k-token context?"""
        return self.is_rwkv or self.is_hybrid or self.window is not None

    # ---- parameter counting (for MODEL_FLOPS = 6 N D) -----------------
    def attn_params(self) -> int:
        hd = self.head_dim
        q = self.d_model * self.num_heads * hd
        kv = 2 * self.d_model * self.num_kv_heads * hd
        o = self.num_heads * hd * self.d_model
        bias = (self.num_heads + 2 * self.num_kv_heads) * hd if self.qkv_bias else 0
        return q + kv + o + bias

    def mlp_params(self, d_ff: Optional[int] = None) -> int:
        f = d_ff or self.d_ff
        n_mat = 3 if self.mlp_type == "swiglu" else 2
        return n_mat * self.d_model * f

    def rglru_params(self) -> int:
        w = self.lru_width
        # in-proj (x & gate), conv, RG-LRU gates (W_a, W_x, Lambda), out-proj
        return (2 * self.d_model * w + self.conv_width * w
                + 2 * w * w + w + w * self.d_model)

    def rwkv_params(self) -> int:
        d = self.d_model
        # time-mix: r,k,v,g,w,o (6 d^2) + lora mixers (small) ; channel-mix
        tm = 6 * d * d + 7 * d * 64
        cm = 2 * d * self.d_ff + d * d
        return tm + cm

    def layer_params(self, kind: str = "attn") -> int:
        if self.is_rwkv:
            return self.rwkv_params() + 2 * self.d_model
        mixer = self.attn_params() if kind == "attn" else self.rglru_params()
        if self.is_moe:
            ff = self.num_experts * self.mlp_params()
            if self.moe_dense_ff:
                ff += self.mlp_params(self.moe_dense_ff)
            ff += self.d_model * self.num_experts  # router
        else:
            ff = self.mlp_params()
        return mixer + ff + 2 * self.d_model

    def layer_kinds(self) -> Tuple[str, ...]:
        if self.is_rwkv:
            return tuple("rwkv" for _ in range(self.num_layers))
        if not self.block_pattern:
            return tuple("attn" for _ in range(self.num_layers))
        p = self.block_pattern
        return tuple(p[i % len(p)] for i in range(self.num_layers))

    def param_count(self) -> int:
        emb = self.vocab_size * self.d_model
        head = 0 if self.tie_embeddings else self.vocab_size * self.d_model
        body = sum(
            self.layer_params("attn" if k == "attn" else "rec" if k == "rec"
                              else "rwkv")
            for k in self.layer_kinds()
        )
        return emb + head + body + self.d_model  # final norm

    def active_param_count(self) -> int:
        """Per-token active params (MoE: only routed experts count)."""
        if not self.is_moe:
            return self.param_count()
        total = self.param_count()
        inactive = (
            self.num_layers
            * (self.num_experts - self.experts_per_token)
            * self.mlp_params()
        )
        return total - inactive
