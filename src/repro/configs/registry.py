"""--arch registry: maps architecture ids to (full, smoke) configs."""
from repro.configs import (
    arctic_480b,
    dbrx_132b,
    h2o_danube_1_8b,
    llava_next_34b,
    musicgen_large,
    qwen2_5_32b,
    qwen3_32b,
    recurrentgemma_2b,
    rwkv6_1_6b,
    yi_34b,
)

ARCHS = {
    "recurrentgemma-2b": recurrentgemma_2b,
    "musicgen-large": musicgen_large,
    "qwen3-32b": qwen3_32b,
    "qwen2.5-32b": qwen2_5_32b,
    "h2o-danube-1.8b": h2o_danube_1_8b,
    "yi-34b": yi_34b,
    "rwkv6-1.6b": rwkv6_1_6b,
    "llava-next-34b": llava_next_34b,
    "dbrx-132b": dbrx_132b,
    "arctic-480b": arctic_480b,
}


def get_config(arch: str):
    return ARCHS[arch].FULL


def get_smoke_config(arch: str):
    return ARCHS[arch].SMOKE
