"""rwkv6-1.6b "Finch" [ssm]: attention-free, data-dependent decay
(arXiv:2404.05892). 24L d_model=2048 d_ff=7168 vocab=65536."""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    d_ff=7168,
    vocab_size=65_536,
    is_rwkv=True,
    rwkv_head_dim=64,
    param_dtype="float32",
)

SMOKE = ModelConfig(
    name="rwkv6-smoke",
    family="ssm",
    num_layers=2,
    d_model=64,
    num_heads=0,
    num_kv_heads=0,
    d_ff=128,
    vocab_size=128,
    is_rwkv=True,
    rwkv_head_dim=16,
    logits_chunk=32,
)
