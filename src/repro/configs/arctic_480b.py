"""arctic-480b [moe]: 128 experts top-2 + dense residual MLP
(hf:Snowflake/snowflake-arctic-base). 35L d_model=7168 56H (GQA kv=8)
d_ff=4864 vocab=32000. Adafactor + bf16 params (param+opt state would
exceed HBM with AdamW f32 -- see DESIGN.md SS5)."""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32_000,
    num_experts=128,
    experts_per_token=2,
    moe_dense_ff=4864,
    optimizer="adafactor",
    param_dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="arctic-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=96,
    vocab_size=128,
    num_experts=8,
    experts_per_token=2,
    moe_dense_ff=96,
    optimizer="adafactor",
    q_chunk_size=32,
    logits_chunk=32,
)
