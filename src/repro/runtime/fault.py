"""The elasticity subsystem: checkpointed chunk carries, permanent
membership events, and fault injection for tree-DCA sessions.

The paper's synchronous schedule assumes every leaf answers every round;
production networks lose and gain leaves mid-solve.  Three layers turn the
seed's unintegrated ``runtime/checkpoint.py`` / ``runtime/elastic.py``
modules into the fault-tolerance story:

* **Checkpointed carries** -- :class:`CheckpointPolicy` drives
  ``Session.run(checkpoint=...)``.  The key fact making the snapshot small
  and backend-portable: at every root-round boundary under full
  participation the executor's blocked state *collapses* -- the root sync
  refreshes every snapshot, so all per-leaf ``w`` replicas are equal and
  every snapshot equals the live state.  A COMPLETE carry is therefore
  just ``{alpha (m,), w (d,), per-compressed-depth error-feedback
  residuals (n, d), root RNG key}`` plus scalar metadata; restore on ANY
  backend is ``init(X, alpha, w)`` + residual substitution (on mesh, a
  :func:`repro.runtime.elastic.remesh_state` onto the new mesh's
  shardings -- the device count may differ between save and resume).

* **Membership events** -- :class:`MembershipLog` records permanent
  ``leave(name, at_round)`` / ``join(name, X, y, at_round)`` events;
  :class:`ElasticSession` runs the solve in segments, splicing the data /
  dual rows at each boundary, rebuilding ``w = X^T alpha / (lam m)`` (the
  eq.-(13) invariant survives any row deletion/insertion), re-weighting
  aggregation from the *surviving* leaves (``weighting="size"`` -- the
  imbalanced-data rule of arXiv:2308.14783) and recompiling only what
  changed (executors are memoized on the plan fingerprint;
  :func:`repro.core.engine.plan.plan_diff` reports the changed slices).
  A join warm-starts exactly like PR 3's stale-snapshot re-join: the new
  leaf enters with a zero dual block against the current global ``w``.

* **Fault injection** -- :class:`FaultModel` samples crash rounds and
  permanent-leave processes (layered on the transient
  :class:`~repro.core.delay.StragglerModel`);
  :func:`run_with_faults` drives simulated kill-and-resume runs whose
  final iterates are bit-identical to the uninterrupted solve.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, List, Optional, Sequence, Union

import jax.numpy as jnp
import numpy as np

from repro.core import compression as comp_mod
from repro.runtime.checkpoint import CheckpointManager

Array = Any

PAYLOAD_VERSION = 1


# ---------------------------------------------------------------------------
# checkpoint policy
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class CheckpointPolicy:
    """How a session checkpoints: where, how often, how many to keep.

    ``every`` is the snapshot period in root rounds; ``"auto"`` uses the
    Young/Daly period the schedule planned (``resolved.ckpt_every``, set
    when the schedule was compiled with ``DelayModel(mtbf=...)`` --
    ``tau = sqrt(2 t_write MTBF)`` over the modeled round time).  The
    final round is always snapshotted so ``Session.resume`` of a
    completed run is a no-op restore.  ``async_save`` moves the write off
    the round loop (one in flight at a time; a failed write surfaces on
    the next save/wait)."""
    directory: Union[str, os.PathLike]
    every: Union[int, str] = 1
    keep: int = 3
    async_save: bool = False

    def __post_init__(self):
        if isinstance(self.every, str):
            if self.every != "auto":
                raise ValueError(
                    f"every must be a positive int or 'auto', "
                    f"got {self.every!r}")
        elif int(self.every) < 1:
            raise ValueError(f"every must be >= 1, got {self.every}")

    def manager(self) -> CheckpointManager:
        return CheckpointManager(directory=str(self.directory),
                                 keep=self.keep, async_save=self.async_save)


def bind_policy(checkpoint, resolved=None):
    """Normalize ``Session.run(checkpoint=...)``'s argument (a directory
    path or a :class:`CheckpointPolicy`) into ``(policy, manager,
    every_int)``, resolving ``every="auto"`` against the schedule."""
    if isinstance(checkpoint, (str, os.PathLike)):
        checkpoint = CheckpointPolicy(directory=checkpoint)
    every = checkpoint.every
    if every == "auto":
        ck = getattr(resolved, "ckpt_every", None)
        if ck is None:
            raise ValueError(
                "CheckpointPolicy(every='auto') needs a schedule compiled "
                "with DelayModel(mtbf=..., ckpt_write=...): the Young/Daly "
                "period lives in resolved.ckpt_every")
        every = int(ck)
    return checkpoint, checkpoint.manager(), int(every)


# ---------------------------------------------------------------------------
# the chunk-carry payload (backend-portable)
# ---------------------------------------------------------------------------
def n_residuals(plan) -> int:
    """Per-compressed-depth error-feedback residual count of a plan."""
    return sum(
        1 for dd in range(plan.depth)
        if (plan.compress_kind[dd] != comp_mod.KIND_NONE).any())


def payload_template(plan, m: int, d: int, dtype):
    """The pytree a checkpointed chunk carry restores into: flat dual,
    primal, per-compressed-depth EF residuals, raw root RNG key."""
    return {
        "alpha": np.zeros((m,), dtype),
        "w": np.zeros((d,), dtype),
        "key": np.zeros((2,), np.uint32),
        "res": [np.zeros((plan.n_leaves, d), np.float32)
                for _ in range(n_residuals(plan))],
    }


def ef_residuals(session, state) -> List[Array]:
    """Extract the per-compressed-depth ``(n, d)`` f32 error-feedback
    residuals from a live StateExecutor carry (empty for uncompressed
    plans) -- the only part of the blocked state that does NOT collapse
    into (alpha, w) at a root-round boundary.  Returned as live device
    arrays: the checkpoint writer gathers to host at write time (the
    save may be deferred past the stall window on purpose)."""
    plan = session.plan
    if state is None or not plan.has_compression:
        return []
    # residuals are the TRAILING carry slots on every backend and method
    # flavor (accelerated programs insert their momentum anchors BEFORE
    # the residuals), so index from the end rather than hard-coding the
    # server-tail length of one particular lowering
    if session.backend in ("vmap", "pallas"):
        return list(state[-1])              # the trailing residual tuple
    return list(state[-n_residuals(plan):])


def with_ef_residuals(session, state, res: Sequence[np.ndarray]):
    """Substitute restored EF residuals into a freshly ``init``-ed carry.
    On mesh the host arrays are remeshed onto the *current* mesh's
    shardings (:func:`repro.runtime.elastic.remesh_state`), so a carry
    checkpointed on one device count restores onto any other."""
    res = tuple(res)
    if not res:
        return state
    plan = session.plan
    n_res = n_residuals(plan)
    if len(res) != n_res:
        raise ValueError(
            f"checkpoint carries {len(res)} EF residuals but the plan "
            f"compresses {n_res} depths -- was the schedule's compression "
            "changed between save and resume?")
    if session.backend in ("vmap", "pallas"):
        sub = tuple(jnp.asarray(np.asarray(r), jnp.float32) for r in res)
        return state[:-1] + (sub,)
    from repro.runtime.elastic import remesh_state, replicated
    host = tuple(np.asarray(r, np.float32) for r in res)
    sub = remesh_state(host, replicated(session._spec_sharding, host))
    return state[:-n_res] + sub


# ---------------------------------------------------------------------------
# membership events (permanent leave / join)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MembershipEvent:
    kind: str                 # "leave" | "join"
    name: str
    at_round: int
    X: Optional[Array] = None   # join only: the new leaf's data block
    y: Optional[Array] = None
    parent: Optional[str] = None  # join only: internal node (default root)

    def __post_init__(self):
        if self.kind not in ("leave", "join"):
            raise ValueError(f"unknown event kind {self.kind!r}")
        if self.at_round < 0:
            raise ValueError(f"at_round must be >= 0, got {self.at_round}")
        if self.kind == "join" and (self.X is None or self.y is None):
            raise ValueError("a join event needs the new leaf's (X, y)")


class MembershipLog:
    """An ordered log of permanent membership events, applied at root-round
    boundaries by :class:`ElasticSession` (a leave/join ``at_round=t``
    takes effect after round ``t`` completes; ``at_round=0`` before the
    first round)."""

    def __init__(self, events: Sequence[MembershipEvent] = ()):
        self.events: List[MembershipEvent] = list(events)

    def leave(self, name: str, *, at_round: int) -> "MembershipLog":
        self.events.append(MembershipEvent("leave", name, int(at_round)))
        return self

    def join(self, name: str, X, y, *, at_round: int,
             parent: Optional[str] = None) -> "MembershipLog":
        self.events.append(MembershipEvent(
            "join", name, int(at_round), X=X, y=y, parent=parent))
        return self

    def boundaries(self) -> List[int]:
        return sorted({e.at_round for e in self.events})

    def at(self, t: int) -> List[MembershipEvent]:
        return [e for e in self.events if e.at_round == t]

    def __len__(self) -> int:
        return len(self.events)


class ElasticSession:
    """A session whose leaf set changes mid-solve.

    Runs ``rounds`` root rounds against a :class:`MembershipLog`: at every
    event boundary the data matrix / dual vector rows are spliced (a
    leaving leaf's block is deleted outright -- its dual mass leaves with
    it; a joining leaf enters with a zero dual block, the PR 3
    stale-snapshot re-join warm start), the primal is rebuilt as
    ``w = X^T alpha / (lam m)`` over the NEW data (eq. (13) -- note ``m``
    changed, so ``w`` genuinely moves), and the session recompiles against
    the edited topology.  Aggregation re-weights from the surviving
    leaves: the default ``weighting="size"`` schedule is exactly the
    data-proportional rule of arXiv:2308.14783.  Executor memoization
    makes recompiles cheap (an unchanged plan fingerprint is a cache hit);
    ``self.plan_diffs`` records what each event actually changed
    (:func:`repro.core.engine.plan.plan_diff`)."""

    def __init__(self, problem, topology, schedule=None, *,
                 backend: str = "vmap"):
        from repro.api.schedule import Schedule
        self.schedule = schedule if schedule is not None \
            else Schedule(weighting="size")
        self.problem = problem
        self.topology = topology
        self.backend = backend
        self.plan_diffs: List[dict] = []
        # post-run views (the final membership's problem/topology)
        self.current_problem = problem
        self.current_topology = topology

    def run(self, rounds: int, *, membership: Optional[MembershipLog] = None,
            key=None, lam: Optional[float] = None,
            record_history: bool = True, history_every: int = 1):
        from repro.api.session import Session
        from repro.core import dual as dual_mod
        from repro.core.engine import plan as plan_mod
        from repro.core.instrument import SolveResult

        T = int(rounds)
        events = list(membership.events) if membership is not None else []
        for e in events:
            if e.at_round >= T:
                raise ValueError(
                    f"event {e.kind}({e.name!r}) at round {e.at_round} "
                    f"never takes effect in a {T}-round run")
        boundaries = sorted({e.at_round for e in events})

        prob, topo = self.problem, self.topology
        sess = Session.compile(prob, topo, self.schedule,
                               backend=self.backend)
        lam_run = prob.lam if lam is None else float(lam)
        history: List[dict] = []
        diffs: List[dict] = []
        prev: Optional[SolveResult] = None
        cur = 0
        for b in boundaries + [T]:
            seg = b - cur
            if seg > 0:
                res = sess.run(
                    seg, key=(key if prev is None else None),
                    warm_start=prev, lam=lam_run,
                    record_history=record_history,
                    history_every=history_every)
                history += res.history
                prev = res
                cur = b
            if b == T:
                break

            # apply this boundary's events: splice rows by leaf NAME
            if prev is not None:
                alpha = np.asarray(prev.alpha)
                next_key = prev.next_key
            else:
                alpha = np.asarray(jnp.zeros((prob.m,), prob.X.dtype))
                next_key = key
            X = np.asarray(prob.X)
            y = np.asarray(prob.y)
            old_plan = sess.plan
            for e in [ev for ev in events if ev.at_round == b]:
                if e.kind == "leave":
                    off, sz = topo.leaf_span(e.name)
                    topo = topo.without_leaf(e.name)
                    keep = np.r_[0:off, off + sz:len(y)]
                    X, y, alpha = X[keep], y[keep], alpha[keep]
                else:
                    Xn = np.asarray(e.X, X.dtype)
                    yn = np.asarray(e.y, y.dtype)
                    if Xn.ndim != 2 or Xn.shape[1] != X.shape[1]:
                        raise ValueError(
                            f"join {e.name!r}: X must be (k, {X.shape[1]}),"
                            f" got {Xn.shape}")
                    topo = topo.with_leaf(e.name, parent=e.parent,
                                          data_size=len(yn))
                    off, _ = topo.leaf_span(e.name)
                    X = np.concatenate([X[:off], Xn, X[off:]])
                    y = np.concatenate([y[:off], yn, y[off:]])
                    alpha = np.concatenate(
                        [alpha[:off], np.zeros(len(yn), alpha.dtype),
                         alpha[off:]])
            prob = dataclasses.replace(prob, X=jnp.asarray(X),
                                       y=jnp.asarray(y))
            sess = Session.compile(prob, topo, self.schedule,
                                   backend=self.backend)
            diffs.append({"round": b,
                          **plan_mod.plan_diff(old_plan, sess.plan)})
            # m changed -> the eq.-(13) primal must be rebuilt, and a
            # joining leaf's zero dual block sees the warm global w
            alpha_j = jnp.asarray(alpha, prob.X.dtype)
            w = dual_mod.w_of_alpha(alpha_j, prob.X, lam_run)
            anchor = history[-1] if history else \
                {"round": 0, "time": 0.0, "dual": float("nan"),
                 "primal": float("nan"), "gap": float("nan")}
            prev = SolveResult(alpha=alpha_j, w=w, history=[dict(anchor)],
                               next_key=next_key, lam=lam_run)

        self.plan_diffs = diffs
        self.current_problem = prob
        self.current_topology = topo
        if prev is None:    # T == 0 with no events
            z = jnp.zeros((prob.m,), prob.X.dtype)
            prev = SolveResult(alpha=z,
                               w=jnp.zeros((prob.d,), prob.X.dtype),
                               history=[], next_key=key, lam=lam_run)
        return SolveResult(alpha=prev.alpha, w=prev.w, history=history,
                           next_key=prev.next_key, lam=lam_run)


# ---------------------------------------------------------------------------
# fault injection
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class FaultModel:
    """Stochastic fault processes for simulated runs.

    ``crash_prob`` is the per-root-round probability the coordinator dies
    (kill-and-resume via :func:`run_with_faults`); ``leave_prob`` the
    per-round per-leaf probability of *permanent* loss (a
    :class:`MembershipLog` for :class:`ElasticSession`, never shrinking
    below ``min_leaves``).  ``straggler`` optionally carries the
    *transient*-delay layer (a :class:`~repro.core.delay.StragglerModel`
    to hand a ``StragglerPolicy``): stragglers skip syncs and re-join,
    faults here never come back."""
    crash_prob: float = 0.0
    leave_prob: float = 0.0
    min_leaves: int = 2
    straggler: Optional[Any] = None

    def __post_init__(self):
        for nm in ("crash_prob", "leave_prob"):
            v = getattr(self, nm)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{nm} must be in [0, 1], got {v}")
        if self.min_leaves < 1:
            raise ValueError(
                f"min_leaves must be >= 1, got {self.min_leaves}")

    def sample_crashes(self, rounds: int, seed: int = 0) -> List[int]:
        """Rounds (1..rounds-1) after which the coordinator dies."""
        rng = np.random.default_rng(seed)
        return [t for t in range(1, int(rounds))
                if rng.random() < self.crash_prob]

    def sample_leaves(self, leaf_names: Sequence[str], rounds: int,
                      seed: int = 0) -> MembershipLog:
        """A permanent-loss :class:`MembershipLog` over ``rounds``."""
        rng = np.random.default_rng(seed)
        log = MembershipLog()
        alive = list(leaf_names)
        for t in range(1, int(rounds)):
            for nm in list(alive):
                if len(alive) <= self.min_leaves:
                    break
                if rng.random() < self.leave_prob:
                    log.leave(nm, at_round=t)
                    alive.remove(nm)
        return log


def run_with_faults(session, rounds: Optional[int] = None, *, checkpoint,
                    fault: FaultModel, key=None, seed: int = 0,
                    lam: Optional[float] = None, local_h=None,
                    record_history: bool = True, history_every: int = 1):
    """Drive a simulated kill-and-resume run: at every sampled crash round
    the in-memory state is DISCARDED (the kill) and the solve restarts
    from the newest complete checkpoint via ``Session.resume`` -- exactly
    the production restart path, so the returned result is bit-identical
    to an uninterrupted checkpointed run.  Returns ``(result, report)``
    where the report lists each crash / restart (``resumed_from`` < the
    crash round whenever the crash out-ran the checkpoint period: that
    work is recomputed)."""
    T = session.resolved.rounds if rounds is None else int(rounds)
    policy, mgr, _ = bind_policy(checkpoint, session.resolved)
    crashes = fault.sample_crashes(T, seed)
    kw = dict(lam=lam, local_h=local_h, record_history=record_history,
              history_every=history_every)
    stops = crashes + [T]
    restarts = []
    result = None
    for i, stop in enumerate(stops):
        # a leg that ends in a crash dies WITHOUT the forced final-round
        # save: only period-aligned checkpoints survive the kill, so the
        # resume genuinely recomputes the rounds the crash out-ran
        is_crash = i < len(crashes)
        if i == 0:
            result = session.run(stop, key=key, checkpoint=policy,
                                 _final_save=not is_crash, **kw)
        else:
            step = mgr.latest_step()
            if step is None:       # crashed before the first save: scratch
                step = 0
                result = session.run(stop, key=key, checkpoint=policy,
                                     _final_save=not is_crash, **kw)
            else:
                result = session.resume(policy, rounds=stop - step,
                                        _final_save=not is_crash, **kw)
            restarts.append({"crash_at": int(crashes[i - 1]),
                             "resumed_from": int(step),
                             "ran_to": int(stop)})
        if is_crash:
            result = None                          # the simulated kill
    return result, {"rounds": T, "crashes": [int(c) for c in crashes],
                    "restarts": restarts}
