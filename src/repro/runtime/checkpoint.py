"""Fault-tolerant checkpointing: atomic writes, keep-k retention, async
save thread, auto-resume.

Format: one .npz per checkpoint holding every leaf (keyed by its pytree
path) + a JSON sidecar with step / pytree structure / metadata. Writes go
to a temp name then os.replace() -- a crash mid-save can never corrupt the
latest checkpoint, and restart always resumes from the newest *complete*
checkpoint (the restart path of the checkpoint/restart story).
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import threading
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

PyTree = Any

_STEP_RE = re.compile(r"step_(\d+)\.npz$")


def _flatten(tree: PyTree) -> Dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", None))) for k in path)
        out[key] = np.asarray(leaf)
    return out


def _unflatten(template: PyTree, arrays: Dict[str, np.ndarray]) -> PyTree:
    flat, tdef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", None))) for k in path)
        arr = arrays[key]
        want = getattr(leaf, "dtype", None)
        if want is not None and arr.dtype != want:
            arr = arr.astype(want)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(tdef, leaves)


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep: int = 3
    async_save: bool = False

    def __post_init__(self):
        if self.keep < 1:
            raise ValueError(f"keep must be >= 1, got {self.keep}")
        self.dir = Path(self.directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ---- save -----------------------------------------------------------
    def save(self, step: int, state: PyTree,
             metadata: Optional[Dict] = None) -> Path:
        if self.async_save:
            self.wait()  # one in flight at a time; re-raises a failed save
            host_state = jax.tree.map(np.asarray, state)  # snapshot now
            self._thread = threading.Thread(
                target=self._save_guarded,
                args=(step, host_state, metadata))
            self._thread.start()
            return self._path(step)
        return self._save_sync(step, state, metadata)

    def _save_guarded(self, step: int, state: PyTree,
                      metadata: Optional[Dict]) -> None:
        """Thread target: capture the exception instead of dying silently
        on the save thread; ``wait()`` / the next ``save()`` re-raise it."""
        try:
            self._save_sync(step, state, metadata)
        except BaseException as e:       # noqa: BLE001 -- surfaced later
            self._error = e

    def _save_sync(self, step: int, state: PyTree,
                   metadata: Optional[Dict]) -> Path:
        final = self._path(step)
        tmp = final.with_suffix(".tmp.npz")
        arrays = _flatten(state)
        # dtype-preserving: bf16 has no numpy dtype -> view as uint16
        packed = {}
        dtypes = {}
        for k, v in arrays.items():
            if v.dtype == jax.numpy.bfloat16:
                packed[k] = v.view(np.uint16)
                dtypes[k] = "bfloat16"
            else:
                packed[k] = v
                dtypes[k] = str(v.dtype)
        np.savez(tmp, **packed)
        meta = {"step": int(step), "time": time.time(),
                "dtypes": dtypes, **(metadata or {})}
        tmp_meta = final.with_suffix(".tmp.json")
        tmp_meta.write_text(json.dumps(meta))
        os.replace(tmp, final)                       # atomic publish
        os.replace(tmp_meta, final.with_suffix(".json"))
        self._gc()
        return final

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(
                "async checkpoint save failed; the checkpoint was NOT "
                "written") from err

    # ---- restore --------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        steps = sorted(self.all_steps())
        return steps[-1] if steps else None

    def all_steps(self) -> List[int]:
        out = []
        for p in self.dir.glob("step_*.npz"):
            m = _STEP_RE.search(p.name)
            if m and p.with_suffix(".json").exists():  # complete only
                out.append(int(m.group(1)))
        return sorted(out)

    def restore(self, template: PyTree, step: Optional[int] = None
                ) -> Tuple[int, PyTree]:
        implicit = step is None
        # an implicit restore retries once with a fresh listing: a
        # concurrent save's GC may have retired the step it first picked
        for attempt in (0, 1):
            s = self.latest_step() if implicit else step
            if s is None:
                raise FileNotFoundError(f"no checkpoints in {self.dir}")
            try:
                return s, self._read(s, template)
            except FileNotFoundError:
                if not implicit or attempt:
                    raise
        raise AssertionError("unreachable")

    def _read(self, step: int, template: PyTree) -> PyTree:
        final = self._path(step)
        meta = json.loads(final.with_suffix(".json").read_text())
        with np.load(final) as z:
            arrays = {}
            for k in z.files:
                v = z[k]
                if meta["dtypes"].get(k) == "bfloat16":
                    v = v.view(jax.numpy.bfloat16)
                arrays[k] = v
        return _unflatten(template, arrays)

    def metadata(self, step: Optional[int] = None) -> Dict:
        """The JSON sidecar of ``step`` (default: the newest complete
        checkpoint) -- step/time/dtypes plus whatever ``save`` attached."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        return json.loads(self._path(step).with_suffix(".json").read_text())

    # ---- retention ------------------------------------------------------
    def _gc(self):
        # ONE listing snapshot decides retention, and the newest complete
        # step is never deleted -- a concurrent restore that just listed it
        # can still read it (plus restore's own implicit-step retry above).
        steps = self.all_steps()
        newest = steps[-1] if steps else None
        for s in steps[: max(len(steps) - self.keep, 0)]:
            if s == newest:
                continue
            # sidecar first: the step turns "incomplete" (invisible to
            # all_steps/latest_step) before its payload disappears
            self._path(s).with_suffix(".json").unlink(missing_ok=True)
            self._path(s).unlink(missing_ok=True)

    def _path(self, step: int) -> Path:
        return self.dir / f"step_{step:010d}.npz"


def resume_or_init(mgr: CheckpointManager, init_fn: Callable[[], PyTree]
                   ) -> Tuple[int, PyTree]:
    """Auto-resume: newest complete checkpoint, else fresh init at step 0."""
    template = None
    if mgr.latest_step() is not None:
        template = init_fn()
        return mgr.restore(template)
    return 0, init_fn()
