"""Elastic scaling: re-shard training state onto a different mesh.

When nodes fail (or capacity is added), the job restarts on a different
device count. Checkpoints are stored as *global* host arrays (see
checkpoint.py), so elasticity is: rebuild shardings for the new mesh from
the same rules and device_put. `remesh_state` also works in-process for
live shrink/grow (state -> host -> new mesh), and `fold_batch` rescales the
per-replica batch so the global batch size is invariant across remeshes
(learning dynamics are preserved -- same tokens/step).

The contract that makes this trivially correct: every sharding in the
framework is a *function of (config, mesh, rules)* -- nothing is baked into
the state itself.

The tree-DCA sessions reuse the same contract: a chunk-carry checkpoint
(see ``runtime/fault.py``) stores global host arrays, and
``Session.resume`` rebuilds the mesh carry by ``init`` + `remesh_state`
of the error-feedback residuals onto the *current* mesh's shardings via
`replicated` -- so a carry saved on one device count restores onto any
other (the elastic-remesh path of ROADMAP item 2).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from repro.launch import sharding as sh

PyTree = Any


def to_host(state: PyTree) -> PyTree:
    """Gather a (possibly sharded) pytree to host numpy arrays."""
    return jax.tree.map(lambda t: np.asarray(jax.device_get(t)), state)


def remesh_state(state: PyTree, shardings: PyTree) -> PyTree:
    """Place a host (or differently-sharded) state onto new shardings."""
    return jax.tree.map(
        lambda t, s: jax.device_put(t, s), state, shardings)


def replicated(sharding, tree: PyTree) -> PyTree:
    """A shardings pytree placing every leaf of ``tree`` with the same
    ``sharding`` -- the leaf-matched structure `remesh_state` needs when a
    whole state restores under one spec (e.g. the per-depth EF residuals
    of a checkpointed chunk carry, all row-sharded the same way)."""
    return jax.tree.map(lambda _: sharding, tree)


def remesh_params(cfg, params: PyTree, new_mesh: Mesh,
                  rules: sh.AxisRules = sh.DEFAULT_RULES) -> PyTree:
    pshape = jax.eval_shape(lambda t: t, params)
    shardings = sh.param_shardings(cfg, pshape, new_mesh, rules)
    return remesh_state(params, shardings)


def fold_batch(global_batch: int, mesh: Mesh) -> Dict[str, int]:
    """Per-device batch for an invariant global batch on any mesh size."""
    from repro.launch.mesh import axis_size
    dp = axis_size(mesh, "data") * axis_size(mesh, "pod")
    assert global_batch % dp == 0, (
        f"global batch {global_batch} must divide data parallelism {dp}; "
        f"pad or regrid the batch")
    return {"data_parallel": dp, "per_replica": global_batch // dp}


def shrink_survivors(n_devices: int, lost: int, model_parallel: int) -> int:
    """Largest usable device count after losing `lost` devices, keeping the
    model-parallel group width (a TP group is an atomic failure domain)."""
    alive = n_devices - lost
    return (alive // model_parallel) * model_parallel
