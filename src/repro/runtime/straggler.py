"""Straggler mitigation -- the paper's own mechanism, operationalized.

The paper's core observation (§6): when a link/worker is slow, do MORE
local work per sync (larger H) instead of letting the barrier idle the
fleet. TreeSync exposes per-level sync periods; this module turns observed
per-step timing into updated periods via the paper's eq. (12), plus a
bounded-skip barrier policy for transient stragglers.

No real cluster exists in this container, so the observation side is an
interface (`StepTimer.observe`) fed by the launcher; the *decision* side
(re-optimizing H, skip decisions) is pure and fully tested.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Optional

import numpy as np

from repro.core.delay import StragglerModel, optimal_h


@dataclasses.dataclass
class StepTimer:
    """Online robust timing stats per sync level (median + MAD)."""
    window: int = 64

    def __post_init__(self):
        # deque(maxlen=...) evicts the oldest sample in O(1); the previous
        # list.pop(0) was O(window) per observation
        self.samples: Deque[float] = collections.deque(maxlen=self.window)

    def observe(self, seconds: float) -> None:
        self.samples.append(seconds)

    @property
    def median(self) -> float:
        return float(np.median(self.samples)) if self.samples else 0.0

    @property
    def mad(self) -> float:
        if not self.samples:
            return 0.0
        m = self.median
        return float(np.median(np.abs(np.array(self.samples) - m)))

    def is_straggling(self, seconds: float, k: float = 5.0,
                      rel_floor: float = 0.2) -> bool:
        """Is this step an outlier vs the recent window? Requires BOTH a
        k-MAD exceedance and a minimum relative slowdown (a 1% blip on a
        perfectly steady cluster is not a straggler)."""
        if len(self.samples) < 8:
            return False
        return seconds > max(self.median + k * self.mad,
                             self.median * (1.0 + rel_floor))


@dataclasses.dataclass
class AdaptiveSchedule:
    """Re-optimize the paper's H when the observed delay drifts.

    C, delta: the convergence-bound constants of eq. (11)-(12);
    t_total: the planning horizon; re-planning uses the *measured*
    t_lp (local step) and t_delay (sync barrier) medians.

    The suggestion is live: ``repro.api.Session.run(straggler=...)``
    applies it to the next chunk through the engine's runtime step-mask
    operand (H is an executor INPUT, not a compile constant), so an
    adaptive session replans with zero retraces.
    """
    C: float = 0.5
    delta: float = 1e-3
    t_total: float = 3600.0
    K: int = 2
    h_max: int = 4096
    hysteresis: float = 1.3   # only change H when >30% off current optimum

    current_h: int = 1

    def replan(self, t_lp: float, t_delay: float, t_cp: float = 0.0) -> int:
        h, _ = optimal_h(C=self.C, K=self.K, delta=self.delta,
                         t_total=self.t_total, t_lp=max(t_lp, 1e-9),
                         t_delay=max(t_delay, 0.0), t_cp=t_cp,
                         h_max=self.h_max)
        if (max(h, self.current_h) / max(min(h, self.current_h), 1)
                >= self.hysteresis):
            self.current_h = h
        return self.current_h


@dataclasses.dataclass
class BoundedSkip:
    """Transient-straggler policy: a sync round may be skipped (local work
    continues) at most `max_consecutive` times, then the barrier is forced.
    This bounds replica divergence: with period H and at most s skips, any
    two replicas are never more than H*(s+1) local steps apart -- the same
    bounded-staleness object the paper's tree analysis tolerates (each
    subtree runs more local rounds before the parent round closes)."""
    max_consecutive: int = 2
    skipped: int = 0

    def decide(self, barrier_would_stall: bool) -> bool:
        """True => skip the sync this round."""
        if barrier_would_stall and self.skipped < self.max_consecutive:
            self.skipped += 1
            return True
        self.skipped = 0
        return False


@dataclasses.dataclass
class StragglerStep:
    """One chunk's straggler decisions and simulated timing."""
    mask: np.ndarray        # (n,) float32 in {0,1}: 1 = leaf participates
    dt_async: float         # simulated round time when stragglers are dropped
    dt_sync: float          # simulated round time of the full barrier
    delays: np.ndarray      # (n,) the sampled per-leaf sync-path delays
    h_suggest: Optional[int]  # AdaptiveSchedule's replanned H (None if unset)


@dataclasses.dataclass
class StragglerPolicy:
    """Per-chunk straggler decisions for ``repro.api.Session.run``.

    Each root-round chunk: sample per-leaf sync-path delays from ``model``
    (around the topology's nominal link delays), classify stragglers
    against the fleet :class:`StepTimer` window (median + MAD), let each
    leaf's :class:`BoundedSkip` decide whether the barrier drops it (at
    most ``max_consecutive`` consecutive skips, then a forced barrier), and
    account the simulated wall-clock both ways:

      * ``dt_sync``  = compute + max over ALL leaves' delays (the paper's
        synchronous barrier, throttled by the slowest link), and
      * ``dt_async`` = compute + max over PARTICIPATING leaves only (the
        straggler's uplink no longer gates the round).

    The emitted per-leaf mask covers the whole chunk -- the chunk boundary
    is the staleness point, so a dropped leaf keeps solving on its stale
    snapshots and re-joins with a bounded-staleness delta (see
    ``docs/architecture.md``).  The final chunk always runs a full barrier
    (``force_final_barrier``) so the run ends with every replica agreeing
    with ``w = A alpha``.  ``adaptive`` (optional) is re-fed the observed
    delay medians every chunk; its replanned H is reported in the step
    info AND applied by the session: ``Session.run`` feeds ``h_suggest``
    into the next chunk's runtime step-mask operand (clamped to the
    compiled H capacity -- compile with ``Schedule(h_cap=...)`` for
    headroom), so replanning never retraces."""
    model: StragglerModel = dataclasses.field(default_factory=StragglerModel)
    max_consecutive: int = 2
    seed: int = 0
    warmup: int = 1          # chunks before skip decisions kick in
    k_mad: float = 5.0
    rel_floor: float = 0.5
    force_final_barrier: bool = True
    adaptive: Optional[AdaptiveSchedule] = None

    def bind(self, base_delays, t_compute: float, t_lp: float = 0.0) -> None:
        """(Re)start per-run state: nominal per-leaf sync-path delays and
        the compute-only per-chunk time.  Called by ``Session.run``.

        Re-binding the same policy (a warm-restarted continuation run)
        advances the delay stream instead of replaying it: the first run
        is reproducible from ``seed``, and split runs sample a fresh
        continuation of the simulated network process."""
        self._base = np.asarray(base_delays, dtype=np.float64)
        self._t_compute = float(t_compute)
        self._t_lp = float(t_lp)
        self._runs = getattr(self, "_runs", -1) + 1
        self._rng = np.random.default_rng([self.seed, self._runs])
        self._timer = StepTimer()
        self._skips = [BoundedSkip(max_consecutive=self.max_consecutive)
                       for _ in range(len(self._base))]
        self._chunk = 0
        self.last_h_suggest: Optional[int] = None

    def retime(self, t_compute: float) -> None:
        """Update the per-chunk compute time mid-run.  ``Session.run``
        calls this when adaptive replanning changes the executed H, so
        the simulated async/sync clocks charge the work that actually
        runs, not the H the run started with."""
        self._t_compute = float(t_compute)

    def step(self, final: bool = False) -> StragglerStep:
        """Decide one chunk; ``final`` forces the closing full barrier."""
        n = len(self._base)
        d = self.model.sample(self._base, self._rng)
        warm = self._chunk >= self.warmup
        stall = np.array([
            warm and self._timer.is_straggling(
                float(d[i]), k=self.k_mad, rel_floor=self.rel_floor)
            for i in range(n)
        ])
        if final and self.force_final_barrier:
            for s in self._skips:
                s.skipped = 0
            skip = np.zeros(n, dtype=bool)
        else:
            skip = np.array([self._skips[i].decide(bool(stall[i]))
                             for i in range(n)])
        for i in range(n):
            self._timer.observe(float(d[i]))
        self._chunk += 1
        mask = (~skip).astype(np.float32)
        dt_sync = self._t_compute + float(d.max(initial=0.0))
        part = d[~skip]
        dt_async = self._t_compute + float(part.max(initial=0.0))
        h = None
        if self.adaptive is not None:
            h = self.adaptive.replan(
                t_lp=max(self._t_lp, 1e-9), t_delay=float(np.median(d)))
            self.last_h_suggest = h
        return StragglerStep(mask=mask, dt_async=dt_async, dt_sync=dt_sync,
                             delays=d, h_suggest=h)
