"""Straggler mitigation -- the paper's own mechanism, operationalized.

The paper's core observation (§6): when a link/worker is slow, do MORE
local work per sync (larger H) instead of letting the barrier idle the
fleet. TreeSync exposes per-level sync periods; this module turns observed
per-step timing into updated periods via the paper's eq. (12), plus a
bounded-skip barrier policy for transient stragglers.

No real cluster exists in this container, so the observation side is an
interface (`StepTimer.observe`) fed by the launcher; the *decision* side
(re-optimizing H, skip decisions) is pure and fully tested.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.delay import optimal_h


@dataclasses.dataclass
class StepTimer:
    """Online robust timing stats per sync level (median + MAD)."""
    window: int = 64

    def __post_init__(self):
        self.samples: List[float] = []

    def observe(self, seconds: float) -> None:
        self.samples.append(seconds)
        if len(self.samples) > self.window:
            self.samples.pop(0)

    @property
    def median(self) -> float:
        return float(np.median(self.samples)) if self.samples else 0.0

    @property
    def mad(self) -> float:
        if not self.samples:
            return 0.0
        m = self.median
        return float(np.median(np.abs(np.array(self.samples) - m)))

    def is_straggling(self, seconds: float, k: float = 5.0,
                      rel_floor: float = 0.2) -> bool:
        """Is this step an outlier vs the recent window? Requires BOTH a
        k-MAD exceedance and a minimum relative slowdown (a 1% blip on a
        perfectly steady cluster is not a straggler)."""
        if len(self.samples) < 8:
            return False
        return seconds > max(self.median + k * self.mad,
                             self.median * (1.0 + rel_floor))


@dataclasses.dataclass
class AdaptiveSchedule:
    """Re-optimize the paper's H when the observed delay drifts.

    C, delta: the convergence-bound constants of eq. (11)-(12);
    t_total: the planning horizon; re-planning uses the *measured*
    t_lp (local step) and t_delay (sync barrier) medians.
    """
    C: float = 0.5
    delta: float = 1e-3
    t_total: float = 3600.0
    K: int = 2
    h_max: int = 4096
    hysteresis: float = 1.3   # only change H when >30% off current optimum

    current_h: int = 1

    def replan(self, t_lp: float, t_delay: float, t_cp: float = 0.0) -> int:
        h, _ = optimal_h(C=self.C, K=self.K, delta=self.delta,
                         t_total=self.t_total, t_lp=max(t_lp, 1e-9),
                         t_delay=max(t_delay, 0.0), t_cp=t_cp,
                         h_max=self.h_max)
        if (max(h, self.current_h) / max(min(h, self.current_h), 1)
                >= self.hysteresis):
            self.current_h = h
        return self.current_h


@dataclasses.dataclass
class BoundedSkip:
    """Transient-straggler policy: a sync round may be skipped (local work
    continues) at most `max_consecutive` times, then the barrier is forced.
    This bounds replica divergence: with period H and at most s skips, any
    two replicas are never more than H*(s+1) local steps apart -- the same
    bounded-staleness object the paper's tree analysis tolerates (each
    subtree runs more local rounds before the parent round closes)."""
    max_consecutive: int = 2
    skipped: int = 0

    def decide(self, barrier_would_stall: bool) -> bool:
        """True => skip the sync this round."""
        if barrier_would_stall and self.skipped < self.max_consecutive:
            self.skipped += 1
            return True
        self.skipped = 0
        return False
