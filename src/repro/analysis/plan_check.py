"""Plan-IR verifier: structural invariants of a lowered ``TreePlan`` (and
its method-agnostic ``SchedulePlan`` view), checked in O(plan size) host
numpy -- no tracing, no device work -- so ``Session.compile`` runs it on
every plan (the ``BENCH_engine.json`` ``analysis`` scenario gates the
overhead at <= 5% of compile time).

Invariant families
------------------

GEOMETRY      block layout coherent: offsets are the size cumsum, ``m_b``
              the max block, ``h_max`` the max capacity, tick/depth
              counts positive.
SHAPES        every per-tick / per-(depth, leaf) array has the schedule's
              exact shape and (for masks) is 0/1 -- a mask with a stray
              value multiplies deltas by it silently.
SCHEDULE      derived schedule fields are exactly their definitions:
              ``refresh_mask`` the running max of ``sync_mask`` over
              depth, ``root_sync`` the depth-0 event row, and the last
              tick ends a root round (the chunk-carry completeness that
              ``Session.run``'s exactness rests on).
AGGREGATION   each sync event covers whole contiguous groups, child
              weights are a convex combination (per-group ``w_coeff``
              sums to 1, ``alpha_scale`` in (0, 1]), and
              ``w_coeff == alpha_scale / child_size`` leaf-wise -- the
              paper's eq.-(13) ``w = A alpha`` preservation.
COMPRESSION   per-(depth, edge) specs valid: known kind codes, top-k
              fractions in (0, 1], zero fractions elsewhere, and one
              spec per child edge (every leaf of a child shares its
              up-link).
RNG           schedule-independence of the key/draw stream: runtime step
              masks can never exceed the compiled per-leaf draw capacity
              (``steps_for_h`` clamps to ``leaf_h``), so no runtime
              schedule can perturb which randints are drawn.
FINGERPRINT   the soundness audit (:func:`audit_fingerprint`): every
              dataclass field of ``TreePlan`` is classified in the plan
              IR's fingerprint registry (behavior / derived / metadata),
              derived fields really are recomputable, and perturbing any
              behavior field changes the fingerprint -- i.e. two
              semantically distinct plans cannot collide on the executor
              cache key (the bug class PRs 4 and 6 fixed ad hoc).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.core import compression as comp_mod
from repro.core.engine import plan as plan_mod
from repro.core.engine.plan import SchedulePlan, TreePlan


@dataclasses.dataclass(frozen=True)
class Finding:
    """One verifier finding: a machine code, where it sits, and an
    actionable message (what is wrong + what to change)."""
    code: str        # e.g. "P102"
    where: str       # e.g. "sync_mask" or "fingerprint-registry"
    message: str

    def __str__(self):
        return f"[{self.code}] {self.where}: {self.message}"


class AnalysisError(ValueError):
    """Raised by :func:`verify_plan` when a plan violates an invariant;
    carries the full finding list."""

    def __init__(self, findings: List[Finding]):
        self.findings = list(findings)
        lines = "\n  ".join(str(f) for f in self.findings)
        super().__init__(
            f"plan verification failed with {len(self.findings)} "
            f"finding(s):\n  {lines}")


def _is_binary(a: np.ndarray) -> bool:
    return bool(np.isin(np.unique(a), (0.0, 1.0)).all())


# ---------------------------------------------------------------------------
# TreePlan structural checks
# ---------------------------------------------------------------------------
def check_tree_plan(plan: TreePlan) -> List[Finding]:
    """All structural findings for ``plan`` (empty list == verified)."""
    out: List[Finding] = []
    add = lambda c, w, m: out.append(Finding(c, w, m))  # noqa: E731
    n, S, D = plan.n_leaves, plan.n_ticks, plan.depth

    # ---- geometry ------------------------------------------------------
    if n < 1 or S < 1 or D < 1:
        add("P100", "geometry",
            f"need n_leaves, n_ticks, depth >= 1; got ({n}, {S}, {D}) -- "
            "compile plans through engine.plan.compile_tree")
        return out  # nothing below is meaningful
    sizes = np.asarray(plan.leaf_sizes)
    if sizes.shape != (n,) or (sizes < 1).any():
        add("P101", "leaf_sizes",
            f"expected (n={n},) positive ints, got shape {sizes.shape} "
            f"min {sizes.min() if sizes.size else '-'}")
    else:
        if int(sizes.max()) != plan.m_b:
            add("P101", "m_b",
                f"m_b={plan.m_b} != max leaf block {int(sizes.max())}; "
                "the blocked (n, m_b) layout would truncate a leaf")
        if int(sizes.sum()) != plan.m_total:
            add("P101", "m_total",
                f"m_total={plan.m_total} != sum(leaf_sizes)="
                f"{int(sizes.sum())}")
        offs = np.concatenate([[0], np.cumsum(sizes)[:-1]])
        if not np.array_equal(np.asarray(plan.leaf_offsets), offs):
            add("P101", "leaf_offsets",
                "leaf_offsets is not the cumulative sum of leaf_sizes; "
                "the flat<->blocked alpha maps would scatter wrong rows")
    leaf_h = np.asarray(plan.leaf_h)
    if leaf_h.shape != (n,) or (leaf_h < 1).any():
        add("P102", "leaf_h",
            f"per-leaf H capacity must be (n={n},) ints >= 1, got shape "
            f"{leaf_h.shape}")
    elif int(leaf_h.max()) != plan.h_max:
        add("P102", "h_max",
            f"h_max={plan.h_max} != max(leaf_h)={int(leaf_h.max())}; "
            "step masks and draw shapes would disagree")
    if len(plan.leaf_names) != n or len(set(plan.leaf_names)) != n:
        add("P103", "leaf_names",
            f"need {n} unique leaf names, got {len(plan.leaf_names)} "
            f"({len(set(plan.leaf_names))} unique) -- plan_diff keys "
            "membership on names")

    # ---- shapes --------------------------------------------------------
    expect = {
        "solve_mask": (S, n), "sync_mask": (S, D, n),
        "refresh_mask": (S, D, n), "root_sync": (S,),
        "alpha_scale": (D, n), "w_coeff": (D, n), "group_ids": (D, n),
        "child_ids": (D, n), "child_sizes": (D, n),
        "compress_kind": (D, n), "compress_frac": (D, n),
    }
    bad_shape = set()
    for name, shp in expect.items():
        a = np.asarray(getattr(plan, name))
        if a.shape != shp:
            bad_shape.add(name)
            add("P110", name,
                f"expected shape {shp} for (S={S}, D={D}, n={n}), got "
                f"{a.shape} -- executors would broadcast or crash "
                "mid-trace")
    for name in ("solve_mask", "sync_mask", "refresh_mask"):
        if name in bad_shape:
            continue
        a = np.asarray(getattr(plan, name))
        if not _is_binary(a):
            add("P111", name,
                "schedule masks must be 0/1 (they multiply deltas); "
                f"found values {np.setdiff1d(np.unique(a), (0.0, 1.0))[:4]}")
    if len(plan.n_groups) != D or len(plan.n_children) != D:
        add("P112", "n_groups/n_children",
            f"need one segment count per depth (D={D}); got "
            f"{len(plan.n_groups)} / {len(plan.n_children)}")

    if bad_shape or len(plan.n_groups) != D or len(plan.n_children) != D:
        return out  # the schedule/aggregation checks index these arrays

    sync = np.asarray(plan.sync_mask)
    solve = np.asarray(plan.solve_mask)

    # ---- schedule coherence -------------------------------------------
    if not np.array_equal(np.asarray(plan.refresh_mask),
                          np.maximum.accumulate(sync, axis=1)):
        add("P120", "refresh_mask",
            "refresh_mask != running max of sync_mask over depth: a "
            "snapshot would go stale (or refresh early) relative to its "
            "ancestor's sync -- recompute it, don't hand-edit plans")
    root = sync[:, 0, :].max(axis=1) > 0.0
    if not np.array_equal(np.asarray(plan.root_sync), root):
        add("P121", "root_sync",
            "root_sync != (sync_mask depth-0 row has an event): chunked "
            "sessions would cut carries at non-root ticks")
    if not bool(root[-1]):
        add("P122", "root_sync",
            "the last tick must end a root round (root syncs refresh "
            "every snapshot; Session.run's exact chunk carry depends on "
            "it) -- the plan's span does not cover whole root rounds")
    if not solve.any(axis=0).all():
        idle = [plan.leaf_names[i]
                for i in np.nonzero(~solve.any(axis=0))[0][:4]]
        add("P123", "solve_mask",
            f"leaves {idle} never solve; their alpha blocks would be "
            "dead weight and their RNG keys unused")

    # ---- aggregation ---------------------------------------------------
    # Only leaves that ever sync at depth d carry meaningful depth-d
    # columns: a shallow leaf outside every depth-d subtree keeps the
    # lowering's default zeros in group/child/w columns, and no executor
    # ever reads them (its sync_mask row is 0 there).
    ascale = np.asarray(plan.alpha_scale)
    wcoef = np.asarray(plan.w_coeff)
    gids = np.asarray(plan.group_ids)
    cids = np.asarray(plan.child_ids)
    csize = np.asarray(plan.child_sizes)
    for d in range(D):
        act = sync[:, d, :].max(axis=0) > 0.0
        if not act.any():
            continue
        ng, nc = plan.n_groups[d], plan.n_children[d]
        g_a, c_a = gids[d][act], cids[d][act]
        if g_a.min() < 0 or g_a.max() >= ng:
            add("P130", f"group_ids[depth {d}]",
                f"ids must lie in [0, n_groups[{d}]={ng}); got "
                f"[{g_a.min()}, {g_a.max()}] -- segment sums would drop "
                "or alias groups")
            continue
        if c_a.min() < 0 or c_a.max() >= nc:
            add("P130", f"child_ids[depth {d}]",
                f"ids must lie in [0, n_children[{d}]={nc}); got "
                f"[{c_a.min()}, {c_a.max()}]")
            continue
        # groups and children are contiguous leaf ranges (the lowering
        # indexes subtrees as [lo:hi) slices)
        pos = np.nonzero(act)[0]
        for name, ids in (("group_ids", g_a), ("child_ids", c_a)):
            ok = True
            for u in np.unique(ids):
                where = pos[ids == u]
                ok &= int(where.max() - where.min()) == len(where) - 1
            if not ok:
                add("P131", f"{name}[depth {d}]",
                    "segment ids must tile contiguous leaf ranges "
                    "(subtrees are [lo:hi) slices); found an id that "
                    "recurs after a different id")
        # every child nests inside exactly one group
        for c in np.unique(c_a):
            gs = np.unique(g_a[c_a == c])
            if len(gs) != 1:
                add("P132", f"child_ids[depth {d}]",
                    f"child {c} spans groups {gs.tolist()}; a sync would "
                    "average across different parents")
        # child_sizes is the actual member count
        counts = np.bincount(c_a, minlength=nc)
        if not np.array_equal(csize[d][act],
                              counts[c_a].astype(csize.dtype)):
            add("P133", f"child_sizes[depth {d}]",
                "child_sizes != leaf count of the child subtree; the "
                "|child|/|present| participation correction would "
                "mis-scale partial children")
        # convex combination per group; eq.-(13) preservation
        if (ascale[d][act] <= 0).any() or (ascale[d][act] > 1).any():
            add("P134", f"alpha_scale[depth {d}]",
                f"child weights must lie in (0, 1]; got "
                f"[{ascale[d][act].min():.3g}, "
                f"{ascale[d][act].max():.3g}]")
        wsum = np.zeros(ng)
        np.add.at(wsum, g_a, wcoef[d][act])
        live = np.zeros(ng, bool)
        live[np.unique(g_a)] = True
        if not np.allclose(wsum[live], 1.0, atol=1e-5):
            add("P135", f"w_coeff[depth {d}]",
                f"per-group w-average weights must sum to 1 (convex "
                f"combination preserves w = A alpha, paper eq. (13)); "
                f"got sums in [{wsum[live].min():.6g}, "
                f"{wsum[live].max():.6g}]")
        if not np.allclose(wcoef[d][act] * csize[d][act], ascale[d][act],
                           atol=1e-5):
            add("P136", f"w_coeff[depth {d}]",
                "w_coeff != alpha_scale / child_size leaf-wise: the "
                "alpha rescale and the w average would apply different "
                "child weights, breaking w = A alpha at the sync")
        # sync events cover whole groups
        ev = sync[:, d, :]
        for s in np.nonzero(ev.any(axis=1))[0]:
            on = ev[s] > 0
            touched = np.unique(gids[d][on])
            full = act & np.isin(gids[d], touched)
            if not np.array_equal(on, full):
                add("P137", f"sync_mask[tick {s}, depth {d}]",
                    "a sync event must cover every leaf of each "
                    "participating group (partial attendance is the "
                    "RUNTIME participation mask's job, not the plan's)")
                break

    # ---- compression specs --------------------------------------------
    kind = np.asarray(plan.compress_kind)
    frac = np.asarray(plan.compress_frac)
    known = (comp_mod.KIND_NONE, comp_mod.KIND_INT8, comp_mod.KIND_TOPK)
    if not np.isin(kind, known).all():
        add("P140", "compress_kind",
            f"unknown kind codes {np.setdiff1d(np.unique(kind), known)}; "
            "use repro.core.compression.KIND_*")
    else:
        topk = kind == comp_mod.KIND_TOPK
        if ((frac[topk] <= 0.0) | (frac[topk] > 1.0)).any():
            add("P141", "compress_frac",
                f"top-k fraction must lie in (0, 1]; got "
                f"[{frac[topk].min():.3g}, {frac[topk].max():.3g}] -- "
                "parse specs through compression.parse_spec")
        if (frac[~topk] != 0.0).any():
            add("P142", "compress_frac",
                "non-top-k edges must carry frac=0 (the fraction is "
                "top-k's parameter; a stray value changes the "
                "fingerprint without changing behavior)")
        for d in range(D):
            act = sync[:, d, :].max(axis=0) > 0.0
            for c in np.unique(cids[d][act]):
                rows = act & (cids[d] == c)
                pairs = {(int(k), float(f))
                         for k, f in zip(kind[d][rows], frac[d][rows],
                                         strict=True)}
                if len(pairs) > 1:
                    add("P143", f"compress_kind[depth {d}]",
                        f"child {c} mixes specs "
                        f"{sorted(comp_mod.spec_name(*p) for p in pairs)} "
                        "across its leaves; an up-link is ONE edge and "
                        "must compress uniformly")

    # ---- RNG schedule-independence ------------------------------------
    if not out:  # shapes are sane; the functional check is meaningful
        cap = plan_mod.steps_for_h(plan, np.full((n,), 1 << 30, np.int64))
        want = (np.arange(plan.h_max)[None, :]
                < leaf_h[:, None]).astype(np.float32)
        if not np.array_equal(cap, np.broadcast_to(want[None], cap.shape)):
            add("P150", "steps_for_h",
                "a maximal runtime step mask exceeds the compiled "
                "per-leaf draw capacity: runtime schedules could "
                "perturb the randint stream, breaking the "
                "schedule-independent RNG contract (draws must always "
                "cover leaf_h)")

    # ---- fingerprint ---------------------------------------------------
    if not plan.fingerprint:
        add("P160", "fingerprint",
            "empty fingerprint: the executor cache would key every plan "
            "to one entry")
    elif plan.fingerprint != plan_mod.compute_fingerprint(plan):
        add("P161", "fingerprint",
            "stored fingerprint != recomputed canonical hash: the plan "
            "was mutated after construction (plans are frozen; build a "
            "new one via dataclasses.replace with fingerprint='')")
    out.extend(audit_fingerprint(plan))
    return out


# ---------------------------------------------------------------------------
# fingerprint-soundness audit
# ---------------------------------------------------------------------------
def audit_fingerprint(plan: Optional[TreePlan] = None) -> List[Finding]:
    """The soundness audit of the plan IR's executor cache key.

    Class-level (always): every dataclass field of ``TreePlan`` must be
    classified in the fingerprint registry
    (``plan.FINGERPRINT_ARRAY_FIELDS`` / ``FINGERPRINT_SCALAR_FIELDS`` /
    ``DERIVED_FIELDS`` / ``METADATA_FIELDS``) exactly once.  A field
    added without classification fails HERE, at analysis time -- not
    three PRs later when two distinct plans silently share a compiled
    executor (the PR-4 lambda / PR-6 compression cache-key bug class).

    Instance-level (when ``plan`` is given): derived fields really are
    recomputable from behavior fields, and perturbing each cheap
    behavior field changes the fingerprint (collision spot-check; the
    exhaustive per-field mutation audit lives in
    ``tests/test_analysis.py``)."""
    out: List[Finding] = []
    fields = {f.name for f in dataclasses.fields(TreePlan)}
    reg = {
        "behavior-array": set(plan_mod.FINGERPRINT_ARRAY_FIELDS),
        "behavior-scalar": set(plan_mod.FINGERPRINT_SCALAR_FIELDS),
        "derived": set(plan_mod.DERIVED_FIELDS),
        "metadata": set(plan_mod.METADATA_FIELDS),
    }
    seen: dict = {}
    for cls, names in reg.items():
        for nm in names:
            if nm in seen:
                out.append(Finding(
                    "F200", "fingerprint-registry",
                    f"field {nm!r} classified twice ({seen[nm]} and "
                    f"{cls}); a field has exactly one cache-key role"))
            seen[nm] = cls
            if nm not in fields:
                out.append(Finding(
                    "F201", "fingerprint-registry",
                    f"registry names {nm!r} but TreePlan has no such "
                    "field; remove the stale entry"))
    missing = fields - set(seen)
    if missing:
        out.append(Finding(
            "F202", "fingerprint-registry",
            f"TreePlan field(s) {sorted(missing)} are not classified in "
            "the fingerprint registry: decide whether each is compiled "
            "behavior (hash it), derived (prove it), or metadata "
            "(document it) in engine/plan.py -- an unclassified "
            "behavior field lets two distinct plans collide on the "
            "executor cache key"))
    if plan is None or out:
        return out

    # derived fields really are derived
    root = np.asarray(plan.sync_mask)[:, 0, :].max(axis=1) > 0.0
    if not np.array_equal(np.asarray(plan.root_sync), root):
        out.append(Finding(
            "F210", "root_sync",
            "classified derived but does not equal its derivation from "
            "sync_mask; either fix the plan or promote the field to a "
            "hashed behavior field"))
    cids = np.asarray(plan.child_ids)
    derived_nc = tuple(max(int(cids[d].max()) + 1, 1)
                       for d in range(plan.depth))
    if tuple(plan.n_children) != derived_nc:
        out.append(Finding(
            "F210", "n_children",
            f"classified derived but {tuple(plan.n_children)} != "
            f"max(child_ids)+1 per depth {derived_nc}; promote it to a "
            "hashed behavior field or fix the lowering"))

    # collision spot-check on the cheap scalar fields
    base = plan.fingerprint
    probe = dataclasses.replace(plan, weighting=plan.weighting + "?",
                                fingerprint="")
    if probe.fingerprint == base:
        out.append(Finding(
            "F220", "weighting",
            "perturbing a behavior field left the fingerprint unchanged "
            "-- the canonical serialization dropped it"))
    arr = np.array(plan.compress_kind, copy=True)
    arr[0, 0] = comp_mod.KIND_INT8 if arr[0, 0] != comp_mod.KIND_INT8 \
        else comp_mod.KIND_TOPK
    probe = dataclasses.replace(plan, compress_kind=arr, fingerprint="")
    if probe.fingerprint == base:
        out.append(Finding(
            "F220", "compress_kind",
            "changing an edge codec left the fingerprint unchanged: the "
            "exact PR-6 bug (compressed and uncompressed plans sharing "
            "one executor)"))
    return out


# ---------------------------------------------------------------------------
# SchedulePlan checks
# ---------------------------------------------------------------------------
def check_schedule_plan(sview: SchedulePlan) -> List[Finding]:
    """Structural findings for a method-agnostic schedule view."""
    out: List[Finding] = []
    D = sview.depth
    if len(sview.periods) != D:
        out.append(Finding(
            "S300", "periods",
            f"need one period per level (depth={D}, bottom-up: leaf H "
            f"first); got {len(sview.periods)}"))
    if any(int(p) < 1 for p in sview.periods):
        out.append(Finding(
            "S301", "periods",
            f"periods must be >= 1 (a 0 period never syncs its level); "
            f"got {tuple(sview.periods)}"))
    if any(int(g) < 1 for g in sview.group_sizes):
        out.append(Finding(
            "S302", "group_sizes",
            f"level fan-outs must be >= 1; got "
            f"{tuple(sview.group_sizes)}"))
    if len(sview.compression) != D:
        out.append(Finding(
            "S303", "compression",
            f"need one up-link codec per level; got "
            f"{len(sview.compression)} for depth {D}"))
    for i, spec in enumerate(sview.compression):
        try:
            comp_mod.parse_spec(spec)
        except (ValueError, TypeError) as e:
            out.append(Finding(
                "S304", f"compression[{i}]", str(e)))
    if not sview.fingerprint:
        out.append(Finding(
            "S305", "fingerprint",
            "schedule view carries no plan fingerprint; LM executors "
            "could not be cache-keyed"))
    return out


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------
def verify_plan(plan, *, schedule_view: bool = True) -> None:
    """Verify ``plan`` (a :class:`TreePlan` or :class:`SchedulePlan`) and
    raise :class:`AnalysisError` listing every violated invariant.

    ``Session.compile`` calls this on every lowered plan; by default the
    level-homogeneous schedule view is additionally checked when the plan
    has one (mesh/LM consumers)."""
    if isinstance(plan, SchedulePlan):
        findings = check_schedule_plan(plan)
    elif isinstance(plan, TreePlan):
        findings = check_tree_plan(plan)
        if schedule_view and plan.levels is not None:
            leaf_h = np.asarray(plan.leaf_h)
            if plan.n_leaves and (leaf_h == leaf_h[0]).all():
                findings += check_schedule_plan(
                    plan_mod.schedule_view(plan))
    else:
        raise TypeError(
            f"verify_plan takes a TreePlan or SchedulePlan, got "
            f"{type(plan).__name__}")
    if findings:
        raise AnalysisError(findings)
