"""Repo-specific AST lint rules (``python -m repro.analysis``): the
discipline the engine's architecture depends on but generic linters
cannot see.

Rule catalog (``docs/analysis.md`` has the rationale in full):

``wall-clock-in-trace``
    No ``time.time()`` / ``time.perf_counter()`` / ``datetime.now()``
    inside a traced body: a traced call evaluates ONCE at trace time and
    bakes the timestamp into the compiled program (measure around the
    dispatch, not inside it).
``python-random-in-trace``
    No Python-level ``random.*`` / ``np.random.*`` inside a traced body:
    same trace-once constant-folding, plus it breaks the replayable
    ``jax.random`` key discipline that makes backends bit-comparable.
``static-operand-capture``
    Runtime operands (``lam``/``lr``/``local_h``/``periods``/
    ``participation``) must reach a traced body as ARGUMENTS, never as
    closure captures: a captured Python float is a compile-time
    constant, so every sweep point retraces (the PR-4 lambda bug class).
``jit-outside-engine``
    ``jax.jit`` belongs in ``core/engine`` and ``kernels`` (plus
    explicitly waived call sites): stray jits fragment the executor
    caches, dodge the cache-stats accounting strict mode budgets, and
    hide retraces the trace guard cannot see.
``mutable-default-in-frozen-dataclass``
    No mutable literal defaults in frozen dataclasses; plans and configs
    are hashed/compared, and a shared mutable default aliases state
    across instances.
``undonated-carry``
    Engine jits of chunk-carry step functions (``step*`` /
    ``program_state*``) must pass ``donate_argnums``: callers rebind
    ``state = step(...)`` every chunk, so an undonated carry doubles the
    peak state footprint and forces XLA to allocate fresh buffers per
    round instead of updating in place.

Waivers: append ``# analysis: allow(<rule-name>)`` on the offending
line (or the ``def``/``class`` line that owns the body) -- every waiver
is a reviewed, documented exception, greppable as a set.
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Iterable, List, Optional, Sequence, Set


@dataclasses.dataclass(frozen=True)
class LintFinding:
    rule: str
    path: str
    line: int
    message: str

    def __str__(self):
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


# directories whose files may call jax.jit freely (the engine owns the
# executor caches; kernels wrap their own dispatch)
JIT_ALLOWED_PREFIXES = ("src/repro/core/engine/", "src/repro/kernels/")
# jit discipline only binds library code; tests/benchmarks/examples jit
# ad hoc by design (they ARE the call sites being measured)
JIT_RULE_SCOPE_PREFIX = "src/repro/"

WALLCLOCK_CALLS = {
    ("time", "time"), ("time", "perf_counter"), ("time", "monotonic"),
    ("time", "process_time"), ("datetime", "now"), ("datetime", "utcnow"),
}
PYRANDOM_MODULES = {"random"}
NUMPY_RANDOM_ATTR = "random"   # np.random.* inside a traced body
# runtime operands of the schedule engine: these names reaching a traced
# body as free variables (closure captures) instead of arguments is the
# retrace-per-sweep-point bug class
RUNTIME_OPERANDS = {"lam", "lr", "local_h", "periods", "participation",
                    "acceleration"}

# chunk-carry step functions (rebind ``state = step(...)`` per chunk);
# jitting one in the engine without buffer donation doubles the carry's
# peak footprint -- see the ``undonated-carry`` rule
CARRY_STEP_PREFIXES = ("step", "program_state")
# transforms a carry step may be wrapped in on its way into jax.jit
_CARRY_WRAPPERS = {"jax.vmap", "vmap", "shard_map",
                   "jax.experimental.shard_map.shard_map"}

_ALLOW_PREFIX = "# analysis: allow("


def _waivers(source: str) -> dict:
    """line number -> set of waived rule names."""
    out: dict = {}
    for i, line in enumerate(source.splitlines(), start=1):
        idx = line.find(_ALLOW_PREFIX)
        if idx < 0:
            continue
        inner = line[idx + len(_ALLOW_PREFIX):]
        inner = inner.split(")", 1)[0]
        out[i] = {r.strip() for r in inner.split(",") if r.strip()}
    return out


def _call_name(node: ast.AST) -> Optional[str]:
    """Dotted name of a call's function, e.g. ``jax.jit`` -> "jax.jit"."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_jit_expr(node: ast.AST) -> bool:
    """Does this expression produce a jitted/traced transform of a
    function?  Covers ``jax.jit``, ``jit``, ``functools.partial(jax.jit,
    ...)`` and ``jax.jit(f, ...)``."""
    name = _call_name(node)
    if name in ("jax.jit", "jit", "pjit", "jax.pjit"):
        return True
    if isinstance(node, ast.Call):
        fn = _call_name(node.func)
        if fn in ("jax.jit", "jit", "pjit", "jax.pjit"):
            return True
        if fn in ("functools.partial", "partial") and node.args:
            return _is_jit_expr(node.args[0])
    return False


TRACING_TRANSFORMS = {
    "jax.jit", "jit", "jax.pjit", "pjit",
    "jax.vmap", "vmap", "jax.pmap", "pmap",
    "jax.grad", "grad", "jax.value_and_grad", "value_and_grad",
    "jax.lax.scan", "lax.scan", "scan",
    "jax.lax.fori_loop", "lax.fori_loop", "fori_loop",
    "jax.lax.while_loop", "lax.while_loop", "while_loop",
    "jax.lax.cond", "lax.cond",
    "jax.lax.map", "lax.map",
    "shard_map", "jax.experimental.shard_map.shard_map",
    "jax.checkpoint", "jax.remat",
    "pl.pallas_call", "pallas_call",
}


class _Analyzer(ast.NodeVisitor):
    """Single-pass file analyzer.

    Traced-function discovery (two sources, then closure over nesting):
      * decorated defs: ``@jax.jit``, ``@functools.partial(jax.jit, ..)``
      * call sites: a function NAME (or a ``def`` passed by name later)
        appearing as the function/first-arg of a tracing transform --
        ``jax.jit(step)``, ``lax.scan(body, ...)``, ``shard_map(f, ..)``.
    Any ``def`` nested inside a traced def is traced too (it runs under
    the same trace).
    """

    def __init__(self, path: str, tree: ast.Module, source: str):
        self.path = path
        self.tree = tree
        self.waivers = _waivers(source)
        self.findings: List[LintFinding] = []
        self.traced_defs: Set[ast.AST] = set()
        self._def_stack: List[ast.AST] = []
        self._parents: dict = {}
        for parent in ast.walk(tree):
            for child in ast.iter_child_nodes(parent):
                self._parents[child] = parent

    # -- helpers ---------------------------------------------------------
    def _emit(self, rule: str, node: ast.AST, message: str,
              owner: Optional[ast.AST] = None):
        lines = {getattr(node, "lineno", 0)}
        if owner is not None:
            lines.add(getattr(owner, "lineno", 0))
        for ln in lines:
            if rule in self.waivers.get(ln, ()):
                return
        self.findings.append(
            LintFinding(rule, self.path, getattr(node, "lineno", 0),
                        message))

    # -- traced-def discovery -------------------------------------------
    def collect_traced(self):
        named_defs: dict = {}
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                named_defs.setdefault(node.name, node)
                for dec in node.decorator_list:
                    if _is_jit_expr(dec) or \
                            _call_name(dec) in TRACING_TRANSFORMS or \
                            (isinstance(dec, ast.Call)
                             and _call_name(dec.func) in TRACING_TRANSFORMS):
                        self.traced_defs.add(node)
                    # functools.partial(jax.vmap, ...) style
                    if isinstance(dec, ast.Call) and \
                            _call_name(dec.func) in ("functools.partial",
                                                     "partial") and \
                            dec.args and \
                            _call_name(dec.args[0]) in TRACING_TRANSFORMS:
                        self.traced_defs.add(node)
        # names passed into tracing transforms
        traced_names: Set[str] = set()
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            fn = _call_name(node.func)
            if fn not in TRACING_TRANSFORMS:
                continue
            for arg in node.args[:2]:  # (f, ...) or scan(body, init, ...)
                if isinstance(arg, ast.Name):
                    traced_names.add(arg.id)
                elif isinstance(arg, (ast.Lambda,)):
                    self.traced_defs.add(arg)
        for name in traced_names:
            if name in named_defs:
                self.traced_defs.add(named_defs[name])
        # closure, to a fixed point, over two edges:
        #   * nesting -- a def inside a traced def runs under the trace;
        #   * calls -- a same-file def CALLED from a traced body executes
        #     under the trace too, so its parameters are tracers/operands
        #     there (without this edge, an operand threaded through a
        #     helper's argument list mis-reports as a closure capture)
        calls_in: dict = {}             # def node -> called names
        for node in ast.walk(self.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                     ast.Lambda)):
                continue
            names: Set[str] = set()
            body = node.body if not isinstance(node, ast.Lambda) \
                else [node.body]
            for stmt in body:
                for sub in ast.walk(stmt):
                    if isinstance(sub, ast.Call):
                        cn = _call_name(sub.func)
                        if cn is not None and "." not in cn:
                            names.add(cn)
            calls_in[node] = names
        changed = True
        while changed:
            changed = False
            for node in ast.walk(self.tree):
                if not isinstance(node, (ast.FunctionDef,
                                         ast.AsyncFunctionDef, ast.Lambda)):
                    continue
                if node in self.traced_defs:
                    continue
                p = self._parents.get(node)
                while p is not None:
                    if p in self.traced_defs:
                        self.traced_defs.add(node)
                        changed = True
                        break
                    p = self._parents.get(p)
            for caller in list(self.traced_defs):
                for cn in calls_in.get(caller, ()):
                    callee = named_defs.get(cn)
                    if callee is not None and \
                            callee not in self.traced_defs:
                        self.traced_defs.add(callee)
                        changed = True
        return self.traced_defs

    def _owning_def(self, node: ast.AST) -> Optional[ast.AST]:
        p = self._parents.get(node)
        while p is not None:
            if isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda)):
                return p
            p = self._parents.get(p)
        return None

    def _in_traced(self, node: ast.AST) -> Optional[ast.AST]:
        d = self._owning_def(node)
        while d is not None:
            if d in self.traced_defs:
                return d
            d = self._owning_def(d)
        return None

    # -- rules -----------------------------------------------------------
    def run(self) -> List[LintFinding]:
        self.collect_traced()
        self._rule_traced_bodies()
        self._rule_jit_location()
        self._rule_frozen_defaults()
        self._rule_undonated_carry()
        return self.findings

    def _rule_traced_bodies(self):
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            owner = self._in_traced(node)
            if owner is None:
                continue
            fn = _call_name(node.func)
            if fn is None:
                continue
            parts = tuple(fn.split("."))
            if len(parts) >= 2 and parts[-2:] in WALLCLOCK_CALLS:
                self._emit(
                    "wall-clock-in-trace", node,
                    f"{fn}() inside a traced body evaluates ONCE at "
                    "trace time (the compiled program reuses the baked "
                    "constant); time around the dispatch instead",
                    owner)
            if parts[0] in PYRANDOM_MODULES or \
                    (len(parts) >= 2 and parts[0] in ("np", "numpy")
                     and parts[1] == NUMPY_RANDOM_ATTR):
                self._emit(
                    "python-random-in-trace", node,
                    f"{fn}() inside a traced body is constant-folded at "
                    "trace time and breaks the replayable jax.random "
                    "key discipline; thread a PRNG key in as an operand",
                    owner)
        # static closure capture of runtime operands.  A load inside a
        # traced def is fine when the nearest enclosing def BINDING the
        # name is itself traced (the value is a tracer/operand there);
        # it is the bug when the binder is a non-traced builder or the
        # module scope -- the value crosses the trace boundary as a
        # baked compile-time constant.
        for sub in ast.walk(self.tree):
            if not (isinstance(sub, ast.Name)
                    and isinstance(sub.ctx, ast.Load)
                    and sub.id in RUNTIME_OPERANDS):
                continue
            owner = self._in_traced(sub)
            if owner is None:
                continue
            binder = None
            d = self._owning_def(sub)
            while d is not None:
                if sub.id in _bound_names(d):
                    binder = d
                    break
                d = self._owning_def(d)
            if binder is not None and binder in self.traced_defs:
                continue
            self._emit(
                "static-operand-capture", sub,
                f"traced body closes over runtime operand {sub.id!r} "
                "from outside the trace: a captured Python value is a "
                "compile-time constant, so every new value retraces "
                "(pass it as an argument; the executors take "
                "lambda/lr/step masks as operands)",
                owner)

    def _rule_jit_location(self):
        norm = self.path.replace("\\", "/")
        anchor = norm.find("src/repro/")
        rel = norm[anchor:] if anchor >= 0 else norm
        if not rel.startswith(JIT_RULE_SCOPE_PREFIX):
            return
        if any(rel.startswith(p) for p in JIT_ALLOWED_PREFIXES):
            return
        decorator_exprs = set()
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    decorator_exprs.add(id(dec))
                    if _is_jit_expr(dec):
                        self._emit(
                            "jit-outside-engine", dec,
                            "bare jax.jit outside core/engine + kernels: "
                            "stray jits fragment the executor caches and "
                            "dodge the cache-stats accounting strict "
                            "mode budgets.  Route through the engine "
                            "executors, or waive with '# analysis: "
                            "allow(jit-outside-engine)' and a reason",
                            node)
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Call) and id(node) not in \
                    decorator_exprs and _is_jit_expr(node):
                self._emit(
                    "jit-outside-engine", node,
                    "bare jax.jit outside core/engine + kernels: stray "
                    "jits fragment the executor caches and dodge the "
                    "cache-stats accounting strict mode budgets.  Route "
                    "through the engine executors, or waive with "
                    "'# analysis: allow(jit-outside-engine)' and a "
                    "reason")

    def _rule_undonated_carry(self):
        """Engine-only: a ``jax.jit`` whose jitted function is a
        chunk-carry step (name ``step*`` / ``program_state*``, possibly
        wrapped in ``jax.vmap`` / ``shard_map``) must donate the carry
        via ``donate_argnums`` -- callers rebind ``state = step(...)``
        every chunk, so the previous carry is dead the moment the call
        dispatches and its buffers should be reused in place."""
        norm = self.path.replace("\\", "/")
        anchor = norm.find("src/repro/")
        rel = norm[anchor:] if anchor >= 0 else norm
        if not rel.startswith("src/repro/core/engine/"):
            return

        def _carry_target(arg) -> Optional[str]:
            # unwrap vmap/shard_map layers down to the named function
            while isinstance(arg, ast.Call) and \
                    _call_name(arg.func) in _CARRY_WRAPPERS:
                if not arg.args:
                    return None
                arg = arg.args[0]
            name = _call_name(arg)
            if name is not None and any(
                    name.split(".")[-1].startswith(p)
                    for p in CARRY_STEP_PREFIXES):
                return name
            return None

        for node in ast.walk(self.tree):
            if not (isinstance(node, ast.Call)
                    and _call_name(node.func) in ("jax.jit", "jit",
                                                  "jax.pjit", "pjit")
                    and node.args):
                continue
            target = _carry_target(node.args[0])
            if target is None:
                continue
            if any(kw.arg == "donate_argnums" for kw in node.keywords):
                continue
            self._emit(
                "undonated-carry", node,
                f"jax.jit of chunk-carry step {target!r} without "
                "donate_argnums: callers rebind state = step(...) every "
                "chunk, so the undonated carry doubles the peak state "
                "footprint (donate the state argument, or waive with "
                "'# analysis: allow(undonated-carry)' and a reason)")

    def _rule_frozen_defaults(self):
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            frozen = False
            for dec in node.decorator_list:
                name = _call_name(dec.func if isinstance(dec, ast.Call)
                                  else dec)
                if name in ("dataclasses.dataclass", "dataclass"):
                    if isinstance(dec, ast.Call):
                        for kw in dec.keywords:
                            if kw.arg == "frozen" and \
                                    isinstance(kw.value, ast.Constant) and \
                                    kw.value.value is True:
                                frozen = True
            if not frozen:
                continue
            for stmt in node.body:
                if not isinstance(stmt, ast.AnnAssign) or stmt.value is None:
                    continue
                if isinstance(stmt.value, (ast.List, ast.Dict, ast.Set)) or \
                        (isinstance(stmt.value, ast.Call)
                         and _call_name(stmt.value.func) in
                         ("list", "dict", "set", "bytearray")):
                    self._emit(
                        "mutable-default-in-frozen-dataclass", stmt,
                        "mutable literal default in a frozen dataclass: "
                        "the object is shared across every instance (and "
                        "frozen classes are hashed/compared as values); "
                        "use dataclasses.field(default_factory=...) or a "
                        "tuple", node)


def _bound_names(fn) -> Set[str]:
    """Names bound in ``fn``'s OWN scope: parameters plus assignments
    directly in its body (nested defs contribute their name, not their
    locals -- matching Python scoping, so a Name not bound here resolves
    to an enclosing scope)."""
    out: Set[str] = set()
    args = fn.args
    for a in (list(args.posonlyargs) + list(args.args)
              + list(args.kwonlyargs)):
        out.add(a.arg)
    if args.vararg:
        out.add(args.vararg.arg)
    if args.kwarg:
        out.add(args.kwarg.arg)
    if isinstance(fn, ast.Lambda):
        return out
    stack = list(fn.body)
    while stack:
        sub = stack.pop()
        if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out.add(sub.name)
            continue  # its locals are its own scope
        if isinstance(sub, ast.Lambda):
            continue
        if isinstance(sub, ast.Name) and isinstance(sub.ctx, ast.Store):
            out.add(sub.id)
        stack.extend(ast.iter_child_nodes(sub))
    return out


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------
def lint_file(path: str) -> List[LintFinding]:
    """All rule findings for one Python source file."""
    source = Path(path).read_text()
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [LintFinding("syntax-error", path, e.lineno or 0, str(e))]
    return _Analyzer(path, tree, source).run()


def iter_python_files(paths: Sequence[str]) -> Iterable[str]:
    for p in paths:
        pp = Path(p)
        if pp.is_file() and pp.suffix == ".py":
            yield str(pp)
        elif pp.is_dir():
            for f in sorted(pp.rglob("*.py")):
                if "__pycache__" in f.parts:
                    continue
                yield str(f)


def lint_paths(paths: Sequence[str]) -> List[LintFinding]:
    """Run every rule over all ``.py`` files under ``paths``."""
    out: List[LintFinding] = []
    for f in iter_python_files(paths):
        out.extend(lint_file(f))
    return out
