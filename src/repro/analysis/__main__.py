"""``python -m repro.analysis [--strict] PATH...`` -- run the static
analysis over source trees; exit 1 on any finding.

Default: the AST lint rules (``repro.analysis.rules``) over every
``.py`` under the given paths.  ``--strict`` additionally runs the
machine-checkable plan-IR audits that need no plan instance: the
fingerprint-registry classification audit and a verifier self-check on
a representative compiled plan (so CI catches a plan.py regression even
when no test constructs that shape).
"""
from __future__ import annotations

import argparse
import sys

from repro.analysis import plan_check, rules


def _strict_audits() -> int:
    """Plan-IR audits that run without user input; returns #findings."""
    findings = plan_check.audit_fingerprint(None)
    # a representative nontrivial plan: 3 levels, heterogeneous leaf
    # sizes/H, mixed per-depth compression -- exercises every checker
    from repro.core.engine.plan import compile_tree
    from repro.core.tree import TreeNode
    leaves_a = tuple(
        TreeNode(name=f"a{i}", rounds=2 + i, data_size=5 + i)
        for i in range(2))
    leaves_b = tuple(
        TreeNode(name=f"b{i}", rounds=3, data_size=4) for i in range(3))
    tree = TreeNode(name="root", rounds=2, children=(
        TreeNode(name="ga", rounds=2, children=leaves_a),
        TreeNode(name="gb", rounds=1, children=leaves_b),
    ))
    plan = compile_tree(tree, compression=(None, "int8"))
    findings += plan_check.check_tree_plan(plan)
    for f in findings:
        print(f"plan-ir: {f}", file=sys.stderr)
    return len(findings)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-specific static analysis: AST lint rules, plus "
                    "(--strict) the plan-IR fingerprint/verifier audits")
    ap.add_argument("paths", nargs="+", help="files or directories to lint")
    ap.add_argument("--strict", action="store_true",
                    help="also run the plan-IR self-audits")
    args = ap.parse_args(argv)

    findings = rules.lint_paths(args.paths)
    for f in findings:
        print(str(f), file=sys.stderr)
    n = len(findings)
    if args.strict:
        n += _strict_audits()
    if n:
        print(f"repro.analysis: {n} finding(s)", file=sys.stderr)
        return 1
    print("repro.analysis: clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
