"""Static-analysis layer for the schedule engine: correctness tooling
that proves the compiled program matches the plan IR (and stays matched
across refactors), instead of re-fixing cache-key and bit-identity bugs
after the fact.

Three layers (see ``docs/analysis.md``):

  * :mod:`repro.analysis.plan_check` -- structural invariant checks over
    ``TreePlan`` / ``SchedulePlan`` plus the fingerprint-soundness audit
    (every compiled-behavior field must be classified in the plan IR's
    fingerprint registry, so the PR-4/PR-6 cache-key bug class fails at
    compile time instead of shipping).
  * :mod:`repro.analysis.trace_guard` -- a strict runtime mode for
    ``Session``: unexpected executor-cache misses become errors carrying
    a structured diff of the offending cache keys, host syncs inside the
    chunk loop's dispatch region are disallowed, and an opt-in NaN/Inf
    sanitizer checks the chunk carry each round.
  * :mod:`repro.analysis.rules` -- repo-specific AST lint rules run by
    ``python -m repro.analysis``: no wall-clock / Python RNG inside
    traced bodies, no static closure capture of runtime operands
    (lambda / lr / local_h / periods), no ``jax.jit`` outside
    ``core/engine`` + ``kernels`` without a waiver, no mutable defaults
    in frozen dataclasses.
"""
from repro.analysis.plan_check import (       # noqa: F401
    AnalysisError, Finding, audit_fingerprint, check_schedule_plan,
    check_tree_plan, verify_plan)
from repro.analysis.trace_guard import (      # noqa: F401
    HostSyncError, NonFiniteError, TraceGuard, UnexpectedRetraceError,
    as_trace_guard, check_finite, no_retrace)
from repro.analysis.rules import lint_paths   # noqa: F401
