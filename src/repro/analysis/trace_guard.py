"""Runtime trace guard: strict mode for ``repro.api.Session``.

The engine's whole performance story is "ONE compiled program per plan
fingerprint; schedules, lambdas, masks and step masks are runtime
operands".  A silent retrace -- an executor-cache miss where a hit was
expected -- means that contract broke: something that should be a
runtime operand leaked into the cache key (or a fingerprint changed when
it should not have).  Historically those regressions surfaced as mystery
slowdowns in sweeps; strict mode turns them into errors at the point of
the miss, carrying a structured field-by-field diff of the offending
cache key against the nearest cached one.

Three independent guards, bundled by :class:`TraceGuard`:

  * :func:`no_retrace` -- a context manager holding an executor-cache
    miss budget (default 0) over a region; on exceeding it, raises
    :class:`UnexpectedRetraceError` with the named key diffs from the
    engine miss logs (``engine.host.executor_miss_log``).
  * host-sync guard -- ``jax.transfer_guard_device_to_host("disallow")``
    scoped around the chunk loop's *executor dispatch region only*:
    ``.item()`` / implicit ``float()`` / ``np.asarray`` on a traced or
    device value inside the hot loop blocks the dispatch pipeline and
    shows up as unexplained host gaps.  Intentional host reads (history
    recording between chunks, convergence checks) live OUTSIDE the
    guarded region and stay legal.
  * :func:`check_finite` -- opt-in NaN/Inf sanitizer over the chunk
    carry, raising :class:`NonFiniteError` naming the first offending
    pytree leaf.  Off by default: it forces a device sync per chunk.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Iterator, List, Optional

import jax
import numpy as np


class UnexpectedRetraceError(RuntimeError):
    """An executor-cache miss happened where strict mode budgeted none.

    ``misses`` holds the offending named cache keys (newest last), each
    with a ``diff`` against the nearest key already in that backend's
    cache -- the differing fields are exactly the operands that leaked
    into the cache key."""

    def __init__(self, message: str, misses: List[dict]):
        super().__init__(message)
        self.misses = misses


class HostSyncError(RuntimeError):
    """A device-to-host transfer happened inside the guarded dispatch
    region of the chunk loop (``.item()``, implicit ``float()``,
    ``np.asarray`` on a device value, ...)."""


class NonFiniteError(FloatingPointError):
    """The sanitizer found NaN/Inf in a guarded value; ``where`` names
    the offending pytree leaf."""

    def __init__(self, message: str, where: str):
        super().__init__(message)
        self.where = where


# ---------------------------------------------------------------------------
# retrace guard
# ---------------------------------------------------------------------------
def _total_misses() -> int:
    from repro.core.engine import host as host_mod
    return host_mod.executor_cache_stats()["misses"]


def _key_diff(new: dict, cached: List[dict]) -> Optional[dict]:
    """Field-by-field diff of ``new`` against its nearest neighbour in
    ``cached`` (fewest differing fields wins): {field: (new, cached)}."""
    best = None
    for old in cached:
        if set(old) != set(new):
            continue
        delta = {f: (new[f], old[f]) for f in new if new[f] != old[f]}
        if best is None or len(delta) < len(best):
            best = delta
    return best


def _describe_miss(entry: dict) -> dict:
    """Attach the nearest-cached-key diff to one miss-log entry."""
    from repro.core.engine import host as host_mod
    from repro.core.engine import mesh as mesh_mod
    cached = (mesh_mod.mesh_executor_cache_keys()
              if entry["backend"] == "mesh"
              else host_mod.executor_cache_keys())
    # the missed key itself is in the cache by now -- diff against others
    others = [k for k in cached if k != entry["key"]]
    return dict(entry, diff=_key_diff(entry["key"], others))


@contextlib.contextmanager
def no_retrace(budget: int = 0) -> Iterator[None]:
    """Assert at most ``budget`` executor-cache misses (across the host,
    mesh and LM caches) happen inside the ``with`` body; raise
    :class:`UnexpectedRetraceError` with structured key diffs otherwise.

    The canonical strict-session usage budgets the FIRST chunk's builds
    and holds zero for the rest of the run; standalone use::

        with no_retrace():            # everything is already compiled
            sess.run(lam=0.01)
    """
    from repro.core.engine import host as host_mod
    before = _total_misses()
    log_before = len(host_mod.executor_miss_log())
    yield
    new = _total_misses() - before
    if new <= budget:
        return
    entries = [_describe_miss(e)
               for e in host_mod.executor_miss_log()[log_before:]]
    lines = []
    for e in entries:
        lines.append(f"  [{e['backend']}] key = {e['key']}")
        if e["diff"]:
            for f, (nv, ov) in e["diff"].items():
                lines.append(f"      {f}: {nv!r} (cached: {ov!r})")
        elif e["diff"] is not None:
            lines.append("      (identical to a cached key -- the entry "
                         "was evicted by LRU pressure; raise the cache "
                         "size or narrow the sweep)")
    detail = "\n".join(lines) or "  (miss in a cache without a miss log)"
    raise UnexpectedRetraceError(
        f"{new} executor-cache miss(es) in a region budgeted for "
        f"{budget}: an operand that should be a runtime input leaked "
        "into a cache key (or the plan fingerprint changed "
        "mid-session).  Offending keys, with field diffs against the "
        f"nearest cached key:\n{detail}", entries)


# ---------------------------------------------------------------------------
# host-sync guard
# ---------------------------------------------------------------------------
@contextlib.contextmanager
def _no_host_sync() -> Iterator[None]:
    """Disallow device-to-host transfers in the body; jax's transfer
    guard raises on ``.item()`` / ``float()`` / ``np.asarray`` of a
    device value, re-raised as :class:`HostSyncError` naming the fix."""
    try:
        with jax.transfer_guard_device_to_host("disallow"):
            yield
    except Exception as e:  # jax raises bare RuntimeError subclasses
        if "transfer" not in str(e).lower():
            raise
        raise HostSyncError(
            "device-to-host transfer inside the dispatch region of the "
            "chunk loop: a traced/device value was pulled to the host "
            "(.item(), implicit float(), np.asarray, ...), which blocks "
            "dispatch pipelining.  Move the read outside the guarded "
            f"region (history recording between chunks is fine).  "
            f"Original: {e}") from e


# ---------------------------------------------------------------------------
# NaN/Inf sanitizer
# ---------------------------------------------------------------------------
def check_finite(tree, where: str = "value") -> None:
    """Raise :class:`NonFiniteError` if any leaf of ``tree`` holds
    NaN/Inf.  Deliberately a HOST check (it materializes each leaf):
    strict sessions call it between chunks, outside the host-sync
    guard."""
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    for path, leaf in leaves:
        arr = np.asarray(leaf)
        if not np.issubdtype(arr.dtype, np.floating):
            continue
        if not np.isfinite(arr).all():
            n_bad = int((~np.isfinite(arr)).sum())
            loc = f"{where}{jax.tree_util.keystr(path)}"
            raise NonFiniteError(
                f"non-finite values in {loc}: {n_bad}/{arr.size} "
                "entries are NaN/Inf.  The solve diverged -- lower "
                "lambda/lr, shrink H, or inspect the round history up "
                "to this chunk.", loc)


# ---------------------------------------------------------------------------
# the bundle Session threads through its chunk loop
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TraceGuard:
    """Strict-mode policy for one ``Session``.

    ``Session.compile(strict=True)`` installs ``TraceGuard()``;
    ``strict=TraceGuard(...)`` customizes.  Fields:

      * ``error_on_retrace`` -- unexpected executor-cache misses inside
        ``Session.run`` raise :class:`UnexpectedRetraceError`.  The
        session budgets the FIRST dispatch of each compiled
        configuration (compiles are expected); after that, zero.
      * ``miss_budget`` -- extra allowed misses per guarded region, on
        top of the expected first-dispatch builds.
      * ``guard_host_sync`` -- disallow device-to-host transfers inside
        the executor dispatch region.
      * ``sanitize`` -- check the chunk carry for NaN/Inf after every
        chunk (costs one device sync per chunk; off by default).
    """
    error_on_retrace: bool = True
    miss_budget: int = 0
    guard_host_sync: bool = True
    sanitize: bool = False

    def retrace_region(self, budget: Optional[int] = None):
        """The no-retrace scope for one dispatch region (nullcontext
        when retrace errors are off)."""
        if not self.error_on_retrace:
            return contextlib.nullcontext()
        extra = self.miss_budget if budget is None else budget
        return no_retrace(extra)

    def dispatch_region(self):
        """The host-sync scope for one executor dispatch (nullcontext
        when the guard is off)."""
        if not self.guard_host_sync:
            return contextlib.nullcontext()
        return _no_host_sync()

    def check_carry(self, tree, where: str = "carry") -> None:
        if self.sanitize:
            check_finite(tree, where)


def as_trace_guard(strict) -> Optional[TraceGuard]:
    """Normalize ``Session.compile``'s ``strict`` argument: falsy ->
    None, True -> default :class:`TraceGuard`, a TraceGuard -> itself."""
    if not strict:
        return None
    if strict is True:
        return TraceGuard()
    if isinstance(strict, TraceGuard):
        return strict
    raise TypeError(
        f"strict must be a bool or a TraceGuard, got {type(strict).__name__}")
