"""SGD with (Nesterov) momentum -- used for TreeSync local steps."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.api import Optimizer


def make_sgd(lr: float = 0.1, momentum: float = 0.9,
             nesterov: bool = False) -> Optimizer:
    base_lr = lr

    def init(params):
        if momentum == 0.0:
            mom = None
        else:
            mom = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {"step": jnp.zeros((), jnp.int32), "mom": mom}

    def update(params, grads, state, lr=None):
        # lr=None -> the constructor rate; a traced scalar overrides it
        # (runtime operand, so an lr sweep is one vmapped executor)
        lr_t = base_lr if lr is None else lr
        step = state["step"] + 1
        if momentum == 0.0:
            new_p = jax.tree.map(
                lambda p, g: (p.astype(jnp.float32)
                              - lr_t * g.astype(jnp.float32)).astype(p.dtype),
                params, grads)
            return new_p, {"step": step, "mom": None}

        def upd(p, g, m):
            g = g.astype(jnp.float32)
            m = momentum * m + g
            d = g + momentum * m if nesterov else m
            return (p.astype(jnp.float32) - lr_t * d).astype(p.dtype), m

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_m = jax.tree.leaves(state["mom"])
        out = [upd(p, g, m) for p, g, m in zip(flat_p, flat_g, flat_m, strict=True)]
        return (tdef.unflatten([o[0] for o in out]),
                {"step": step, "mom": tdef.unflatten([o[1] for o in out])})

    return Optimizer("sgd", init, update)
