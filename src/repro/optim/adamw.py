"""AdamW with decoupled weight decay and linear-warmup/cosine schedules.

Moment states are stored in float32 regardless of parameter dtype (standard
mixed-precision practice); the update is computed in float32 and cast back.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.optim.api import Optimizer


def warmup_cosine(lr: float, warmup: int = 100, total: int = 10_000,
                  final_frac: float = 0.1) -> Callable:
    """Standard LM schedule: linear warmup then cosine decay to final_frac*lr."""
    def sched(step):
        step = step.astype(jnp.float32)
        warm = lr * jnp.minimum(step / max(warmup, 1), 1.0)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = final_frac * lr + (1 - final_frac) * lr * 0.5 * (
            1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos)
    return sched


def make_adamw(
    lr: float = 3e-4,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: Optional[float] = 1.0,
    schedule: Optional[Callable] = None,
) -> Optimizer:
    sched = schedule if schedule is not None else (lambda step: lr)

    def init(params):
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {
            "step": jnp.zeros((), jnp.int32),
            "mu": zeros,
            "nu": jax.tree.map(jnp.copy, zeros),
        }

    def update(params, grads, state, lr=None):
        step = state["step"] + 1
        stepf = step.astype(jnp.float32)
        # lr=None -> the built-in schedule; a traced scalar overrides it
        # (runtime operand, so an lr sweep is one vmapped executor)
        lr_t = sched(step) if lr is None else lr

        if grad_clip is not None:
            gsq = sum(
                jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads)
            )
            gnorm = jnp.sqrt(gsq + 1e-16)
            scale = jnp.minimum(1.0, grad_clip / gnorm)
        else:
            scale = jnp.float32(1.0)

        bc1 = 1.0 - b1**stepf
        bc2 = 1.0 - b2**stepf

        def upd(p, g, mu, nu):
            g = g.astype(jnp.float32) * scale
            mu = b1 * mu + (1 - b1) * g
            nu = b2 * nu + (1 - b2) * g * g
            mhat = mu / bc1
            nhat = nu / bc2
            pf = p.astype(jnp.float32)
            # decoupled weight decay: skip 1-D params (norms, biases)
            wd = weight_decay if p.ndim >= 2 else 0.0
            pf = pf - lr_t * (mhat / (jnp.sqrt(nhat) + eps) + wd * pf)
            return pf.astype(p.dtype), mu, nu

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_mu = jax.tree.leaves(state["mu"])
        flat_nu = jax.tree.leaves(state["nu"])
        out = [upd(p, g, m, n)
               for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu,
                                     strict=True)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_mu = tdef.unflatten([o[1] for o in out])
        new_nu = tdef.unflatten([o[2] for o in out])
        return new_p, {"step": step, "mu": new_mu, "nu": new_nu}

    return Optimizer("adamw", init, update)
