"""Minimal pytree optimizers (no external deps): AdamW, Adafactor, SGD.

API (optax-like but self-contained):
    opt = get_optimizer(cfg)            # from a ModelConfig, or make_adamw(...)
    state = opt.init(params)
    params, state = opt.update(params, grads, state)
"""
from repro.optim.api import Optimizer, get_optimizer
from repro.optim.adamw import make_adamw
from repro.optim.adafactor import make_adafactor
from repro.optim.sgd import make_sgd

__all__ = [
    "Optimizer",
    "get_optimizer",
    "make_adamw",
    "make_adafactor",
    "make_sgd",
]
