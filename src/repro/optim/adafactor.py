"""Adafactor (Shazeer & Stern 2018): factored second moments.

Parameters with >= 2 dims (and both trailing dims >= min_dim_size_to_factor)
store only row/col mean accumulators -- O(n+m) instead of O(nm) -- which is
what makes optimizer state for the 480B MoE config fit in HBM.
Implements the standard pieces: pow decay, RMS update clipping, relative
step-size scaling.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.api import Optimizer


def _factored(shape, min_size: int) -> bool:
    return len(shape) >= 2 and shape[-1] >= min_size and shape[-2] >= min_size


def make_adafactor(
    lr: float = 1e-3,
    decay_pow: float = 0.8,
    clip_threshold: float = 1.0,
    eps1: float = 1e-30,
    eps2: float = 1e-3,
    min_dim_size_to_factor: int = 128,
    weight_decay: float = 0.0,
) -> Optimizer:
    base_lr = lr

    def init(params):
        def leaf_state(p):
            if _factored(p.shape, min_dim_size_to_factor):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {
            "step": jnp.zeros((), jnp.int32),
            "v": jax.tree.map(leaf_state, params,
                              is_leaf=lambda x: hasattr(x, "shape")),
        }

    def update(params, grads, state, lr=None):
        step = state["step"] + 1
        stepf = step.astype(jnp.float32)
        beta2 = 1.0 - stepf ** (-decay_pow)
        # lr=None -> the constructor rate; a traced scalar overrides it
        # (runtime operand, so an lr sweep is one vmapped executor)
        lr_t = base_lr if lr is None else lr

        def upd(p, g, s):
            g = g.astype(jnp.float32)
            g2 = g * g + eps1
            if _factored(p.shape, min_dim_size_to_factor):
                vr = beta2 * s["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * s["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                # rank-1 reconstruction of the second moment
                denom = jnp.mean(vr, axis=-1, keepdims=True)
                u = (g
                     * jax.lax.rsqrt(vr / jnp.maximum(denom, eps1))[..., None]
                     * jax.lax.rsqrt(vc)[..., None, :])
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta2 * s["v"] + (1 - beta2) * g2
                u = g * jax.lax.rsqrt(v)
                new_s = {"v": v}
            # RMS clipping
            rms = jnp.sqrt(jnp.mean(u * u) + eps1)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            pf = p.astype(jnp.float32)
            # relative step size (scaled by param RMS, floored at eps2)
            scale = jnp.maximum(jnp.sqrt(jnp.mean(pf * pf)), eps2)
            pf = pf - lr_t * scale * u
            if weight_decay and p.ndim >= 2:
                pf = pf - lr_t * weight_decay * pf
            return pf.astype(p.dtype), new_s

        is_state = lambda x: isinstance(x, dict) and ("v" in x or "vr" in x)
        flat_p, tdef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_s, sdef = jax.tree.flatten(state["v"], is_leaf=is_state)
        out = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s, strict=True)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_v = sdef.unflatten([o[1] for o in out])
        return new_p, {"step": step, "v": new_v}

    return Optimizer("adafactor", init, update)
