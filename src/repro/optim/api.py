"""Optimizer container + config-driven selection."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    """A pair of pure functions over parameter pytrees.

    ``init(params) -> state`` and
    ``update(params, grads, state) -> (new_params, new_state)``.
    ``state`` always carries a scalar int32 ``step`` as its first element so
    checkpointing can report progress uniformly.
    """
    name: str
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree], Tuple[PyTree, PyTree]]


def get_optimizer(cfg, lr: float = 3e-4, weight_decay: float = 0.1) -> Optimizer:
    """Pick the optimizer named by a ModelConfig (adamw | adafactor | sgd)."""
    from repro.optim.adafactor import make_adafactor
    from repro.optim.adamw import make_adamw
    from repro.optim.sgd import make_sgd

    kind = getattr(cfg, "optimizer", "adamw")
    if kind == "adamw":
        return make_adamw(lr=lr, weight_decay=weight_decay)
    if kind == "adafactor":
        return make_adafactor(lr=lr)
    if kind == "sgd":
        return make_sgd(lr=lr)
    raise ValueError(f"unknown optimizer {kind!r}")
