"""Sharding rules: divisibility guards, head alignment, FSDP+TP 2D layout,
and an end-to-end sharded train step on the host mesh."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.registry import ARCHS
from repro.configs.shapes import SHAPES
from repro.launch import sharding as sh
from repro.launch import steps
from repro.launch.mesh import make_abstract_mesh, make_host_mesh


def _flat(tree):
    return {
        "/".join(str(getattr(k, "key", getattr(k, "idx", None)))
                 for k in path): v
        for path, v in jax.tree_util.tree_flatten_with_path(
            tree, is_leaf=lambda x: isinstance(x, P))[0]
    }


@pytest.fixture(scope="module")
def mesh():
    return make_host_mesh(model=1) if len(jax.devices()) < 4 else \
        jax.make_mesh((len(jax.devices()) // 2, 2), ("data", "model"))


def test_qwen3_full_specs_2d():
    """On the production mesh shapes, qwen3 weights are FSDP x TP sharded."""
    cfg = ARCHS["qwen3-32b"].FULL
    mesh = make_abstract_mesh((16, 16), ("data", "model"))
    pshape = steps.params_shape(cfg)
    specs = _flat(sh.param_specs(cfg, pshape, mesh))
    assert specs["blocks/sub0/mix/wq"] == P(None, "data", "model")
    assert specs["blocks/sub0/mix/wo"] == P(None, "model", "data")
    assert specs["blocks/sub0/ffn/w_gate"] == P(None, "data", "model")
    assert specs["blocks/sub0/ffn/w_down"] == P(None, "model", "data")
    assert specs["embed"] == P("model", "data")
    assert specs["blocks/sub0/ln1"] == P(None, None)
    # kv fused dim: kv=8 heads < 16-way axis -> head-alignment guard trips
    assert specs["blocks/sub0/mix/wk"] == P(None, "data", None)


def test_head_alignment_guard_yi():
    """yi-34b: 56 q-heads don't divide 16 -> heads dim replicated."""
    cfg = ARCHS["yi-34b"].FULL
    mesh = make_abstract_mesh((16, 16), ("data", "model"))
    specs = _flat(sh.param_specs(cfg, steps.params_shape(cfg), mesh))
    assert specs["blocks/sub0/mix/wq"] == P(None, "data", None)
    # but the FFN still gets TP
    assert specs["blocks/sub0/ffn/w_gate"] == P(None, "data", "model")


def test_moe_expert_parallel():
    cfg = ARCHS["arctic-480b"].FULL
    mesh = make_abstract_mesh((16, 16), ("data", "model"))
    specs = _flat(sh.param_specs(cfg, steps.params_shape(cfg), mesh))
    assert specs["blocks/sub0/ffn/w_gate"] == P(None, "model", "data", None)
    assert specs["blocks/sub0/ffn/w_down"] == P(None, "model", None, "data")
    assert specs["blocks/sub0/ffn/router"] == P(None, "data", None)
    # arctic's dense residual branch is a plain MLP
    assert specs["blocks/sub0/ffn/dense/w_gate"] == P(None, "data", "model")


def test_opt_state_inherits_param_specs():
    from repro.optim import make_adamw
    cfg = ARCHS["qwen3-32b"].SMOKE
    mesh = make_abstract_mesh((4, 2), ("data", "model"))
    pshape = steps.params_shape(cfg)
    opt = make_adamw()
    oshape = jax.eval_shape(opt.init, pshape)
    ospecs = sh.opt_state_specs(cfg, oshape, pshape, mesh)
    pspecs = sh.param_specs(cfg, pshape, mesh)
    assert _flat(ospecs)["mu/blocks/sub0/mix/wq"] == \
        _flat(pspecs)["blocks/sub0/mix/wq"]
    assert _flat(ospecs)["step"] == P()


def test_adafactor_factored_state_specs():
    from repro.optim import make_adafactor
    cfg = ARCHS["arctic-480b"].FULL
    mesh = make_abstract_mesh((16, 16), ("data", "model"))
    pshape = steps.params_shape(cfg)
    opt = make_adafactor()
    oshape = jax.eval_shape(opt.init, pshape)
    ospecs = _flat(sh.opt_state_specs(cfg, oshape, pshape, mesh))
    # vr of (L, E, D, F) w_gate: drops the last (F) dim's spec
    assert ospecs["v/blocks/sub0/ffn/w_gate/vr"] == P(None, "model", "data")
    assert ospecs["v/blocks/sub0/ffn/w_gate/vc"] == P(None, "model", None)


def test_divisibility_fallback():
    """A dim that doesn't divide the axis falls back to replication."""
    cfg = dataclasses.replace(ARCHS["qwen3-32b"].SMOKE, d_model=60)
    mesh = make_abstract_mesh((16, 16), ("data", "model"))
    dropped = []
    specs = _flat(sh.param_specs(cfg, steps.params_shape(cfg), mesh, dropped=dropped))
    assert specs["blocks/sub0/mix/wq"][1] is None  # 60 % 16 != 0
    assert any(d[1] == "embed" for d in dropped)


def test_end_to_end_sharded_train_step(mesh):
    """Run (not just lower) a sharded train step on the host mesh; the
    result must equal the single-device step."""
    cfg = dataclasses.replace(ARCHS["qwen3-32b"].SMOKE, remat=False)
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=32,
                                global_batch=8)
    cell = steps.build_cell(cfg, shape, mesh)
    params = jax.device_put(
        jax.tree.map(jnp.zeros_like,
                     jax.eval_shape(lambda: None) or None), None) \
        if False else None
    # build real values
    from repro.models.transformer import init_params
    from repro.optim import get_optimizer
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    opt = get_optimizer(cfg)
    opt_state = opt.init(params)
    batch = {
        "tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (8, 32), 0, cfg.vocab_size),
    }
    ref_step = jax.jit(steps.make_train_step(cfg, opt))
    p_ref, o_ref, m_ref = ref_step(params, opt_state, batch)

    p_sh = jax.device_put(params, cell.in_shardings[0])
    o_sh = jax.device_put(opt_state, cell.in_shardings[1])
    b_sh = {k: jax.device_put(v, cell.in_shardings[2][k])
            for k, v in batch.items()}
    p2, o2, m2 = cell.jitted(p_sh, o_sh, b_sh)
    np.testing.assert_allclose(float(m2["loss"]), float(m_ref["loss"]),
                               rtol=1e-3)  # bf16 reduction-order noise
    for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(p_ref),
                    strict=True):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=5e-3, atol=1e-3)  # Adam amplifies bf16 grad noise near eps
