"""Optimizers: quadratic convergence, state shapes, Adafactor factoring."""
import jax
import jax.numpy as jnp
import pytest

from repro.optim import make_adafactor, make_adamw, make_sgd
from repro.optim.adamw import warmup_cosine


def _quadratic_losses(opt, steps=200, dim=16):
    key = jax.random.PRNGKey(0)
    target = jax.random.normal(key, (dim, dim))
    params = {"w": jnp.zeros((dim, dim)), "b": jnp.zeros((dim,))}
    state = opt.init(params)

    def loss_fn(p):
        return jnp.mean((p["w"] - target) ** 2) + jnp.mean(p["b"] ** 2)

    @jax.jit
    def step(params, state):
        g = jax.grad(loss_fn)(params)
        return opt.update(params, g, state)

    losses = [float(loss_fn(params))]
    for _ in range(steps):
        params, state = step(params, state)
    losses.append(float(loss_fn(params)))
    return losses


@pytest.mark.parametrize("make", [
    lambda: make_adamw(lr=3e-2, weight_decay=0.0),
    lambda: make_adafactor(lr=3e-1, min_dim_size_to_factor=8),
    lambda: make_sgd(lr=0.3, momentum=0.9),
])
def test_quadratic_convergence(make):
    losses = _quadratic_losses(make())
    assert losses[-1] < losses[0] * 1e-2, losses


def test_adamw_step_counter_and_dtypes():
    opt = make_adamw()
    params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    state = opt.init(params)
    assert state["step"].dtype == jnp.int32
    g = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    params2, state = opt.update(params, g, state)
    assert int(state["step"]) == 1
    assert params2["w"].dtype == jnp.bfloat16        # cast back
    assert state["mu"]["w"].dtype == jnp.float32     # f32 moments


def test_adafactor_factored_state_memory():
    opt = make_adafactor(min_dim_size_to_factor=128)
    params = {"big": jnp.zeros((1024, 2048)), "small": jnp.zeros((64, 64)),
              "vec": jnp.zeros((4096,))}
    state = opt.init(params)
    s = state["v"]
    assert set(s["big"].keys()) == {"vr", "vc"}
    assert s["big"]["vr"].shape == (1024,)
    assert s["big"]["vc"].shape == (2048,)
    assert set(s["small"].keys()) == {"v"}           # below factor threshold
    assert set(s["vec"].keys()) == {"v"}             # 1-D never factored


def test_warmup_cosine_schedule():
    sched = warmup_cosine(1.0, warmup=10, total=110, final_frac=0.1)
    assert float(sched(jnp.int32(0))) == 0.0
    assert abs(float(sched(jnp.int32(10))) - 1.0) < 1e-6
    assert abs(float(sched(jnp.int32(110))) - 0.1) < 1e-6
    assert float(sched(jnp.int32(60))) < 1.0


def test_grad_clip_bounds_update():
    opt = make_adamw(lr=1.0, grad_clip=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros((8, 8))}
    state = opt.init(params)
    g = {"w": 1e6 * jnp.ones((8, 8))}
    params2, _ = opt.update(params, g, state)
    # clipped grad -> bounded first update (~lr since |mhat/sqrt(nhat)| ~= 1)
    assert float(jnp.max(jnp.abs(params2["w"]))) < 1.5
