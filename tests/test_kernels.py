"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode
(deliverable c; no TPU in this container)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dual as dual_mod
from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.sdca.kernel import sdca_block_kernel
from repro.kernels.sdca.ref import sdca_block_ref
from repro.kernels.sdca.ops import sdca_block_solve


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
def _qkv(key, B, S, H, KV, D, dtype):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, S, H, D), dtype)
    k = jax.random.normal(kk, (B, S, KV, D), dtype)
    v = jax.random.normal(kv, (B, S, KV, D), dtype)
    return q, k, v


@pytest.mark.parametrize("B,S,H,KV,D,bq,bk", [
    (1, 128, 2, 2, 32, 64, 64),     # MHA
    (2, 256, 4, 2, 64, 128, 128),   # GQA 2:1
    (1, 256, 8, 1, 64, 64, 128),    # MQA
    (1, 64, 2, 2, 128, 32, 16),     # small blocks, big head_dim
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_causal_shapes_dtypes(B, S, H, KV, D, bq, bk, dtype):
    q, k, v = _qkv(jax.random.PRNGKey(0), B, S, H, KV, D, dtype)
    out = flash_attention_kernel(q, k, v, causal=True, block_q=bq,
                                 block_k=bk, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [16, 64, 100])
def test_flash_sliding_window(window):
    q, k, v = _qkv(jax.random.PRNGKey(1), 2, 256, 4, 4, 32, jnp.float32)
    out = flash_attention_kernel(q, k, v, causal=True, window=window,
                                 block_q=64, block_k=64, interpret=True)
    ref = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_noncausal():
    q, k, v = _qkv(jax.random.PRNGKey(2), 1, 128, 2, 2, 32, jnp.float32)
    out = flash_attention_kernel(q, k, v, causal=False, block_q=64,
                                 block_k=64, interpret=True)
    ref = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_band_pruning_matches_full_scan():
    """Loop-bound pruning (the TPU adaptation) must not change results:
    compare a heavily-windowed case against block_k == S (no pruning)."""
    q, k, v = _qkv(jax.random.PRNGKey(3), 1, 256, 2, 2, 32, jnp.float32)
    pruned = flash_attention_kernel(q, k, v, causal=True, window=32,
                                    block_q=32, block_k=32, interpret=True)
    unpruned = flash_attention_kernel(q, k, v, causal=True, window=32,
                                      block_q=32, block_k=256,
                                      interpret=True)
    np.testing.assert_allclose(np.asarray(pruned), np.asarray(unpruned),
                               rtol=1e-5, atol=1e-5)


def test_flash_vs_model_attention_path():
    """The model's attention (attention_impl='flash') equals the XLA path."""
    import dataclasses
    from repro.configs.registry import ARCHS
    from repro.models import attention as attn_mod
    from repro.models.transformer import init_params

    cfg = dataclasses.replace(ARCHS["qwen3-32b"].SMOKE, q_chunk_size=32)
    params = init_params(cfg, jax.random.PRNGKey(0))
    blk = jax.tree.map(lambda t: t[0], params["blocks"])["sub0"]["mix"]
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model),
                                jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(64, dtype=jnp.int32), (2, 64))
    ref = attn_mod.attention_train(blk, cfg, x, pos)
    out = attn_mod.attention_flash(blk, cfg, x, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=5e-3, atol=5e-3)


# ---------------------------------------------------------------------------
# blocked SDCA
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("loss_name", ["squared", "smooth_hinge_1", "hinge"])
@pytest.mark.parametrize("K,m_b,d,H", [(2, 32, 16, 64), (4, 64, 8, 128),
                                       (1, 128, 32, 256)])
def test_sdca_kernel_matches_ref(loss_name, K, m_b, d, H):
    loss = dual_mod.LOSSES[loss_name]
    key = jax.random.PRNGKey(0)
    kx, ky, ka, kw, ki = jax.random.split(key, 5)
    X = jax.random.normal(kx, (K, m_b, d))
    y = (jnp.sign(jax.random.normal(ky, (K, m_b))) if loss.gamma != 1.0
         else jax.random.normal(ky, (K, m_b)))
    alpha = 0.1 * jax.random.normal(ka, (K, m_b))
    if loss_name != "squared":   # hinge-family feasibility: alpha*y in [0,1]
        alpha = jnp.abs(alpha) * y
    lam, m_total = 0.1, K * m_b
    w = jax.random.normal(kw, (d,)) * 0.1
    idx = jax.random.randint(ki, (K, H), 0, m_b)

    da_k, dw_k = sdca_block_kernel(X, y, alpha, w, idx, loss=loss,
                                   lm=lam * m_total, interpret=True)
    da_r, dw_r = sdca_block_ref(X, y, alpha, w, idx, loss=loss,
                                lm=lam * m_total)
    np.testing.assert_allclose(np.asarray(da_k), np.asarray(da_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(dw_k), np.asarray(dw_r),
                               rtol=1e-5, atol=1e-5)


def test_sdca_kernel_matches_sequential_local_sdca():
    """K=1 kernel == the core-layer sequential Procedure P (same PRNG)."""
    loss = dual_mod.LOSSES["squared"]
    key = jax.random.PRNGKey(3)
    kx, ky, kw, ki = jax.random.split(key, 4)
    m_b, d, H = 64, 16, 128
    X = jax.random.normal(kx, (m_b, d))
    y = jax.random.normal(ky, (m_b,))
    alpha = jnp.zeros((m_b,))
    w = jnp.zeros((d,))
    lam = 0.1
    idx = jax.random.randint(ki, (1, H), 0, m_b)

    da_k, dw_k = sdca_block_kernel(X[None], y[None], alpha[None], w, idx,
                                   loss=loss, lm=lam * m_b, interpret=True)

    # replicate the same coordinate sequence through the core path
    def run_seq():
        a_c, w_c = alpha, w
        lm = lam * m_b
        xsq = jnp.sum(X * X, axis=1) / lm
        for h in range(H):
            i = int(idx[0, h])
            wx = jnp.dot(w_c, X[i])
            dlt = loss.coord_delta(wx, a_c[i], y[i], xsq[i])
            a_c = a_c.at[i].add(dlt)
            w_c = w_c + (dlt / lm) * X[i]
        return a_c - alpha, w_c - w

    da_s, dw_s = run_seq()
    np.testing.assert_allclose(np.asarray(da_k[0]), np.asarray(da_s),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(dw_k[0]), np.asarray(dw_s),
                               rtol=1e-5, atol=1e-6)


def test_sdca_solve_increases_dual_and_converges():
    """Repeated kernel rounds drive the duality gap toward 0 (CoCoA on
    ridge regression, K=4 workers)."""
    from repro.data.synthetic import gaussian_regression
    loss = dual_mod.LOSSES["squared"]
    K, lam = 4, 0.1
    X, y = gaussian_regression(m=256, d=32)
    m = X.shape[0]
    Xb = X.reshape(K, m // K, -1)
    yb = y.reshape(K, m // K)
    alpha = jnp.zeros((K, m // K))
    w = jnp.zeros((X.shape[1],))
    key = jax.random.PRNGKey(0)
    gaps = []
    for _t in range(30):
        key, k = jax.random.split(key)
        alpha, w, _ = sdca_block_solve(Xb, yb, alpha, w, k, loss=loss,
                                       lam=lam, m_total=m, num_steps=256)
        gap = float(dual_mod.duality_gap(alpha.reshape(-1), X, y, loss, lam))
        gaps.append(gap)
    assert gaps[-1] < 2e-3 * gaps[0], gaps[:3] + gaps[-3:]
    assert gaps[-1] < gaps[len(gaps) // 2]  # still descending late
    # w stays consistent with alpha: w == A alpha
    w_check = dual_mod.w_of_alpha(alpha.reshape(-1), X, lam)
    np.testing.assert_allclose(np.asarray(w), np.asarray(w_check),
                               rtol=1e-4, atol=1e-5)
