"""Delay model (eq. (9)-(12)) and optimal-H behaviour (paper SS6, Fig. 4)."""
import math

import numpy as np
import pytest

from repro.core import delay as dl

# the paper's Fig. 4 parameter set
PAPER = dict(C=0.5, K=3, delta=1.0 / 300, t_total=1.0, t_lp=4e-5, t_cp=3e-5)


def test_rounds_for_budget_eq10():
    assert dl.rounds_for_budget(1.0, 100, 4e-5, 0.4, 3e-5) == pytest.approx(
        1.0 / (4e-5 * 100 + 0.4 + 3e-5)
    )


def test_improvement_constant_validated():
    """Satellite regression: C > K makes eq. (11)'s 'factor' negative for
    large H (log_bound silently clamped it); the planners must reject it
    up front instead of optimizing garbage."""
    bad = dict(PAPER)
    bad["C"] = 4.0          # > K = 3
    with pytest.raises(ValueError, match="0 < C <= K"):
        dl.optimal_h(t_delay=0.1, **bad)
    with pytest.raises(ValueError, match="0 < C <= K"):
        dl.optimal_h(t_delay=0.1, **{**PAPER, "C": 0.0})
    with pytest.raises(ValueError, match="0 < C <= K"):
        dl.optimal_h(t_delay=0.1, **{**PAPER, "C": -1.0})
    # the hierarchical planner names the offending level
    levels = [dl.FixedLevel("inner", 4, 1e-4), dl.FixedLevel("outer", 2, 0.1)]
    with pytest.raises(ValueError, match="outer"):
        dl.plan_hierarchical_h(levels, C=3.0, delta=1e-2, t_total=1.0,
                               t_lp=1e-5)
    # the boundary C == K is legal (factor hits 0 only at H -> inf)
    h, _ = dl.optimal_h(t_delay=0.1, **{**PAPER, "C": 3.0})
    assert h >= 1


def test_per_round_factor_limits():
    # H -> 0: no local progress, factor -> 1
    assert dl.per_round_factor(0, 0.5, 3, 0.01) == pytest.approx(1.0)
    # H -> inf: factor -> 1 - C/K
    assert dl.per_round_factor(10**9, 0.5, 3, 0.01) == pytest.approx(
        1.0 - 0.5 / 3
    )


def test_optimal_h_increases_with_delay():
    """Paper Fig. 4(b): optimal H is nondecreasing in the delay ratio r."""
    rs = [0, 10, 1e3, 1e5, 1e7]
    hs = dl.optimal_h_vs_delay(rs, **PAPER)
    assert (np.diff(hs) >= 0).all()
    assert hs[0] < hs[-1]


def test_optimal_h_small_when_no_delay():
    h, _ = dl.optimal_h(t_delay=0.0, **PAPER)
    # with no delay, communicate often (H stays small relative to big-delay H)
    h_big, _ = dl.optimal_h(t_delay=1e5 * PAPER["t_lp"], **PAPER)
    assert h < h_big
    assert h <= 200


def test_optimal_h_beats_neighbors():
    h, v = dl.optimal_h(t_delay=10 * PAPER["t_lp"], **PAPER)
    for other in (max(1, h // 2), h * 2, max(1, h - 1), h + 1):
        assert v <= dl.log_bound(other, t_delay=10 * PAPER["t_lp"], **PAPER) + 1e-12


def test_log_bound_matches_direct_eval_small():
    # for small numbers compare against direct eq. (12) evaluation
    H = 50
    args = dict(C=0.5, K=3, delta=0.01, t_total=1e-2, t_lp=4e-5,
                t_delay=1e-3, t_cp=3e-5)
    g = dl.per_round_factor(H, 0.5, 3, 0.01)
    T = dl.rounds_for_budget(1e-2, H, 4e-5, 1e-3, 3e-5)
    assert dl.log_bound(H, **args) == pytest.approx(T * math.log(g))


def test_ring_allreduce_delay_scaling():
    link = dl.LinkModel("x", latency_s=1e-6, bw_bytes_per_s=1e9)
    d2 = dl.ring_allreduce_delay(link, 1e6, 2)
    d8 = dl.ring_allreduce_delay(link, 1e6, 8)
    assert d8 > d2  # more hops
    assert dl.ring_allreduce_delay(link, 1e6, 1) == 0.0


def test_plan_hierarchical_h_slow_outer_link_gets_longer_period():
    """The cross-pod (slow) level must sync less frequently than the
    intra-pod level -- the paper's qualitative result, applied to TreeSync."""
    msg = 200e6  # 100M-param model deltas, bf16
    levels = [
        dl.SyncLevel("intra_pod", 16, dl.ICI_LINK, msg),
        dl.SyncLevel("cross_pod", 2, dl.DCI_LINK, msg),
    ]
    plan = dl.plan_hierarchical_h(
        levels, C=0.5, delta=1e-3, t_total=100.0, t_lp=5e-3,
    )
    assert plan[0]["name"] == "intra_pod"
    # outer level round time must be >= inner round time (it contains it)
    assert plan[1]["round_time"] >= plan[0]["round_time"]
    assert plan[0]["H"] >= 1 and plan[1]["H"] >= 1
