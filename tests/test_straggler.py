"""Async / stale-sync execution: participation masks in the plan IR and
straggler-adaptive sessions.

The load-bearing claims:

  * all-ones participation masks are BIT-identical to the synchronous
    schedule (star / two-level / imbalanced, vmap + pallas) -- the async
    program is a strict superset;
  * whole-chunk skip masks preserve the ``w = A alpha`` invariant exactly
    on every tree shape (dropped leaves' weights renormalize, re-joins
    fold bounded-staleness deltas into the group servers);
  * ``Session.run(straggler=...)`` drops stragglers, accounts simulated
    async vs synchronous wall-clock, forces the final barrier, and with an
    always-participate policy reproduces the synchronous run bit-for-bit;
  * ``BoundedSkip`` never exceeds ``max_consecutive`` skips,
    ``AdaptiveSchedule`` hysteresis suppresses small replans, and the
    ``StepTimer`` deque keeps exact median/MAD over its window.
"""
import jax
import numpy as np
import pytest

from repro.api import Problem, Session, Topology
from repro.core import dual as D
from repro.core.delay import StragglerModel
from repro.core.engine.host import execute_plan
from repro.core.engine.plan import (chunk_participation, compile_tree,
                                    full_participation, key_plan)
from repro.core.tree import star
from repro.data.synthetic import gaussian_regression
from repro.runtime.straggler import (AdaptiveSchedule, BoundedSkip,
                                     StepTimer, StragglerPolicy)

LAM = 0.1

TOPOLOGIES = {
    "star": lambda: Topology.star(4, 32, rounds=6, local_steps=48),
    "two_level": lambda: Topology.two_level(
        2, 2, 32, root_rounds=5, group_rounds=2, local_steps=40),
    "imbalanced": lambda: Topology.groups(
        [[24, 16], [12, 20, 8], 20],
        root_rounds=5, group_rounds=2, local_steps=30),
}


# ---------------------------------------------------------------------------
# all-ones masks == the synchronous program, bit for bit
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["vmap", "pallas"])
@pytest.mark.parametrize("case", sorted(TOPOLOGIES))
def test_full_participation_bit_identical_to_sync(case, backend):
    topo = TOPOLOGIES[case]()
    X, y = gaussian_regression(m=topo.m_total, d=10)
    key = jax.random.PRNGKey(7)
    plan = compile_tree(topo.tree)
    keys = key_plan(topo.tree, plan, key)
    a_sync, w_sync = execute_plan(plan, X, y, keys, loss=D.squared, lam=LAM,
                                  record_history=False, backend=backend)
    a_mask, w_mask = execute_plan(plan, X, y, keys, loss=D.squared, lam=LAM,
                                  record_history=False, backend=backend,
                                  participation=full_participation(plan))
    np.testing.assert_array_equal(np.asarray(a_sync), np.asarray(a_mask))
    np.testing.assert_array_equal(np.asarray(w_sync), np.asarray(w_mask))


@pytest.mark.parametrize("case", ["star", "two_level"])
def test_always_participate_session_bit_identical(case):
    """An always-participate policy (max_consecutive=0 never skips) routes
    through the state-carrying async executor yet reproduces the
    synchronous chunked run bit-for-bit."""
    topo = TOPOLOGIES[case]()
    X, y = gaussian_regression(m=topo.m_total, d=8)
    sess = Session.compile(Problem(X, y, lam=LAM), topo)
    key = jax.random.PRNGKey(3)
    plain = sess.run(rounds=5, key=key, record_history=False)
    pol = StragglerPolicy(
        model=StragglerModel(slow_prob=0.9, slow_factor=50.0),
        max_consecutive=0, seed=0)
    async_ = sess.run(rounds=5, key=key, record_history=False, straggler=pol)
    np.testing.assert_array_equal(np.asarray(plain.alpha),
                                  np.asarray(async_.alpha))
    np.testing.assert_array_equal(np.asarray(plain.w), np.asarray(async_.w))


# ---------------------------------------------------------------------------
# whole-chunk skips keep w = A alpha exactly
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("case", sorted(TOPOLOGIES))
def test_chunk_masks_preserve_w_invariant(case):
    topo = TOPOLOGIES[case]()
    tree = topo.tree
    X, y = gaussian_regression(m=topo.m_total, d=10)
    plan = compile_tree(tree)
    keys = key_plan(tree, plan, jax.random.PRNGKey(1))
    rounds = tree.rounds
    per = plan.n_ticks // rounds
    part = np.ones((plan.n_ticks, plan.n_leaves), np.float32)
    rng = np.random.default_rng(0)
    for r in range(1, rounds - 1):          # final chunk: full barrier
        drop = rng.random(plan.n_leaves) < 0.3
        part[r * per:(r + 1) * per, drop] = 0.0
    a, w = execute_plan(plan, X, y, keys, loss=D.squared, lam=LAM,
                        record_history=False, participation=part)
    w_expect = D.w_of_alpha(a, X, LAM)
    np.testing.assert_allclose(np.asarray(w), np.asarray(w_expect),
                               rtol=1e-4, atol=1e-5)


def test_no_participants_sync_is_noop():
    """A sync round where EVERY leaf is absent must be a no-op (and not
    divide by zero): equivalent to never syncing at that round."""
    tree = star(3, 16, outer_rounds=3, local_steps=20)
    X, y = gaussian_regression(m=48, d=6)
    plan = compile_tree(tree)
    keys = key_plan(tree, plan, jax.random.PRNGKey(2))
    part = np.ones((plan.n_ticks, plan.n_leaves), np.float32)
    part[1, :] = 0.0
    a, w = execute_plan(plan, X, y, keys, loss=D.squared, lam=LAM,
                        record_history=False, participation=part)
    assert np.isfinite(np.asarray(a)).all()
    assert np.isfinite(np.asarray(w)).all()
    w_expect = D.w_of_alpha(a, X, LAM)      # final round is a full barrier
    np.testing.assert_allclose(np.asarray(w), np.asarray(w_expect),
                               rtol=1e-4, atol=1e-5)


def test_chunk_participation_helper_shapes():
    plan = compile_tree(star(4, 8, outer_rounds=3, local_steps=4))
    ones = full_participation(plan)
    assert ones.shape == (plan.n_ticks, plan.n_leaves) and ones.all()
    mask = chunk_participation(plan, [1, 0, 1, 1])
    assert mask.shape == ones.shape
    assert (mask[:, 1] == 0).all() and mask[:, [0, 2, 3]].all()


# ---------------------------------------------------------------------------
# straggler-adaptive sessions
# ---------------------------------------------------------------------------
def test_straggler_session_drops_stragglers_and_stays_consistent():
    topo = Topology.two_level(2, 2, 32, root_rounds=12, group_rounds=2,
                              local_steps=32, t_lp=1e-5,
                              root_delay=0.02, group_delay=1e-3)
    X, y = gaussian_regression(m=topo.m_total, d=10)
    sess = Session.compile(Problem(X, y, lam=LAM), topo)
    pol = StragglerPolicy(
        model=StragglerModel(slow_prob=0.3, slow_factor=30.0, jitter=0.02),
        max_consecutive=2, seed=1)
    res = sess.run(rounds=12, key=jax.random.PRNGKey(0), straggler=pol)

    parts = [h["participants"] for h in res.history if "participants" in h]
    assert any(p < topo.n_leaves for p in parts), parts
    assert parts[-1] == topo.n_leaves          # forced final barrier
    # the final barrier restores exact primal-dual consistency
    w_expect = D.w_of_alpha(res.alpha, X, LAM)
    np.testing.assert_allclose(np.asarray(res.w), np.asarray(w_expect),
                               rtol=1e-4, atol=1e-6)
    # simulated async clock beats the synchronous-equivalent one and both
    # are monotone
    times = [h["time"] for h in res.history]
    sync_times = [h["time_sync"] for h in res.history if "time_sync" in h]
    assert all(b > a for a, b in zip(times, times[1:], strict=False))
    assert all(b > a
               for a, b in zip(sync_times, sync_times[1:], strict=False))
    assert times[-1] < sync_times[-1]
    # and the solve still converges
    assert res.gaps[-1] < 0.05 * res.gaps[0]


def test_straggler_session_warm_restart_continues_clock():
    """Satellite regression: split async runs concatenate into one monotone
    history (round and simulated-time axes both continue)."""
    topo = Topology.star(4, 32, rounds=6, local_steps=48,
                         t_lp=1e-5, t_delay=0.01)
    X, y = gaussian_regression(m=topo.m_total, d=8)
    sess = Session.compile(Problem(X, y, lam=LAM), topo)
    key = jax.random.PRNGKey(5)
    pol = StragglerPolicy(seed=2)
    r1 = sess.run(rounds=3, key=key, straggler=pol)
    pol2 = StragglerPolicy(seed=9)
    r2 = sess.run(rounds=3, warm_start=r1, straggler=pol2)
    hist = r1.history + r2.history
    assert [h["round"] for h in hist] == list(range(7))
    times = [h["time"] for h in hist]
    assert all(b > a for a, b in zip(times, times[1:], strict=False)), times


def test_warm_restart_history_concatenates_sync():
    """Satellite bugfix: warm-restarted synchronous runs no longer reset
    the time axis nor duplicate the round-0 entry."""
    topo = Topology.two_level(2, 2, 24, root_rounds=8, group_rounds=2,
                              local_steps=24, t_lp=1e-5, root_delay=0.5)
    X, y = gaussian_regression(m=topo.m_total, d=8)
    sess = Session.compile(Problem(X, y, lam=LAM), topo)
    key = jax.random.PRNGKey(11)
    r1 = sess.run(rounds=3, key=key)
    r2 = sess.run(rounds=5, warm_start=r1)
    hist = r1.history + r2.history
    assert [h["round"] for h in hist] == list(range(9))
    times = [h["time"] for h in hist]
    assert all(b > a for a, b in zip(times, times[1:], strict=False)), times
    # identical to one long run, entries included
    full = sess.run(rounds=8, key=key)
    np.testing.assert_array_equal(np.asarray(r2.alpha),
                                  np.asarray(full.alpha))
    assert [h["round"] for h in hist] == [h["round"] for h in full.history]
    np.testing.assert_allclose(times, [h["time"] for h in full.history],
                               rtol=1e-12)


# ---------------------------------------------------------------------------
# adaptive H: h_suggest drives the runtime step-mask operand
# ---------------------------------------------------------------------------
class _FixedH(AdaptiveSchedule):
    """AdaptiveSchedule stub suggesting a constant H (deterministic test
    double for the replanner)."""
    target = 3

    def replan(self, t_lp, t_delay, t_cp=0.0):
        self.current_h = self.target
        return self.target


def test_adaptive_h_suggestion_drives_execution():
    """Bugfix regression: ``AdaptiveSchedule.h_suggest`` used to be
    computed and silently dropped.  It now feeds the NEXT chunk's
    runtime-H operand: the executed step count actually changes (asserted
    against an explicit ``local_h`` replay), with zero executor
    rebuilds."""
    from repro.core.engine.host import executor_cache_stats
    topo = Topology.star(4, 16, rounds=4, local_steps=12, t_lp=1e-5,
                         t_delay=1e-3)
    X, y = gaussian_regression(m=topo.m_total, d=6)
    sess = Session.compile(Problem(X, y, lam=LAM), topo)
    key = jax.random.PRNGKey(1)
    pol = StragglerPolicy(
        max_consecutive=0, seed=0,   # always-participate: isolate the H path
        adaptive=_FixedH(C=0.5, delta=1 / 16, t_total=1.0, K=4))
    before = executor_cache_stats()["misses"]
    res = sess.run(rounds=4, key=key, straggler=pol)

    # chunk 1 ran the compiled H=12; chunks 2..4 the replanned H=3
    hs = [h["h"] for h in res.history if "h" in h]
    assert hs == [12, 3, 3, 3]
    first = sess.run(rounds=1, key=key, record_history=False)
    manual = sess.run(rounds=3, warm_start=first, local_h=3,
                      record_history=False)
    np.testing.assert_array_equal(np.asarray(res.alpha),
                                  np.asarray(manual.alpha))
    np.testing.assert_array_equal(np.asarray(res.w), np.asarray(manual.w))
    # the suggestion measurably changed the executed step count
    full = sess.run(rounds=4, key=key, record_history=False)
    assert not np.array_equal(np.asarray(res.alpha), np.asarray(full.alpha))
    # replanning is an input swap, never a retrace
    assert executor_cache_stats()["misses"] == before + 1  # carry_state only


def test_adaptive_h_retimes_simulated_clock():
    """Regression: after adaptive replanning changes H, the straggler
    clocks must charge the NEW per-chunk compute time, not the H the run
    started with."""
    class _Drop(_FixedH):
        target = 4

    topo = Topology.star(4, 32, rounds=4, local_steps=64, t_lp=1e-4,
                         t_delay=1e-3)
    X, y = gaussian_regression(m=topo.m_total, d=6)
    sess = Session.compile(Problem(X, y, lam=LAM), topo)
    pol = StragglerPolicy(
        max_consecutive=0, seed=0,
        model=StragglerModel(slow_prob=0.0, slow_factor=1.0, jitter=0.0),
        adaptive=_Drop(C=0.5, delta=1 / 32, t_total=1.0, K=4))
    res = sess.run(rounds=4, key=jax.random.PRNGKey(0), straggler=pol)
    dts = np.diff([h["time"] for h in res.history])
    assert abs(dts[0] - (64e-4 + 1e-3)) < 1e-9      # chunk 1: H=64
    for d in dts[1:]:                               # replanned: H=4
        assert abs(d - (4e-4 + 1e-3)) < 1e-9, dts


def test_adaptive_h_replaces_heterogeneous_mask():
    """Regression: a scalar suggestion equal to the MAX of a heterogeneous
    per-leaf runtime H must still be applied (the comparison is on the
    effective per-leaf counts, not their max)."""
    topo = Topology.star(3, 16, rounds=3, local_steps=12, t_lp=1e-5,
                         t_delay=1e-3)
    X, y = gaussian_regression(m=topo.m_total, d=6)
    sess = Session.compile(Problem(X, y, lam=LAM), topo)
    key = jax.random.PRNGKey(2)
    ad = _FixedH(C=0.5, delta=1 / 16, t_total=1.0, K=3)
    ad.target = 12                     # == max of the initial [4, 8, 12]
    pol = StragglerPolicy(max_consecutive=0, seed=0, adaptive=ad)
    res = sess.run(rounds=3, key=key, straggler=pol, local_h=[4, 8, 12],
                   record_history=False)
    first = sess.run(rounds=1, key=key, local_h=[4, 8, 12],
                     record_history=False)
    manual = sess.run(rounds=2, warm_start=first, local_h=12,
                      record_history=False)
    np.testing.assert_array_equal(np.asarray(res.alpha),
                                  np.asarray(manual.alpha))
    stuck = sess.run(rounds=3, key=key, local_h=[4, 8, 12],
                     record_history=False)
    assert not np.array_equal(np.asarray(res.alpha),
                              np.asarray(stuck.alpha))


# ---------------------------------------------------------------------------
# straggler-aware eq.-(12) planning (joint H / BoundedSkip threshold)
# ---------------------------------------------------------------------------
def test_bounded_skip_simulation_and_joint_planner():
    from repro.core.delay import optimal_h_bounded_skip, \
        simulate_bounded_skip
    model = StragglerModel(slow_prob=0.2, slow_factor=50.0, jitter=0.02)
    base = [0.01] * 4
    d0, r0 = simulate_bounded_skip(base, model, max_consecutive=0)
    d2, r2 = simulate_bounded_skip(base, model, max_consecutive=2)
    assert r0 == 1.0                       # never skips = the sync barrier
    assert d2 < d0 and r2 < 1.0            # skips cut the barrier delay
    row = optimal_h_bounded_skip(
        C=0.5, K=4, delta=1 / 64, t_total=1.0, t_lp=1e-5, t_cp=0.0,
        base_delays=base, model=model, skip_max=3, h_max=10**5)
    assert row["skip"] > 0                 # heavy tail => skipping wins
    assert 0.0 < row["participation"] < 1.0
    # a calm network reduces to plain eq. (12): no skipping planned
    calm = StragglerModel(slow_prob=0.0, slow_factor=1.0, jitter=0.0)
    from repro.core.delay import optimal_h
    row0 = optimal_h_bounded_skip(
        C=0.5, K=4, delta=1 / 64, t_total=1.0, t_lp=1e-5, t_cp=0.0,
        base_delays=base, model=calm, skip_max=3, h_max=10**5)
    h_ref, _ = optimal_h(C=0.5, K=4, delta=1 / 64, t_total=1.0,
                         t_lp=1e-5, t_delay=0.01, t_cp=0.0, h_max=10**5)
    assert row0["skip"] == 0 and row0["H"] == h_ref


def test_schedule_auto_straggler_aware_end_to_end():
    """DelayModel(straggler=...) plans (H, skip) jointly; the session
    exposes the planned policy (``Session.straggler_policy``) and runs
    it through the participation masks."""
    from repro.api import Schedule
    topo = Topology.star(4, 64, rounds=8, local_steps=32, t_lp=1e-5,
                         t_delay=0.01)
    X, y = gaussian_regression(m=topo.m_total, d=8)
    prob = Problem(X, y, lam=LAM)
    model = StragglerModel(slow_prob=0.2, slow_factor=50.0, jitter=0.02)
    sess = Session.compile(
        prob, topo, Schedule.auto(t_total=1.0, straggler=model,
                                  skip_max=3, h_max=10**4))
    assert sess.resolved.skip is not None and sess.resolved.skip > 0
    lp0 = sess.level_plan[0]
    assert {"skip", "participation"} <= set(lp0)
    pol = sess.straggler_policy(seed=0)
    assert pol.max_consecutive == sess.resolved.skip
    assert pol.model is model
    res = sess.run(rounds=6, straggler=pol)
    assert np.isfinite(res.gaps).all()
    # sessions without a straggler-aware schedule refuse to fabricate one
    with pytest.raises(ValueError, match="straggler"):
        Session.compile(prob, topo).straggler_policy()


# ---------------------------------------------------------------------------
# decision-layer properties (BoundedSkip / AdaptiveSchedule / StepTimer)
# ---------------------------------------------------------------------------
def test_bounded_skip_never_exceeds_max_consecutive():
    """Property: over arbitrary stall sequences, at most `max_consecutive`
    consecutive skips before a forced barrier."""
    rng = np.random.default_rng(42)
    for max_c in (0, 1, 3):
        pol = BoundedSkip(max_consecutive=max_c)
        streak = 0
        for stall in rng.random(500) < 0.8:
            if pol.decide(bool(stall)):
                streak += 1
                assert streak <= max_c, (max_c, streak)
            else:
                streak = 0


def test_adaptive_schedule_hysteresis():
    s = AdaptiveSchedule(C=0.5, delta=1 / 300, t_total=1.0, K=3,
                         h_max=10**6, hysteresis=1.3)
    h0 = s.replan(t_lp=4e-5, t_delay=4e-3, t_cp=3e-5)
    # a small drift (well under 30%) must NOT move H
    h1 = s.replan(t_lp=4e-5, t_delay=4.4e-3, t_cp=3e-5)
    assert h1 == h0
    # a large drift must
    h2 = s.replan(t_lp=4e-5, t_delay=4e-1, t_cp=3e-5)
    assert h2 != h0


def test_step_timer_deque_window_and_exact_stats():
    """Satellite: deque(maxlen) eviction keeps median/MAD exactly equal to
    the list-based reference."""
    t = StepTimer(window=8)
    ref = []
    rng = np.random.default_rng(0)
    for x in rng.exponential(1.0, 50):
        t.observe(float(x))
        ref.append(float(x))
        ref = ref[-8:]
        assert len(t.samples) == len(ref)
        assert t.median == pytest.approx(float(np.median(ref)), abs=0)
        mad = float(np.median(np.abs(np.array(ref) - np.median(ref))))
        assert t.mad == pytest.approx(mad, abs=0)


def test_straggler_policy_feeds_adaptive_schedule():
    pol = StragglerPolicy(adaptive=AdaptiveSchedule(C=0.5, delta=1 / 64,
                                                    t_total=1.0, K=4),
                          seed=0)
    pol.bind(base_delays=[0.01] * 4, t_compute=1e-3, t_lp=1e-5)
    step = pol.step()
    assert step.h_suggest is not None and step.h_suggest >= 1
    assert pol.last_h_suggest == step.h_suggest


def test_straggler_model_validation_and_sampling():
    with pytest.raises(ValueError):
        StragglerModel(slow_prob=1.5)
    with pytest.raises(ValueError):
        StragglerModel(slow_factor=0.5)
    m = StragglerModel(slow_prob=0.5, slow_factor=10.0, jitter=0.0)
    d = m.sample(np.full(1000, 2.0), np.random.default_rng(0))
    assert set(np.round(d, 6)) <= {2.0, 20.0}
    frac = (d > 10).mean()
    assert 0.4 < frac < 0.6


def test_topology_leaf_sync_delays():
    topo = Topology.two_level(2, 2, 8, root_delay=1.0, group_delay=0.25)
    assert topo.leaf_sync_delays() == [1.25] * 4
    mixed = Topology.groups([[8, 8], 8], root_delay=0.5, group_delay=0.1)
    assert mixed.leaf_sync_delays() == [0.6, 0.6, 0.5]


# ---------------------------------------------------------------------------
# mesh backend: masks are lowered there too
# ---------------------------------------------------------------------------
def test_mesh_accepts_participation_masks():
    from repro.core.engine.mesh import execute_plan_mesh
    n = len(jax.devices())
    tree = star(n, 64 // n, outer_rounds=6, local_steps=32)
    X, y = gaussian_regression(m=64, d=8)
    plan = compile_tree(tree)
    mesh = jax.make_mesh((n,), ("data",))
    a0, w0 = execute_plan_mesh(plan, tree, X, y, mesh, axes=("data",),
                               loss=D.squared, lam=LAM,
                               key=jax.random.PRNGKey(0))
    a1, w1 = execute_plan_mesh(plan, tree, X, y, mesh, axes=("data",),
                               loss=D.squared, lam=LAM,
                               key=jax.random.PRNGKey(0),
                               participation=full_participation(plan))
    np.testing.assert_array_equal(np.asarray(a0), np.asarray(a1))
    np.testing.assert_array_equal(np.asarray(w0), np.asarray(w1))
