"""Per-architecture smoke tests (deliverable f): every assigned arch at a
reduced config runs one forward/train step on CPU with finite loss and
correct output shapes, plus prefill->decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS
from repro.data.lm import lm_batch
from repro.launch.steps import (make_prefill_step, make_serve_step,
                                make_train_step)
from repro.models import transformer
from repro.optim import get_optimizer

ALL_ARCHS = list(ARCHS)


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_shapes_and_finite(arch, rng):
    cfg = ARCHS[arch].SMOKE
    B, S = 4, 32
    params = transformer.init_params(cfg, rng)
    opt = get_optimizer(cfg)
    opt_state = opt.init(params)
    batch = lm_batch(cfg, B, S, step=0)
    step = jax.jit(make_train_step(cfg, opt))
    params2, opt_state2, metrics = step(params, opt_state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0, loss
    # params updated, shapes preserved, all finite
    changed = 0
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2),
                    strict=True):
        assert a.shape == b.shape and a.dtype == b.dtype
        assert np.isfinite(np.asarray(b, np.float32)).all()
        changed += int(not np.array_equal(np.asarray(a), np.asarray(b)))
    assert changed > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_loss_decreases_on_fixed_batch(arch, rng):
    cfg = ARCHS[arch].SMOKE
    params = transformer.init_params(cfg, rng)
    opt = get_optimizer(cfg, lr=3e-3)
    opt_state = opt.init(params)
    batch = lm_batch(cfg, 4, 32, step=0)
    step = jax.jit(make_train_step(cfg, opt))
    losses = []
    for _ in range(8):
        params, opt_state, metrics = step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_matches_decode(arch, rng):
    """Prefill(prompt) then decode must see the same history as decoding
    token-by-token from scratch: compare next-token logits paths."""
    cfg = ARCHS[arch].SMOKE
    B, S = 2, 16
    params = transformer.init_params(cfg, rng)
    if cfg.input_mode == "embeddings":
        prompt = {"embeds": 0.02 * jax.random.normal(
            rng, (B, S, cfg.d_model), jnp.bfloat16)}
    else:
        prompt = {"tokens": jax.random.randint(rng, (B, S), 0,
                                               cfg.vocab_size)}
    max_len = S + 8
    prefill = jax.jit(make_prefill_step(cfg, max_len=max_len))
    serve = jax.jit(make_serve_step(cfg))
    logits, cache = prefill(params, prompt)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    outs = [tok]
    for _ in range(4):
        tok, cache = serve(params, cache, tok)
        assert tok.shape == (B, 1)
        outs.append(tok)
    gen = jnp.concatenate(outs, axis=1)
    assert int(gen.min()) >= 0 and int(gen.max()) < cfg.vocab_size


@pytest.mark.parametrize("arch", ["qwen3-32b", "rwkv6-1.6b",
                                  "recurrentgemma-2b", "dbrx-132b"])
def test_scan_vs_unrolled_forward_equal(arch, rng):
    """scan_layers=False (analysis mode) computes the same function."""
    cfg = ARCHS[arch].SMOKE
    params = transformer.init_params(cfg, rng)
    batch = lm_batch(cfg, 2, 32, step=0)
    loss_s, _ = transformer.forward_train(cfg, params, batch)
    cfg_u = dataclasses.replace(cfg, scan_layers=False)
    loss_u, _ = transformer.forward_train(cfg_u, params, batch)
    # scan and unrolled fuse differently -> bf16-level disagreement only
    # (MoE scatter reduction order adds a little more)
    np.testing.assert_allclose(float(loss_s), float(loss_u), rtol=5e-3)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned hyperparameters."""
    cfg = ARCHS[arch].FULL
    expect = {
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256_000),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "qwen3-32b": (64, 5120, 64, 8, 25_600, 151_936),
        "qwen2.5-32b": (64, 5120, 40, 8, 27_648, 152_064),
        "h2o-danube-1.8b": (24, 2560, 32, 8, 6912, 32_000),
        "yi-34b": (60, 7168, 56, 8, 20_480, 64_000),
        "rwkv6-1.6b": (24, 2048, 0, 0, 7168, 65_536),
        "llava-next-34b": (60, 7168, 56, 8, 20_480, 64_000),
        "dbrx-132b": (40, 6144, 48, 8, 10_752, 100_352),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32_000),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expect, (got, expect)
    if arch == "dbrx-132b":
        assert (cfg.num_experts, cfg.experts_per_token) == (16, 4)
    if arch == "arctic-480b":
        assert (cfg.num_experts, cfg.experts_per_token) == (128, 2)
        assert cfg.moe_dense_ff > 0  # dense residual branch


@pytest.mark.parametrize("arch,expected_b", [
    ("h2o-danube-1.8b", 1.8e9), ("rwkv6-1.6b", 1.6e9),
    ("recurrentgemma-2b", 2.7e9),     # RG counts w/o embeddings (2.0e9 body)
    ("qwen3-32b", 32.8e9), ("qwen2.5-32b", 32.5e9), ("yi-34b", 34.4e9),
    ("dbrx-132b", 132e9), ("arctic-480b", 482e9),
])
def test_param_counts_near_nameplate(arch, expected_b):
    n = ARCHS[arch].FULL.param_count()
    assert 0.8 * expected_b < n < 1.25 * expected_b, (arch, n, expected_b)
