"""RG-LRU Pallas kernel vs oracle: shape/chunk sweeps + model-path check."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.rglru.kernel import rglru_scan_kernel
from repro.kernels.rglru.ref import rglru_scan_ref


def _inputs(key, B, S, W, decay=0.9):
    ka, kb, kh = jax.random.split(key, 3)
    # a in (0, 1) like real RG-LRU decays; b arbitrary
    a = decay + (1 - decay) * jax.random.uniform(ka, (B, S, W))
    b = jax.random.normal(kb, (B, S, W))
    h0 = jax.random.normal(kh, (B, W))
    return a, b, h0


@pytest.mark.parametrize("B,S,W,bw", [
    (1, 32, 128, 128),      # single chunk (S < T_CHUNK)
    (2, 256, 128, 128),     # exactly one T_CHUNK
    (2, 512, 256, 128),     # multi-chunk, multi-block
    (1, 384, 128, 64),      # chunk + remainder guard (S % T_CHUNK != 0)
])
def test_kernel_matches_ref(B, S, W, bw):
    a, b, h0 = _inputs(jax.random.PRNGKey(0), B, S, W)
    if S % min(256, S) != 0:
        pytest.skip("kernel requires S % chunk == 0")
    h_k, hl_k = rglru_scan_kernel(a, b, h0, block_w=bw, interpret=True)
    h_r, hl_r = rglru_scan_ref(a, b, h0)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(hl_k), np.asarray(hl_r),
                               rtol=1e-5, atol=1e-5)


def test_long_sequence_stability():
    """4k steps with realistic decays: no drift vs the oracle."""
    a, b, h0 = _inputs(jax.random.PRNGKey(1), 1, 4096, 128, decay=0.99)
    h_k, hl_k = rglru_scan_kernel(a, b, h0, interpret=True)
    h_r, hl_r = rglru_scan_ref(a, b, h0)
    np.testing.assert_allclose(np.asarray(hl_k), np.asarray(hl_r),
                               rtol=1e-4, atol=1e-4)


def test_matches_model_associative_scan():
    """The kernel agrees with the model stack's associative_scan path
    (repro.models.rglru._scan_linear) with h0 = 0."""
    from repro.models.rglru import _scan_linear
    a, b, _ = _inputs(jax.random.PRNGKey(2), 2, 128, 128)
    h_model = _scan_linear(a, b)
    h_k, _ = rglru_scan_kernel(a, b, jnp.zeros((2, 128)), interpret=True)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_model),
                               rtol=1e-5, atol=1e-5)
