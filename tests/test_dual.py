"""Unit tests for losses, conjugates and primal/dual objectives."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dual as D

jax.config.update("jax_platform_name", "cpu")


LOSS_LABELS = {
    "squared": 0.7,
    "hinge": 1.0,
    "smooth_hinge_1": 1.0,
    "logistic": -1.0,
}


@pytest.mark.parametrize("name", list(D.LOSSES))
def test_conjugate_is_legendre_transform(name):
    """l*(-alpha) must equal sup_a (-alpha*a - l(a)) on the feasible set."""
    loss = D.LOSSES[name]
    y = jnp.float32(LOSS_LABELS.get(name, 1.0))
    a_grid = jnp.linspace(-50.0, 50.0, 200_001)
    if name == "squared":
        alphas = jnp.linspace(-3.0, 3.0, 7)
    else:
        # feasible set of the dual variable is alpha*y in [0,1]
        alphas = jnp.linspace(0.02, 0.98, 7) * y
    for alpha in alphas:
        sup = jnp.max(-alpha * a_grid - loss.value(a_grid, y))
        np.testing.assert_allclose(
            float(loss.conj_neg(alpha, y)), float(sup), rtol=2e-3, atol=2e-3
        )


@pytest.mark.parametrize("name", list(D.LOSSES))
def test_coord_delta_is_argmax(name):
    """The closed-form/Newton coordinate step must beat a dense grid search."""
    loss = D.LOSSES[name]
    y = jnp.float32(LOSS_LABELS.get(name, 1.0))
    wx = jnp.float32(0.3)
    alpha = jnp.float32(0.4 * y if name != "squared" else 0.25)
    xsq_over_lm = jnp.float32(0.8)

    def obj(d):
        return (
            -0.5 * xsq_over_lm * d**2 - wx * d - loss.conj_neg(alpha + d, y)
        )

    d_star = loss.coord_delta(wx, alpha, y, xsq_over_lm)
    if name == "squared":
        d_grid = jnp.linspace(-5.0, 5.0, 400_001)
    else:
        d_grid = (jnp.linspace(0.0, 1.0, 400_001)) * y - alpha
    best = jnp.max(obj(d_grid))
    assert float(obj(d_star)) >= float(best) - 1e-4


@pytest.mark.parametrize("name", ["logistic", "smooth_hinge_1",
                                  "smooth_hinge_0.3", "squared"])
def test_coord_delta_matches_scipy_numeric_optimum(name):
    """The analytic/Newton coordinate step must agree with a scipy numeric
    optimizer of the same scalar subproblem (satellite check for the
    logistic Newton solve and the smoothed-hinge closed form)."""
    scipy_opt = pytest.importorskip("scipy.optimize")
    loss = D.get_loss(name)
    for y_ in (1.0, -1.0) if name != "squared" else (0.7,):
        y = jnp.float32(y_)
        for wx, alpha0, xsq in [(0.3, 0.35, 0.8), (-1.2, 0.6, 2.5),
                                (0.05, 0.9, 0.1)]:
            alpha = jnp.float32(alpha0 * y_ if name != "squared" else alpha0)

            def obj(d, wx=wx, xsq=xsq, alpha=alpha):
                return float(-0.5 * xsq * d * d - wx * d
                             - loss.conj_neg(alpha + d, y))

            if name == "squared":
                lo, hi = -50.0, 50.0
            else:
                # feasible set: (alpha + d) y in [0, 1]
                lo, hi = sorted((0.0 * y_ - float(alpha),
                                 1.0 * y_ - float(alpha)))
            r = scipy_opt.minimize_scalar(
                lambda d: -obj(d), bounds=(lo, hi), method="bounded",
                options={"xatol": 1e-10})
            d_star = float(loss.coord_delta(jnp.float32(wx), alpha, y,
                                            jnp.float32(xsq)))
            assert obj(d_star) >= -r.fun - 5e-5, (
                name, y_, wx, alpha0, xsq, d_star, r.x)


def test_loss_registry_resolution():
    assert D.get_loss("squared") is D.squared
    assert D.get_loss(D.logistic) is D.logistic
    g = D.get_loss("smooth_hinge_0.7")
    assert g.gamma == 0.7 and D.get_loss("smooth_hinge_0.7") is g
    custom = D.Loss("custom_sq", D.squared.value, D.squared.conj_neg,
                    D.squared.coord_delta, gamma=1.0)
    assert D.register_loss(custom) is custom
    assert D.get_loss("custom_sq") is custom
    with pytest.raises(KeyError):
        D.get_loss("nope")
    with pytest.raises(ValueError):
        D.get_loss("smooth_hinge_-1")


def test_weak_duality_and_ridge_optimum():
    key = jax.random.PRNGKey(0)
    X = jax.random.normal(key, (40, 8))
    y = jax.random.normal(jax.random.PRNGKey(1), (40,))
    lam = 0.1
    alpha = 0.01 * jax.random.normal(jax.random.PRNGKey(2), (40,))
    gap = D.duality_gap(alpha, X, y, D.squared, lam)
    assert float(gap) >= -1e-5  # weak duality

    a_star = D.ridge_dual_optimum(X, y, lam)
    gap_star = D.duality_gap(a_star, X, y, D.squared, lam)
    assert float(gap_star) < 1e-3  # strong duality at the optimum
    # optimum is a stationary point: numeric gradient of D ~ 0
    g = jax.grad(lambda a: D.dual_value(a, X, y, D.squared, lam))(a_star)  # analysis: allow(static-operand-capture) fixed lam, single trace by construction
    np.testing.assert_allclose(np.asarray(g), 0.0, atol=1e-5)


def test_primal_dual_relationship():
    X = jax.random.normal(jax.random.PRNGKey(3), (30, 5))
    lam = 0.05
    alpha = jax.random.normal(jax.random.PRNGKey(4), (30,))
    w = D.w_of_alpha(alpha, X, lam)
    A = D.data_matrix(X, lam)
    np.testing.assert_allclose(np.asarray(w), np.asarray(A @ alpha), rtol=1e-5)
