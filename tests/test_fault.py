"""Elastic fault-tolerant sessions (ROADMAP item 2): checkpointed chunk
carries, kill-and-resume bit-identity on every backend (including a
subprocess remesh onto a different device count), permanent leaf
leave/join with size re-weighting, fault-injected fleets."""
import json
import os
import subprocess
import sys
import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (CheckpointPolicy, DelayModel, ElasticSession,
                       FaultModel, MembershipLog, Problem, Schedule, Session,
                       Sweep, Topology, run_with_faults)
from repro.core import dual as dual_mod
from repro.core.delay import checkpoint_period
from repro.data.synthetic import gaussian_regression
from repro.runtime.checkpoint import CheckpointManager

LAM = 0.1


@pytest.fixture(scope="module")
def data():
    return gaussian_regression(m=64, d=8)


def _problem(data, lam=LAM):
    X, y = data
    return Problem(X, y, loss="squared", lam=lam)


def _star(rounds=6):
    return Topology.star(4, 16, rounds=rounds, local_steps=8)


def _assert_same(res, ref):
    np.testing.assert_array_equal(np.asarray(res.alpha),
                                  np.asarray(ref.alpha))
    np.testing.assert_array_equal(np.asarray(res.w), np.asarray(ref.w))
    np.testing.assert_array_equal(np.asarray(res.next_key),
                                  np.asarray(ref.next_key))
    assert res.history == ref.history


# ---------------------------------------------------------------------------
# crash-mid-solve bit-identity
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["vmap", "pallas"])
def test_resume_bit_identity(data, backend, tmp_path):
    """Kill after round 3 of 6; the resumed run's iterates, RNG chain and
    concatenated history are bit-identical to the uninterrupted solve."""
    sess = Session.compile(_problem(data), _star(), Schedule(),
                           backend=backend)
    key = jax.random.PRNGKey(7)
    ref = sess.run(6, key=key)
    sess.run(3, key=key, checkpoint=CheckpointPolicy(directory=tmp_path,
                                                     every=1))
    res = sess.resume(tmp_path, rounds=3)
    _assert_same(res, ref)


def test_resume_bit_identity_mesh(data, tmp_path):
    n = len(jax.devices())
    topo = Topology.star(n, 64 // n, rounds=6, local_steps=8)
    sess = Session.compile(_problem(data), topo, Schedule(), backend="mesh")
    key = jax.random.PRNGKey(7)
    ref = sess.run(6, key=key)
    sess.run(3, key=key, checkpoint=str(tmp_path))   # plain-dir shorthand
    res = sess.resume(tmp_path, rounds=3)
    _assert_same(res, ref)


def test_resume_of_completed_run_restores(data, tmp_path):
    """rounds_total is reached: resume is a pure restore (0 extra rounds),
    returning the final iterates and the FULL recorded history."""
    sess = Session.compile(_problem(data), _star(), Schedule(),
                           backend="vmap")
    key = jax.random.PRNGKey(3)
    ref = sess.run(6, key=key, checkpoint=CheckpointPolicy(
        directory=tmp_path, every=2))
    res = sess.resume(tmp_path)
    np.testing.assert_array_equal(np.asarray(res.alpha),
                                  np.asarray(ref.alpha))
    np.testing.assert_array_equal(np.asarray(res.next_key),
                                  np.asarray(ref.next_key))
    assert [h["round"] for h in res.history] == \
        [h["round"] for h in ref.history]


@pytest.mark.parametrize("backend", ["vmap", "mesh"])
def test_resume_compressed_plan_carries_residuals(data, backend, tmp_path):
    """Compressed plans thread error-feedback residuals through the carry;
    the checkpoint payload must include them for bit-identical resume."""
    sched = Schedule(compression="topk_0.2")
    n = len(jax.devices())
    topo = _star() if backend == "vmap" else \
        Topology.star(n, 64 // n, rounds=6, local_steps=8)
    sess = Session.compile(_problem(data), topo, sched, backend=backend)
    key = jax.random.PRNGKey(11)
    ref = sess.run(6, key=key)
    sess.run(3, key=key, checkpoint=CheckpointPolicy(directory=tmp_path,
                                                     every=1))
    # the payload genuinely carries (n, d) residuals
    with np.load(tmp_path / "step_0000000003.npz") as z:
        res_keys = [k for k in z.files if k.startswith("res")]
        assert res_keys, list(z.files)
    res = sess.resume(tmp_path, rounds=3)
    _assert_same(res, ref)


def test_resume_cross_backend(data, tmp_path):
    """A carry checkpointed by the host backend restores on the device
    backend (and vice versa): the payload is backend-portable."""
    prob, topo = _problem(data), _star()
    n = len(jax.devices())
    topo_m = Topology.star(n, 64 // n, rounds=6, local_steps=8)
    if n == 4:
        topo = topo_m   # identical trees -> identical plan fingerprints
    sess_v = Session.compile(prob, topo_m, Schedule(), backend="vmap")
    sess_m = Session.compile(prob, topo_m, Schedule(), backend="mesh")
    key = jax.random.PRNGKey(5)
    ref = sess_v.run(6, key=key)
    sess_v.run(3, key=key, checkpoint=CheckpointPolicy(directory=tmp_path,
                                                       every=1))
    res = sess_m.resume(tmp_path, rounds=3)
    np.testing.assert_array_equal(np.asarray(res.alpha),
                                  np.asarray(ref.alpha))
    np.testing.assert_array_equal(np.asarray(res.w), np.asarray(ref.w))


_REMESH_CHILD = """
import numpy as np, jax
from repro.api import Problem, Topology, Schedule, Session
z = np.load({data!r})
prob = Problem(z["X"], z["y"], loss="squared", lam={lam})
topo = Topology.star(2, 32, rounds=6, local_steps=8)
assert len(jax.devices()) == 2, jax.devices()
sess = Session.compile(prob, topo, Schedule(), backend="mesh")
res = sess.resume({ckpt!r}, rounds=3)
np.savez({out!r}, alpha=np.asarray(res.alpha), w=np.asarray(res.w),
         key=np.asarray(res.next_key))
"""


def test_resume_remesh_subprocess_different_device_count(data, tmp_path):
    """The elastic-remesh contract end to end: a carry checkpointed by a
    single-process vmap session is resumed by a SEPARATE process running a
    2-device mesh -- a device count that never existed at save time."""
    X, y = data
    topo = Topology.star(2, 32, rounds=6, local_steps=8)
    sess = Session.compile(_problem(data), topo, Schedule(), backend="vmap")
    key = jax.random.PRNGKey(9)
    ref = sess.run(6, key=key)
    ckpt = tmp_path / "ckpt"
    sess.run(3, key=key, checkpoint=CheckpointPolicy(directory=ckpt,
                                                     every=1))
    datap = tmp_path / "data.npz"
    np.savez(datap, X=np.asarray(X), y=np.asarray(y))
    out = tmp_path / "out.npz"
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=2",
               JAX_PLATFORMS="cpu")
    script = _REMESH_CHILD.format(data=str(datap), lam=LAM,
                                  ckpt=str(ckpt), out=str(out))
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    with np.load(out) as z:
        np.testing.assert_array_equal(z["alpha"], np.asarray(ref.alpha))
        np.testing.assert_array_equal(z["w"], np.asarray(ref.w))
        np.testing.assert_array_equal(z["key"], np.asarray(ref.next_key))


def test_resume_refuses_changed_plan(data, tmp_path):
    sess = Session.compile(_problem(data), _star(), Schedule(),
                           backend="vmap")
    sess.run(2, key=jax.random.PRNGKey(0), checkpoint=str(tmp_path))
    other = Session.compile(
        _problem(data), Topology.star(4, 16, rounds=6, local_steps=9),
        Schedule(), backend="vmap")
    with pytest.raises(ValueError, match="fingerprint|plan"):
        other.resume(tmp_path)


def test_checkpoint_refuses_straggler(data, tmp_path):
    from repro.runtime.straggler import StragglerPolicy
    sess = Session.compile(_problem(data), _star(), Schedule(),
                           backend="vmap")
    with pytest.raises(ValueError, match="straggler"):
        sess.run(2, key=jax.random.PRNGKey(0), straggler=StragglerPolicy(),
                 checkpoint=str(tmp_path))


# ---------------------------------------------------------------------------
# the Young/Daly checkpoint period (eq.-(12) round-time model extension)
# ---------------------------------------------------------------------------
def test_checkpoint_period_young_daly():
    # tau = sqrt(2 t_write MTBF) in wall time, floored to >= 1 round
    assert checkpoint_period(1.0, 0.5, 100.0) == 10
    assert checkpoint_period(1.0, 0.0, 100.0) == 1      # free writes
    assert checkpoint_period(50.0, 0.5, 100.0) == 1     # slow rounds clamp
    assert checkpoint_period(1.0, 0.5, 100.0, max_period=4) == 4
    # monotone in MTBF: rarer faults -> sparser checkpoints
    ps = [checkpoint_period(1.0, 0.5, mtbf) for mtbf in (10, 100, 1000)]
    assert ps == sorted(ps)


def test_schedule_plans_ckpt_every(data):
    """DelayModel(mtbf=, ckpt_write=) makes the schedule fault-aware: the
    resolved plan carries the Young/Daly period, rounds='auto' charges the
    amortized write cost, and CheckpointPolicy(every='auto') consumes it."""
    topo = Topology.star(4, 16, rounds=6, local_steps=8, t_lp=1e-4)
    plain = Schedule(rounds="auto",
                     delay=DelayModel(t_total=0.2, C=1.0)).resolve(topo)
    faulty = Schedule(rounds="auto",
                      delay=DelayModel(t_total=0.2, C=1.0, mtbf=1.0,
                                       ckpt_write=0.01)).resolve(topo)
    assert plain.ckpt_every is None
    assert faulty.ckpt_every is not None and faulty.ckpt_every >= 1
    # the write cost eats budget: never MORE rounds than the fault-free plan
    assert faulty.rounds <= plain.rounds
    # fixed-rounds schedules get the period too
    fixed = Schedule(delay=DelayModel(t_total=0.2, C=1.0, mtbf=1.0,
                                      ckpt_write=0.01)).resolve(topo)
    assert fixed.ckpt_every is not None


def test_every_auto_needs_fault_aware_schedule(data, tmp_path):
    sess = Session.compile(_problem(data), _star(), Schedule(),
                           backend="vmap")
    with pytest.raises(ValueError, match="auto"):
        sess.run(2, key=jax.random.PRNGKey(0),
                 checkpoint=CheckpointPolicy(directory=tmp_path,
                                             every="auto"))


# ---------------------------------------------------------------------------
# membership: permanent leave / join
# ---------------------------------------------------------------------------
def test_elastic_leave_join_converges(data):
    """Leaves leave and join mid-solve; each boundary splices the dual and
    rebuilds w = X^T alpha / (lam m); the solve keeps converging on the
    CURRENT problem and the final iterates satisfy eq. (13)."""
    X, y = data
    prob = _problem(data)
    rng = np.random.default_rng(0)
    Xn = rng.normal(size=(12, X.shape[1])).astype(np.float32)
    yn = rng.normal(size=(12,)).astype(np.float32)
    log = (MembershipLog()
           .leave("W1", at_round=2)
           .join("W9", Xn, yn, at_round=4))
    es = ElasticSession(prob, _star(), backend="vmap")
    res = es.run(12, membership=log, key=jax.random.PRNGKey(1))

    assert es.current_topology.leaf_names() == ["W0", "W2", "W3", "W9"]
    assert es.current_problem.m == 64 - 16 + 12
    assert len(res.alpha) == es.current_problem.m
    # the returned primal is the eq.-(13) image of the returned dual
    w_ref = dual_mod.w_of_alpha(res.alpha, es.current_problem.X, LAM)
    np.testing.assert_allclose(np.asarray(res.w), np.asarray(w_ref),
                               rtol=1e-5, atol=1e-6)
    # history spans all 12 rounds and the tail converges on the final
    # membership's problem
    assert [h["round"] for h in res.history][-1] == 12
    gaps = [h["gap"] for h in res.history]
    assert gaps[-1] < gaps[-6]

    # plan_diff reports exactly what each event changed
    assert [d["round"] for d in es.plan_diffs] == [2, 4]
    assert es.plan_diffs[0]["leaves_removed"] == ["W1"]
    assert es.plan_diffs[1]["leaves_added"] == ["W9"]
    assert all(d["fingerprint_changed"] for d in es.plan_diffs)


def test_elastic_reweights_by_size(data):
    """The default schedule re-weights aggregation data-proportionally
    (arXiv:2308.14783): after an unbalanced leave, the surviving leaves'
    plan weights track |data block| / m."""
    es = ElasticSession(_problem(data), _star(), backend="vmap")
    log = MembershipLog().leave("W0", at_round=1)
    es.run(3, membership=log, key=jax.random.PRNGKey(0))
    assert es.schedule.weighting == "size"
    assert "W0" not in es.current_topology.leaf_names()
    assert es.plan_diffs[0]["weights_changed"]  # survivors re-weighted
    sizes = [es.current_topology.leaf_span(nm)[1]
             for nm in es.current_topology.leaf_names()]
    assert sum(sizes) == es.current_problem.m


def test_elastic_event_past_horizon_refused(data):
    es = ElasticSession(_problem(data), _star(), backend="vmap")
    log = MembershipLog().leave("W1", at_round=5)
    with pytest.raises(ValueError, match="never takes effect"):
        es.run(4, membership=log, key=jax.random.PRNGKey(0))


def test_topology_leaf_editing():
    topo = Topology.star(3, 8, rounds=4, local_steps=4)
    assert topo.leaf_names() == ["W0", "W1", "W2"]
    assert topo.leaf_span("W1") == (8, 8)
    smaller = topo.without_leaf("W1")
    assert smaller.leaf_names() == ["W0", "W2"]
    assert smaller.leaf_span("W2") == (8, 8)
    bigger = smaller.with_leaf("W7", data_size=5)
    assert bigger.leaf_names() == ["W0", "W2", "W7"]
    assert bigger.leaf_span("W7") == (16, 5)
    with pytest.raises(KeyError):
        topo.without_leaf("nope")
    with pytest.raises(ValueError):
        bigger.with_leaf("W7", data_size=3)   # duplicate name


# ---------------------------------------------------------------------------
# fault injection + fleets
# ---------------------------------------------------------------------------
def test_fault_model_sampling():
    fm = FaultModel(crash_prob=0.5, leave_prob=0.5, min_leaves=2)
    c1 = fm.sample_crashes(20, seed=4)
    assert c1 == fm.sample_crashes(20, seed=4)       # deterministic
    assert c1 and all(1 <= t < 20 for t in c1)
    log = fm.sample_leaves(["a", "b", "c", "d"], 20, seed=4)
    left = {e.name for e in log.events}
    assert len(left) <= 2                             # min_leaves respected
    with pytest.raises(ValueError):
        FaultModel(crash_prob=1.5)


def test_run_with_faults_bit_identity(data, tmp_path):
    """Kill-and-resume through the production restart path: crashes strike
    mid-period (every=2) so real work is lost and recomputed, yet the
    final iterates/history equal the uninterrupted run's."""
    sess = Session.compile(_problem(data), _star(), Schedule(),
                           backend="vmap")
    key = jax.random.PRNGKey(2)
    ref = sess.run(6, key=key)
    res, report = run_with_faults(
        sess, 6, checkpoint=CheckpointPolicy(directory=tmp_path, every=2),
        fault=FaultModel(crash_prob=0.5), key=key, seed=3)
    assert report["crashes"], report
    _assert_same(res, ref)
    for r in report["restarts"]:
        assert r["resumed_from"] <= r["crash_at"] < r["ran_to"] <= 6


def test_sweep_fleet_resume(data, tmp_path):
    """An interrupted checkpointed fleet continues under Sweep(resume=):
    both the fused-batched and the sequential-member layout restart
    bit-identically (crash simulated by dropping post-round-4 snapshots)."""
    prob, topo = _problem(data), _star()
    lams = [0.05, 0.1, 0.4]

    def crash_after(root, round_):
        for f in Path(root).rglob("step_*.*"):
            if int(f.stem.split("_")[1]) > round_:
                f.unlink()

    # fused/batched groups -> group_base/ stacked snapshots
    sess = Session.compile(prob, topo, Schedule(), backend="vmap")
    ref = sess.sweep(Sweep(lams=lams, seeds=[0, 1]), rounds=6)
    d1 = tmp_path / "batched"
    sess.sweep(Sweep(lams=lams, seeds=[0, 1]), rounds=6,
               checkpoint=CheckpointPolicy(directory=d1, every=1))
    assert (d1 / "fleet.json").exists()
    assert (d1 / "group_base").is_dir()
    crash_after(d1, 4)
    rs = sess.sweep(Sweep(lams=lams, seeds=[0, 1], resume=d1), rounds=6)
    np.testing.assert_array_equal(np.asarray(rs.alphas),
                                  np.asarray(ref.alphas))
    np.testing.assert_array_equal(np.asarray(rs.ws), np.asarray(ref.ws))

    # compressed plans run members sequentially -> member_*/ checkpoints
    sess_c = Session.compile(prob, topo, Schedule(compression="topk_0.2"),
                             backend="vmap")
    ref_c = sess_c.sweep(Sweep(lams=lams), rounds=6)
    d2 = tmp_path / "sequential"
    sess_c.sweep(Sweep(lams=lams), rounds=6,
                 checkpoint=CheckpointPolicy(directory=d2, every=1))
    assert sorted(p.name for p in d2.glob("member_*")) == \
        ["member_0000", "member_0001", "member_0002"]
    crash_after(d2, 4)
    rs_c = sess_c.sweep(Sweep(lams=lams, resume=d2), rounds=6)
    np.testing.assert_array_equal(np.asarray(rs_c.alphas),
                                  np.asarray(ref_c.alphas))
    np.testing.assert_array_equal(np.asarray(rs_c.ws),
                                  np.asarray(ref_c.ws))


def test_sweep_fleet_resume_refuses_changed_spec(data, tmp_path):
    sess = Session.compile(_problem(data), _star(), Schedule(),
                           backend="vmap")
    sess.sweep(Sweep(lams=[0.1, 0.2]), rounds=4,
               checkpoint=CheckpointPolicy(directory=tmp_path, every=2))
    with pytest.raises(ValueError, match="fleet.json mismatch"):
        sess.sweep(Sweep(lams=[0.3], resume=tmp_path), rounds=4)
    with pytest.raises(ValueError, match="disagree"):
        sess.sweep(Sweep(lams=[0.1, 0.2], resume=tmp_path), rounds=4,
                   checkpoint=CheckpointPolicy(directory=tmp_path / "x"))
