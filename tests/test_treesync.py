"""TreeSync: bit-exactness of the synchronous special case, convergence of
local-step schedules, and compression round-trips."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import compression as comp
from repro.core import treesync as tsy
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_train_step
from repro.optim import make_sgd

CFG = ModelConfig(
    name="tiny", family="dense", num_layers=2, d_model=32, num_heads=4,
    num_kv_heads=2, head_dim=8, d_ff=64, vocab_size=64, q_chunk_size=16,
    logits_chunk=16, remat=False,
)


def _batch(key, B=8, S=16, vocab=64):
    kt, kl = jax.random.split(key)
    return {
        "tokens": jax.random.randint(kt, (B, S), 0, vocab),
        "labels": jax.random.randint(kl, (B, S), 0, vocab),
    }


def test_sync_special_case_matches_dp():
    """periods=(1,): TreeSync with SGD(momentum=0) == plain DP (the paper's
    fully synchronous star network). f32 activations so the only difference
    is summation order -> near-machine-precision agreement."""
    cfg = dataclasses.replace(CFG, activation_dtype="float32")
    mesh = make_host_mesh()
    opt = make_sgd(lr=0.05, momentum=0.0)
    ts = tsy.TreeSyncConfig(sync_axes=("data",), periods=(1,),
                            average_opt_state=False)
    n = tsy.replica_count(ts, mesh)
    if n == 1:
        pytest.skip("needs >1 device to be meaningful")

    key = jax.random.PRNGKey(0)
    state = tsy.init_state(cfg, opt, key, mesh, ts)
    step = jax.jit(tsy.make_treesync_step(cfg, opt, ts, mesh))

    # plain DP reference
    from repro.models.transformer import init_params
    params_ref = init_params(cfg, key)
    opt_ref = opt.init(params_ref)
    dp_step = jax.jit(make_train_step(cfg, opt))

    for i in range(3):
        batch = _batch(jax.random.PRNGKey(10 + i))
        state, m = step(state, tsy.split_batch(batch, n))
        params_ref, opt_ref, m_ref = dp_step(params_ref, opt_ref, batch)

    avg = tsy.consensus_params(state)
    for a, b in zip(jax.tree.leaves(avg), jax.tree.leaves(params_ref),
                    strict=True):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-6)


def test_local_steps_still_converge():
    """periods=(4,): loss decreases over a fixed-batch overfit run."""
    mesh = make_host_mesh()
    opt = make_sgd(lr=0.1, momentum=0.0)
    ts = tsy.TreeSyncConfig(sync_axes=("data",), periods=(4,),
                            average_opt_state=False)
    n = tsy.replica_count(ts, mesh)
    state = tsy.init_state(CFG, opt, jax.random.PRNGKey(0), mesh, ts)
    step = jax.jit(tsy.make_treesync_step(CFG, opt, ts, mesh))
    batch = tsy.split_batch(_batch(jax.random.PRNGKey(1)), n)
    losses = []
    for _ in range(12):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses


def test_replica_divergence_and_resync():
    """Between syncs, replicas diverge; on the sync step they re-agree."""
    mesh = make_host_mesh()
    if len(jax.devices()) < 2:
        pytest.skip("needs >1 device")
    opt = make_sgd(lr=0.1, momentum=0.0)
    ts = tsy.TreeSyncConfig(sync_axes=("data",), periods=(3,),
                            average_opt_state=False)
    n = tsy.replica_count(ts, mesh)
    state = tsy.init_state(CFG, opt, jax.random.PRNGKey(0), mesh, ts)
    step = jax.jit(tsy.make_treesync_step(CFG, opt, ts, mesh))

    def spread(ps):
        leaf = jax.tree.leaves(ps)[0]
        return float(jnp.max(jnp.abs(leaf - leaf.mean(0, keepdims=True))))

    key = jax.random.PRNGKey(5)
    spreads = []
    for _i in range(6):
        key, k = jax.random.split(key)
        # distinct per-replica batches so replicas actually diverge
        state, _ = step(state, tsy.split_batch(_batch(k, B=8 * 1), n)
                        if False else tsy.split_batch(_batch(k), n))
        spreads.append(spread(state.params))
    # steps are 1-indexed inside; sync at steps 3 and 6 -> spread == 0
    assert spreads[2] == 0.0 and spreads[5] == 0.0, spreads
    assert spreads[0] > 0.0 and spreads[3] > 0.0, spreads


@pytest.mark.parametrize("name", ["int8", "topk"])
def test_compression_roundtrip_error_feedback(name):
    key = jax.random.PRNGKey(0)
    x = {"a": jax.random.normal(key, (64, 64)),
         "b": jax.random.normal(jax.random.fold_in(key, 1), (33,))}
    c = comp.COMPRESSORS[name]() if name != "topk" else comp.TopKCompressor(0.25)
    res = c.init_residual(x)
    wire, res = c.compress(x, res)
    deq = c.decompress(wire)
    # error feedback: residual exactly the quantization error
    for k in x:
        np.testing.assert_allclose(
            np.asarray(x[k]), np.asarray(deq[k]) + np.asarray(res[k]),
            rtol=1e-5, atol=1e-5)
    # int8 error is small relative to signal
    if name == "int8":
        err = np.abs(np.asarray(res["a"])).max()
        assert err < 0.05, err


def test_compressed_sync_converges():
    """int8 cross-level sync with error feedback still trains."""
    mesh = make_host_mesh()
    opt = make_sgd(lr=0.1, momentum=0.0)
    ts = tsy.TreeSyncConfig(sync_axes=("data",), periods=(2,),
                            compression="int8", average_opt_state=False)
    n = tsy.replica_count(ts, mesh)
    state = tsy.init_state(CFG, opt, jax.random.PRNGKey(0), mesh, ts)
    step = jax.jit(tsy.make_treesync_step(CFG, opt, ts, mesh))
    batch = tsy.split_batch(_batch(jax.random.PRNGKey(1)), n)
    losses = []
    for _ in range(10):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.95, losses
    assert np.isfinite(losses).all()
