"""The static-analysis layer (``repro.analysis``): every layer must
demonstrably catch its seeded defect class.

The load-bearing claims:

  * the plan-IR verifier accepts every valid plan the lowering produces
    (homogeneous, heterogeneous, size-weighted, compressed) and rejects
    seeded structural defects with actionable finding codes;
  * the fingerprint is SOUND: mutating ANY registered behavior field of
    a ``TreePlan`` changes ``plan.fingerprint`` (exhaustive per-field
    property test), and dropping a field from the registry is caught by
    ``audit_fingerprint`` (the PR-4/PR-6 cache-key bug class);
  * strict mode turns a forced executor rebuild into an
    ``UnexpectedRetraceError`` with a structured key diff, while a
    well-behaved strict run stays bit-identical to the plain run;
  * the AST lint rules flag wall-clock/RNG in traced bodies, static
    closure capture of runtime operands, stray ``jax.jit``, and mutable
    defaults in frozen dataclasses -- and honor waiver comments.
"""
import dataclasses
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (AnalysisError, NonFiniteError, TraceGuard,
                            UnexpectedRetraceError, audit_fingerprint,
                            check_finite, check_schedule_plan,
                            check_tree_plan, no_retrace, verify_plan)
from repro.analysis import rules as lint
from repro.api import Problem, Session, Topology
from repro.core import dual as D
from repro.core.engine import host as host_mod
from repro.core.engine import plan as plan_mod
from repro.core.engine.plan import SchedulePlan, compile_tree, schedule_view
from repro.core.tree import TreeNode, star
from repro.core.treesync import TreeSyncConfig
from repro.data.synthetic import gaussian_regression

LAM = 0.1


def _codes(findings):
    return {f.code for f in findings}


def _star_plan(n=4, m=6, rounds=3, h=8, **kw):
    return compile_tree(star(n, m, outer_rounds=rounds, local_steps=h), **kw)


def _hetero_plan():
    # a shallow leaf next to a deeper subtree: exercises the inactive-
    # leaf (default-zero) columns the verifier must NOT flag
    leaves = tuple(TreeNode(name=f"l{i}", rounds=2 + i, data_size=4 + i)
                   for i in range(3))
    return compile_tree(TreeNode(name="root", rounds=2, children=(
        TreeNode(name="g", rounds=2, children=leaves),
        TreeNode(name="x", rounds=3, data_size=5),
    )))


# ---------------------------------------------------------------------------
# verifier: valid plans pass
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mk", [
    lambda: _star_plan(),
    lambda: _star_plan(compression=("int8",)),
    lambda: _star_plan(compression=("topk_0.25",)),
    _hetero_plan,
    lambda: compile_tree(star(3, 5, outer_rounds=2, local_steps=4),
                         weighting="size"),
], ids=["star", "int8", "topk", "hetero", "size-weighted"])
def test_verifier_accepts_valid_plans(mk):
    plan = mk()
    assert check_tree_plan(plan) == []
    assert audit_fingerprint(plan) == []
    verify_plan(plan)  # no raise


def test_verifier_accepts_schedule_view():
    sview = schedule_view(_star_plan())
    assert check_schedule_plan(sview) == []
    verify_plan(sview)


# ---------------------------------------------------------------------------
# fingerprint soundness: exhaustive per-field mutation
# ---------------------------------------------------------------------------
def _mutate(plan, name):
    """Return a semantically-distinct copy differing only in `name`."""
    val = getattr(plan, name)
    if isinstance(val, np.ndarray):
        arr = np.array(val, copy=True)
        flat = arr.reshape(-1)
        if arr.dtype.kind == "f":
            # masks are 0/1 -- flip; weights -- nudge
            flat[0] = 1.0 - flat[0] if flat[0] in (0.0, 1.0) \
                else flat[0] * 0.5 + 0.25
        else:
            flat[0] = flat[0] + 1
        return dataclasses.replace(plan, **{name: arr}, fingerprint="")
    if isinstance(val, str):
        return dataclasses.replace(plan, **{name: val + "?"}, fingerprint="")
    if isinstance(val, tuple):
        return dataclasses.replace(
            plan, **{name: tuple(v + 1 for v in val)}, fingerprint="")
    return dataclasses.replace(plan, **{name: val + 1}, fingerprint="")


@pytest.mark.parametrize("field", plan_mod.FINGERPRINT_ARRAY_FIELDS
                         + plan_mod.FINGERPRINT_SCALAR_FIELDS)
def test_fingerprint_changes_under_every_behavior_field(field):
    plan = _star_plan()
    probe = _mutate(plan, field)
    assert probe.fingerprint != plan.fingerprint, (
        f"mutating behavior field {field!r} left the fingerprint "
        "unchanged: two distinct plans would share a compiled executor")


def test_fingerprint_ignores_metadata():
    plan = _star_plan()
    renamed = dataclasses.replace(
        plan, leaf_names=tuple(f"r{i}" for i in range(plan.n_leaves)),
        fingerprint="")
    assert renamed.fingerprint == plan.fingerprint


def test_fingerprint_deterministic_across_recompile():
    t = star(4, 6, outer_rounds=3, local_steps=8)
    assert compile_tree(t).fingerprint == compile_tree(t).fingerprint


# ---------------------------------------------------------------------------
# seeded defect #1: a field omitted from the registry fails the audit
# ---------------------------------------------------------------------------
def test_audit_catches_unregistered_field(monkeypatch):
    monkeypatch.setattr(
        plan_mod, "FINGERPRINT_ARRAY_FIELDS",
        tuple(f for f in plan_mod.FINGERPRINT_ARRAY_FIELDS
              if f != "compress_kind"))
    findings = audit_fingerprint(None)
    assert "F202" in _codes(findings)
    assert any("compress_kind" in f.message for f in findings)


def test_audit_catches_double_classification(monkeypatch):
    monkeypatch.setattr(
        plan_mod, "METADATA_FIELDS",
        plan_mod.METADATA_FIELDS + ("solve_mask",))
    assert "F200" in _codes(audit_fingerprint(None))


def test_audit_catches_stale_registry_entry(monkeypatch):
    monkeypatch.setattr(
        plan_mod, "FINGERPRINT_SCALAR_FIELDS",
        plan_mod.FINGERPRINT_SCALAR_FIELDS + ("no_such_field",))
    assert "F201" in _codes(audit_fingerprint(None))


def test_audit_catches_dropped_field_in_payload(monkeypatch):
    # a serialization that silently drops compress_kind collides the
    # compressed and uncompressed plans -- exactly the PR-6 bug
    real = plan_mod.fingerprint_payload

    def lossy(plan):
        return real(dataclasses.replace(
            plan, compress_kind=np.zeros_like(plan.compress_kind),
            fingerprint="x"))
    monkeypatch.setattr(plan_mod, "compute_fingerprint",
                        lambda p: __import__("hashlib").sha1(
                            lossy(p)).hexdigest())
    # compile_tree is lru-cached: clear so the base plan is fingerprinted
    # by the seeded-lossy serialization too (and again after, so no plan
    # stamped with the lossy hash leaks into later tests)
    plan_mod._compile_tree_cached.cache_clear()
    try:
        plan = _star_plan(compression=("int8",))
        assert "F220" in _codes(audit_fingerprint(plan))
    finally:
        plan_mod._compile_tree_cached.cache_clear()


# ---------------------------------------------------------------------------
# adversarial invalid plans
# ---------------------------------------------------------------------------
def _replace(plan, **kw):
    return dataclasses.replace(plan, **kw)


def test_rejects_mismatched_mask_shape():
    plan = _star_plan()
    bad = _replace(plan, solve_mask=plan.solve_mask[:, :-1])
    findings = check_tree_plan(bad)
    assert "P110" in _codes(findings)
    assert any("solve_mask" in f.where for f in findings)
    with pytest.raises(AnalysisError, match="P110"):
        verify_plan(bad)


def test_rejects_nonbinary_mask():
    plan = _star_plan()
    arr = np.array(plan.solve_mask, copy=True)
    arr[0, 0] = 0.5
    assert "P111" in _codes(check_tree_plan(_replace(plan, solve_mask=arr)))


def test_rejects_out_of_range_compress_frac():
    plan = _star_plan(compression=("topk_0.25",))
    arr = np.array(plan.compress_frac, copy=True)
    arr[arr > 0] = 1.5
    findings = check_tree_plan(_replace(plan, compress_frac=arr))
    assert "P141" in _codes(findings)
    assert any("(0, 1]" in f.message for f in findings)


def test_rejects_unknown_compress_kind():
    plan = _star_plan()
    arr = np.array(plan.compress_kind, copy=True)
    arr[0, 0] = 99
    assert "P140" in _codes(check_tree_plan(_replace(plan,
                                                     compress_kind=arr)))


def test_rejects_bad_w_coeff():
    plan = _star_plan()
    assert {"P135", "P136"} & _codes(
        check_tree_plan(_replace(plan, w_coeff=plan.w_coeff * 0.5)))


def test_rejects_refresh_sync_mismatch():
    plan = _star_plan()
    assert "P120" in _codes(check_tree_plan(
        _replace(plan, refresh_mask=np.zeros_like(plan.refresh_mask))))


def test_rejects_stale_fingerprint():
    plan = _star_plan()
    arr = np.array(plan.solve_mask, copy=True)  # behavior change ...
    arr[0, :] = 1.0 - arr[0, :]
    # ... with the OLD fingerprint smuggled through
    stale = _replace(plan, solve_mask=arr, fingerprint=plan.fingerprint)
    assert "P161" in _codes(check_tree_plan(stale))


def test_rejects_bad_schedule_plan():
    sview = schedule_view(_star_plan())
    assert "S301" in _codes(check_schedule_plan(
        dataclasses.replace(sview, periods=(0,) + sview.periods[1:])))
    assert "S304" in _codes(check_schedule_plan(
        dataclasses.replace(sview, compression=("wat",))))
    assert "S305" in _codes(check_schedule_plan(
        dataclasses.replace(sview, fingerprint="")))


def test_rejects_duplicate_sync_axes():
    with pytest.raises(ValueError, match="duplicate sync_axes"):
        TreeSyncConfig(sync_axes=("data", "data"), periods=(2, 2))


def test_verify_plan_rejects_wrong_type():
    with pytest.raises(TypeError):
        verify_plan({"not": "a plan"})


# ---------------------------------------------------------------------------
# trace guard: strict sessions
# ---------------------------------------------------------------------------
def _problem_topo():
    topo = Topology.star(4, 24, rounds=4, local_steps=16)
    X, y = gaussian_regression(m=topo.m_total, d=8)
    return Problem.ridge(X, y, lam=LAM), topo


def test_strict_run_bit_identical_to_plain():
    prob, topo = _problem_topo()
    plain = Session.compile(prob, topo).run(key=jax.random.PRNGKey(0))
    strict = Session.compile(prob, topo, strict=True).run(key=jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(plain.alpha),
                                  np.asarray(strict.alpha))
    np.testing.assert_array_equal(np.asarray(plain.w),
                                  np.asarray(strict.w))


def test_strict_catches_forced_rebuild():
    # seeded defect #2: evicting the session's executor forces a rebuild
    # on the next run -- strict mode turns that silent retrace into an
    # error (and the session recovers on the run after)
    prob, topo = _problem_topo()
    sess = Session.compile(prob, topo, strict=True)
    sess.run(key=jax.random.PRNGKey(0))
    host_mod._EXEC_CACHE.clear()
    with pytest.raises(UnexpectedRetraceError, match="cache miss"):
        sess.run(key=jax.random.PRNGKey(0))
    sess.run(key=jax.random.PRNGKey(0))  # rebuilt entry is a hit again


def test_strict_false_by_default_tolerates_rebuild():
    prob, topo = _problem_topo()
    sess = Session.compile(prob, topo)
    sess.run(key=jax.random.PRNGKey(0))
    host_mod._EXEC_CACHE.clear()
    sess.run(key=jax.random.PRNGKey(0))  # no raise


def test_no_retrace_budget_and_key_diff():
    plan = _star_plan()

    def fetch():
        host_mod.get_host_executor(plan, loss=D.squared,
                                   record_history=False, backend="vmap")
    fetch()  # populate
    host_mod._EXEC_CACHE.clear()
    with pytest.raises(UnexpectedRetraceError) as ei:
        with no_retrace(budget=0):
            fetch()
    assert ei.value.misses  # structured miss entries ride along
    assert "plan_fingerprint" in str(ei.value)
    host_mod._EXEC_CACHE.clear()
    with no_retrace(budget=1):  # an explicit budget tolerates the rebuild
        fetch()
    with no_retrace(budget=0):  # and now it hits
        fetch()


def test_trace_guard_validation():
    from repro.analysis.trace_guard import as_trace_guard
    assert as_trace_guard(False) is None
    assert isinstance(as_trace_guard(True), TraceGuard)
    g = TraceGuard(miss_budget=2)
    assert as_trace_guard(g) is g
    with pytest.raises(TypeError):
        as_trace_guard("strict")


def test_check_finite_names_offender():
    tree = {"ok": jnp.ones(3), "bad": jnp.array([1.0, np.nan])}
    with pytest.raises(NonFiniteError, match="bad"):
        check_finite(tree, "chunk[3]")
    check_finite({"i": jnp.arange(3)}, "ints are skipped")


def test_cache_stats_by_backend():
    stats = host_mod.executor_cache_stats()
    assert {"vmap", "pallas", "mesh", "lm"} <= set(stats["by_backend"])
    before = dict(stats["by_backend"]["vmap"])
    prob, topo = _problem_topo()
    Session.compile(prob, topo)
    Session.compile(prob, topo)  # same config: second fetch must hit
    after = host_mod.executor_cache_stats()["by_backend"]["vmap"]
    assert after["hits"] > before["hits"]
    # totals stay consistent: sum over backends == global counters
    stats = host_mod.executor_cache_stats()
    assert stats["hits"] == sum(b["hits"]
                                for b in stats["by_backend"].values())
    assert stats["misses"] == sum(b["misses"]
                                  for b in stats["by_backend"].values())


# ---------------------------------------------------------------------------
# lint rules
# ---------------------------------------------------------------------------
def _lint(tmp_path, source, name="pkg/fixture.py"):
    f = tmp_path / name
    f.parent.mkdir(parents=True, exist_ok=True)
    f.write_text(textwrap.dedent(source))
    return lint.lint_file(str(f))


def test_lint_static_lambda_closure(tmp_path):
    # seeded defect #3: the PR-4 bug shape -- lambda baked into the trace
    findings = _lint(tmp_path, """\
        import jax

        def make_step(lam):
            @jax.jit
            def step(alpha):
                return alpha * lam
            return step
        """)
    assert [f.rule for f in findings] == ["static-operand-capture"]
    assert "lam" in findings[0].message


def test_lint_operand_as_argument_is_clean(tmp_path):
    findings = _lint(tmp_path, """\
        import jax

        @jax.jit
        def step(alpha, lam):
            return alpha * lam
        """)
    assert findings == []


def test_lint_wallclock_and_random_in_trace(tmp_path):
    findings = _lint(tmp_path, """\
        import time, random
        import jax

        @jax.jit
        def f(x):
            t0 = time.time()
            return x + random.random() + t0
        """)
    assert {"wall-clock-in-trace", "python-random-in-trace"} == \
        {f.rule for f in findings}


def test_lint_wallclock_outside_trace_is_clean(tmp_path):
    assert _lint(tmp_path, """\
        import time

        def bench(f):
            t0 = time.time()
            f()
            return time.time() - t0
        """) == []


def test_lint_jit_location(tmp_path):
    src = """\
        import jax

        @jax.jit
        def f(x):
            return x + 1
        """
    bad = _lint(tmp_path, src, name="src/repro/launch/stray.py")
    assert [f.rule for f in bad] == ["jit-outside-engine"]
    assert _lint(tmp_path, src,
                 name="src/repro/core/engine/fine.py") == []
    assert _lint(tmp_path, src, name="tests/fine.py") == []


def test_lint_traced_via_scan_and_vmap(tmp_path):
    findings = _lint(tmp_path, """\
        import time
        import jax

        def outer(xs):
            def body(c, x):
                return c + time.time(), x
            return jax.lax.scan(body, 0.0, xs)
        """)
    assert [f.rule for f in findings] == ["wall-clock-in-trace"]


def test_lint_frozen_mutable_default(tmp_path):
    findings = _lint(tmp_path, """\
        import dataclasses

        @dataclasses.dataclass(frozen=True)
        class Cfg:
            xs: list = []
            ys: dict = dict()
        """)
    assert ([f.rule for f in findings]
            == ["mutable-default-in-frozen-dataclass"] * 2)


def test_lint_waiver_comment(tmp_path):
    findings = _lint(tmp_path, """\
        import jax

        @jax.jit  # analysis: allow(jit-outside-engine) fixture
        def f(x):
            return x + 1
        """, name="src/repro/launch/waived.py")
    assert findings == []


def test_lint_undonated_carry(tmp_path):
    """Engine jits of chunk-carry steps must donate the carry: callers
    rebind ``state = step(...)`` every chunk, so an undonated carry
    doubles the peak state footprint."""
    findings = _lint(tmp_path, """\
        import jax

        def _build():
            def step_chunk(X, y, state):
                return state
            return jax.jit(step_chunk)
        """, name="src/repro/core/engine/fine.py")
    assert [f.rule for f in findings] == ["undonated-carry"]
    # donating the carry satisfies the rule; non-carry jits are exempt
    clean = _lint(tmp_path, """\
        import jax

        def _build():
            def step_chunk(X, y, state):
                return state
            def finalize(state):
                return state
            return (jax.jit(step_chunk, donate_argnums=(2,)),
                    jax.jit(finalize))
        """, name="src/repro/core/engine/fine2.py")
    assert clean == []


def test_lint_undonated_carry_unwraps_transforms(tmp_path):
    """The rule sees through the batched/mesh wrappers: a carry step
    jitted as ``jax.jit(jax.vmap(step))`` or ``jax.jit(shard_map(step))``
    still needs donation."""
    findings = _lint(tmp_path, """\
        import jax
        from jax.experimental.shard_map import shard_map

        def _build(mesh):
            def program_state_b(state, ops):
                return state
            batched = jax.jit(jax.vmap(program_state_b))
            meshed = jax.jit(shard_map(program_state_b, mesh=mesh),
                             donate_argnums=(0,))
            return batched, meshed
        """, name="src/repro/core/engine/mesh_fixture.py")
    assert [f.rule for f in findings] == ["undonated-carry"]


def test_lint_undonated_carry_scope_and_waiver(tmp_path):
    src = """\
        import jax

        def _build():
            def step(state):
                return state
            return jax.jit(step)  # analysis: allow(undonated-carry) ok
        """
    assert _lint(tmp_path, src,
                 name="src/repro/core/engine/waived.py") == []
    # outside the engine package the carry rule does not apply
    outside = _lint(
        tmp_path,
        src.replace("  # analysis: allow(undonated-carry) ok", ""),
        name="pkg/driver.py")
    assert "undonated-carry" not in {f.rule for f in outside}


def test_lint_operand_threaded_through_helper_is_clean(tmp_path):
    """Regression: a runtime operand that reaches an inner traced body
    through a HELPER's parameter (traced caller -> helper call -> closure
    in the helper) is a tracer at every call site, not a baked constant.
    The call graph must propagate tracedness to the helper, or this shape
    false-positives as static-operand-capture."""
    findings = _lint(tmp_path, """\
        import jax

        def _scan(xs, lam):
            def body(c, x):
                return c + x * lam, x
            return jax.lax.scan(body, 0.0, xs)

        @jax.jit
        def solve(xs, lam):
            return _scan(xs, lam)
        """, name="src/repro/core/engine/helper_fixture.py")
    assert findings == []


def test_lint_shipped_tree_is_clean():
    assert lint.lint_paths(["src", "tests"]) == []


# ---------------------------------------------------------------------------
# deferred history recording under strict mode
# ---------------------------------------------------------------------------
def test_strict_run_defers_history_host_sync():
    """History recording holds device scalars inside the guarded dispatch
    region and materializes them in ONE explicit ``jax.device_get`` -- a
    strict session with ``record_history=True`` must run clean even
    though the guard forbids implicit device->host transfers (the control
    below shows an eager per-round ``float()`` would raise)."""
    import contextlib

    from repro.analysis.trace_guard import HostSyncError
    prob, topo = _problem_topo()
    sess = Session.compile(prob, topo, strict=True)
    res = sess.run(key=jax.random.PRNGKey(0))
    assert all(isinstance(h["gap"], float) for h in res.history)
    # and the materialized entries match an unguarded eager run exactly
    plain = Session.compile(prob, topo).run(key=jax.random.PRNGKey(0))
    assert [h["gap"] for h in res.history] == \
        [h["gap"] for h in plain.history]
    # control: the guard region is live (not a nullcontext).  On
    # accelerator backends an implicit float() inside it raises; on the
    # CPU backend jax's transfer guard is vacuous (device memory IS host
    # memory), so the raise can only be asserted off-CPU.
    assert not isinstance(sess._guard.dispatch_region(),
                          contextlib.nullcontext)
    if jax.default_backend() != "cpu":
        x = jnp.ones(())
        with pytest.raises(HostSyncError):
            with sess._guard.dispatch_region():
                float(x)
