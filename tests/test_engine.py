"""Unified tree-schedule engine vs the legacy recursion oracle.

The engine replays the legacy key derivation, so for ANY topology the
compiled scan must reproduce the reference iterates up to float
reassociation -- star, chain, multi-level, and imbalanced/heterogeneous
trees alike -- while preserving the w = A alpha invariant and keeping
``cocoa_star_solve`` bit-equivalent to the engine on the depth-1 star.
"""
import jax
import numpy as np
import pytest

from repro.core import dual as D
from repro.core import engine
from repro.core.engine.plan import balanced_tree, compile_tree, index_plan
from repro.core.tree import TreeNode, star, two_level
from repro.core.treedual import (cocoa_star_solve, tree_dual_solve,
                                 tree_dual_solve_reference)
from repro.data.synthetic import gaussian_regression

LAM = 0.1
TOL = dict(rtol=1e-4, atol=1e-5)


def _imbalanced_tree():
    """Mixed depth (1..3), heterogeneous per-leaf H and block sizes, and
    heterogeneous internal rounds -- the case the legacy mesh path could
    never express."""
    la = TreeNode(name="A", rounds=40, data_size=24)
    lb = TreeNode(name="B", rounds=30, data_size=16)
    lc = TreeNode(name="C", rounds=50, data_size=8)
    g = TreeNode(name="g", children=(lb, lc), rounds=2)
    ld = TreeNode(name="Dd", rounds=20, data_size=12)
    le = TreeNode(name="E", rounds=25, data_size=20)
    h = TreeNode(name="h", children=(ld, le), rounds=3)
    mid = TreeNode(name="mid", children=(g, h), rounds=2)
    return TreeNode(name="root", children=(la, mid), rounds=6)


def _chain_tree():
    """A deep path: root -> mid -> group -> 2 leaves."""
    leaves = (TreeNode(name="l0", rounds=60, data_size=30),
              TreeNode(name="l1", rounds=60, data_size=30))
    grp = TreeNode(name="grp", children=leaves, rounds=2)
    mid = TreeNode(name="mid", children=(grp,), rounds=3)
    return TreeNode(name="root", children=(mid,), rounds=4)


CASES = {
    "star": lambda: star(4, 60, outer_rounds=8, local_steps=120),
    "chain": _chain_tree,
    "two_level": lambda: two_level(2, 2, 60, root_rounds=5, group_rounds=3,
                                   local_steps=100),
    "imbalanced": _imbalanced_tree,
}


@pytest.mark.parametrize("case", sorted(CASES))
def test_engine_matches_reference_recursion(case):
    tree = CASES[case]()
    m = tree.total_data()
    X, y = gaussian_regression(m=m, d=16)
    key = jax.random.PRNGKey(5)
    ref = tree_dual_solve_reference(tree, X, y, loss=D.squared, lam=LAM,
                                    key=key)
    eng = tree_dual_solve(tree, X, y, loss=D.squared, lam=LAM, key=key)
    np.testing.assert_allclose(np.asarray(eng.alpha), np.asarray(ref.alpha),
                               **TOL)
    np.testing.assert_allclose(np.asarray(eng.w), np.asarray(ref.w), **TOL)
    # same history semantics: aligned rounds, times, and objective values
    assert len(eng.history) == len(ref.history) == tree.rounds + 1
    np.testing.assert_allclose(eng.times, ref.times, rtol=1e-9)
    np.testing.assert_allclose(eng.duals, ref.duals, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(eng.gaps, ref.gaps, rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("case", sorted(CASES))
def test_engine_preserves_w_invariant(case):
    tree = CASES[case]()
    m = tree.total_data()
    X, y = gaussian_regression(m=m, d=12)
    res = tree_dual_solve(tree, X, y, loss=D.squared, lam=LAM)
    w_expect = D.w_of_alpha(res.alpha, X, LAM)
    np.testing.assert_allclose(np.asarray(res.w), np.asarray(w_expect),
                               rtol=1e-4, atol=1e-5)


def test_cocoa_star_bit_equivalent_to_engine_star():
    """Algorithm 1 is the engine's depth-1 special case, bit-for-bit."""
    X, y = gaussian_regression(m=240, d=20)
    key = jax.random.PRNGKey(9)
    res = cocoa_star_solve(X, y, 4, loss=D.squared, lam=LAM,
                           outer_rounds=10, local_steps=80, key=key)
    tree = star(4, 60, outer_rounds=10, local_steps=80)
    eng = engine.solve(tree, X, y, loss=D.squared, lam=LAM, key=key)
    np.testing.assert_array_equal(np.asarray(res.alpha),
                                  np.asarray(eng.alpha))
    np.testing.assert_array_equal(np.asarray(res.w), np.asarray(eng.w))


def test_pallas_leaf_backend_matches_vmap():
    tree = _imbalanced_tree()
    X, y = gaussian_regression(m=tree.total_data(), d=12)
    key = jax.random.PRNGKey(2)
    a = tree_dual_solve(tree, X, y, loss=D.squared, lam=LAM, key=key)
    b = tree_dual_solve(tree, X, y, loss=D.squared, lam=LAM, key=key,
                        backend="pallas")
    np.testing.assert_allclose(np.asarray(a.alpha), np.asarray(b.alpha),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(a.w), np.asarray(b.w),
                               rtol=1e-5, atol=1e-6)


def test_size_weighting_converges_and_keeps_invariant():
    """CoCoA-style |block|-proportional aggregation: still a convex
    combination, so w-consistency holds and the solve converges."""
    tree = _imbalanced_tree()
    X, y = gaussian_regression(m=tree.total_data(), d=12)
    res = tree_dual_solve(tree, X, y, loss=D.squared, lam=LAM,
                          weighting="size")
    w_expect = D.w_of_alpha(res.alpha, X, LAM)
    np.testing.assert_allclose(np.asarray(res.w), np.asarray(w_expect),
                               rtol=1e-4, atol=1e-5)
    assert res.gaps[-1] < 0.05 * res.gaps[0]


def test_plan_geometry_and_levels():
    """Plan IR sanity: tick counts, level detection, index replay shape."""
    tree = two_level(2, 2, 16, root_rounds=4, group_rounds=3, local_steps=8)
    plan = compile_tree(tree)
    assert plan.n_ticks == 4 * 3 and plan.depth == 2
    assert plan.n_leaves == 4 and plan.m_b == 16 and plan.h_max == 8
    assert plan.levels is not None
    assert [l.rounds for l in plan.levels] == [4, 3]
    assert [l.group_size for l in plan.levels] == [2, 2]
    assert int(plan.root_sync.sum()) == 4   # one per root round
    # balanced leaves solve every tick; root sync at the end of each round
    assert plan.solve_mask.all()
    idx = index_plan(tree, plan, jax.random.PRNGKey(0))
    assert idx.shape == (12, 4, 8)
    assert (idx >= 0).all() and (idx < 16).all()

    # imbalanced trees are not mesh-lowerable and say so
    plan2 = compile_tree(_imbalanced_tree())
    assert plan2.levels is None
    # the shallow leaf ("A") idles while the deep subtree keeps solving
    assert not plan2.solve_mask.all()


def test_balanced_tree_constructor_roundtrip():
    tree = balanced_tree([2, 2, 2], [4, 2, 3], local_steps=16, m_leaf=8)
    assert tree.depth() == 3 and len(tree.leaves()) == 8
    plan = compile_tree(tree)
    assert plan.n_ticks == 4 * 2 * 3
    assert plan.levels is not None and [l.rounds for l in plan.levels] == \
        [4, 2, 3]


def test_balanced_tree_names_unique_at_production_fanout():
    """Fan-out >= 10 (e.g. a 16x16 pod mesh) must not collide leaf names
    (digit concatenation would alias (1,15) / (11,5) / (1,1,5))."""
    tree = balanced_tree([16, 16], [2, 2], local_steps=4, m_leaf=2)
    names = [l.name for l in tree.leaves()]
    assert len(set(names)) == 256
    plan = compile_tree(tree)   # would raise on duplicate names
    assert plan.n_leaves == 256 and plan.levels is not None


def test_typed_prng_keys_accepted():
    """New-style jax.random.key(...) keys work and match the legacy-format
    PRNGKey (same threefry data -> same replayed draws)."""
    tree = star(2, 20, outer_rounds=3, local_steps=10)
    X, y = gaussian_regression(m=40, d=6)
    a = tree_dual_solve(tree, X, y, loss=D.squared, lam=LAM,
                        key=jax.random.key(5), record_history=False)
    b = tree_dual_solve(tree, X, y, loss=D.squared, lam=LAM,
                        key=jax.random.PRNGKey(5), record_history=False)
    np.testing.assert_array_equal(np.asarray(a.alpha), np.asarray(b.alpha))


def test_record_history_false_skips_history():
    tree = star(2, 20, outer_rounds=3, local_steps=10)
    X, y = gaussian_regression(m=40, d=6)
    res = tree_dual_solve(tree, X, y, loss=D.squared, lam=LAM,
                          record_history=False)
    assert res.history == []
    assert res.alpha.shape == (40,)


def test_hinge_loss_through_engine():
    """Non-smooth losses run through the same compiled program."""
    from repro.data.synthetic import gaussian_classification
    tree = two_level(2, 2, 32, root_rounds=8, group_rounds=2,
                     local_steps=128)
    X, y = gaussian_classification(m=128, d=10)
    key = jax.random.PRNGKey(4)
    loss = D.LOSSES["smooth_hinge_1"]
    ref = tree_dual_solve_reference(tree, X, y, loss=loss, lam=0.05, key=key)
    eng = tree_dual_solve(tree, X, y, loss=loss, lam=0.05, key=key)
    np.testing.assert_allclose(np.asarray(eng.alpha), np.asarray(ref.alpha),
                               **TOL)
    assert eng.gaps[-1] < 0.2 * eng.gaps[0]


# ---------------------------------------------------------------------------
# runtime step masks: heterogeneous H as an executor input
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["vmap", "pallas"])
def test_full_step_mask_bit_identical_to_static(backend):
    """All-ones / full-capacity runtime step masks reproduce the static-H
    program bit for bit -- including on a tree with heterogeneous PER-LEAF
    H capacities (the masks multiply the static gates by exactly 1.0)."""
    from repro.core.engine.host import execute_plan
    from repro.core.engine.plan import full_steps, key_plan, steps_for_h
    tree = _imbalanced_tree()
    X, y = gaussian_regression(m=tree.total_data(), d=10)
    plan = compile_tree(tree)
    keys = key_plan(tree, plan, jax.random.PRNGKey(3))
    base = execute_plan(plan, X, y, keys, loss=D.squared, lam=LAM,
                        record_history=False, backend=backend)
    ones = execute_plan(plan, X, y, keys, loss=D.squared, lam=LAM,
                        record_history=False, backend=backend,
                        steps=full_steps(plan))
    caps = execute_plan(plan, X, y, keys, loss=D.squared, lam=LAM,
                        record_history=False, backend=backend,
                        steps=steps_for_h(plan, plan.leaf_h))
    for other in (ones, caps):
        np.testing.assert_array_equal(np.asarray(base[0]),
                                      np.asarray(other[0]))
        np.testing.assert_array_equal(np.asarray(base[1]),
                                      np.asarray(other[1]))


def test_mesh_full_step_mask_bit_identical():
    """The mesh backend's step-mask operand: all-ones masks reproduce the
    static program bit for bit."""
    from repro.core.engine.mesh import execute_plan_mesh
    from repro.core.engine.plan import full_steps
    n = len(jax.devices())
    tree = star(n, 64 // n, outer_rounds=4, local_steps=16)
    X, y = gaussian_regression(m=64, d=8)
    plan = compile_tree(tree)
    mesh = jax.make_mesh((n,), ("data",))
    a0, w0 = execute_plan_mesh(plan, tree, X, y, mesh, axes=("data",),
                               loss=D.squared, lam=LAM,
                               key=jax.random.PRNGKey(0))
    a1, w1 = execute_plan_mesh(plan, tree, X, y, mesh, axes=("data",),
                               loss=D.squared, lam=LAM,
                               key=jax.random.PRNGKey(0),
                               steps=full_steps(plan))
    np.testing.assert_array_equal(np.asarray(a0), np.asarray(a1))
    np.testing.assert_array_equal(np.asarray(w0), np.asarray(w1))


def test_runtime_heterogeneous_h_matches_reference():
    """Per-leaf runtime H (step masks over full-capacity draws) matches an
    independent star recursion that draws the capacity-shaped coordinate
    stream and applies only the first h_l updates per leaf -- and differs
    from the full-capacity solve."""
    from repro.core.engine.host import execute_plan
    from repro.core.engine.plan import key_plan, steps_for_h
    from repro.kernels.sdca.ref import sdca_block_ref
    import jax.numpy as jnp
    K, m_leaf, cap, T = 3, 16, 12, 4
    hs = np.array([5, 12, 1])
    tree = star(K, m_leaf, outer_rounds=T, local_steps=cap)
    X, y = gaussian_regression(m=K * m_leaf, d=6)
    key = jax.random.PRNGKey(7)
    plan = compile_tree(tree)
    keys = key_plan(tree, plan, key)
    a_eng, w_eng = execute_plan(plan, X, y, keys, loss=D.squared, lam=LAM,
                                record_history=False,
                                steps=steps_for_h(plan, hs))
    a_full, _ = execute_plan(plan, X, y, keys, loss=D.squared, lam=LAM,
                             record_history=False)
    assert not np.array_equal(np.asarray(a_eng), np.asarray(a_full))

    # reference: the paper's star round with capacity draws, first h_l
    # steps applied (step_mask on the oracle Procedure-P implementation)
    lm = LAM * (K * m_leaf)
    Xb = jnp.asarray(X).reshape(K, m_leaf, -1)
    yb = jnp.asarray(y).reshape(K, m_leaf)
    mask = (np.arange(cap)[None, :] < hs[:, None]).astype(np.float32)
    a = jnp.zeros((K, m_leaf))
    w = jnp.zeros((X.shape[1],), X.dtype)
    for t in range(T):
        idx = jnp.stack([
            jax.random.randint(jnp.asarray(keys[t, l]), (cap,), 0, m_leaf)
            for l in range(K)])
        da, dw = sdca_block_ref(Xb, yb, a, w, idx, loss=D.squared, lm=lm,
                                step_mask=jnp.asarray(mask))
        a = a + da / K
        w = w + dw.sum(axis=0) / K
    np.testing.assert_allclose(np.asarray(a_eng),
                               np.asarray(a).reshape(-1),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(w_eng), np.asarray(w),
                               rtol=1e-5, atol=1e-6)


def test_steps_for_h_shapes_and_clamping():
    tree = star(3, 8, outer_rounds=2, local_steps=6)
    plan = compile_tree(tree)
    from repro.core.engine.plan import full_steps, index_plan, steps_for_h
    ones = full_steps(plan)
    assert ones.shape == (plan.n_ticks, 3, 6) and ones.all()
    # scalar, per-leaf, per-slot specs; clamped to the capacity
    np.testing.assert_array_equal(steps_for_h(plan, 99), ones)
    s = steps_for_h(plan, [2, 6, 0])
    assert s[:, 0].sum(axis=-1).tolist() == [2.0, 2.0]
    assert (s[:, 1] == 1).all() and (s[:, 2] == 0).all()
    per_slot = np.array([[1, 2, 3], [4, 5, 6]])
    s2 = steps_for_h(plan, per_slot)
    np.testing.assert_array_equal(s2.sum(axis=-1),
                                  np.minimum(per_slot, 6))
    with pytest.raises(ValueError, match="per leaf"):
        steps_for_h(plan, [1, 2])
    # index replay: draws at capacity, runtime-H entries zeroed beyond h
    idx_cap = index_plan(tree, plan, jax.random.PRNGKey(0))
    idx_run = index_plan(tree, plan, jax.random.PRNGKey(0),
                         local_h=[2, 6, 0])
    np.testing.assert_array_equal(idx_run[:, 0, :2], idx_cap[:, 0, :2])
    assert (idx_run[:, 0, 2:] == 0).all()
    np.testing.assert_array_equal(idx_run[:, 1], idx_cap[:, 1])
    assert (idx_run[:, 2] == 0).all()


def test_delay_plan_feeds_engine_rounds():
    """Paper eq. (12) per-level planning (core.delay.plan_hierarchical_h)
    flows into engine round counts via tree_from_level_plan."""
    from repro.core.delay import ICI_LINK, DCI_LINK, SyncLevel, \
        plan_hierarchical_h
    from repro.core.engine.plan import tree_from_level_plan

    levels = [
        SyncLevel("ici", group_size=2, link=ICI_LINK, msg_bytes=4 * 64),
        SyncLevel("dci", group_size=2, link=DCI_LINK, msg_bytes=4 * 64),
    ]
    lp = plan_hierarchical_h(levels, C=0.5, delta=1 / 64, t_total=0.5,
                             t_lp=1e-6, h_max=10**4)
    tree = tree_from_level_plan(lp, [2, 2], m_leaf=16, root_rounds=3)
    assert tree.leaves()[0].rounds == lp[0]["H"]
    assert tree.children[0].rounds == lp[1]["H"]
    plan = compile_tree(tree)
    assert plan.levels is not None
    X, y = gaussian_regression(m=tree.total_data(), d=8)
    res = tree_dual_solve(tree, X, y, loss=D.squared, lam=LAM)
    assert np.isfinite(res.gaps).all()
