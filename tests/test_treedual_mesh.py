"""Device-level TreeDualMethod (shard_map + psum + Pallas leaf kernel)."""
import jax
import numpy as np
import pytest

from repro.core import dual as dual_mod
from repro.core.treedual_mesh import mesh_tree_dual_solve
from repro.data.synthetic import gaussian_regression

LAM = 0.1


@pytest.fixture(scope="module")
def data():
    return gaussian_regression(m=256, d=32)


def _gap(alpha, X, y):
    loss = dual_mod.LOSSES["squared"]
    return float(dual_mod.duality_gap(alpha, X, y, loss, LAM))


def test_star_on_mesh_converges(data):
    X, y = data
    n = len(jax.devices())
    mesh = jax.make_mesh((n,), ("data",))
    loss = dual_mod.LOSSES["squared"]
    alpha, w = mesh_tree_dual_solve(
        X, y, mesh, loss=loss, lam=LAM, axes=("data",), rounds=(40,),
        local_steps=256)
    g = _gap(alpha, X, y)
    assert g < 1e-3, g
    # w-consistency: w == A alpha
    w_ref = dual_mod.w_of_alpha(alpha, X, LAM)
    np.testing.assert_allclose(np.asarray(w), np.asarray(w_ref),
                               rtol=1e-4, atol=1e-5)


def test_two_level_tree_on_mesh(data):
    X, y = data
    n = len(jax.devices())
    if n < 4:
        pytest.skip("needs 4 devices for a 2x2 tree")
    mesh = jax.make_mesh((2, n // 2), ("pod", "data"))
    loss = dual_mod.LOSSES["squared"]
    alpha, w = mesh_tree_dual_solve(
        X, y, mesh, loss=loss, lam=LAM, axes=("data", "pod"),
        rounds=(3, 12), local_steps=256)
    g = _gap(alpha, X, y)
    assert g < 1e-3, g
    w_ref = dual_mod.w_of_alpha(alpha, X, LAM)
    np.testing.assert_allclose(np.asarray(w), np.asarray(w_ref),
                               rtol=1e-4, atol=1e-5)


def test_mesh_matches_host_reference_quality(data):
    """The mesh program and the host-recursion program solve the same
    problem to comparable suboptimality under equal total local steps."""
    from repro.core.treedual import cocoa_star_solve
    X, y = data
    n = len(jax.devices())
    mesh = jax.make_mesh((n,), ("data",))
    loss = dual_mod.LOSSES["squared"]
    alpha_m, _ = mesh_tree_dual_solve(
        X, y, mesh, loss=loss, lam=LAM, axes=("data",), rounds=(20,),
        local_steps=128)
    res = cocoa_star_solve(X, y, n, loss=loss, lam=LAM, outer_rounds=20,
                           local_steps=128, key=jax.random.PRNGKey(7))
    g_mesh, g_host = _gap(alpha_m, X, y), res.gaps[-1]
    assert g_mesh < 5 * g_host + 1e-5, (g_mesh, g_host)
    assert g_host < 5 * g_mesh + 1e-5, (g_mesh, g_host)


def test_kernel_vs_ref_leaf_same_result(data):
    """use_kernel=False (pure-jnp leaves) and True agree bit-for-bit given
    the same replayed per-solve keys (engine key_plan)."""
    X, y = data
    n = len(jax.devices())
    mesh = jax.make_mesh((n,), ("data",))
    loss = dual_mod.LOSSES["squared"]
    kw = dict(loss=loss, lam=LAM, axes=("data",), rounds=(3,),
              local_steps=64, key=jax.random.PRNGKey(3))
    a1, w1 = mesh_tree_dual_solve(X, y, mesh, use_kernel=True, **kw)
    a2, w2 = mesh_tree_dual_solve(X, y, mesh, use_kernel=False, **kw)
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2),
                               rtol=1e-6, atol=1e-7)
