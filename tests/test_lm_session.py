"""Session-driven LM training: bit-identity with the legacy treesync
step and with plain DP at periods=(1,), checkpoint/resume equality,
fused (lr x seed) sweeps, and TreeSyncConfig validation."""
import dataclasses
import tempfile
import warnings

import jax
import numpy as np
import pytest

from repro.api import (CheckpointPolicy, Problem, Schedule, Session, Sweep,
                       Topology)
from repro.api.schedule import DelayModel
from repro.configs.base import ModelConfig
from repro.core import treesync as tsy
from repro.data.lm import lm_batch
from repro.launch.mesh import make_host_mesh
from repro.optim import make_sgd

CFG = dataclasses.replace(
    ModelConfig(
        name="tiny", family="dense", num_layers=2, d_model=32, num_heads=4,
        num_kv_heads=2, head_dim=8, d_ff=64, vocab_size=64, q_chunk_size=16,
        logits_chunk=16, remat=False,
    ),
    activation_dtype="float32",
)


def _trees_equal(a, b):
    return all(bool((x == y).all())
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b),
                          strict=True))


def _session(periods=(2,), **topo_kw):
    mesh = make_host_mesh()
    opt = make_sgd(lr=0.05, momentum=0.0)
    prob = Problem.lm(CFG, opt, batch=8, seq=16, seed=0)
    topo = Topology.from_mesh(mesh, sync_axes=("data",), periods=periods,
                              **topo_kw)
    return Session.compile(prob, topo, backend="mesh", mesh=mesh), mesh, opt


def test_session_matches_legacy_treesync():
    """The Session-driven program is bit-identical to make_treesync_step
    at the same fixed periods/seed: same init, same data stream, same
    jitted math -- only the periods moved from trace constants to a
    runtime operand."""
    sess, mesh, opt = _session(periods=(2,))
    res = sess.run(steps=6, key=jax.random.PRNGKey(0))

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        ts = tsy.TreeSyncConfig(sync_axes=("data",), periods=(2,))
        n = tsy.replica_count(ts, mesh)
        state = tsy.init_state(CFG, opt, jax.random.PRNGKey(0), mesh, ts)
        step = jax.jit(tsy.make_treesync_step(CFG, opt, ts, mesh))
    for i in range(6):
        batch = tsy.split_batch(lm_batch(CFG, 8, 16, i, seed=0), n)
        state, _ = step(state, batch)

    assert _trees_equal(res.state.params, state.params)
    assert _trees_equal(res.state.opt_state, state.opt_state)


def test_sync_periods_match_plain_dp():
    """periods=(1,) + SGD(momentum=0) == plain data parallelism: the
    fully synchronous star network is a special case of the one
    program (the old --mode=sync is just --sync now)."""
    sess, mesh, opt = _session(periods=(1,))
    if sess.n_replicas == 1:
        pytest.skip("needs >1 device to be meaningful")

    res = sess.run(steps=3, key=jax.random.PRNGKey(0))

    from repro.launch.steps import make_train_step
    from repro.models.transformer import init_params
    params = init_params(CFG, jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    dp_step = jax.jit(make_train_step(CFG, opt))
    for i in range(3):
        params, opt_state, _ = dp_step(params, opt_state,
                                       lm_batch(CFG, 8, 16, i, seed=0))

    avg = res.consensus()
    for a, b in zip(jax.tree.leaves(avg), jax.tree.leaves(params),
                    strict=True):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-6)


def test_resume_after_kill_bit_identical():
    """Kill after 4 of 6 steps, resume from the snapshot: the stitched
    run is bit-identical to the uninterrupted one and the history is
    the full 6 entries."""
    sess, _, _ = _session(periods=(2,))
    full = sess.run(steps=6, key=jax.random.PRNGKey(3))
    with tempfile.TemporaryDirectory() as d:
        pol = CheckpointPolicy(directory=d, every=1)
        sess.run(steps=4, key=jax.random.PRNGKey(3), checkpoint=pol)
        res = sess.resume(pol, steps=2)
    assert _trees_equal(full.state.params, res.state.params)
    assert _trees_equal(full.state.opt_state, res.state.opt_state)
    assert len(res.history) == 6
    assert [e["step"] for e in res.history] == list(range(1, 7))


def test_sweep_one_executor_per_grid():
    """A (lr x seed) LM grid compiles ONE batched executor (lr is a
    runtime operand, seeds stack on the batch axis) and returns stacked
    losses with a working best()."""
    sess, _, _ = _session(periods=(2,))
    s0 = sess.cache_stats()
    rs = sess.sweep(Sweep(lrs=[0.01, 0.05], seeds=[0, 1]), steps=4)
    s1 = sess.cache_stats()
    assert s1["misses"] - s0["misses"] == 1
    assert rs.losses.shape == (4, 4)
    assert np.isfinite(rs.losses).all()
    i = rs.best()
    assert 0 <= i < 4 and rs.points[i].lr in (0.01, 0.05)
    # repeat grid: fully cache-hit
    s2 = sess.cache_stats()
    sess.sweep(Sweep(lrs=[0.01, 0.05], seeds=[0, 1]), steps=2)
    s3 = sess.cache_stats()
    assert s3["misses"] == s2["misses"]


def test_straggler_adaptive_history():
    """A straggler policy on the LM session produces eq.-(12)-replanned
    histories: per-round wall clocks, participant counts and the local-H
    actually used, without retracing."""
    sess, _, _ = _session(periods=(2,), level_delays=[0.5], t_lp=1e-3)
    pol_mod = pytest.importorskip("repro.runtime.straggler")
    pol = pol_mod.StragglerPolicy(seed=0, adaptive=pol_mod.AdaptiveSchedule())
    out = sess.run(rounds=4, key=jax.random.PRNGKey(0), straggler=pol)
    last = out.history[-1]
    for k in ("time", "time_sync", "participants", "h"):
        assert k in last, sorted(last)
    assert np.isfinite(out.final_loss)


def test_auto_schedule_plans_lm_periods():
    """Schedule(rounds='auto', compression='auto') drives the SAME
    eq.-(12) planner for the LM workload: a delay model yields concrete
    periods and an outer codec, and the planned program runs."""
    mesh = make_host_mesh()
    opt = make_sgd(lr=0.05, momentum=0.0)
    prob = Problem.lm(CFG, opt, batch=8, seq=16, seed=0)
    topo = Topology.from_mesh(mesh, sync_axes=("data",), periods=(2,),
                              level_delays=[0.5], t_lp=1e-3)
    sch = Schedule(rounds="auto", compression="auto",
                   delay=DelayModel(C=1.0, delta=0.05, t_total=2.0))
    sess = Session.compile(prob, topo, sch, backend="mesh", mesh=mesh)
    assert all(p >= 1 for p in sess.periods)
    out = sess.run(steps=2)
    assert np.isfinite(out.final_loss)


@pytest.mark.parametrize("kw,msg", [
    (dict(sync_axes=("data",), periods=(0,)), "positive"),
    (dict(sync_axes=("data",), periods=(-2,)), "positive"),
    (dict(sync_axes=("data", "data"), periods=(2, 2)), "duplicate"),
    (dict(sync_axes=("data",), periods=(2, 2)), "periods"),
    (dict(sync_axes=("data",), periods=(2,), compression="zstd"),
     "compression"),
])
def test_treesync_config_validation(kw, msg):
    with pytest.raises(ValueError, match=msg):
        tsy.TreeSyncConfig(**kw)
